"""Out-of-core dataset store: columnar, row-sharded, memmap-able on disk.

Layout of a store directory::

    manifest.json       fingerprint + shard table + class histogram
                        (atomic fsync'd tmp-rename — the GridManifest
                        discipline from repro.train.checkpoint)
    stats_<k>.npz       per-class min/max scalers + the mergeable quantile
                        sketch state after the first k shards (the manifest
                        names the one consistent with its shard table)
    shard_00000.x.npy   feature rows [rows, p] fp32 — np.load(mmap_mode=..)
    shard_00000.y.npy   labels [rows] int64 (only for labelled sources)

:func:`ingest` builds a store from any row-batch iterator in **one pass**:
each committed shard atomically advances the manifest together with the
running statistics (class histogram, per-class min/max scalers, and a
:class:`~repro.data.sketch.QuantileSketch` per feature), so the scalers and
quantile edges every fit needs are precomputed at write time and no reader
ever has to stream (let alone sort) the full dataset again.

Crash-resume: the manifest is only rewritten after a shard's files are
durably on disk, so any crash leaves a prefix of committed shards plus, at
worst, orphaned files the next attempt overwrites. ``ingest(...,
resume=True)`` replays the (deterministic) iterator, skips exactly the
committed rows — finished shard files are never re-read or re-written —
and refuses a manifest whose fingerprint does not match the new call.

Freshness: a sealed store is appendable. :meth:`DatasetStore.append` adds
shards from a new batch iterator under the same commit discipline, merges
the new rows into the class stats and quantile sketch
(:meth:`QuantileSketch.merge`), and bumps a monotonic manifest ``version``
on completion — the data half of the incremental refresh loop (append →
``extend_artifacts`` warm-start fit → live swap).

Memory model: ingest holds O(batch + shard) rows; a :class:`DatasetStore`
reader holds O(1) metadata plus whatever rows a caller asks for —
``store[rows]`` gathers only from the shards those rows live in, which is
what lets ``repro.forest.distributed.build_row_shards`` stage per-device
slices straight from disk.
"""
from __future__ import annotations

import json
import os
from typing import Iterable, Optional, Tuple

import numpy as np

from repro.data.sketch import QuantileSketch
from repro.obs import default_registry, default_tracer
from repro.train.checkpoint import _fsync_replace, describe_fingerprint_mismatch

FORMAT_VERSION = 1
MANIFEST = "manifest.json"


def _shard_base(i: int) -> str:
    return f"shard_{i:05d}"


def _write_npy_atomic(directory: str, name: str, arr: np.ndarray) -> str:
    final = os.path.join(directory, name)
    tmp = os.path.join(directory, f".tmp_{name}")
    with open(tmp, "wb") as f:
        np.lib.format.write_array(f, np.ascontiguousarray(arr),
                                  allow_pickle=False)
    _fsync_replace(tmp, final)
    return final


def _write_npz_atomic(directory: str, name: str, arrays: dict) -> str:
    final = os.path.join(directory, name)
    tmp = os.path.join(directory, f".tmp_{name}")
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    _fsync_replace(tmp, final)
    return final


def _write_manifest(directory: str, payload: dict) -> None:
    tmp = os.path.join(directory, MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    _fsync_replace(tmp, os.path.join(directory, MANIFEST))


def _read_manifest(directory: str) -> Optional[dict]:
    path = os.path.join(directory, MANIFEST)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


class _ClassStats:
    """Streaming class histogram + per-class min/max scalers. Matches
    :func:`repro.tabgen.fitting.class_stats_streaming` exactly (min/max and
    counts are associative, so chunking never changes the result)."""

    def __init__(self, p: int):
        self.p = p
        self.classes = np.empty((0,), np.int64)
        self.counts = np.empty((0,), np.int64)
        self.mins = np.empty((0, p), np.float32)
        self.maxs = np.empty((0, p), np.float32)

    def update(self, X: np.ndarray, y: np.ndarray) -> None:
        y = np.asarray(y, np.int64)
        new = np.setdiff1d(np.unique(y), self.classes)
        if len(new):
            merged = np.union1d(self.classes, new)
            remap = np.searchsorted(merged, self.classes)
            counts = np.zeros(len(merged), np.int64)
            mins = np.full((len(merged), self.p), np.inf, np.float32)
            maxs = np.full((len(merged), self.p), -np.inf, np.float32)
            counts[remap] = self.counts
            mins[remap] = self.mins
            maxs[remap] = self.maxs
            self.classes, self.counts, self.mins, self.maxs = (
                merged, counts, mins, maxs)
        cid = np.searchsorted(self.classes, y)
        xb = np.asarray(X, np.float32)
        for i in np.unique(cid):
            sel = xb[cid == i]
            self.counts[i] += len(sel)
            self.mins[i] = np.minimum(self.mins[i], sel.min(axis=0))
            self.maxs[i] = np.maximum(self.maxs[i], sel.max(axis=0))

    def state_dict(self) -> dict:
        return {"classes": self.classes, "counts": self.counts,
                "mins": self.mins, "maxs": self.maxs}

    @classmethod
    def from_state(cls, state, p: int) -> "_ClassStats":
        st = cls(p)
        st.classes = np.asarray(state["classes"], np.int64)
        st.counts = np.asarray(state["counts"], np.int64)
        st.mins = np.asarray(state["mins"], np.float32)
        st.maxs = np.asarray(state["maxs"], np.float32)
        return st


class DatasetStore:
    """Reader for an ingested store — array-like enough for the trainers.

    Exposes ``shape`` / ``dtype`` / ``len()`` / row indexing (slices and
    fancy integer arrays, always returning materialised fp32 row copies),
    so :func:`repro.forest.distributed.build_row_shards` treats it exactly
    like the host ndarray it replaces while touching only the shards a row
    slice actually lives in (memmap reads, no full-dataset residency).
    """

    def __init__(self, directory: str):
        man = _read_manifest(directory)
        if man is None:
            raise FileNotFoundError(f"no {MANIFEST} in {directory} — not a "
                                    "dataset store (run repro.launch.ingest)")
        if man.get("format_version", 0) > FORMAT_VERSION:
            raise ValueError(f"store at {directory} uses a newer format "
                             f"({man['format_version']} > {FORMAT_VERSION})")
        if not man.get("complete"):
            raise ValueError(
                f"store at {directory} is an unfinished ingest "
                f"({man.get('n_rows', 0)} rows committed); finish it with "
                "ingest(batches, directory, resume=True)")
        self.directory = directory
        self.manifest = man
        self.fingerprint = man["fingerprint"]
        self._shard_rows = np.asarray([s["rows"] for s in man["shards"]],
                                      np.int64)
        self._starts = np.concatenate(
            [np.zeros(1, np.int64), np.cumsum(self._shard_rows)])
        self.n_rows = int(self._starts[-1])
        self.p = int(self.fingerprint["p"])
        self.has_labels = self.fingerprint.get("label_dtype") is not None
        self._stats_cache: Optional[dict] = None
        self._labels_cache: Optional[np.ndarray] = None

    # -- array-like surface -------------------------------------------------

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n_rows, self.p)

    @property
    def dtype(self):
        return np.dtype(np.float32)

    @property
    def ndim(self) -> int:
        return 2

    def __len__(self) -> int:
        return self.n_rows

    @property
    def n_shards(self) -> int:
        return len(self._shard_rows)

    @property
    def version(self) -> int:
        """Monotonic store version: 1 after the initial ingest, +1 per
        completed :meth:`append` — what model lineage records so a serving
        host can tell which data vintage a model was fit on."""
        return int(self.manifest.get("version", 1))

    @property
    def nbytes(self) -> int:
        """On-disk feature bytes (what in-memory residency would cost)."""
        return self.n_rows * self.p * 4

    # -- shard access -------------------------------------------------------

    def _path(self, i: int, kind: str) -> str:
        return os.path.join(self.directory, f"{_shard_base(i)}.{kind}.npy")

    def shard_x(self, i: int, mmap: bool = True) -> np.ndarray:
        """Feature rows of shard ``i`` (a read-only memmap by default)."""
        return np.load(self._path(i, "x"), mmap_mode="r" if mmap else None)

    def shard_y(self, i: int) -> Optional[np.ndarray]:
        if not self.has_labels:
            return None
        return np.load(self._path(i, "y"))

    def labels(self) -> np.ndarray:
        """All labels ``[n]`` int64 (zeros when unlabelled) — O(n) host
        metadata, 8 bytes/row; the fp32 features stay on disk."""
        if self._labels_cache is None:
            if not self.has_labels:
                self._labels_cache = np.zeros((self.n_rows,), np.int64)
            else:
                self._labels_cache = np.concatenate(
                    [self.shard_y(i) for i in range(self.n_shards)])
        return self._labels_cache

    def take(self, rows) -> np.ndarray:
        """Gather arbitrary global rows ``[k, p]`` fp32, reading only the
        shards those rows live in (grouped per shard, order preserved)."""
        rows = np.asarray(rows, np.int64)
        out = np.empty((len(rows), self.p), np.float32)
        shard_of = np.searchsorted(self._starts, rows, side="right") - 1
        for s in np.unique(shard_of):
            sel = shard_of == s
            # plain (non-mmap) shard read: one shard-sized buffer at a time
            # that is freed on return, so peak RSS stays O(gather + shard) —
            # memmap page faults would pin every touched page in ru_maxrss
            arr = self.shard_x(int(s), mmap=False)
            out[sel] = arr[rows[sel] - self._starts[s]]
            del arr
        return out

    def __getitem__(self, key) -> np.ndarray:
        if isinstance(key, (int, np.integer)):
            return self.take([int(key)])[0]
        if isinstance(key, slice):
            start, stop, step = key.indices(self.n_rows)
            return self.take(np.arange(start, stop, step, dtype=np.int64))
        return self.take(key)

    def iter_batches(self, batch_rows: int = 65536
                     ) -> Iterable[Tuple[np.ndarray, Optional[np.ndarray]]]:
        """Stream ``(X, y)`` row batches shard by shard (y ``None`` when
        unlabelled) — the round-trip twin of the ingest input."""
        for i in range(self.n_shards):
            xs = self.shard_x(i)
            ys = self.shard_y(i)
            for s in range(0, xs.shape[0], batch_rows):
                xb = np.asarray(xs[s:s + batch_rows], np.float32)
                yield xb, None if ys is None else ys[s:s + batch_rows]

    # -- precomputed statistics --------------------------------------------

    def _stats(self) -> dict:
        if self._stats_cache is None:
            path = os.path.join(self.directory, self.manifest["stats"])
            with np.load(path) as data:
                self._stats_cache = {k: data[k] for k in data.files}
        return self._stats_cache

    def class_stats(self):
        """``(classes, counts, mins, maxs)`` — equal to what
        :func:`repro.tabgen.fitting.class_stats_streaming` would compute
        over the materialised rows, but read from the manifest instead of
        re-streamed (the fit-time stats pass disappears)."""
        st = self._stats()
        return (np.asarray(st["classes"], np.int64),
                np.asarray(st["counts"], np.int64),
                np.asarray(st["mins"], np.float32),
                np.asarray(st["maxs"], np.float32))

    @property
    def sketch(self) -> QuantileSketch:
        """The dataset-level per-feature quantile sketch built at ingest."""
        return QuantileSketch.from_state(self._stats())

    def edges(self, n_bins: int, mode: str = "floor") -> np.ndarray:
        """Precomputed global bin edges ``[p, n_bins - 1]`` from the ingest
        sketch — the out-of-core replacement for sorting full columns (see
        :func:`repro.forest.binning.fit_bins_streaming`)."""
        return self.sketch.edges(n_bins, mode=mode)

    # -- incremental append -------------------------------------------------

    def append(self, batches, *, source=None, resume: bool = False,
               metrics=None, tracer=None) -> "DatasetStore":
        """Add shards from a new batch iterator to this sealed store.

        The freshness-loop writer: new rows commit as additional shards
        under the same fsync/tmp-rename discipline as :func:`ingest`, each
        shard's rows folded into the running class stats and merged into
        the dataset-level quantile sketch (a per-shard
        :class:`~repro.data.sketch.QuantileSketch` absorbed via
        :meth:`~repro.data.sketch.QuantileSketch.merge` — the same path a
        parallel ingest combines writers with). The store stays a valid,
        readable, *complete* store throughout: concurrent readers opened
        before or during an append see a consistent committed prefix.

        Versioning: a durable ``append`` marker (recording the base row
        count and this call's ``source``) lands in the manifest before the
        first new row is consumed; the final commit drops the marker and
        bumps the manifest ``version`` (1 after ingest, +1 per completed
        append). A crash mid-append leaves the marker plus a prefix of
        committed shards — ``append(batches, resume=True)`` replays the
        deterministic iterator, skips exactly the committed new rows, and
        finishes the version bump. Resuming when no append is in flight is
        a no-op returning a fresh reader (the retry-after-success case).

        Returns a **new** :class:`DatasetStore` reader over the grown
        store; ``self`` keeps serving the pre-append row count.
        """
        _m = metrics or default_registry()
        _t = tracer or default_tracer()
        c_rows = _m.counter("ingest_rows", "Rows committed to dataset stores")
        c_shards = _m.counter("ingest_shards",
                              "Shards durably committed (manifest advanced)")
        c_batches = _m.counter("ingest_batches",
                               "Source batches consumed (after resume skip)")
        h_commit = _m.histogram(
            "ingest_shard_commit_seconds",
            "Per-shard commit time: shard files + stats + manifest "
            "(ingest.shard span durations)")

        directory = self.directory
        man = _read_manifest(directory)
        marker = man.get("append")
        if marker is not None and not resume:
            raise ValueError(
                f"store at {directory} has an unfinished append "
                f"({man['n_rows'] - marker['base_rows']} of its rows "
                "committed); finish it with append(batches, resume=True) "
                "or re-ingest into a fresh directory")
        if marker is None and resume:
            return DatasetStore(directory)   # append already completed
        if marker is not None and marker.get("source") != source:
            raise ValueError(
                f"append at {directory} was started with source="
                f"{marker.get('source')!r} but this resume passes "
                f"{source!r}; resuming would mix two streams")

        fingerprint = man["fingerprint"]
        p = int(fingerprint["p"])
        has_labels = fingerprint.get("label_dtype") is not None
        shard_rows = int(fingerprint["shard_rows"])
        sketch_entries = int(fingerprint["sketch_entries"])
        if marker is None:
            marker = {"source": source, "base_rows": int(man["n_rows"]),
                      "base_version": int(man.get("version", 1))}

        stats_path = os.path.join(directory, man["stats"])
        with np.load(stats_path) as data:
            state = {k: data[k] for k in data.files}
        sketch = QuantileSketch.from_state(state)
        cstats = _ClassStats.from_state(state, p)
        shards = list(man["shards"])
        skip = int(man["n_rows"]) - int(marker["base_rows"])

        def _commit_inner(xs, ys, final):
            i = len(shards)
            if len(xs):
                _write_npy_atomic(directory, f"{_shard_base(i)}.x.npy", xs)
                if ys is not None:
                    _write_npy_atomic(directory, f"{_shard_base(i)}.y.npy",
                                      ys)
                batch_sk = QuantileSketch(p, sketch_entries)
                batch_sk.update(xs)
                sketch.merge(batch_sk)
                cstats.update(xs, ys if ys is not None
                              else np.zeros(len(xs), np.int64))
                shards.append({"rows": int(len(xs))})
            stats_name = _stats_name(len(shards))
            _write_npz_atomic(directory, stats_name,
                              dict(sketch.state_dict(),
                                   **cstats.state_dict()))
            payload = {
                "format_version": FORMAT_VERSION,
                "fingerprint": fingerprint,
                "complete": True,
                "version": (marker["base_version"] + 1 if final
                            else marker["base_version"]),
                "n_rows": int(sum(s["rows"] for s in shards)),
                "n_classes": int(len(cstats.classes)),
                "class_histogram": {str(c): int(n) for c, n in
                                    zip(cstats.classes, cstats.counts)},
                "shards": shards,
                "stats": stats_name,
            }
            if not final:
                payload["append"] = marker
            _write_manifest(directory, payload)
            if len(xs):   # drop the superseded stats snapshot (best-effort)
                prev = os.path.join(directory, _stats_name(len(shards) - 1))
                if os.path.exists(prev) and prev != stats_path:
                    os.unlink(prev)

        def _commit(xs, ys, final):
            with _t.span("ingest.shard", shard=len(shards),
                         rows=int(len(xs)), complete=final) as sp:
                _commit_inner(xs, ys, final)
            h_commit.observe(sp.duration_s)
            if len(xs):
                c_rows.inc(int(len(xs)))
                c_shards.inc(1)

        with _t.span("store.append", base_rows=marker["base_rows"],
                     base_version=marker["base_version"], resume=resume):
            if not resume:
                # durable in-flight marker *before* any new row lands: every
                # crash state is either resumable or trivially retryable
                _write_manifest(directory, dict(man, append=marker))
            buf_x, buf_y, buffered = [], [], 0
            for b in batches:
                xb, yb = _norm_batch(b, p, has_labels)
                if skip:
                    take = min(skip, len(xb))
                    skip -= take
                    xb = xb[take:]
                    yb = None if yb is None else yb[take:]
                    if not len(xb):
                        continue
                c_batches.inc(1)
                buf_x.append(xb)
                if yb is not None:
                    buf_y.append(yb)
                buffered += len(xb)
                while buffered >= shard_rows:
                    xs = np.concatenate(buf_x) if len(buf_x) > 1 else buf_x[0]
                    ys = ((np.concatenate(buf_y) if len(buf_y) > 1
                           else buf_y[0]) if has_labels else None)
                    _commit(xs[:shard_rows],
                            None if ys is None else ys[:shard_rows],
                            final=False)
                    buf_x = [xs[shard_rows:]] if len(xs) > shard_rows else []
                    buf_y = (([ys[shard_rows:]] if len(ys) > shard_rows
                              else []) if has_labels else [])
                    buffered -= shard_rows
            if skip:
                raise ValueError(
                    f"append resume expected at least {skip} more rows from "
                    "the iterator than it produced — the stream is not the "
                    "one this append started with")
            xs = (np.concatenate(buf_x) if len(buf_x) > 1
                  else (buf_x[0] if buf_x else np.empty((0, p), np.float32)))
            ys = None
            if has_labels:
                ys = (np.concatenate(buf_y) if len(buf_y) > 1
                      else (buf_y[0] if buf_y else np.empty((0,), np.int64)))
            _commit(xs, ys, final=True)
        return DatasetStore(directory)


# ---------------------------------------------------------------------------
# ingest writer
# ---------------------------------------------------------------------------

def _norm_batch(b, p: Optional[int], has_labels: Optional[bool]):
    """Normalise one iterator item to ``(X fp32 [k, p], y int64 [k]|None)``
    and validate it against the stream's established shape/labelledness."""
    if isinstance(b, tuple):
        X, y = b
    else:
        X, y = b, None
    X = np.asarray(X)
    if X.ndim != 2:
        raise ValueError(f"batch must be [rows, p], got shape {X.shape}")
    if p is not None and X.shape[1] != p:
        raise ValueError(f"batch has p={X.shape[1]}, stream started with "
                         f"p={p}")
    if has_labels is not None and (y is not None) != has_labels:
        raise ValueError("stream mixes labelled and unlabelled batches")
    return (X.astype(np.float32, copy=False),
            None if y is None else np.asarray(y, np.int64))


def _stats_name(n_shards: int) -> str:
    return f"stats_{n_shards:05d}.npz"


def ingest(batches, directory: str, *, shard_rows: int = 65536,
           resume: bool = False, source=None,
           sketch_entries: int = 2048,
           metrics=None, tracer=None) -> DatasetStore:
    """Write a :class:`DatasetStore` from a row-batch iterator in one pass.

    ``batches`` yields ``X [k, p]`` arrays or ``(X, y)`` tuples (any ``k``;
    rows are re-chunked into ``shard_rows``-row shards). Per committed
    shard, the running class stats and quantile sketch advance and are
    durably written *before* the manifest that references them, so the
    manifest is always consistent with some prefix of the stream.

    ``resume=True`` continues a crashed ingest: the (deterministic)
    iterator is replayed, rows already committed are skipped without
    touching their shard files, and a fingerprint mismatch (different
    ``shard_rows`` / ``sketch_entries`` / ``source`` / schema) refuses
    loudly rather than mixing two streams. Resuming a complete store is a
    no-op returning the reader.

    ``source`` is an arbitrary JSON-serialisable description fingerprinted
    into the manifest (e.g. the CLI's generator spec) so a resume can only
    ever continue the stream it started with.

    Each shard commit runs under an ``ingest.shard`` span and advances
    ``ingest_rows`` / ``ingest_shards`` / ``ingest_batches`` counters plus
    an ``ingest_shard_commit_seconds`` histogram on ``metrics`` /
    ``tracer`` (default: the process-wide :func:`repro.obs.default_registry`
    / :func:`repro.obs.default_tracer`, which ``repro.launch.ingest
    --metrics-dump`` renders at exit).
    """
    _m = metrics or default_registry()
    _t = tracer or default_tracer()
    c_rows = _m.counter("ingest_rows", "Rows committed to dataset stores")
    c_shards = _m.counter("ingest_shards",
                          "Shards durably committed (manifest advanced)")
    c_batches = _m.counter("ingest_batches",
                           "Source batches consumed (after resume skip)")
    h_commit = _m.histogram(
        "ingest_shard_commit_seconds",
        "Per-shard commit time: shard files + stats + manifest "
        "(ingest.shard span durations)")

    os.makedirs(directory, exist_ok=True)
    existing = _read_manifest(directory)
    if existing is not None and not resume:
        raise ValueError(
            f"{directory} already holds a "
            f"{'complete store' if existing.get('complete') else 'partial ingest'}"
            " — pass resume=True to continue it, or use a fresh directory")

    it = iter(batches)
    try:
        first = _norm_batch(next(it), None, None)
    except StopIteration:
        raise ValueError("ingest got an empty batch iterator") from None
    p = first[0].shape[1]
    has_labels = first[1] is not None
    fingerprint = {
        "p": int(p),
        "dtype": "float32",
        "label_dtype": "int64" if has_labels else None,
        "shard_rows": int(shard_rows),
        "sketch_entries": int(sketch_entries),
        "source": source,
    }

    if existing is not None:
        stale = existing.get("fingerprint")
        if stale != fingerprint:
            raise ValueError(
                f"ingest at {directory} was started under a mismatched "
                "configuration; resuming would mix two streams. Use a "
                "fresh directory to re-ingest.\n"
                + describe_fingerprint_mismatch(stale, fingerprint,
                                                stale_name="store",
                                                new_name="requested"))
        if existing.get("complete"):
            return DatasetStore(directory)
        shards = list(existing["shards"])
        stats_path = os.path.join(directory, existing["stats"])
        with np.load(stats_path) as data:
            state = {k: data[k] for k in data.files}
        sketch = QuantileSketch.from_state(state)
        cstats = _ClassStats.from_state(state, p)
    else:
        shards = []
        sketch = QuantileSketch(p, sketch_entries)
        cstats = _ClassStats(p)

    skip = int(sum(s["rows"] for s in shards))

    def _commit(xs: np.ndarray, ys: Optional[np.ndarray], complete: bool):
        """One atomic step: shard files -> stats -> manifest."""
        with _t.span("ingest.shard", shard=len(shards), rows=int(len(xs)),
                     complete=complete) as sp:
            _commit_inner(xs, ys, complete)
        h_commit.observe(sp.duration_s)
        if len(xs):
            c_rows.inc(int(len(xs)))
            c_shards.inc(1)

    def _commit_inner(xs, ys, complete):
        i = len(shards)
        if len(xs):
            _write_npy_atomic(directory, f"{_shard_base(i)}.x.npy", xs)
            if ys is not None:
                _write_npy_atomic(directory, f"{_shard_base(i)}.y.npy", ys)
            sketch.update(xs)
            cstats.update(xs, ys if ys is not None
                          else np.zeros(len(xs), np.int64))
            shards.append({"rows": int(len(xs))})
        stats_name = _stats_name(len(shards))
        state = dict(sketch.state_dict(), **cstats.state_dict())
        _write_npz_atomic(directory, stats_name, state)
        n_rows = int(sum(s["rows"] for s in shards))
        _write_manifest(directory, {
            "format_version": FORMAT_VERSION,
            "fingerprint": fingerprint,
            "complete": complete,
            "n_rows": n_rows,
            "n_classes": int(len(cstats.classes)),
            "class_histogram": {str(c): int(n) for c, n in
                                zip(cstats.classes, cstats.counts)},
            "shards": shards,
            "stats": stats_name,
        })
        if len(xs):   # drop the superseded stats snapshot (best-effort)
            prev = os.path.join(directory, _stats_name(len(shards) - 1))
            if os.path.exists(prev):
                os.unlink(prev)

    def stream():
        yield first
        for b in it:
            yield _norm_batch(b, p, has_labels)

    buf_x, buf_y, buffered = [], [], 0
    for xb, yb in stream():
        if skip:
            take = min(skip, len(xb))
            skip -= take
            xb = xb[take:]
            yb = None if yb is None else yb[take:]
            if not len(xb):
                continue
        c_batches.inc(1)
        buf_x.append(xb)
        if yb is not None:
            buf_y.append(yb)
        buffered += len(xb)
        while buffered >= shard_rows:
            xs = np.concatenate(buf_x) if len(buf_x) > 1 else buf_x[0]
            ys = (np.concatenate(buf_y) if len(buf_y) > 1 else buf_y[0]) \
                if has_labels else None
            _commit(xs[:shard_rows],
                    None if ys is None else ys[:shard_rows], complete=False)
            buf_x = [xs[shard_rows:]] if len(xs) > shard_rows else []
            buf_y = ([ys[shard_rows:]] if len(ys) > shard_rows else []) \
                if has_labels else []
            buffered -= shard_rows
    if skip:
        raise ValueError(
            f"resume expected at least {skip} more rows from the iterator "
            "than it produced — the stream is not the one this ingest "
            "started with")
    # final (possibly partial) shard + the completing manifest write
    xs = (np.concatenate(buf_x) if len(buf_x) > 1
          else (buf_x[0] if buf_x else np.empty((0, p), np.float32)))
    ys = None
    if has_labels:
        ys = (np.concatenate(buf_y) if len(buf_y) > 1
              else (buf_y[0] if buf_y else np.empty((0,), np.int64)))
    _commit(xs, ys, complete=True)
    return DatasetStore(directory)
