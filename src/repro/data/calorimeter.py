"""Synthetic calorimeter showers with the CaloChallenge schema (paper §2.4).

The real Photons/Pions files are not redistributable here, so this generator
produces voxelised showers with the same structure: cylindrical voxel grid
(layers x radial x angular), 15 log-spaced incident-energy classes, radial
exponential decay, layer-wise longitudinal profile, multiplicative noise, and
heavy sparsity — enough for every pipeline and metric to run at the paper's
scale (n ~ 121k, p = 368 / 533).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

# (layers, radial, angular) grids chosen so p matches the Challenge datasets
GEOMETRY = {
    "photons": (5, 8, 9),   # 360 voxels + 8 pad features -> p = 368
    "pions": (7, 8, 9),     # 504 voxels + 29 extra cells  -> p = 533
    # reduced grids with the same structure for the CPU-quick benchmark path
    "photons_mini": (3, 4, 5),   # 60 voxels -> p = 64
    "pions_mini": (4, 4, 5),     # 80 voxels -> p = 96
}
P_TARGET = {"photons": 368, "pions": 533, "photons_mini": 64,
            "pions_mini": 96}
N_CLASSES = 15


def generate(dataset: str, n: int, seed: int = 0
             ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (X [n, p] fp32 energies, y [n] int64 energy-class labels)."""
    layers, nr, na = GEOMETRY[dataset]
    p = P_TARGET[dataset]
    rng = np.random.default_rng(seed)
    y = rng.integers(0, N_CLASSES, size=n)
    e_inc = 2.0 ** (y + 8)                     # log-spaced incident energies
    # longitudinal profile: gamma-like over layers, class-dependent peak
    depth = np.arange(layers)[None, :]
    peak = 1.0 + 0.15 * y[:, None] + 0.3 * rng.normal(size=(n, 1))
    long_prof = np.exp(-0.5 * ((depth - peak) / 1.2) ** 2)
    long_prof /= long_prof.sum(1, keepdims=True)
    # radial profile: exponential decay, slight class dependence
    r = np.arange(nr)[None, :]
    rad_scale = 1.0 + 0.05 * y[:, None]
    rad_prof = np.exp(-r / rad_scale)
    rad_prof /= rad_prof.sum(1, keepdims=True)
    # angular: nearly uniform with a random phase modulation per shower
    phase = rng.uniform(0, 2 * np.pi, size=(n, 1))
    ang = (1.0 + 0.3 * np.cos(np.linspace(0, 2 * np.pi, na)[None, :] + phase))
    ang /= ang.sum(1, keepdims=True)

    vox = (e_inc[:, None, None, None]
           * long_prof[:, :, None, None]
           * rad_prof[:, None, :, None]
           * ang[:, None, None, :])
    noise = rng.lognormal(0.0, 0.35, size=vox.shape)
    vox = vox * noise
    # sparsity: read-out threshold kills small deposits
    vox[vox < 0.01 * e_inc[:, None, None, None] / vox.shape[1]] = 0.0
    X = vox.reshape(n, -1).astype(np.float32)
    if X.shape[1] < p:
        pad = np.zeros((n, p - X.shape[1]), np.float32)
        # pad features carry summary stats so they are informative, not dead
        pad[:, 0] = X.sum(1)
        if pad.shape[1] > 1:
            pad[:, 1] = (X > 0).sum(1)
        X = np.concatenate([X, pad], axis=1)
    return X[:, :p], y.astype(np.int64)


def generate_batches(dataset: str, n: int, *, batch_rows: int = 8192,
                     seed: int = 0):
    """Chunked twin of :func:`generate` for :func:`repro.data.store.ingest`:
    yields ``(X, y)`` shower batches totalling ``n`` rows, batch ``b`` from
    its own stream ``[seed, b]`` (deterministic, replayable, never holds
    more than ``batch_rows`` showers in memory)."""
    for b, s in enumerate(range(0, n, batch_rows)):
        rows = min(batch_rows, n - s)
        batch_seed = np.random.SeedSequence([seed, b]).generate_state(1)[0]
        yield generate(dataset, rows, seed=int(batch_seed))


# ---------------------------------------------------------------------------
# Challenge metrics (App. A.1)
# ---------------------------------------------------------------------------

def high_level_features(X: np.ndarray, dataset: str) -> dict:
    """Expert features: E_dep/E_layer, center of energy + width per layer."""
    layers, nr, na = GEOMETRY[dataset]
    vox = X[:, :layers * nr * na].reshape(-1, layers, nr, na)
    e_layer = vox.sum((2, 3))                          # [n, layers]
    e_tot = e_layer.sum(1) + 1e-12
    feats = {"e_dep": e_tot}
    eta = np.arange(nr)[None, None, :, None]
    phi = np.arange(na)[None, None, None, :]
    w = vox / (vox.sum((2, 3), keepdims=True) + 1e-12)
    ce_eta = (w * eta).sum((2, 3))                     # [n, layers]
    ce_phi = (w * phi).sum((2, 3))
    wd_eta = np.sqrt(np.clip((w * eta ** 2).sum((2, 3)) - ce_eta ** 2, 0, None))
    wd_phi = np.sqrt(np.clip((w * phi ** 2).sum((2, 3)) - ce_phi ** 2, 0, None))
    for l in range(layers):
        feats[f"e_dep_l{l}"] = e_layer[:, l]
        feats[f"ce_eta_l{l}"] = ce_eta[:, l]
        feats[f"ce_phi_l{l}"] = ce_phi[:, l]
        feats[f"width_eta_l{l}"] = wd_eta[:, l]
        feats[f"width_phi_l{l}"] = wd_phi[:, l]
    return feats


def chi2_separation(a: np.ndarray, b: np.ndarray, bins: int = 30) -> float:
    """Paper Eq. 7: chi^2 separation power between two histograms."""
    lo = min(a.min(), b.min())
    hi = max(a.max(), b.max())
    if hi <= lo:
        return 0.0
    ha, _ = np.histogram(a, bins=bins, range=(lo, hi))
    hb, _ = np.histogram(b, bins=bins, range=(lo, hi))
    fa = ha / max(ha.sum(), 1)
    fb = hb / max(hb.sum(), 1)
    denom = fa + fb
    mask = denom > 0
    return float(0.5 * np.sum((fa[mask] - fb[mask]) ** 2 / denom[mask]))
