"""Deterministic, stateless token pipeline.

``batch(i)`` is a pure function of (seed, i): any host can recompute any
microbatch after a failure or re-shard — there is no shuffle state to lose,
which is the straggler/elasticity story at 1000+ nodes (DESIGN.md §5).
The stream is a synthetic Zipf-ish mixture with local n-gram structure so
cross-entropy actually decreases during the example runs.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0):
        self.vocab, self.seq_len, self.batch = vocab, seq_len, batch
        self.seed = seed
        rng = np.random.default_rng(seed)
        # fixed bigram transition structure (low-rank) shared by all batches
        k = 16
        self._emit = rng.dirichlet(np.ones(vocab) * 0.05, size=k)
        self._trans = rng.dirichlet(np.ones(k), size=k)

    def batch_at(self, i: int):
        rng = np.random.default_rng((self.seed, i))
        b, s = self.batch, self.seq_len
        states = rng.integers(0, self._trans.shape[0], size=b)
        toks = np.empty((b, s + 1), np.int32)
        for t in range(s + 1):
            for j in range(b):
                toks[j, t] = rng.choice(self.vocab, p=self._emit[states[j]])
            states = np.array([rng.choice(len(self._trans), p=self._trans[st])
                               for st in states])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class FastTokenStream:
    """Vectorised variant for larger batches (unigram mixture, still
    stateless-deterministic)."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0):
        self.vocab, self.seq_len, self.batch = vocab, seq_len, batch
        self.seed = seed

    def batch_at(self, i: int):
        rng = np.random.default_rng((self.seed, i))
        b, s = self.batch, self.seq_len
        # Zipf marginal + deterministic "copy previous token" structure
        base = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
        toks = (base % self.vocab).astype(np.int32)
        copy = rng.random((b, s + 1)) < 0.3
        for t in range(1, s + 1):
            toks[:, t] = np.where(copy[:, t], toks[:, t - 1], toks[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
