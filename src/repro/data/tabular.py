"""Synthetic tabular datasets for resource-scaling benchmarks (paper §4.1,
App. D.1) plus small real-ish benchmark generators for quality metrics."""
from __future__ import annotations

import numpy as np


def synthetic_resource_dataset(n: int, p: int, n_y: int, seed: int = 0):
    """Paper D.1: X ~ N(0, I); labels uniform over [0, n_y). Random feature
    correlations make unregularised trees use their full capacity."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p)).astype(np.float32)
    y = rng.integers(0, n_y, size=n).astype(np.int64)
    return X, y


def two_moons(n: int, noise: float = 0.08, seed: int = 0):
    rng = np.random.default_rng(seed)
    n2 = n // 2
    t = np.pi * rng.random(n2)
    a = np.stack([np.cos(t), np.sin(t)], 1)
    b = np.stack([1 - np.cos(t), 0.5 - np.sin(t)], 1)
    X = np.concatenate([a, b]) + noise * rng.normal(size=(2 * n2, 2))
    y = np.concatenate([np.zeros(n2), np.ones(n2)]).astype(np.int64)
    perm = rng.permutation(len(X))
    return X[perm].astype(np.float32), y[perm]


def correlated_gaussian(n: int, p: int, seed: int = 0):
    """Full-rank correlated Gaussian — tests joint-structure learning (the
    paper's MO-trees motivation)."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(p, p)) / np.sqrt(p)
    cov = A @ A.T + 0.1 * np.eye(p)
    X = rng.multivariate_normal(np.zeros(p), cov, size=n)
    return X.astype(np.float32), cov
