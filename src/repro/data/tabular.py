"""Synthetic tabular datasets for resource-scaling benchmarks (paper §4.1,
App. D.1) plus small real-ish benchmark generators for quality metrics.

The ``*_batches`` variants stream the same families as bounded row batches
for :func:`repro.data.store.ingest` and the out-of-core benchmarks: batch
``b`` is drawn from its own PRNG stream seeded ``[seed, b]``, so any run
over the same ``(n, batch_rows, seed)`` yields bit-identical batches, a
larger-than-RAM dataset never exists in memory at once, and a crash-resumed
ingest can replay the stream from scratch at generator (not storage) cost.
They are deliberately *not* row-equal to their one-shot twins (those
interleave X and y draws on a single stream)."""
from __future__ import annotations

import numpy as np


def synthetic_resource_dataset(n: int, p: int, n_y: int, seed: int = 0):
    """Paper D.1: X ~ N(0, I); labels uniform over [0, n_y). Random feature
    correlations make unregularised trees use their full capacity."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p)).astype(np.float32)
    y = rng.integers(0, n_y, size=n).astype(np.int64)
    return X, y


def synthetic_resource_batches(n: int, p: int, n_y: int, *,
                               batch_rows: int = 65536, seed: int = 0):
    """Chunked twin of :func:`synthetic_resource_dataset`: yields
    ``(X [k, p] fp32, y [k] int64)`` batches totalling exactly ``n`` rows,
    deterministic in ``(n, p, n_y, batch_rows, seed)``."""
    for b, s in enumerate(range(0, n, batch_rows)):
        rows = min(batch_rows, n - s)
        rng = np.random.default_rng([seed, b])
        X = rng.normal(size=(rows, p)).astype(np.float32)
        y = rng.integers(0, n_y, size=rows).astype(np.int64)
        yield X, y


def two_moons(n: int, noise: float = 0.08, seed: int = 0):
    rng = np.random.default_rng(seed)
    n2 = n // 2
    t = np.pi * rng.random(n2)
    a = np.stack([np.cos(t), np.sin(t)], 1)
    b = np.stack([1 - np.cos(t), 0.5 - np.sin(t)], 1)
    X = np.concatenate([a, b]) + noise * rng.normal(size=(2 * n2, 2))
    y = np.concatenate([np.zeros(n2), np.ones(n2)]).astype(np.int64)
    perm = rng.permutation(len(X))
    return X[perm].astype(np.float32), y[perm]


def two_moons_batches(n: int, noise: float = 0.08, *,
                      batch_rows: int = 65536, seed: int = 0):
    """Chunked twin of :func:`two_moons` (each batch is an independently
    shuffled small two-moons draw; the union has the same distribution)."""
    for b, s in enumerate(range(0, n, batch_rows)):
        rows = min(batch_rows, n - s)
        batch_seed = np.random.SeedSequence([seed, b]).generate_state(1)[0]
        # two_moons returns 2*(n//2) rows: over-ask by one and slice so
        # odd batches (e.g. the tail) still total exactly n
        X, y = two_moons(rows + rows % 2, noise=noise, seed=int(batch_seed))
        yield X[:rows], y[:rows]


def correlated_gaussian(n: int, p: int, seed: int = 0):
    """Full-rank correlated Gaussian — tests joint-structure learning (the
    paper's MO-trees motivation)."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(p, p)) / np.sqrt(p)
    cov = A @ A.T + 0.1 * np.eye(p)
    X = rng.multivariate_normal(np.zeros(p), cov, size=n)
    return X.astype(np.float32), cov


def correlated_gaussian_batches(n: int, p: int, *, batch_rows: int = 65536,
                                seed: int = 0):
    """Chunked, label-free correlated Gaussian (one shared covariance drawn
    from ``seed``; rows per batch from stream ``[seed, b]``) — exercises
    the unlabelled ingest path with a non-trivial joint structure."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(p, p)) / np.sqrt(p)
    cov = A @ A.T + 0.1 * np.eye(p)
    for b, s in enumerate(range(0, n, batch_rows)):
        rows = min(batch_rows, n - s)
        brng = np.random.default_rng([seed, b])
        yield brng.multivariate_normal(np.zeros(p), cov,
                                       size=rows).astype(np.float32)
