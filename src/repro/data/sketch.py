"""Mergeable weighted quantile sketches — the host-side QuantileDMatrix half.

:class:`QuantileSketch` summarises per-feature value distributions from
streamed row batches: updates cost ``O(batch log batch)``, two sketches
merge (concat + compress), and the summary answers the two quantile queries
the forest code path uses —

* ``mode="floor"``  — :func:`repro.tabgen.fitting.weighted_edges` semantics:
  the value at rank ``floor(q * (W - 1))`` over the positive-weight rows
  (zero-weight rows are excluded entirely, matching the padded-row masking);
* ``mode="linear"`` — :func:`repro.forest.binning.fit_bins` /
  ``np.quantile`` semantics: linear interpolation between adjacent ranks.

Exactness contract: while a sketch holds at most ``max_entries`` distinct
points it is *exact* — both modes reproduce the reference functions
bit-for-bit (the rank arithmetic deliberately mirrors their float32
rounding). Past that it compresses to ``max_entries`` summary entries by
picking values at evenly spaced cumulative-weight positions (the XGBoost
approx-sketch merge-and-prune scheme), adding a rank error of at most
``total_weight / max_entries`` per compression.

Built for :mod:`repro.data.store`: the ingest writer keeps one sketch per
dataset, updates it shard by shard, persists its state next to the store
manifest, and consumers read ``edges()`` instead of sorting full columns.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class QuantileSketch:
    """Per-feature weighted quantile summary over ``p`` features.

    State is a pair of ``[p, m]`` arrays (values sorted per row, and their
    weights); every operation keeps ``m`` identical across features, so the
    whole sketch vectorises and serialises as two dense arrays.
    """

    #: rows absorbed per internal sort, bounding the [p, m + chunk] transient
    _ABSORB_CHUNK = 65536

    def __init__(self, p: int, max_entries: int = 2048):
        if p < 1 or max_entries < 8:
            raise ValueError(f"p={p}, max_entries={max_entries}: need p >= 1 "
                             "and max_entries >= 8")
        self.p = int(p)
        self.max_entries = int(max_entries)
        self.vals = np.empty((self.p, 0), np.float32)
        self.wts = np.empty((self.p, 0), np.float32)
        self.total_weight = 0.0
        self.n_points = 0

    # -- building -----------------------------------------------------------

    def update(self, X, w=None) -> "QuantileSketch":
        """Absorb a row batch ``X [n, p]`` with optional row weights ``w
        [n]``. Rows with ``w <= 0`` are dropped (the ``weighted_edges``
        convention for padded rows)."""
        X = np.asarray(X)
        if X.ndim != 2 or X.shape[1] != self.p:
            raise ValueError(f"batch shape {X.shape} != [n, {self.p}]")
        wr = (np.ones(X.shape[0], np.float32) if w is None
              else np.asarray(w, np.float32))
        keep = wr > 0
        if not keep.all():
            X, wr = X[keep], wr[keep]
        for s in range(0, X.shape[0], self._ABSORB_CHUNK):
            xb = X[s:s + self._ABSORB_CHUNK]
            wb = wr[s:s + self._ABSORB_CHUNK]
            self._absorb(np.ascontiguousarray(xb.T, dtype=np.float32),
                         np.broadcast_to(wb, (self.p, len(wb))))
        self.total_weight += float(wr.sum(dtype=np.float64))
        self.n_points += int(X.shape[0])
        return self

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Absorb another sketch's summary (same ``p``); mergeability is
        what lets parallel ingests (or per-shard sketches) combine into one
        dataset-level summary."""
        if other.p != self.p:
            raise ValueError(f"cannot merge p={other.p} into p={self.p}")
        if other.n_points:
            self._absorb(other.vals, other.wts)
            self.total_weight += other.total_weight
            self.n_points += other.n_points
        return self

    def _absorb(self, v, wt):
        """Merge ``[p, k]`` (values, weights) into the sorted summary."""
        vals = np.concatenate([self.vals, v], axis=1)
        wts = np.concatenate([self.wts, np.asarray(wt, np.float32)], axis=1)
        order = np.argsort(vals, axis=1, kind="stable")
        self.vals = np.take_along_axis(vals, order, axis=1)
        self.wts = np.take_along_axis(wts, order, axis=1)
        if self.vals.shape[1] > 2 * self.max_entries:
            self._compress()

    def _compress(self):
        """Prune to ``max_entries`` summary points at evenly spaced
        cumulative-weight positions. Keeps the per-feature min and max and
        preserves every feature's total weight exactly (new weights are
        diffs of the original cumulative weights at the picked entries)."""
        m = self.vals.shape[1]
        cap = self.max_entries
        cw = np.cumsum(self.wts, axis=1, dtype=np.float64)
        frac = np.linspace(0.0, 1.0, cap)
        new_vals = np.empty((self.p, cap), np.float32)
        new_cw = np.empty((self.p, cap), np.float64)
        for r in range(self.p):
            idx = np.minimum(np.searchsorted(cw[r], frac * cw[r, -1],
                                             side="left"), m - 1)
            idx[0] = 0
            new_vals[r] = self.vals[r, idx]
            new_cw[r] = cw[r, idx]
        self.vals = new_vals
        self.wts = np.diff(new_cw, prepend=0.0, axis=1).astype(np.float32)

    # -- queries ------------------------------------------------------------

    def quantiles(self, qs, mode: str = "floor") -> np.ndarray:
        """Quantile values at ``qs`` per feature: ``[p, len(qs)]`` fp32."""
        m = self.vals.shape[1]
        if m == 0:
            raise ValueError("empty sketch (no positive-weight rows seen)")
        if mode not in ("floor", "linear"):
            raise ValueError(f"mode={mode!r}: expected 'floor' or 'linear'")
        qs = np.asarray(qs, np.float32)
        cw = np.cumsum(self.wts, axis=1, dtype=np.float64)
        out = np.empty((self.p, len(qs)), np.float32)
        for r in range(self.p):
            w_tot = cw[r, -1]
            if mode == "floor":
                # rank arithmetic in float32, truncation toward zero —
                # mirrors weighted_edges' `(qs * (n_real - 1)).astype(int)`
                ranks = np.clip((qs * np.float32(w_tot - 1.0))
                                .astype(np.int64), 0, None)
                idx = np.minimum(np.searchsorted(cw[r], ranks + 1,
                                                 side="left"), m - 1)
                out[r] = self.vals[r, idx]
            else:
                # np.quantile/jnp.quantile 'linear': interpolate between the
                # order statistics straddling position q * (W - 1), in fp32
                pos = qs * np.float32(w_tot - 1.0)
                lo_rank = np.floor(pos)
                fr = (pos - lo_rank).astype(np.float32)
                lo = np.minimum(np.searchsorted(cw[r], lo_rank + 1.0,
                                                side="left"), m - 1)
                hi = np.minimum(np.searchsorted(cw[r], lo_rank + 2.0,
                                                side="left"), m - 1)
                out[r] = (self.vals[r, lo] * (1.0 - fr)
                          + self.vals[r, hi] * fr)
        return out

    def edges(self, n_bins: int, mode: str = "floor") -> np.ndarray:
        """Per-feature bin edges ``[p, n_bins - 1]`` — drop-in for
        :func:`~repro.tabgen.fitting.weighted_edges` (``mode="floor"``) or
        :func:`~repro.forest.binning.fit_bins` (``mode="linear"``)."""
        if mode == "floor":
            qs = np.arange(1, n_bins, dtype=np.float32) / np.float32(n_bins)
        else:
            qs = np.linspace(0.0, 1.0, n_bins + 1,
                             dtype=np.float32)[1:-1]
        return self.quantiles(qs, mode=mode)

    # -- persistence --------------------------------------------------------

    def state_dict(self) -> dict:
        """Dense-array state for ``np.savez`` (see repro.data.store)."""
        return {
            "sketch_vals": self.vals,
            "sketch_wts": self.wts,
            "sketch_meta": np.asarray([self.p, self.max_entries,
                                       self.n_points], np.int64),
            "sketch_total_weight": np.float64(self.total_weight),
        }

    @classmethod
    def from_state(cls, state) -> "QuantileSketch":
        p, max_entries, n_points = (int(v) for v in state["sketch_meta"])
        sk = cls(p, max_entries)
        sk.vals = np.asarray(state["sketch_vals"], np.float32)
        sk.wts = np.asarray(state["sketch_wts"], np.float32)
        sk.total_weight = float(state["sketch_total_weight"])
        sk.n_points = n_points
        return sk


def sketch_dataset(X, w=None, *, max_entries: int = 2048,
                   row_chunk: int = 65536,
                   sketch: Optional[QuantileSketch] = None) -> QuantileSketch:
    """One-call sketch of an array-like ``X [n, p]`` fed in row chunks —
    never materialises a converted or sorted full copy of a column."""
    n, p = X.shape
    sk = sketch or QuantileSketch(p, max_entries)
    for s in range(0, n, row_chunk):
        wb = None if w is None else np.asarray(w[s:s + row_chunk])
        sk.update(np.asarray(X[s:s + row_chunk]), wb)
    return sk
