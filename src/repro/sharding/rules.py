"""Partitioning rules: parameter/optimizer/batch/cache PartitionSpecs.

Scheme (DESIGN.md §5): 2D logical layout on mesh axes (dp, tp) where dp is
the data/FSDP axis group — ("data",) single-pod, ("pod", "data") multi-pod —
and tp = "model" carries tensor/expert parallelism.

* dense weights: contraction dim on dp (FSDP; all-gathered per layer inside
  the scan), output-feature/head dim on tp (Megatron-style TP).
* MoE expert stacks: expert dim on tp (EP congruent with TP), d_model on dp.
* embeddings/lm head: vocab on tp, d_model on dp.
* caches: batch on dp, heads (or the widest feature dim) on tp.
* scan-stacked segment params carry a leading None for the layer-group dim.

Everything falls back to a divisibility-checked heuristic so reduced/smoke
configs (tiny dims) simply replicate.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ArchConfig


def axes_for_mesh(multi_pod: bool) -> Tuple[Tuple[str, ...], str]:
    dp = ("pod", "data") if multi_pod else ("data",)
    return dp, "model"


def _fits(dim: int, size: int) -> bool:
    return dim >= size and dim % size == 0


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _param_spec(path: str, shape, dp, tp, dp_size: int, tp_size: int,
                scanned: bool):
    """Spec for one parameter leaf (shape excludes the scan dim)."""
    dims = list(shape)
    nd = len(dims)
    spec = [None] * nd

    def put(d, axis, size):
        if size <= 1 or axis is None:
            return False   # axis unused in this layout (e.g. dp_only: tp=1)
        if 0 <= d < nd and spec[d] is None and _fits(dims[d], size):
            spec[d] = axis
            return True
        return False

    leaf = path.rsplit("/", 1)[-1]
    if leaf == "tokens" or "embed" in path:          # [V, D]
        put(0, tp, tp_size)
        put(1, dp, dp_size)
    elif "lm_head" in path:                          # [D, V]
        put(1, tp, tp_size)
        put(0, dp, dp_size)
    elif leaf in ("wq", "wk", "wv") and nd == 3:     # [D, H, hd]
        put(1, tp, tp_size) or put(2, tp, tp_size)
        put(0, dp, dp_size)
    elif leaf == "wo" and nd == 3 and "moe" not in path:  # [H, hd, D]
        put(0, tp, tp_size) or put(1, tp, tp_size)
        put(2, dp, dp_size)
    elif "moe" in path and nd == 3:                  # [E, D, F] / [E, F, D]
        put(0, tp, tp_size)
        put(1, dp, dp_size) if leaf in ("wi", "wg") else put(2, dp, dp_size)
    elif leaf == "router":                           # [D, E]
        put(0, dp, dp_size)
    elif leaf in ("wq_b", "wk_b", "wv_b") and nd == 3:  # [r, H, x]
        put(1, tp, tp_size)
    elif leaf in ("wq_a", "wkv_a", "wk_rope"):       # [D, r]
        put(0, dp, dp_size)
    elif leaf in ("wi", "wg", "wx", "wgate", "w_up", "w_gate", "wz",
                  "wo_gate") and nd == 2:            # [D, F]-like
        put(1, tp, tp_size)
        put(0, dp, dp_size)
    elif leaf in ("wo", "w_down") and nd == 2:       # [F, D]-like
        put(0, tp, tp_size)
        put(1, dp, dp_size)
    elif leaf == "w_if" and nd == 2:                 # [W, 2H]
        put(0, dp, dp_size)
    elif leaf in ("wa",) and nd == 2:                # [W, W] recurrent gates
        put(1, tp, tp_size)
    elif leaf == "w" and nd == 2 and "conv" in path:  # [K, W]
        put(1, tp, tp_size)
    elif nd >= 2:
        # fallback: tp on last fitting dim, dp on first remaining
        for d in range(nd - 1, -1, -1):
            if put(d, tp, tp_size):
                break
        for d in range(nd):
            if spec[d] is None and put(d, dp, dp_size):
                break
    if scanned:
        spec = [None] + spec
    return P(*spec)


def param_specs(params_tree, cfg: ArchConfig, dp, tp, dp_size: int,
                tp_size: int):
    """PartitionSpec pytree matching ``params_tree`` (shapes or arrays)."""

    def one(path, leaf):
        ps = _path_str(path)
        scanned = "segments" in ps
        shape = leaf.shape[1:] if scanned else leaf.shape
        return _param_spec(ps, shape, dp, tp, dp_size, tp_size, scanned)

    return jax.tree_util.tree_map_with_path(one, params_tree)


def batch_specs(batch_tree, dp, tp, dp_size: int):
    """Input batches: batch dim on dp when divisible; else replicate."""

    def one(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        spec = [None] * len(shape)
        if _fits(shape[0], dp_size):
            spec[0] = dp
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_specs(cache_tree, dp, tp, dp_size: int, tp_size: int):
    """Decode caches: [G, B, ...] — B on dp; heads/feature dim on tp."""

    def one(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        spec = [None] * nd
        # dim 0 is the scanned layer-group stack; dim 1 is batch
        if nd >= 2 and _fits(shape[1], dp_size) and shape[1] > 1:
            spec[1] = dp
        # tp: prefer the head dim (2), then the last dim, then the seq dim
        if tp_size > 1:
            for d in ([2, nd - 1, 3] if nd >= 4 else [nd - 1]):
                if 2 <= d < nd and spec[d] is None and _fits(shape[d], tp_size):
                    spec[d] = tp
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_tree)
