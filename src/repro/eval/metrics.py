"""Generated-data quality metrics (paper App. D.2).

* Wasserstein-1: exact per-feature W1 (scipy) averaged, plus sliced-W1 over
  random projections (joint-structure sensitive; POT's exact OT is not
  available offline, sliced-W1 is the standard surrogate).
* Coverage (Eq. 8): L1-ball k-NN coverage with k auto-chosen so the train
  set has >= 95% coverage of the test set.
* Classifier two-sample AUC (CaloChallenge metric): logistic regression on
  standardized features, manual ROC-AUC.
"""
from __future__ import annotations

import numpy as np
from scipy import stats


def w1_per_feature(a: np.ndarray, b: np.ndarray) -> float:
    return float(np.mean([stats.wasserstein_distance(a[:, j], b[:, j])
                          for j in range(a.shape[1])]))


def sliced_w1(a: np.ndarray, b: np.ndarray, n_proj: int = 64,
              seed: int = 0) -> float:
    rng = np.random.default_rng(seed)
    p = a.shape[1]
    dirs = rng.normal(size=(n_proj, p))
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    vals = [stats.wasserstein_distance(a @ d, b @ d) for d in dirs]
    return float(np.mean(vals))


def _l1_knn_radius(ref: np.ndarray, k: int) -> np.ndarray:
    """L1 distance of each ref point to its k-th nearest neighbour in ref."""
    n = len(ref)
    rad = np.empty(n)
    for i in range(n):
        d = np.abs(ref - ref[i]).sum(1)
        d[i] = np.inf
        rad[i] = np.partition(d, k - 1)[k - 1]
    return rad


def coverage(gen: np.ndarray, ref: np.ndarray, k: int = 3) -> float:
    """Eq. 8: fraction of ref points with >= 1 generated point inside their
    k-NN L1 ball."""
    rad = _l1_knn_radius(ref, k)
    covered = 0
    for j in range(len(ref)):
        d = np.abs(gen - ref[j]).sum(1)
        covered += bool((d <= rad[j]).any())
    return covered / len(ref)


def auto_k(train: np.ndarray, test: np.ndarray, target: float = 0.95,
           k_max: int = 10) -> int:
    for k in range(1, k_max + 1):
        if coverage(train, test, k) >= target:
            return k
    return k_max


def classifier_auc(real: np.ndarray, gen: np.ndarray, seed: int = 0,
                   steps: int = 400) -> float:
    """Two-sample test AUC: logistic regression real-vs-generated.
    0.5 = indistinguishable (best); 1.0 = trivially separable."""
    rng = np.random.default_rng(seed)
    n = min(len(real), len(gen))
    X = np.concatenate([real[:n], gen[:n]]).astype(np.float64)
    y = np.concatenate([np.ones(n), np.zeros(n)])
    mu, sd = X.mean(0), X.std(0) + 1e-9
    X = (X - mu) / sd
    idx = rng.permutation(2 * n)
    X, y = X[idx], y[idx]
    n_tr = int(0.7 * 2 * n)
    Xtr, ytr, Xte, yte = X[:n_tr], y[:n_tr], X[n_tr:], y[n_tr:]
    w = np.zeros(X.shape[1])
    b = 0.0
    lr = 0.5
    for _ in range(steps):
        z = Xtr @ w + b
        p = 1 / (1 + np.exp(-np.clip(z, -30, 30)))
        gw = Xtr.T @ (p - ytr) / len(ytr) + 1e-3 * w
        gb = float(np.mean(p - ytr))
        w -= lr * gw
        b -= lr * gb
    score = Xte @ w + b
    return roc_auc(yte, score)


def roc_auc(y: np.ndarray, score: np.ndarray) -> float:
    order = np.argsort(score)
    ranks = np.empty(len(score))
    ranks[order] = np.arange(1, len(score) + 1)
    pos = y > 0.5
    n_pos, n_neg = pos.sum(), (~pos).sum()
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[pos].sum() - n_pos * (n_pos + 1) / 2)
                 / (n_pos * n_neg))
