"""Checkpointing: atomic, step-numbered, resumable — the fault-tolerance
substrate for both trainers (paper Solution 3, promoted to first-class).

Layout:
  <dir>/step_<N>/arrays.npz      flattened pytree leaves
  <dir>/step_<N>/treedef.json    structure + shapes + dtypes (integrity check)
  <dir>/step_<N>/COMMITTED       written last -> crash-safe commit marker

Besides the step-numbered pytree checkpoints, this module owns the
batch-grid manifest used by the forest trainers (``batch_<b0>.npz`` files +
``manifest.json``): :class:`GridManifest` is thread-safe and every update is
write-tmp-then-``os.replace`` with an fsync, so the pipelined trainer's
writer thread can flush batches while the main thread keeps dispatching,
and a crash between flushes always leaves a consistent (if slightly stale)
manifest that a resume can trust.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any) -> str:
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    final = d / f"step_{step}"
    tmp = Path(tempfile.mkdtemp(dir=d, prefix=f".tmp_step_{step}_"))
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(tmp / "arrays.npz", **arrays)
    meta = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [{"shape": list(np.shape(l)),
                    "dtype": str(np.asarray(l).dtype)} for l in leaves],
    }
    (tmp / "treedef.json").write_text(json.dumps(meta))
    (tmp / "COMMITTED").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)       # atomic on the same filesystem
    return str(final)


def latest_step(directory: str) -> Optional[int]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = []
    for sub in d.iterdir():
        if sub.name.startswith("step_") and (sub / "COMMITTED").exists():
            try:
                steps.append(int(sub.name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(directory: str, tree_like: Any, step: Optional[int] = None
            ) -> Tuple[Any, int]:
    """Restore into the structure of ``tree_like``; verifies shapes/dtypes."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    d = Path(directory) / f"step_{step}"
    meta = json.loads((d / "treedef.json").read_text())
    data = np.load(d / "arrays.npz")
    leaves, treedef = _flatten(tree_like)
    assert len(leaves) == len(meta["leaves"]), "checkpoint structure mismatch"
    out = []
    for i, proto in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        want = tuple(np.shape(proto))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != expected {want} "
                "(use reshard() for elastic restore)")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), step


def reshard(tree, mesh, specs):
    """Elastic restore: place host arrays onto a (possibly different) mesh."""
    def put(x, spec):
        return jax.device_put(x, jax.sharding.NamedSharding(mesh, spec))
    return jax.tree_util.tree_map(put, tree, specs)


def describe_fingerprint_mismatch(stale, new, *, stale_name: str = "on-disk",
                                  new_name: str = "requested") -> str:
    """Human-readable diff of two fingerprint dicts: every differing key
    with both values, then both full fingerprints — shared by the
    :class:`GridManifest` and :mod:`repro.data.store` refusal errors so an
    operator never has to open the manifest to see *what* mismatched."""
    stale = stale or {}
    new = new or {}
    lines = [f"  {k}: {stale_name}={stale.get(k)!r} != "
             f"{new_name}={new.get(k)!r}"
             for k in sorted(set(stale) | set(new))
             if stale.get(k) != new.get(k)]
    return ("differing keys:\n" + "\n".join(lines)
            + f"\n{stale_name} fingerprint: {json.dumps(stale, sort_keys=True)}"
            + f"\n{new_name} fingerprint: {json.dumps(new, sort_keys=True)}")


# ---------------------------------------------------------------------------
# batch-grid manifest (forest trainers: Issue-3 streaming checkpoints)
# ---------------------------------------------------------------------------

def _fsync_replace(tmp: str, final: str) -> None:
    """``os.replace`` with the data already on disk: fsync the temp file,
    rename, then fsync the directory entry. A crash at any point leaves
    either the old complete file or the new complete file — never a
    truncated one the manifest could be tricked into trusting."""
    fd = os.open(tmp, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)
    os.replace(tmp, final)
    dfd = os.open(os.path.dirname(final) or ".", os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def write_batch_npz(directory: str, b0: int, arrays: dict) -> str:
    """Atomically write one trained ensemble batch (``batch_<b0>.npz``)."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"batch_{b0}.npz")
    tmp = os.path.join(directory, f".tmp_batch_{b0}.npz")
    np.savez(tmp, **arrays)
    _fsync_replace(tmp, final)
    return final


def read_batch_npz(directory: str, b0: int) -> dict:
    """Load one committed ensemble batch back as ``{field: np.ndarray}``."""
    with np.load(os.path.join(directory, f"batch_{b0}.npz")) as data:
        return {k: data[k] for k in data.files}


class GridManifest:
    """Which ensemble batches of a (timestep, class) grid are complete.

    The manifest pins the full run fingerprint (config, grid layout, batch
    size, data shape — see ``_manifest_fingerprint`` in
    :mod:`repro.tabgen.fitting`) and the set of committed ``(b0, len)``
    batch keys. :meth:`load_done` refuses to resume under a mismatched
    fingerprint — the PR-2 safety that keeps stale ``batch_*.npz`` files
    from silently mixing with fresh ones.

    Warm-start mode: ``warm_base`` describes the *base* run of a
    warm-start extension (``{"config": <base ForestConfig asdict>, "grid":
    [n_t, n_y]}``). A checkpoint dir whose manifest matches ``warm_base``
    on those keys is accepted with an empty done-set instead of refused:
    the extension retrains every batch (its round buffers are wider than
    the base's, so the base ``batch_*.npz`` files aren't reusable) and
    overwrites them in place, rewriting the manifest under the new
    fingerprint on the first :meth:`mark_done`. Batch size / data shape
    are deliberately not matched — an extension may run with a different
    batching and typically fits *more* rows than the base did.

    Async-safe by construction: :meth:`mark_done` may be called from the
    pipelined trainer's writer thread while the main thread dispatches later
    batches (or, in principle, from several writers completing out of
    order). A lock serialises updates, each update rewrites the whole
    manifest to a temp file and ``os.replace``s it with fsyncs, and a batch
    is only ever marked done *after* its ``batch_*.npz`` is durably
    committed — so every state a crash can expose resumes correctly.
    """

    def __init__(self, directory: str, fingerprint: dict,
                 warm_base: Optional[dict] = None):
        self.directory = directory
        self.path = os.path.join(directory, "manifest.json")
        self.fingerprint = fingerprint
        self.warm_base = warm_base
        self._lock = threading.Lock()
        self._done: set = set()

    def _is_warm_base(self, stale: Optional[dict]) -> bool:
        """Does the on-disk manifest belong to this extension's base run?"""
        if self.warm_base is None or not stale:
            return False
        # config (incl. the base's n_trees) + grid is the whole match: an
        # extension may batch differently and usually fits more rows, and a
        # base that was itself warm-started is still a valid base
        return (stale.get("config") == self.warm_base.get("config")
                and stale.get("grid") == self.warm_base.get("grid"))

    def load_done(self, resume: bool) -> set:
        """The committed batch keys; refuses mismatched-fingerprint resume."""
        if resume and os.path.exists(self.path):
            with open(self.path) as f:
                manifest = json.load(f)
            stale = manifest.get("fingerprint")
            if stale == self.fingerprint:
                done = set(tuple(e) for e in manifest["batches"])
                with self._lock:
                    self._done = done
            elif self._is_warm_base(stale):
                # fingerprint-compatible base checkpoint: accept, but no
                # batch is reusable (base files hold fewer-round buffers) —
                # the extension overwrites them all
                with self._lock:
                    self._done = set()
            else:
                raise ValueError(
                    f"checkpoint at {self.directory} was written under a "
                    "mismatched run configuration; resuming would mix stale "
                    "batch_*.npz files with new ones. Pass resume=False "
                    "(or a fresh checkpoint_dir) to retrain.\n"
                    + describe_fingerprint_mismatch(
                        stale, self.fingerprint, stale_name="checkpoint",
                        new_name="requested"))
        with self._lock:
            return set(self._done)

    def mark_done(self, key: Tuple[int, int]) -> None:
        """Durably record ``key = (b0, n_ensembles)`` as committed."""
        with self._lock:
            self._done.add(key)
            os.makedirs(self.directory, exist_ok=True)
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"fingerprint": self.fingerprint,
                           "batches": sorted(self._done)}, f)
            _fsync_replace(tmp, self.path)
