"""Checkpointing: atomic, step-numbered, resumable — the fault-tolerance
substrate for both trainers (paper Solution 3, promoted to first-class).

Layout:
  <dir>/step_<N>/arrays.npz      flattened pytree leaves
  <dir>/step_<N>/treedef.json    structure + shapes + dtypes (integrity check)
  <dir>/step_<N>/COMMITTED       written last -> crash-safe commit marker
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any) -> str:
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    final = d / f"step_{step}"
    tmp = Path(tempfile.mkdtemp(dir=d, prefix=f".tmp_step_{step}_"))
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(tmp / "arrays.npz", **arrays)
    meta = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [{"shape": list(np.shape(l)),
                    "dtype": str(np.asarray(l).dtype)} for l in leaves],
    }
    (tmp / "treedef.json").write_text(json.dumps(meta))
    (tmp / "COMMITTED").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)       # atomic on the same filesystem
    return str(final)


def latest_step(directory: str) -> Optional[int]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = []
    for sub in d.iterdir():
        if sub.name.startswith("step_") and (sub / "COMMITTED").exists():
            try:
                steps.append(int(sub.name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(directory: str, tree_like: Any, step: Optional[int] = None
            ) -> Tuple[Any, int]:
    """Restore into the structure of ``tree_like``; verifies shapes/dtypes."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    d = Path(directory) / f"step_{step}"
    meta = json.loads((d / "treedef.json").read_text())
    data = np.load(d / "arrays.npz")
    leaves, treedef = _flatten(tree_like)
    assert len(leaves) == len(meta["leaves"]), "checkpoint structure mismatch"
    out = []
    for i, proto in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        want = tuple(np.shape(proto))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != expected {want} "
                "(use reshard() for elastic restore)")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), step


def reshard(tree, mesh, specs):
    """Elastic restore: place host arrays onto a (possibly different) mesh."""
    def put(x, spec):
        return jax.device_put(x, jax.sharding.NamedSharding(mesh, spec))
    return jax.tree_util.tree_map(put, tree, specs)
