"""Pure-JAX AdamW + LR schedules + grad clipping (no optax dependency)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


def init_opt_state(params, moment_dtype=jnp.float32) -> Dict:
    """moment_dtype=bf16 halves optimizer HBM (update math stays fp32)."""
    mk = lambda p: jax.tree_util.tree_map(
        lambda a: jnp.zeros(a.shape, moment_dtype), p)
    return {"m": mk(params), "v": mk(params),
            "step": jnp.zeros((), jnp.int32)}


def lr_schedule(step, cfg: TrainConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads, opt_state, params, cfg: TrainConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    lr = lr_schedule(step, cfg)
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        mdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    return (treedef.unflatten(new_p),
            {"m": treedef.unflatten(new_m), "v": treedef.unflatten(new_v),
             "step": step},
            {"grad_norm": gnorm, "lr": lr})
