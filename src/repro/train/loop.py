"""Training loop: grad accumulation, checkpoint/restart, failure retry.

Fault-tolerance posture (DESIGN.md §5):
* checkpoints are atomic + committed, written every ``ckpt_every`` steps;
* the data pipeline is stateless (batch = f(seed, step)), so resume is exact
  and any replacement host can recompute any microbatch (straggler story);
* ``run_with_retries`` restarts the loop from the last commit on exceptions —
  the single-process analogue of a scheduler rescheduling a failed worker;
* ``reshard`` in checkpoint.py supports elastic restore onto a new mesh.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ArchConfig, TrainConfig
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train.optim import adamw_update, init_opt_state


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig, dtype=jnp.float32,
                    accum: int = 1):
    """Returns jitted (params, opt, batch) -> (params, opt, metrics).

    ``accum > 1`` splits the batch into microbatches and averages grads —
    the memory/throughput knob for large global batches.
    """

    def loss_of(p, b):
        loss, metrics = lm.loss_fn(p, b, cfg, dtype=dtype,
                                   remat_policy=tcfg.remat_policy)
        return loss, metrics

    def step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        else:
            def micro(i, carry):
                g_acc, l_acc = carry
                mb = jax.tree_util.tree_map(
                    lambda a: jax.lax.dynamic_slice_in_dim(
                        a, i * (a.shape[0] // accum), a.shape[0] // accum), batch)
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
                return (jax.tree_util.tree_map(jnp.add, g_acc, g), l_acc + l)

            zero = jax.tree_util.tree_map(
                lambda a: jnp.zeros(a.shape, jnp.float32), params)
            grads, loss = jax.lax.fori_loop(0, accum, micro,
                                            (zero, jnp.float32(0.0)))
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = {}
        params, opt_state, om = adamw_update(grads, opt_state, params, tcfg)
        om["loss"] = loss
        return params, opt_state, om

    return jax.jit(step, donate_argnums=(0, 1))


def train(cfg: ArchConfig, tcfg: TrainConfig, data_fn: Callable[[int], Dict],
          *, steps: int, ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          accum: int = 1, log_every: int = 10, dtype=jnp.float32,
          params=None, log_fn=print):
    """Run (or resume) training. Returns (params, opt_state, history)."""
    if params is None:
        params = lm.init_params(jax.random.PRNGKey(tcfg.seed), cfg)
    opt_state = init_opt_state(params)
    start = 0
    if ckpt_dir is not None and ckpt.latest_step(ckpt_dir) is not None:
        (params, opt_state), start = ckpt.restore(ckpt_dir,
                                                  (params, opt_state))
        log_fn(f"[resume] restored step {start} from {ckpt_dir}")
    step_fn = make_train_step(cfg, tcfg, dtype=dtype, accum=accum)
    history = []
    t0 = time.time()
    for i in range(start, steps):
        batch = {k: jnp.asarray(v) for k, v in data_fn(i).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if (i + 1) % log_every == 0 or i == steps - 1:
            loss = float(m["loss"])
            history.append({"step": i + 1, "loss": loss,
                            "grad_norm": float(m["grad_norm"]),
                            "lr": float(m["lr"]),
                            "elapsed_s": round(time.time() - t0, 1)})
            log_fn(f"step {i+1:5d} loss {loss:.4f} "
                   f"gnorm {float(m['grad_norm']):.3f}")
        if ckpt_dir is not None and (i + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, i + 1, (params, opt_state))
    if ckpt_dir is not None:
        ckpt.save(ckpt_dir, steps, (params, opt_state))
    return params, opt_state, history


def run_with_retries(fn, max_retries: int = 3, log_fn=print):
    """Restart-on-failure wrapper: the last committed checkpoint is the
    recovery point; transient node failures become retries."""
    for attempt in range(max_retries + 1):
        try:
            return fn()
        except (RuntimeError, OSError) as e:  # pragma: no cover
            if attempt == max_retries:
                raise
            log_fn(f"[retry {attempt + 1}/{max_retries}] {type(e).__name__}:"
                   f" {e}; resuming from last checkpoint")
