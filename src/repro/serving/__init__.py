"""Multi-tenant serving control plane over the tabgen data plane.

Layers (each its own module, composable):

* :mod:`repro.serving.registry`  — :class:`ModelRegistry`: many named
  :class:`~repro.tabgen.ForestArtifacts` hot per process, LRU device
  placement under a byte budget, zero-downtime ``swap``.
* :mod:`repro.serving.admission` — :class:`AdmissionController`:
  interactive/bulk priority queues, per-tenant row-rate token buckets,
  bounded queues with reject-and-retry-after, request deadlines.
* :mod:`repro.serving.scheduler` — :class:`InflightScheduler`: in-flight
  micro-batching (dispatch batch ``k+1`` while a waiter thread resolves
  batch ``k``), priority-ordered coalescing, per-sampler / per-tenant
  stats with queue-wait vs device-time breakdown.

Since PR 8 every layer keeps its counters on a
:class:`repro.obs.MetricsRegistry` (typed instruments, one lock, one
consistent snapshot) and the scheduler times the request path with
:class:`repro.obs.Tracer` spans (``serve.queue`` / ``serve.device`` /
``serve.sync``) instead of hand-stamped timestamps. The legacy
``stats`` / ``stats_snapshot()`` dict shapes are preserved as *views*
over those instruments, and :func:`repro.obs.render_prometheus` exposes
the same registries as ``GET /metrics`` — the two can never disagree.
See docs/observability.md.

Front ends: :class:`repro.launch.serve_forest.ForestServer` (single-model,
in-process) and :mod:`repro.launch.serve_http` (multi-model HTTP API).
"""
from repro.serving.admission import (  # noqa: F401
    PRIORITIES, AdmissionController, AdmissionError, DeadlineExceeded,
    QueueFull, RateLimited, TokenBucket)
from repro.serving.registry import (  # noqa: F401
    DEFAULT_BUCKETS, ModelHandle, ModelRegistry, UnknownModel)
from repro.serving.scheduler import (  # noqa: F401
    InflightScheduler, Request)
