"""InflightScheduler: admission-controlled micro-batching with in-flight
dispatch.

The PR-4 ``ForestServer`` dispatcher drained its queue batch-by-batch: form
a batch, dispatch it, *block on the result*, split rows, repeat. Every
request therefore waited queue-time + full device-time of everything ahead
of it, and the device idled while the host unpadded/shuffled/delivered the
previous batch.

This scheduler splits those roles across two threads, riding the same
async-dispatch property the PR-3 training pipeline uses (dispatch under jit
is non-blocking; only materialising the result blocks):

* the **scheduler thread** pops admitted requests (interactive before
  bulk), coalesces same-(model, sampler) requests within a short window,
  and *dispatches* the batch — ``ModelHandle.generate_async`` returns as
  soon as the program is enqueued on the device;
* the **waiter thread** resolves in-flight batches in dispatch order:
  block on the device values, unpad/decode, slice rows back per request,
  deliver futures, account stats.

While the waiter blocks on batch ``k``, the scheduler is already admitting
and dispatching batch ``k+1`` — the device queue stays fed, so queue wait
no longer stacks on device time. ``inflight_depth`` bounds how many
dispatched-but-unresolved batches may exist (backpressure against flooding
the device queue); ``sync_resolve=True`` degrades to the PR-4
drain-then-serve loop (kept as the benchmark reference arm).

Request lifecycle: ``submit()`` validates eagerly (unknown model / sampler
raise to the *caller*, not into a future after a wasted dispatch), the
admission controller rate-limits and bounds queues
(:class:`~repro.serving.admission.RateLimited` /
:class:`~repro.serving.admission.QueueFull`), expired deadlines fail with
:class:`~repro.serving.admission.DeadlineExceeded` before any device time
is spent, and cancelled futures are dropped at batch-claim time.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import List, Optional

from repro.serving.admission import (CLOSED, AdmissionController,
                                     DeadlineExceeded)
from repro.serving.registry import ModelRegistry, UnknownModel  # noqa: F401

#: Seed base of the micro-batched path: coalesced batches draw their own
#: sample seeds from a scheduler-local counter offset far from the ones
#: users hand to ``generate(seed=...)``, so the two paths never collide in
#: the label-draw RNG space.
BATCH_SEED_BASE = 1 << 20

_SHUTDOWN = object()


@dataclasses.dataclass
class Request:
    """One queued generation request. The first three fields keep the PR-4
    ``_Request(n, sampler, future)`` positional layout."""
    n: int
    sampler: str
    future: Future
    model: str = "default"
    tenant: str = "default"
    priority: str = "interactive"
    enqueued_s: float = dataclasses.field(default_factory=time.monotonic)
    deadline_s: Optional[float] = None  # absolute time.monotonic()


@dataclasses.dataclass
class _Inflight:
    """A dispatched-but-unresolved batch travelling to the waiter."""
    handle: object            # ModelHandle snapshot the batch runs on
    sample: object            # SampleHandle / _DecodingHandle
    batch: List[Request]
    total_rows: int
    t_dispatch: float


def _new_stats() -> dict:
    return {
        "requests": 0, "rows": 0, "gen_s": 0.0, "warm_s": 0.0,
        "batches": 0, "coalesced_requests": 0,
        "queue_wait_s": 0.0, "device_s": 0.0,
        "dropped_deadline": 0, "max_inflight_observed": 0,
        "per_sampler": {}, "per_tenant": {},
    }


def _sampler_slot(stats: dict, sampler: str) -> dict:
    return stats["per_sampler"].setdefault(sampler, {
        "requests": 0, "rows": 0, "batches": 0,
        "queue_wait_s": 0.0, "device_s": 0.0})


def _tenant_slot(stats: dict, tenant: str) -> dict:
    return stats["per_tenant"].setdefault(tenant, {
        "requests": 0, "rows": 0, "queue_wait_s": 0.0})


class InflightScheduler:
    def __init__(self, registry: ModelRegistry,
                 admission: Optional[AdmissionController] = None, *,
                 max_coalesce_rows: Optional[int] = None,
                 coalesce_window_s: float = 0.002,
                 inflight_depth: int = 2,
                 sync_resolve: bool = False):
        self.registry = registry
        self.admission = admission or AdmissionController()
        # default row cap = the largest bucket: coalescing past it would
        # push the merged batch into oversize exact-size territory and
        # compile a fresh program per distinct total — the opposite of what
        # micro-batching is for
        self.max_coalesce_rows = int(max_coalesce_rows
                                     or max(registry.buckets))
        self.coalesce_window_s = float(coalesce_window_s)
        self.inflight_depth = int(inflight_depth)
        self.sync_resolve = bool(sync_resolve)
        self.stats = _new_stats()
        self._stats_lock = threading.Lock()
        self._batch_seed = 0
        self._inflight = 0
        self._inflight_q: "queue.Queue" = queue.Queue(maxsize=self.inflight_depth)
        self._scheduler_t: Optional[threading.Thread] = None
        self._waiter_t: Optional[threading.Thread] = None
        self._lifecycle_lock = threading.Lock()

    # -- public API ----------------------------------------------------------

    def submit(self, n: int, *, model: str = "default",
               sampler: Optional[str] = None, tenant: str = "default",
               priority: str = "interactive",
               deadline_s: Optional[float] = None) -> Future:
        """Queue a generation request; resolves to ``(X, y)``.

        Validation is eager: an unknown model raises
        :class:`~repro.serving.registry.UnknownModel` and a sampler the
        model doesn't serve raises :class:`ValueError` here, to the caller —
        never inside the dispatcher after a wasted dispatch attempt.
        Admission rejections (:class:`RateLimited` / :class:`QueueFull`)
        also raise here: explicit backpressure, not unbounded queueing.
        ``deadline_s`` is a *relative* SLO; a request still queued when it
        lapses fails with :class:`DeadlineExceeded` before dispatch.
        """
        handle = self.registry.peek(model)
        name = sampler or handle.samplers[0]
        if name not in handle.samplers:
            raise ValueError(
                f"model {model!r} does not serve sampler {name!r}; "
                f"served: {list(handle.samplers)}")
        now = time.monotonic()
        req = Request(int(n), name, Future(), model=model, tenant=tenant,
                      priority=priority, enqueued_s=now,
                      deadline_s=None if deadline_s is None
                      else now + float(deadline_s))
        # enqueue under the lifecycle lock: a submit racing with stop()
        # could otherwise land behind the close with no threads left to
        # serve it — the lock serialises the two, so the request either
        # precedes the drain or gets fresh threads
        with self._lifecycle_lock:
            self._start_locked()
            self.admission.offer(req)
        return req.future

    def start(self) -> None:
        with self._lifecycle_lock:
            self._start_locked()

    def stop(self, timeout: float = 10.0) -> None:
        """Drain admitted requests, then stop both threads."""
        with self._lifecycle_lock:
            if self._scheduler_t is None:
                return
            self.admission.close()
            self._scheduler_t.join(timeout)
            if self._waiter_t is not None:
                self._waiter_t.join(timeout)
            self._scheduler_t = None
            self._waiter_t = None

    def rows_per_sec(self) -> float:
        with self._stats_lock:
            return self.stats["rows"] / max(self.stats["gen_s"], 1e-9)

    def stats_snapshot(self) -> dict:
        with self._stats_lock:
            out = dict(self.stats)
            out["per_sampler"] = {k: dict(v)
                                  for k, v in self.stats["per_sampler"].items()}
            out["per_tenant"] = {k: dict(v)
                                 for k, v in self.stats["per_tenant"].items()}
            out["inflight"] = self._inflight
            return out

    # -- bookkeeping shared with the synchronous server path -----------------

    def record_warm(self, wall_s: float) -> None:
        with self._stats_lock:
            self.stats["warm_s"] += wall_s

    def record_sync(self, *, n: int, sampler: str, tenant: str,
                    wall_s: float) -> None:
        """Account a synchronous ``generate()`` served outside the queue
        (one request = one batch, zero queue wait)."""
        with self._stats_lock:
            self.stats["requests"] += 1
            self.stats["rows"] += n
            self.stats["gen_s"] += wall_s
            self.stats["device_s"] += wall_s
            self.stats["batches"] += 1
            slot = _sampler_slot(self.stats, sampler)
            slot["requests"] += 1
            slot["rows"] += n
            slot["batches"] += 1
            slot["device_s"] += wall_s
            ten = _tenant_slot(self.stats, tenant)
            ten["requests"] += 1
            ten["rows"] += n

    # -- threads -------------------------------------------------------------

    def _start_locked(self) -> None:
        if self._scheduler_t is None or not self._scheduler_t.is_alive():
            self.admission.reopen()
            self._scheduler_t = threading.Thread(
                target=self._scheduler_loop, name="serving-scheduler",
                daemon=True)
            self._scheduler_t.start()
        if not self.sync_resolve and (
                self._waiter_t is None or not self._waiter_t.is_alive()):
            self._waiter_t = threading.Thread(
                target=self._waiter_loop, name="serving-waiter", daemon=True)
            self._waiter_t.start()

    def _expired(self, req: Request, now: Optional[float] = None) -> bool:
        """Drop a deadline-lapsed request before dispatch; True if dropped."""
        if req.deadline_s is None:
            return False
        now = time.monotonic() if now is None else now
        if now <= req.deadline_s:
            return False
        if req.future.set_running_or_notify_cancel():
            req.future.set_exception(DeadlineExceeded(
                f"deadline lapsed {now - req.deadline_s:.3f}s ago while "
                "queued"))
        with self._stats_lock:
            self.stats["dropped_deadline"] += 1
        return True

    def _scheduler_loop(self) -> None:
        while True:
            req = self.admission.pop(timeout=0.1)
            if req is CLOSED:
                if not self.sync_resolve:
                    self._inflight_q.put(_SHUTDOWN)
                return
            if req is None or self._expired(req):
                continue
            batch, rows = [req], req.n
            deadline = time.monotonic() + self.coalesce_window_s
            while rows < self.max_coalesce_rows:
                left = deadline - time.monotonic()
                if left <= 0:
                    break
                nxt = self.admission.pop_matching(
                    req.model, req.sampler, self.max_coalesce_rows - rows,
                    timeout=left)
                if nxt is None:
                    break
                if self._expired(nxt):
                    continue
                batch.append(nxt)
                rows += nxt.n
            inflight = self._dispatch(batch)
            if inflight is None:
                continue
            if self.sync_resolve:
                # PR-4 drain-then-serve semantics (benchmark reference arm):
                # the scheduler blocks until the batch resolves, so nothing
                # overlaps device time
                self._resolve(inflight)
            else:
                self._inflight_q.put(inflight)  # bounded: dispatch backpressure

    def _waiter_loop(self) -> None:
        while True:
            item = self._inflight_q.get()
            if item is _SHUTDOWN:
                return
            self._resolve(item)

    # -- batch mechanics -----------------------------------------------------

    def _dispatch(self, batch: List[Request]) -> Optional[_Inflight]:
        """Claim futures, snapshot the model, enqueue one device program.
        Returns the in-flight record (or None if nothing survived)."""
        # claim each future first: a client that cancelled while queued is
        # dropped here — set_result on a cancelled Future raises and would
        # otherwise kill the scheduler thread, stranding the whole batch
        batch = [r for r in batch if r.future.set_running_or_notify_cancel()]
        if not batch:
            return None
        total = sum(r.n for r in batch)
        with self._stats_lock:
            seed = BATCH_SEED_BASE + self._batch_seed
            self._batch_seed += 1
        t0 = time.monotonic()
        try:
            handle = self.registry.acquire(batch[0].model)
            sample = handle.generate_async(total, batch[0].sampler, seed=seed)
        except BaseException as exc:  # noqa: BLE001 — delivered via futures
            for r in batch:
                r.future.set_exception(exc)
            return None
        with self._stats_lock:
            self._inflight += 1
            self.stats["max_inflight_observed"] = max(
                self.stats["max_inflight_observed"], self._inflight)
        return _Inflight(handle, sample, batch, total, t0)

    def _resolve(self, inflight: _Inflight) -> None:
        """Block on the device values, deliver per-request slices, account
        queue-wait vs device-time."""
        batch = inflight.batch
        try:
            X, y = inflight.sample.result()
        except BaseException as exc:  # noqa: BLE001 — delivered via futures
            for r in batch:
                r.future.set_exception(exc)
            with self._stats_lock:
                self._inflight -= 1
            return
        now = time.monotonic()
        dt = now - inflight.t_dispatch
        off = 0
        for r in batch:
            r.future.set_result((X[off:off + r.n], y[off:off + r.n]))
            off += r.n
        with self._stats_lock:
            self._inflight -= 1
            waited = sum(inflight.t_dispatch - r.enqueued_s for r in batch)
            self.stats["requests"] += len(batch)
            self.stats["rows"] += inflight.total_rows
            self.stats["gen_s"] += dt
            self.stats["device_s"] += dt
            self.stats["queue_wait_s"] += waited
            self.stats["batches"] += 1
            self.stats["coalesced_requests"] += len(batch) - 1
            slot = _sampler_slot(self.stats, batch[0].sampler)
            slot["requests"] += len(batch)
            slot["rows"] += inflight.total_rows
            slot["batches"] += 1
            slot["device_s"] += dt
            slot["queue_wait_s"] += waited
            for r in batch:
                ten = _tenant_slot(self.stats, r.tenant)
                ten["requests"] += 1
                ten["rows"] += r.n
                ten["queue_wait_s"] += inflight.t_dispatch - r.enqueued_s

    def serve_batch_sync(self, batch: List[Request]) -> None:
        """Dispatch + resolve one pre-formed batch on the calling thread —
        the test seam (and the drain arm's inner step)."""
        inflight = self._dispatch(batch)
        if inflight is not None:
            self._resolve(inflight)
