"""InflightScheduler: admission-controlled micro-batching with in-flight
dispatch.

The PR-4 ``ForestServer`` dispatcher drained its queue batch-by-batch: form
a batch, dispatch it, *block on the result*, split rows, repeat. Every
request therefore waited queue-time + full device-time of everything ahead
of it, and the device idled while the host unpadded/shuffled/delivered the
previous batch.

This scheduler splits those roles across two threads, riding the same
async-dispatch property the PR-3 training pipeline uses (dispatch under jit
is non-blocking; only materialising the result blocks):

* the **scheduler thread** pops admitted requests (interactive before
  bulk), coalesces same-(model, sampler) requests within a short window,
  and *dispatches* the batch — ``ModelHandle.generate_async`` returns as
  soon as the program is enqueued on the device;
* the **waiter thread** resolves in-flight batches in dispatch order:
  block on the device values, unpad/decode, slice rows back per request,
  deliver futures, account stats.

While the waiter blocks on batch ``k``, the scheduler is already admitting
and dispatching batch ``k+1`` — the device queue stays fed, so queue wait
no longer stacks on device time. ``inflight_depth`` bounds how many
dispatched-but-unresolved batches may exist (backpressure against flooding
the device queue); ``sync_resolve=True`` degrades to the PR-4
drain-then-serve loop (kept as the benchmark reference arm).

Request lifecycle: ``submit()`` validates eagerly (unknown model / sampler
raise to the *caller*, not into a future after a wasted dispatch), the
admission controller rate-limits and bounds queues
(:class:`~repro.serving.admission.RateLimited` /
:class:`~repro.serving.admission.QueueFull`), expired deadlines fail with
:class:`~repro.serving.admission.DeadlineExceeded` before any device time
is spent, and cancelled futures are dropped at batch-claim time.

Observability (PR 8): every request carries a ``serve.queue`` span from
``submit()`` to batch-claim, and every dispatched batch a ``serve.device``
span from dispatch to resolution — queue-wait vs device-time is *span
durations*, not hand-stamped timestamp deltas, and the same spans feed the
:mod:`repro.obs` instruments behind ``stats_snapshot()`` (whose dict shape
is unchanged since PR 6), ``/statz``, and ``GET /metrics``.  Pass a shared
``metrics=``/``tracer=`` pair (as ``serve_http`` does) to co-export with
the admission controller and model registry; the default is a private pair
per scheduler so tests and benchmark arms never share counters.

Request-scoped tracing (PR 10): ``submit()`` mints (or accepts) a
``request_id``, stamps it on the ``serve.queue`` span as its
``trace_id``, and the ``serve.device`` span *links* every request id the
coalesced batch served — so ``tracer.trace(rid)`` reconstructs the full
per-request timeline (admission -> queue wait -> batch id -> device time
-> sync) that ``GET /v1/trace/<id>`` returns.  One ``time.monotonic()``
reading per request drives both the span start and the absolute deadline
(``t_start=now`` on the span), so the SLO clock can never skew from the
trace clock.  Per-priority latency objectives (``slo=``) feed
``serving_slo_requests`` / ``serving_slo_violations`` counters — a
request *violates* when its submit->delivery latency exceeds its
priority's objective, or when it is dropped at the deadline — and
resolved requests over the :class:`~repro.obs.SlowLog` threshold dump
their linked span timeline to the slow-log JSONL.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
import uuid
from concurrent.futures import Future
from typing import Dict, List, Optional

from repro.obs import MetricsRegistry, SlowLog, Tracer
from repro.serving.admission import (CLOSED, AdmissionController,
                                     DeadlineExceeded)
from repro.serving.registry import ModelRegistry, UnknownModel  # noqa: F401

#: Seed base of the micro-batched path: coalesced batches draw their own
#: sample seeds from a scheduler-local counter offset far from the ones
#: users hand to ``generate(seed=...)``, so the two paths never collide in
#: the label-draw RNG space.
BATCH_SEED_BASE = 1 << 20

_SHUTDOWN = object()

_EMPTY_HIST = {"buckets": (), "sum": 0.0, "count": 0}


@dataclasses.dataclass
class Request:
    """One queued generation request. The first three fields keep the PR-4
    ``_Request(n, sampler, future)`` positional layout."""
    n: int
    sampler: str
    future: Future
    model: str = "default"
    tenant: str = "default"
    priority: str = "interactive"
    enqueued_s: float = dataclasses.field(default_factory=time.monotonic)
    deadline_s: Optional[float] = None  # absolute time.monotonic()
    span: Optional[object] = None       # serve.queue span (set by submit)
    request_id: str = ""                # trace id minted/accepted by submit


@dataclasses.dataclass
class _Inflight:
    """A dispatched-but-unresolved batch travelling to the waiter."""
    handle: object            # ModelHandle snapshot the batch runs on
    sample: object            # SampleHandle / _DecodingHandle
    batch: List[Request]
    total_rows: int
    span: object              # serve.device span (dispatch -> resolution)


class InflightScheduler:
    def __init__(self, registry: ModelRegistry,
                 admission: Optional[AdmissionController] = None, *,
                 max_coalesce_rows: Optional[int] = None,
                 coalesce_window_s: float = 0.002,
                 inflight_depth: int = 2,
                 sync_resolve: bool = False,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 slo: Optional[Dict[str, float]] = None,
                 slo_error_budget: float = 0.01,
                 slow_log: Optional[SlowLog] = None):
        self.registry = registry
        self.admission = admission or AdmissionController()
        # default row cap = the largest bucket: coalescing past it would
        # push the merged batch into oversize exact-size territory and
        # compile a fresh program per distinct total — the opposite of what
        # micro-batching is for
        self.max_coalesce_rows = int(max_coalesce_rows
                                     or max(registry.buckets))
        self.coalesce_window_s = float(coalesce_window_s)
        self.inflight_depth = int(inflight_depth)
        self.sync_resolve = bool(sync_resolve)
        self.metrics = metrics or MetricsRegistry()
        self.tracer = tracer or Tracer()
        m = self.metrics
        self._m_requests = m.counter(
            "serving_requests", "Generation requests resolved",
            ("sampler", "tenant"))
        self._m_rows = m.counter(
            "serving_rows", "Rows generated and delivered",
            ("sampler", "tenant"))
        self._h_queue_wait = m.histogram(
            "serving_queue_wait_seconds",
            "Per-request wait from submit to batch dispatch "
            "(serve.queue span durations)", ("sampler", "tenant"))
        self._h_device = m.histogram(
            "serving_device_seconds",
            "Per-batch device time from dispatch to resolution "
            "(serve.device span durations); count = batches", ("sampler",))
        self._m_coalesced = m.counter(
            "serving_coalesced_requests",
            "Requests that rode a batch they did not open")
        self._m_dropped = m.counter(
            "serving_dropped_deadline",
            "Requests dropped before dispatch: queued past their deadline")
        self._m_warm = m.counter(
            "serving_warmup_seconds", "Wall time spent in sampler warmup")
        self._m_inflight = m.gauge(
            "serving_inflight", "Dispatched-but-unresolved batches now")
        self._m_inflight_max = m.gauge(
            "serving_inflight_max",
            "High-watermark of concurrently in-flight batches")
        # SLO layer: objectives come from flags / module constants, never
        # from benchmark cfg dicts (record identity must not change)
        if slo_error_budget <= 0:
            raise ValueError(
                f"slo_error_budget={slo_error_budget} must be > 0")
        self.slo = {str(k): float(v) for k, v in (slo or {}).items()}
        self.slo_error_budget = float(slo_error_budget)
        self.slow_log = slow_log
        self._g_slo_objective = m.gauge(
            "serving_slo_objective_seconds",
            "Configured per-priority latency objective", ("priority",))
        self._m_slo_requests = m.counter(
            "serving_slo_requests",
            "Requests measured against a latency objective", ("priority",))
        self._m_slo_violations = m.counter(
            "serving_slo_violations",
            "Requests over their priority's latency objective "
            "(deadline drops included)", ("priority",))
        for prio, objective in self.slo.items():
            if objective <= 0:
                raise ValueError(
                    f"slo[{prio!r}]={objective} must be > 0 seconds")
            self._g_slo_objective.set(objective, priority=prio)
        self._seed_lock = threading.Lock()
        self._batch_seed = 0
        self._inflight_q: "queue.Queue" = queue.Queue(maxsize=self.inflight_depth)
        self._scheduler_t: Optional[threading.Thread] = None
        self._waiter_t: Optional[threading.Thread] = None
        self._lifecycle_lock = threading.Lock()

    # -- public API ----------------------------------------------------------

    def submit(self, n: int, *, model: str = "default",
               sampler: Optional[str] = None, tenant: str = "default",
               priority: str = "interactive",
               deadline_s: Optional[float] = None,
               request_id: Optional[str] = None) -> Future:
        """Queue a generation request; resolves to ``(X, y)``.

        Validation is eager: an unknown model raises
        :class:`~repro.serving.registry.UnknownModel` and a sampler the
        model doesn't serve raises :class:`ValueError` here, to the caller —
        never inside the dispatcher after a wasted dispatch attempt.
        Admission rejections (:class:`RateLimited` / :class:`QueueFull`)
        also raise here: explicit backpressure, not unbounded queueing.
        ``deadline_s`` is a *relative* SLO; a request still queued when it
        lapses fails with :class:`DeadlineExceeded` before dispatch.

        ``request_id`` is the trace identity (minted here when the caller
        doesn't bring one, e.g. from an ingress header); it is stamped on
        the returned future (``future.request_id``) and indexes the
        request's timeline under ``tracer.trace(request_id)``.
        """
        handle = self.registry.peek(model)
        name = sampler or handle.samplers[0]
        if name not in handle.samplers:
            raise ValueError(
                f"model {model!r} does not serve sampler {name!r}; "
                f"served: {list(handle.samplers)}")
        rid = request_id or uuid.uuid4().hex[:16]
        # one clock reading drives the span start AND the absolute
        # deadline: deriving the deadline from a tracer-owned timestamp
        # coupled SLO arithmetic to tracer internals (and skewed if a
        # tracer subclass adjusted t_start)
        now = time.monotonic()
        span = self.tracer.start(
            "serve.queue", trace_id=rid, t_start=now,
            model=model, sampler=name, tenant=tenant,
            priority=priority, rows=int(n))
        req = Request(int(n), name, Future(), model=model, tenant=tenant,
                      priority=priority, enqueued_s=now,
                      deadline_s=None if deadline_s is None
                      else now + float(deadline_s),
                      span=span, request_id=rid)
        req.future.request_id = rid
        # enqueue under the lifecycle lock: a submit racing with stop()
        # could otherwise land behind the close with no threads left to
        # serve it — the lock serialises the two, so the request either
        # precedes the drain or gets fresh threads
        with self._lifecycle_lock:
            self._start_locked()
            t0 = time.monotonic()
            try:
                self.admission.offer(req)
            except BaseException:
                span.end(outcome="rejected")
                raise
            span.attrs["admission_s"] = time.monotonic() - t0
        return req.future

    def start(self) -> None:
        with self._lifecycle_lock:
            self._start_locked()

    def stop(self, timeout: float = 10.0) -> None:
        """Drain admitted requests, then stop both threads."""
        with self._lifecycle_lock:
            if self._scheduler_t is None:
                return
            self.admission.close()
            self._scheduler_t.join(timeout)
            if self._waiter_t is not None:
                self._waiter_t.join(timeout)
            self._scheduler_t = None
            self._waiter_t = None

    def rows_per_sec(self) -> float:
        with self.metrics.lock:
            return self._m_rows.sum() / max(self._h_device.sum(), 1e-9)

    @property
    def stats(self) -> dict:
        """The PR-4 dict surface, now a *view* over the metrics registry
        (``server.stats["rows"]`` keeps working; see ``stats_snapshot``)."""
        return self.stats_snapshot()

    def stats_snapshot(self) -> dict:
        """Legacy-shaped stats dict folded from the metrics registry.

        Same keys as the PR-6 hand-maintained dict (``requests``, ``rows``,
        ``gen_s``, ``warm_s``, ``batches``, ``coalesced_requests``,
        ``queue_wait_s``, ``device_s``, ``dropped_deadline``,
        ``max_inflight_observed``, ``per_sampler``, ``per_tenant``,
        ``inflight``) — but every number is derived from the same
        instruments ``GET /metrics`` exports, so the two surfaces cannot
        disagree.  The fold runs under the registry lock: one consistent
        cut.  New since PR 10: an ``slo`` key (``{}`` when no objectives
        are configured) mapping each priority to its objective, measured
        request / violation counts, violation rate, and error-budget burn
        (violation rate over the allowed budget; > 1.0 means the budget
        is being spent faster than allotted).
        """
        with self.metrics.lock:
            req = self._m_requests.series()      # (sampler, tenant) -> n
            rows = self._m_rows.series()
            qw = self._h_queue_wait.series()     # (sampler, tenant) -> hist
            dev = self._h_device.series()        # (sampler,) -> hist
            coalesced = self._m_coalesced.get()
            dropped = self._m_dropped.get()
            warm = self._m_warm.get()
            inflight = self._m_inflight.get()
            inflight_max = self._m_inflight_max.get()
            slo_req = self._m_slo_requests.series()      # (priority,) -> n
            slo_viol = self._m_slo_violations.series()
        slo = {}
        for prio, objective in sorted(self.slo.items()):
            n = int(slo_req.get((prio,), 0))
            v = int(slo_viol.get((prio,), 0))
            rate = v / n if n else 0.0
            slo[prio] = {
                "objective_s": objective,
                "requests": n,
                "violations": v,
                "violation_rate": rate,
                "error_budget": self.slo_error_budget,
                "budget_burn": rate / self.slo_error_budget,
            }
        per_sampler = {}
        for s in sorted({k[0] for k in req} | {k[0] for k in dev}):
            d = dev.get((s,), _EMPTY_HIST)
            per_sampler[s] = {
                "requests": int(sum(v for k, v in req.items() if k[0] == s)),
                "rows": int(sum(v for k, v in rows.items() if k[0] == s)),
                "batches": int(d["count"]),
                "queue_wait_s": sum(h["sum"] for k, h in qw.items()
                                    if k[0] == s),
                "device_s": d["sum"],
            }
        per_tenant = {}
        for t in sorted({k[1] for k in req}):
            per_tenant[t] = {
                "requests": int(sum(v for k, v in req.items() if k[1] == t)),
                "rows": int(sum(v for k, v in rows.items() if k[1] == t)),
                "queue_wait_s": sum(h["sum"] for k, h in qw.items()
                                    if k[1] == t),
            }
        device_s = sum(h["sum"] for h in dev.values())
        return {
            "requests": int(sum(req.values())),
            "rows": int(sum(rows.values())),
            "gen_s": device_s,
            "warm_s": warm,
            "batches": int(sum(h["count"] for h in dev.values())),
            "coalesced_requests": int(coalesced),
            "queue_wait_s": sum(h["sum"] for h in qw.values()),
            "device_s": device_s,
            "dropped_deadline": int(dropped),
            "max_inflight_observed": int(inflight_max),
            "per_sampler": per_sampler,
            "per_tenant": per_tenant,
            "inflight": int(inflight),
            "slo": slo,
        }

    # -- bookkeeping shared with the synchronous server path -----------------

    def record_warm(self, wall_s: float) -> None:
        self._m_warm.inc(wall_s)

    def record_sync(self, *, n: int, sampler: str, tenant: str,
                    wall_s: float) -> None:
        """Account a synchronous ``generate()`` served outside the queue
        (one request = one batch, zero queue wait)."""
        with self.metrics.lock:
            self._m_requests.inc(1, sampler=sampler, tenant=tenant)
            self._m_rows.inc(n, sampler=sampler, tenant=tenant)
            self._h_queue_wait.observe(0.0, sampler=sampler, tenant=tenant)
            self._h_device.observe(wall_s, sampler=sampler)

    # -- threads -------------------------------------------------------------

    def _start_locked(self) -> None:
        if self._scheduler_t is None or not self._scheduler_t.is_alive():
            self.admission.reopen()
            self._scheduler_t = threading.Thread(
                target=self._scheduler_loop, name="serving-scheduler",
                daemon=True)
            self._scheduler_t.start()
        if not self.sync_resolve and (
                self._waiter_t is None or not self._waiter_t.is_alive()):
            self._waiter_t = threading.Thread(
                target=self._waiter_loop, name="serving-waiter", daemon=True)
            self._waiter_t.start()

    def _expired(self, req: Request, now: Optional[float] = None) -> bool:
        """Drop a deadline-lapsed request before dispatch; True if dropped."""
        if req.deadline_s is None:
            return False
        now = time.monotonic() if now is None else now
        if now <= req.deadline_s:
            return False
        if req.future.set_running_or_notify_cancel():
            req.future.set_exception(DeadlineExceeded(
                f"deadline lapsed {now - req.deadline_s:.3f}s ago while "
                "queued"))
        if req.span is not None:
            req.span.end(outcome="deadline")
        self._m_dropped.inc()
        # a deadline drop is the worst latency outcome there is: it burns
        # error budget even though no latency was ever measured
        if req.priority in self.slo:
            self._m_slo_requests.inc(1, priority=req.priority)
            self._m_slo_violations.inc(1, priority=req.priority)
        return True

    def _scheduler_loop(self) -> None:
        while True:
            req = self.admission.pop(timeout=0.1)
            if req is CLOSED:
                if not self.sync_resolve:
                    self._inflight_q.put(_SHUTDOWN)
                return
            if req is None or self._expired(req):
                continue
            batch, rows = [req], req.n
            deadline = time.monotonic() + self.coalesce_window_s
            while rows < self.max_coalesce_rows:
                left = deadline - time.monotonic()
                if left <= 0:
                    break
                nxt = self.admission.pop_matching(
                    req.model, req.sampler, self.max_coalesce_rows - rows,
                    timeout=left)
                if nxt is None:
                    break
                if self._expired(nxt):
                    continue
                batch.append(nxt)
                rows += nxt.n
            inflight = self._dispatch(batch)
            if inflight is None:
                continue
            if self.sync_resolve:
                # PR-4 drain-then-serve semantics (benchmark reference arm):
                # the scheduler blocks until the batch resolves, so nothing
                # overlaps device time
                self._resolve(inflight)
            else:
                self._inflight_q.put(inflight)  # bounded: dispatch backpressure

    def _waiter_loop(self) -> None:
        while True:
            item = self._inflight_q.get()
            if item is _SHUTDOWN:
                return
            self._resolve(item)

    # -- batch mechanics -----------------------------------------------------

    def _dispatch(self, batch: List[Request]) -> Optional[_Inflight]:
        """Claim futures, snapshot the model, enqueue one device program.
        Returns the in-flight record (or None if nothing survived)."""
        # claim each future first: a client that cancelled while queued is
        # dropped here — set_result on a cancelled Future raises and would
        # otherwise kill the scheduler thread, stranding the whole batch
        claimed = []
        for r in batch:
            if r.future.set_running_or_notify_cancel():
                claimed.append(r)
            elif r.span is not None:
                r.span.end(outcome="cancelled")
        batch = claimed
        if not batch:
            return None
        total = sum(r.n for r in batch)
        with self._seed_lock:
            batch_id = self._batch_seed
            seed = BATCH_SEED_BASE + batch_id
            self._batch_seed += 1
        # the device span opens *before* placement: acquire() may promote a
        # cold model, and that cost belongs to device time (as it did when
        # this was a hand-stamped t0).  It *links* every request id it
        # serves: the coalesced batch belongs to N traces at once.
        trace_ids = tuple(r.request_id for r in batch if r.request_id)
        dspan = self.tracer.start(
            "serve.device", links=trace_ids,
            model=batch[0].model, sampler=batch[0].sampler,
            rows=total, requests=len(batch), batch_id=batch_id)
        for r in batch:
            if r.span is not None:
                r.span.end(batch_id=batch_id)   # queue wait: submit -> claim
        try:
            handle = self.registry.acquire(batch[0].model)
            sample = handle.generate_async(total, batch[0].sampler, seed=seed)
        except BaseException as exc:  # noqa: BLE001 — delivered via futures
            dspan.end(outcome="error")
            for r in batch:
                r.future.set_exception(exc)
            return None
        # fakes in the control-plane tests return bare handles: tag() is
        # best-effort context for downstream tooling, not a contract
        tag = getattr(sample, "tag", None)
        if tag is not None:
            tag(batch_id=batch_id, trace_ids=trace_ids)
        v = self._m_inflight.inc(1)
        self._m_inflight_max.set_max(v)
        return _Inflight(handle, sample, batch, total, dspan)

    def _resolve(self, inflight: _Inflight) -> None:
        """Block on the device values, deliver per-request slices, account
        queue-wait vs device-time from the batch's spans."""
        batch = inflight.batch
        t_sync = time.monotonic()
        try:
            X, y = inflight.sample.result()
        except BaseException as exc:  # noqa: BLE001 — delivered via futures
            inflight.span.end(outcome="error")
            for r in batch:
                r.future.set_exception(exc)
            self._m_inflight.dec(1)
            return
        dt = inflight.span.end(sync_s=time.monotonic() - t_sync,
                               outcome="ok")
        off = 0
        for r in batch:
            r.future.set_result((X[off:off + r.n], y[off:off + r.n]))
            off += r.n
        now = time.monotonic()
        sampler = batch[0].sampler
        with self.metrics.lock:
            self._m_inflight.dec(1)
            self._h_device.observe(dt, sampler=sampler)
            self._m_coalesced.inc(len(batch) - 1)
            for r in batch:
                self._m_requests.inc(1, sampler=sampler, tenant=r.tenant)
                self._m_rows.inc(r.n, sampler=sampler, tenant=r.tenant)
                wait = (r.span.duration_s if r.span is not None
                        else inflight.span.t_start - r.enqueued_s)
                self._h_queue_wait.observe(wait, sampler=sampler,
                                           tenant=r.tenant)
                if r.priority in self.slo:
                    self._m_slo_requests.inc(1, priority=r.priority)
                    if now - r.enqueued_s > self.slo[r.priority]:
                        self._m_slo_violations.inc(1, priority=r.priority)
        # slow-log writes after delivery, outside the metrics lock: file
        # I/O must never serialise the accounting hot path
        if self.slow_log is not None:
            for r in batch:
                lat = now - r.enqueued_s
                if lat <= self.slow_log.threshold_s:
                    continue
                spans = [r.span.to_dict()] if r.span is not None else []
                spans.append(inflight.span.to_dict())
                self.slow_log.record({
                    "request_id": r.request_id,
                    "latency_s": lat,
                    "model": r.model,
                    "sampler": sampler,
                    "tenant": r.tenant,
                    "priority": r.priority,
                    "rows": r.n,
                    "batch_id": inflight.span.attrs.get("batch_id"),
                    "spans": spans,
                })

    def serve_batch_sync(self, batch: List[Request]) -> None:
        """Dispatch + resolve one pre-formed batch on the calling thread —
        the test seam (and the drain arm's inner step)."""
        inflight = self._dispatch(batch)
        if inflight is not None:
            self._resolve(inflight)
