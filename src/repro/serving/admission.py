"""Admission control: priority/SLO buckets, per-tenant token buckets,
bounded queues, explicit backpressure.

The PR-4 server queued everything it was handed — under overload the queue
(and every caller's latency) grew without bound. Here the front door is
explicit:

* two priority classes, ``interactive`` and ``bulk`` (``PRIORITIES``); the
  scheduler always drains interactive first, so bulk traffic can saturate
  the device without moving the interactive tail;
* per-tenant token buckets metered in *rows* (the unit of device work, not
  requests — one 4096-row bulk call costs what 64 interactive 64-row calls
  cost); a tenant over its rate gets :class:`RateLimited` with a concrete
  ``retry_after_s`` instead of a slot in a queue it will time out of;
* per-priority bounded queues — a full queue raises :class:`QueueFull`
  (reject-with-retry-after, the open-loop-load answer to unbounded
  buffering);
* per-request deadlines: the scheduler drops a request whose deadline
  passed *before* spending device time on it and fails its future with
  :class:`DeadlineExceeded`.

``offer``/``pop``/``pop_matching`` are the scheduler-facing queue API; the
batch former uses ``pop_matching`` to coalesce same-(model, sampler)
requests across both priority classes while leaving everything else queued.

Per-tenant accounting lives in :mod:`repro.obs` instruments
(``admission_requests_total{tenant,outcome}`` etc.) rather than a bare
dict: the PR-6 implementation grew per-tenant stats via a ``setdefault``
helper whose lock discipline was implicit in "every caller happens to hold
``_cond``" — exactly the pattern jaxlint's TH001 now flags (see
``tests/test_jaxlint.py``).  Instruments are internally lock-guarded, the
queue-depth gauge is updated under ``_cond`` alongside the deques it
mirrors, and ``stats_snapshot()`` keeps its dict shape as a fold over the
registry.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, Optional, Tuple

from repro.obs import MetricsRegistry

PRIORITIES = ("interactive", "bulk")

#: ``pop()`` returns this once the controller is closed *and* drained —
#: requests accepted before ``close()`` are always served first.
CLOSED = object()

_OUTCOMES = ("admitted", "rejected_rate", "rejected_queue")


class AdmissionError(RuntimeError):
    """Rejected at the door. ``retry_after_s`` tells a well-behaved caller
    when to come back (the HTTP front end maps it to ``Retry-After``)."""

    def __init__(self, msg: str, retry_after_s: float):
        super().__init__(msg)
        self.retry_after_s = float(retry_after_s)


class RateLimited(AdmissionError):
    """Tenant token bucket empty."""


class QueueFull(AdmissionError):
    """Priority queue at its bound (or the server is shutting down)."""


class DeadlineExceeded(RuntimeError):
    """Request expired while queued; dropped before dispatch."""


class TokenBucket:
    """Rows/sec token bucket with lazy monotonic-clock refill.

    Not thread-safe on its own — the controller serialises access under its
    condition lock.
    """

    def __init__(self, rate_rows_per_s: float, burst_rows: float):
        self.rate = float(rate_rows_per_s)
        self.burst = float(burst_rows)
        self.tokens = self.burst
        self._last = None  # first take() starts the clock

    def take(self, rows: float, now: float) -> Optional[float]:
        """Consume ``rows`` tokens. Returns ``None`` when granted, else the
        seconds until enough tokens will have refilled (the request is NOT
        queued against future tokens — retry-after, not reservation)."""
        if self._last is None:
            self._last = now
        self.tokens = min(self.burst, self.tokens + (now - self._last) * self.rate)
        self._last = now
        if rows <= self.tokens:
            self.tokens -= rows
            return None
        deficit = rows - self.tokens
        return deficit / max(self.rate, 1e-9)


class AdmissionController:
    """The scheduler's front door: rate-limit, bound, and order requests.

    ``tenant_rates`` maps tenant name -> ``(rate_rows_per_s, burst_rows)``;
    ``default_rate`` (same tuple) applies to tenants without an explicit
    entry, ``None`` meaning unmetered. ``queue_limits`` bounds the number of
    queued requests per priority class.  ``metrics`` shares a
    :class:`~repro.obs.MetricsRegistry` with the other serving components
    (default: a private registry, so tests never share counters).
    """

    DEFAULT_QUEUE_LIMITS = {"interactive": 256, "bulk": 1024}

    def __init__(self, *, queue_limits: Optional[Dict[str, int]] = None,
                 tenant_rates: Optional[Dict[str, Tuple[float, float]]] = None,
                 default_rate: Optional[Tuple[float, float]] = None,
                 clock=time.monotonic,
                 metrics: Optional[MetricsRegistry] = None):
        self.queue_limits = dict(self.DEFAULT_QUEUE_LIMITS)
        self.queue_limits.update(queue_limits or {})
        self._rates = dict(tenant_rates or {})
        self._default_rate = default_rate
        self._buckets: Dict[str, TokenBucket] = {}
        self._clock = clock
        self._cond = threading.Condition()
        self._queues = {p: deque() for p in PRIORITIES}
        self._closed = False
        self.metrics = metrics or MetricsRegistry()
        self._m_requests = self.metrics.counter(
            "admission_requests", "Admission decisions by tenant and "
            "outcome (admitted / rejected_rate / rejected_queue)",
            ("tenant", "outcome"))
        self._m_rows = self.metrics.counter(
            "admission_rows", "Rows admitted past the front door",
            ("tenant",))
        self._m_queued = self.metrics.gauge(
            "admission_queued", "Requests waiting per priority class",
            ("priority",))
        self._m_queue_limit = self.metrics.gauge(
            "admission_queue_limit", "Configured queue bound per priority "
            "class", ("priority",))
        for p in PRIORITIES:
            self._m_queued.set(0, priority=p)
            self._m_queue_limit.set(self.queue_limits[p], priority=p)

    # -- tenant accounting ---------------------------------------------------

    def _bucket_for_locked(self, tenant: str) -> Optional[TokenBucket]:
        """Caller holds ``_cond`` (buckets are mutated lazily here)."""
        if tenant in self._buckets:
            return self._buckets[tenant]
        spec = self._rates.get(tenant, self._default_rate)
        if spec is None:
            return None
        bucket = TokenBucket(*spec)
        self._buckets[tenant] = bucket
        return bucket

    def charge(self, tenant: str, rows: int) -> None:
        """Meter ``rows`` against ``tenant``'s bucket without queueing —
        the unbatched paths (HTTP ``/v1/impute``) pay for device time too."""
        with self._cond:
            bucket = self._bucket_for_locked(tenant)
            if bucket is not None:
                retry = bucket.take(rows, self._clock())
                if retry is not None:
                    self._m_requests.inc(1, tenant=tenant,
                                         outcome="rejected_rate")
                    raise RateLimited(
                        f"tenant {tenant!r} over its row rate", retry)
            self._m_requests.inc(1, tenant=tenant, outcome="admitted")
            self._m_rows.inc(rows, tenant=tenant)

    # -- queue API (scheduler-facing) ----------------------------------------

    def offer(self, req) -> None:
        """Admit or reject ``req`` (a scheduler Request). Raises
        :class:`RateLimited` / :class:`QueueFull`; on success the request is
        queued and the scheduler woken."""
        if req.priority not in PRIORITIES:
            raise ValueError(f"priority={req.priority!r}: "
                             f"expected one of {PRIORITIES}")
        with self._cond:
            if self._closed:
                raise QueueFull("server is shutting down", 1.0)
            bucket = self._bucket_for_locked(req.tenant)
            if bucket is not None:
                retry = bucket.take(req.n, self._clock())
                if retry is not None:
                    self._m_requests.inc(1, tenant=req.tenant,
                                         outcome="rejected_rate")
                    raise RateLimited(
                        f"tenant {req.tenant!r} over its row rate "
                        f"({req.n} rows)", retry)
            q = self._queues[req.priority]
            limit = self.queue_limits[req.priority]
            if len(q) >= limit:
                self._m_requests.inc(1, tenant=req.tenant,
                                     outcome="rejected_queue")
                # no reservation to base an estimate on; one dispatch
                # window is the cheapest honest hint
                raise QueueFull(
                    f"{req.priority} queue at its bound ({limit})", 0.05)
            self._m_requests.inc(1, tenant=req.tenant, outcome="admitted")
            self._m_rows.inc(req.n, tenant=req.tenant)
            q.append(req)
            span = getattr(req, "span", None)
            if span is not None:
                # depth *seen at admit* (self included) — the per-request
                # trace shows how deep the line was when this request joined
                span.attrs["queue_depth"] = len(q)
            self._m_queued.set(len(q), priority=req.priority)
            self._cond.notify()

    def pop(self, timeout: Optional[float] = None):
        """Highest-priority queued request; ``CLOSED`` once closed and
        drained; ``None`` on timeout."""
        deadline = None if timeout is None else self._clock() + timeout
        with self._cond:
            while True:
                for p in PRIORITIES:
                    if self._queues[p]:
                        req = self._queues[p].popleft()
                        self._m_queued.set(len(self._queues[p]), priority=p)
                        return req
                if self._closed:
                    return CLOSED
                left = (None if deadline is None
                        else deadline - self._clock())
                if left is not None and left <= 0:
                    return None
                self._cond.wait(left)

    def pop_matching(self, model: str, sampler: str, max_rows: int,
                     timeout: float = 0.0):
        """First queued request for the same (model, sampler) whose row
        count fits ``max_rows`` — scanning interactive before bulk, leaving
        everything else queued. Blocks up to ``timeout`` for one to arrive;
        ``None`` when the window closes empty-handed."""
        deadline = self._clock() + timeout
        with self._cond:
            while True:
                for p in PRIORITIES:
                    q = self._queues[p]
                    for i, r in enumerate(q):
                        if (r.model == model and r.sampler == sampler
                                and r.n <= max_rows):
                            del q[i]
                            self._m_queued.set(len(q), priority=p)
                            return r
                if self._closed:
                    return None
                left = deadline - self._clock()
                if left <= 0:
                    return None
                self._cond.wait(left)

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        """Stop admitting; already-queued requests still drain via pop()."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def reopen(self) -> None:
        with self._cond:
            self._closed = False

    def queued(self) -> Dict[str, int]:
        with self._cond:
            return {p: len(q) for p, q in self._queues.items()}

    # -- read side -----------------------------------------------------------

    @property
    def stats(self) -> Dict[str, dict]:
        """Per-tenant counters, PR-6 dict shape — a read-only view folded
        from the metrics registry (the mutable dict it replaces was the
        TH001 lock-discipline bug this PR fixed)."""
        return self._tenants_view()

    def _tenants_view(self) -> Dict[str, dict]:
        with self.metrics.lock:
            req = self._m_requests.series()   # (tenant, outcome) -> n
            rows = self._m_rows.series()      # (tenant,) -> n
        tenants = {t for t, _ in req} | {t for (t,) in rows}
        return {
            t: {
                "admitted": int(req.get((t, "admitted"), 0)),
                "rows": int(rows.get((t,), 0)),
                "rejected_rate": int(req.get((t, "rejected_rate"), 0)),
                "rejected_queue": int(req.get((t, "rejected_queue"), 0)),
            }
            for t in sorted(tenants)
        }

    def stats_snapshot(self) -> dict:
        """PR-6 shape (``queued`` / ``queue_limits`` / ``tenants``), folded
        from the same instruments ``GET /metrics`` exports."""
        with self._cond:
            queued = {p: len(q) for p, q in self._queues.items()}
        return {"queued": queued,
                "queue_limits": dict(self.queue_limits),
                "tenants": self._tenants_view()}
