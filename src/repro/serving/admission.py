"""Admission control: priority/SLO buckets, per-tenant token buckets,
bounded queues, explicit backpressure.

The PR-4 server queued everything it was handed — under overload the queue
(and every caller's latency) grew without bound. Here the front door is
explicit:

* two priority classes, ``interactive`` and ``bulk`` (``PRIORITIES``); the
  scheduler always drains interactive first, so bulk traffic can saturate
  the device without moving the interactive tail;
* per-tenant token buckets metered in *rows* (the unit of device work, not
  requests — one 4096-row bulk call costs what 64 interactive 64-row calls
  cost); a tenant over its rate gets :class:`RateLimited` with a concrete
  ``retry_after_s`` instead of a slot in a queue it will time out of;
* per-priority bounded queues — a full queue raises :class:`QueueFull`
  (reject-with-retry-after, the open-loop-load answer to unbounded
  buffering);
* per-request deadlines: the scheduler drops a request whose deadline
  passed *before* spending device time on it and fails its future with
  :class:`DeadlineExceeded`.

``offer``/``pop``/``pop_matching`` are the scheduler-facing queue API; the
batch former uses ``pop_matching`` to coalesce same-(model, sampler)
requests across both priority classes while leaving everything else queued.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, Optional, Tuple

PRIORITIES = ("interactive", "bulk")

#: ``pop()`` returns this once the controller is closed *and* drained —
#: requests accepted before ``close()`` are always served first.
CLOSED = object()


class AdmissionError(RuntimeError):
    """Rejected at the door. ``retry_after_s`` tells a well-behaved caller
    when to come back (the HTTP front end maps it to ``Retry-After``)."""

    def __init__(self, msg: str, retry_after_s: float):
        super().__init__(msg)
        self.retry_after_s = float(retry_after_s)


class RateLimited(AdmissionError):
    """Tenant token bucket empty."""


class QueueFull(AdmissionError):
    """Priority queue at its bound (or the server is shutting down)."""


class DeadlineExceeded(RuntimeError):
    """Request expired while queued; dropped before dispatch."""


class TokenBucket:
    """Rows/sec token bucket with lazy monotonic-clock refill.

    Not thread-safe on its own — the controller serialises access under its
    condition lock.
    """

    def __init__(self, rate_rows_per_s: float, burst_rows: float):
        self.rate = float(rate_rows_per_s)
        self.burst = float(burst_rows)
        self.tokens = self.burst
        self._last = None  # first take() starts the clock

    def take(self, rows: float, now: float) -> Optional[float]:
        """Consume ``rows`` tokens. Returns ``None`` when granted, else the
        seconds until enough tokens will have refilled (the request is NOT
        queued against future tokens — retry-after, not reservation)."""
        if self._last is None:
            self._last = now
        self.tokens = min(self.burst, self.tokens + (now - self._last) * self.rate)
        self._last = now
        if rows <= self.tokens:
            self.tokens -= rows
            return None
        deficit = rows - self.tokens
        return deficit / max(self.rate, 1e-9)


class AdmissionController:
    """The scheduler's front door: rate-limit, bound, and order requests.

    ``tenant_rates`` maps tenant name -> ``(rate_rows_per_s, burst_rows)``;
    ``default_rate`` (same tuple) applies to tenants without an explicit
    entry, ``None`` meaning unmetered. ``queue_limits`` bounds the number of
    queued requests per priority class.
    """

    DEFAULT_QUEUE_LIMITS = {"interactive": 256, "bulk": 1024}

    def __init__(self, *, queue_limits: Optional[Dict[str, int]] = None,
                 tenant_rates: Optional[Dict[str, Tuple[float, float]]] = None,
                 default_rate: Optional[Tuple[float, float]] = None,
                 clock=time.monotonic):
        self.queue_limits = dict(self.DEFAULT_QUEUE_LIMITS)
        self.queue_limits.update(queue_limits or {})
        self._rates = dict(tenant_rates or {})
        self._default_rate = default_rate
        self._buckets: Dict[str, TokenBucket] = {}
        self._clock = clock
        self._cond = threading.Condition()
        self._queues = {p: deque() for p in PRIORITIES}
        self._closed = False
        self.stats: Dict[str, dict] = {}  # per-tenant counters

    # -- tenant accounting ---------------------------------------------------

    def _tenant_stats(self, tenant: str) -> dict:
        return self.stats.setdefault(tenant, {
            "admitted": 0, "rows": 0, "rejected_rate": 0,
            "rejected_queue": 0})

    def _bucket_for(self, tenant: str) -> Optional[TokenBucket]:
        if tenant in self._buckets:
            return self._buckets[tenant]
        spec = self._rates.get(tenant, self._default_rate)
        if spec is None:
            return None
        bucket = TokenBucket(*spec)
        self._buckets[tenant] = bucket
        return bucket

    def charge(self, tenant: str, rows: int) -> None:
        """Meter ``rows`` against ``tenant``'s bucket without queueing —
        the unbatched paths (HTTP ``/v1/impute``) pay for device time too."""
        with self._cond:
            bucket = self._bucket_for(tenant)
            if bucket is not None:
                retry = bucket.take(rows, self._clock())
                if retry is not None:
                    self._tenant_stats(tenant)["rejected_rate"] += 1
                    raise RateLimited(
                        f"tenant {tenant!r} over its row rate", retry)
            st = self._tenant_stats(tenant)
            st["admitted"] += 1
            st["rows"] += rows

    # -- queue API (scheduler-facing) ----------------------------------------

    def offer(self, req) -> None:
        """Admit or reject ``req`` (a scheduler Request). Raises
        :class:`RateLimited` / :class:`QueueFull`; on success the request is
        queued and the scheduler woken."""
        if req.priority not in PRIORITIES:
            raise ValueError(f"priority={req.priority!r}: "
                             f"expected one of {PRIORITIES}")
        with self._cond:
            if self._closed:
                raise QueueFull("server is shutting down", 1.0)
            bucket = self._bucket_for(req.tenant)
            if bucket is not None:
                retry = bucket.take(req.n, self._clock())
                if retry is not None:
                    self._tenant_stats(req.tenant)["rejected_rate"] += 1
                    raise RateLimited(
                        f"tenant {req.tenant!r} over its row rate "
                        f"({req.n} rows)", retry)
            q = self._queues[req.priority]
            limit = self.queue_limits[req.priority]
            if len(q) >= limit:
                self._tenant_stats(req.tenant)["rejected_queue"] += 1
                # no reservation to base an estimate on; one dispatch
                # window is the cheapest honest hint
                raise QueueFull(
                    f"{req.priority} queue at its bound ({limit})", 0.05)
            st = self._tenant_stats(req.tenant)
            st["admitted"] += 1
            st["rows"] += req.n
            q.append(req)
            self._cond.notify()

    def pop(self, timeout: Optional[float] = None):
        """Highest-priority queued request; ``CLOSED`` once closed and
        drained; ``None`` on timeout."""
        deadline = None if timeout is None else self._clock() + timeout
        with self._cond:
            while True:
                for p in PRIORITIES:
                    if self._queues[p]:
                        return self._queues[p].popleft()
                if self._closed:
                    return CLOSED
                left = (None if deadline is None
                        else deadline - self._clock())
                if left is not None and left <= 0:
                    return None
                self._cond.wait(left)

    def pop_matching(self, model: str, sampler: str, max_rows: int,
                     timeout: float = 0.0):
        """First queued request for the same (model, sampler) whose row
        count fits ``max_rows`` — scanning interactive before bulk, leaving
        everything else queued. Blocks up to ``timeout`` for one to arrive;
        ``None`` when the window closes empty-handed."""
        deadline = self._clock() + timeout
        with self._cond:
            while True:
                for p in PRIORITIES:
                    q = self._queues[p]
                    for i, r in enumerate(q):
                        if (r.model == model and r.sampler == sampler
                                and r.n <= max_rows):
                            del q[i]
                            return r
                if self._closed:
                    return None
                left = deadline - self._clock()
                if left <= 0:
                    return None
                self._cond.wait(left)

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        """Stop admitting; already-queued requests still drain via pop()."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def reopen(self) -> None:
        with self._cond:
            self._closed = False

    def queued(self) -> Dict[str, int]:
        with self._cond:
            return {p: len(q) for p, q in self._queues.items()}

    def stats_snapshot(self) -> dict:
        with self._cond:
            return {"queued": {p: len(q) for p, q in self._queues.items()},
                    "queue_limits": dict(self.queue_limits),
                    "tenants": {t: dict(s) for t, s in self.stats.items()}}
