"""ModelRegistry: many named :class:`ForestArtifacts` hot in one process.

The PR-4 server hosted exactly one model. Production tabular serving is
many-model by nature (per-detector-layer calorimeter ensembles, per-dataset
generators), so the registry keeps a name -> model table with:

* **LRU device placement under a byte budget** — "hot" models have their
  pytree leaves device-placed (``shard(mesh)`` when serving sharded, plain
  ``device_put`` otherwise); cold models keep host (numpy) leaves and cost
  no device memory. Promotion pays the one-time placement; when the hot set
  would exceed ``device_budget_bytes`` (or ``max_hot``), the
  least-recently-used hot models are demoted back to host.
* **Immutable dispatch snapshots** — ``acquire()`` returns a
  :class:`ModelHandle`, a frozen (artifacts, schema, samplers, version)
  view. A batch dispatched against a handle keeps that exact pytree alive
  until it resolves, whatever the registry does meanwhile.
* **Zero-downtime swap** — ``swap(name, artifacts)`` builds and places the
  new version first, then flips the table pointer under the lock. In-flight
  batches finish on the old pytree (their handle still references it);
  every later dispatch sees the new one. No request is ever dropped.

All jit caches key on array *shapes*, not identities, so a swapped-in model
with the same config reuses the old compiled programs — a swap costs one
device placement, zero recompiles.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.obs import MetricsRegistry
from repro.tabgen import TabularGenerator, default_sampler
from repro.tabgen.artifacts import _LEAF_FIELDS, ForestArtifacts
from repro.tabgen.sampling import resolve_mesh, sample_labels

DEFAULT_BUCKETS = (64, 256, 1024)


class UnknownModel(KeyError):
    """Request named a model the registry doesn't hold (HTTP: 404)."""


def _leaves_to_host(artifacts: ForestArtifacts) -> ForestArtifacts:
    """Demote: pytree leaves become numpy — no device memory held."""
    return dataclasses.replace(
        artifacts, **{f: np.asarray(getattr(artifacts, f))
                      for f in _LEAF_FIELDS})


def _leaves_to_device(artifacts: ForestArtifacts, mesh) -> ForestArtifacts:
    """Promote: one-time placement (the cost a cold model pays on first
    use). With a mesh this is the sharded serving placement."""
    if mesh is not None:
        return artifacts.shard(mesh)
    return dataclasses.replace(
        artifacts, **{f: jnp.asarray(getattr(artifacts, f))
                      for f in _LEAF_FIELDS})


def artifacts_nbytes(artifacts: ForestArtifacts) -> int:
    """Device footprint of one model = sum of its pytree leaves."""
    return int(sum(getattr(artifacts, f).nbytes for f in _LEAF_FIELDS))


class ModelHandle:
    """Immutable dispatch snapshot of one registered model version.

    Everything the scheduler needs for a batch: the facade (shared jit
    cache + schema decode), the served sampler set, and the bucket policy.
    Handles are never mutated — ``swap`` and promotion build new ones — so
    an in-flight batch's view of the model cannot change underneath it.
    """

    def __init__(self, name: str, artifacts: ForestArtifacts, *,
                 schema=None, samplers: Sequence[str] = (),
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 mesh=None, impl: Optional[str] = None, version: int = 1):
        cfg = artifacts.config
        self.name = name
        self.artifacts = artifacts
        self.schema = schema
        self.mesh = mesh
        self.impl = impl
        self.version = version
        self.samplers = tuple(samplers) or (
            default_sampler(cfg.method, cfg.diff_sampler),)
        self.buckets = tuple(sorted(buckets))
        self.nbytes = artifacts_nbytes(artifacts)
        # requests delegate to the facade so serving output can never
        # diverge from TabularGenerator's (schema decode, impute masking)
        self._gen = TabularGenerator(cfg, schema=schema)
        self._gen.artifacts = artifacts

    # -- dispatch ------------------------------------------------------------

    def bucket(self, n: int, seed: int) -> int:
        """Smallest bucket covering the largest per-class slice of an
        ``n``-row request. Exact: replays the (cheap, deterministic) label
        draw that ``sample`` will make for this (n, seed)."""
        rng = np.random.default_rng(seed)
        label_idx = sample_labels(np.asarray(self.artifacts.counts), n, rng,
                                  self.artifacts.config.label_sampler)
        worst = int(np.bincount(label_idx,
                                minlength=self.artifacts.n_y).max())
        for b in self.buckets:
            if b >= worst:
                return b
        return worst  # oversize request: exact (compiles once per size)

    def generate_async(self, n: int, sampler: str, *, seed: int,
                       pad_to: Optional[int] = None):
        """Non-blocking dispatch; the scheduler's waiter resolves it."""
        return self._gen.generate_async(
            n, sampler=sampler, seed=seed,
            pad_to=self.bucket(n, seed) if pad_to is None else pad_to,
            mesh=self.mesh, impl=self.impl)

    def generate(self, n: int, sampler: Optional[str] = None, *,
                 seed: int = 0, pad_to: Optional[int] = None):
        return self.generate_async(n, sampler or self.samplers[0],
                                   seed=seed, pad_to=pad_to).result()

    def impute(self, X_missing, y=None, *, seed: int = 0,
               refine_rounds: int = 3) -> np.ndarray:
        return self._gen.impute(X_missing, y, seed=seed,
                                refine_rounds=refine_rounds, impl=self.impl)

    def warmup(self) -> float:
        """Compile every (sampler, bucket) program; returns wall seconds."""
        t0 = time.time()
        total = int(np.asarray(self.artifacts.counts).sum())
        for name in self.samplers:
            for b in self.buckets:
                self.generate(max(min(b, total), 1), name, seed=0, pad_to=b)
        return time.time() - t0


@dataclasses.dataclass
class _Entry:
    handle: ModelHandle
    host_artifacts: ForestArtifacts   # canonical host copy (survives demote)
    hot: bool
    last_used: int


#: lifecycle events tracked per model in ``registry_model_events_total``
_EVENTS = ("acquires", "promotions", "demotions", "swaps")


class ModelRegistry:
    """Thread-safe name -> model table with LRU device placement.

    ``device_budget_bytes`` caps the summed pytree bytes of hot models
    (``None`` = unbounded); ``max_hot`` caps their count. ``mesh`` /
    ``impl`` / ``buckets`` are registry-wide serving defaults applied to
    every handle (a model registered into a sharded registry is placed via
    ``shard(mesh)`` on promotion).

    Promotion happens inside ``acquire`` under the registry lock — a cold
    model's first request pays the placement (and any LRU demotions) before
    dispatch, which is the explicit cost model: hot models never pay it.
    """

    def __init__(self, *, mesh=None, impl: Optional[str] = None,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 device_budget_bytes: Optional[int] = None,
                 max_hot: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.mesh = resolve_mesh(mesh)
        self.impl = impl
        self.buckets = tuple(sorted(buckets))
        self.device_budget_bytes = device_budget_bytes
        self.max_hot = max_hot
        self._lock = threading.RLock()
        self._entries: Dict[str, _Entry] = {}
        self._seq = 0
        self.metrics = metrics or MetricsRegistry()
        self._m_events = self.metrics.counter(
            "registry_model_events", "Model lifecycle events (acquires / "
            "promotions / demotions / swaps)", ("model", "event"))
        self._m_hot_bytes = self.metrics.gauge(
            "registry_hot_bytes", "Summed pytree bytes of device-placed "
            "(hot) models")
        self._m_hot_models = self.metrics.gauge(
            "registry_hot_models", "Models currently device-placed")
        self._m_models = self.metrics.gauge(
            "registry_models", "Models registered (hot or cold)")
        self._sync_gauges_locked()

    # -- internals (call with the lock held) ---------------------------------

    def _tick(self) -> int:
        self._seq += 1
        return self._seq

    def _hot_bytes(self) -> int:
        return sum(e.handle.nbytes for e in self._entries.values() if e.hot)

    def _hot_count(self) -> int:
        return sum(1 for e in self._entries.values() if e.hot)

    def _demote_lru(self, keep: str) -> None:
        """Demote least-recently-used hot entries until the budget holds.
        ``keep`` (the entry being promoted/registered) is never demoted —
        a model larger than the whole budget still gets to serve."""
        def over():
            if (self.device_budget_bytes is not None
                    and self._hot_bytes() > self.device_budget_bytes):
                return True
            return self.max_hot is not None and self._hot_count() > self.max_hot

        while over():
            victims = [(e.last_used, n) for n, e in self._entries.items()
                       if e.hot and n != keep]
            if not victims:
                break
            _, name = min(victims)
            entry = self._entries[name]
            entry.handle = self._build_handle(
                name, entry.host_artifacts, entry.handle, hot=False)
            entry.hot = False
            self._m_events.inc(1, model=name, event="demotions")

    def _sync_gauges_locked(self) -> None:
        """Mirror the hot set into gauges (caller holds the lock, so the
        gauges can never drift from the table they describe)."""
        self._m_hot_bytes.set(self._hot_bytes())
        self._m_hot_models.set(self._hot_count())
        self._m_models.set(len(self._entries))

    def _build_handle(self, name: str, host_artifacts: ForestArtifacts,
                      like: ModelHandle, *, hot: bool,
                      version: Optional[int] = None) -> ModelHandle:
        arts = (_leaves_to_device(host_artifacts, self.mesh) if hot
                else host_artifacts)
        return ModelHandle(
            name, arts, schema=like.schema, samplers=like.samplers,
            buckets=like.buckets, mesh=self.mesh, impl=self.impl,
            version=like.version if version is None else version)

    # -- public API ----------------------------------------------------------

    def register(self, name: str, artifacts: Optional[ForestArtifacts] = None,
                 *, path: Optional[str] = None, schema=None,
                 samplers: Sequence[str] = (),
                 buckets: Optional[Sequence[int]] = None,
                 hot: bool = True) -> ModelHandle:
        """Add (or replace) a model. ``path`` loads a saved
        ``TabularGenerator`` artifact pair (schema rides along); ``hot``
        places it on device immediately (evicting LRU models per budget),
        else it stays cold until first use."""
        if artifacts is None:
            if path is None:
                raise ValueError("register() needs artifacts or path=")
            gen = TabularGenerator.load(path)
            artifacts, schema = gen.artifacts, gen.schema
        host = _leaves_to_host(artifacts)
        seed_handle = ModelHandle(
            name, host, schema=schema, samplers=samplers,
            buckets=buckets or self.buckets, mesh=self.mesh, impl=self.impl)
        with self._lock:
            handle = self._build_handle(name, host, seed_handle, hot=hot)
            self._entries[name] = _Entry(
                handle=handle, host_artifacts=host, hot=hot,
                last_used=self._tick())
            # re-registering a name wipes its event counters (the legacy
            # per-entry stats dict was rebuilt here); scrapers see a
            # normal counter reset
            self._m_events.reset(model=name)
            if hot:
                self._demote_lru(keep=name)
            self._sync_gauges_locked()
            return handle

    def swap(self, name: str, artifacts: ForestArtifacts, *,
             schema=None, keep_schema: bool = True) -> ModelHandle:
        """Zero-downtime replace: the new version is built (and device-
        placed, when the entry is hot) *before* the table pointer flips, so
        there is no window where the name is unservable. In-flight batches
        hold the old handle and finish on the old pytree."""
        host = _leaves_to_host(artifacts)
        with self._lock:
            entry = self._entries.get(name)
            if entry is None:
                raise UnknownModel(name)
            old = entry.handle
            seed_handle = ModelHandle(
                name, host, schema=old.schema if keep_schema else schema,
                samplers=old.samplers, buckets=old.buckets,
                mesh=self.mesh, impl=self.impl)
            entry.handle = self._build_handle(
                name, host, seed_handle, hot=entry.hot,
                version=old.version + 1)
            entry.host_artifacts = host
            entry.last_used = self._tick()
            self._m_events.inc(1, model=name, event="swaps")
            if entry.hot:
                self._demote_lru(keep=name)
            self._sync_gauges_locked()
            return entry.handle

    def acquire(self, name: str) -> ModelHandle:
        """Dispatch-time lookup: promote if cold (LRU-evicting under the
        budget), bump recency, return the immutable handle."""
        with self._lock:
            entry = self._entries.get(name)
            if entry is None:
                raise UnknownModel(name)
            if not entry.hot:
                entry.handle = self._build_handle(
                    name, entry.host_artifacts, entry.handle, hot=True)
                entry.hot = True
                self._m_events.inc(1, model=name, event="promotions")
                self._demote_lru(keep=name)
                self._sync_gauges_locked()
            entry.last_used = self._tick()
            self._m_events.inc(1, model=name, event="acquires")
            return entry.handle

    def peek(self, name: str) -> ModelHandle:
        """Lookup without promotion or recency bump (request validation)."""
        with self._lock:
            entry = self._entries.get(name)
            if entry is None:
                raise UnknownModel(name)
            return entry.handle

    def warmup(self, name: Optional[str] = None) -> float:
        """Compile every (sampler, bucket) program for one model (or all)."""
        names = [name] if name is not None else self.names()
        return sum(self.acquire(n).warmup() for n in names)

    def names(self):
        with self._lock:
            return sorted(self._entries)

    def hot_names(self):
        with self._lock:
            return sorted(n for n, e in self._entries.items() if e.hot)

    def hot_bytes(self) -> int:
        """Device-placed model bytes right now (the ResourceMonitor's
        ``resource_hot_model_bytes`` source)."""
        with self._lock:
            return self._hot_bytes()

    def describe(self) -> dict:
        """Per-model status for ``/v1/models`` and ``/statz``.  Event
        counts are a view over ``registry_model_events_total`` — the same
        series ``GET /metrics`` exports."""
        with self._lock:
            events = self._m_events.series()   # (model, event) -> n
            return {
                name: {
                    "hot": e.hot,
                    "nbytes": e.handle.nbytes,
                    "version": e.handle.version,
                    "samplers": list(e.handle.samplers),
                    "buckets": list(e.handle.buckets),
                    "n_features": e.handle.artifacts.p,
                    "n_classes": e.handle.artifacts.n_y,
                    # data provenance (rows / store fingerprint+version at
                    # fit time, base round range) — how an operator spots a
                    # stale model-vs-store pairing before/after a swap
                    "lineage": e.host_artifacts.lineage,
                    **{ev: int(events.get((name, ev), 0))
                       for ev in _EVENTS},
                }
                for name, e in self._entries.items()}

    def stats_snapshot(self) -> dict:
        with self._lock:
            return {"models": self.describe(),
                    "hot_bytes": self._hot_bytes(),
                    "device_budget_bytes": self.device_budget_bytes,
                    "max_hot": self.max_hot}
