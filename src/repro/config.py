"""Configuration dataclasses for the repro framework.

Two families of configs live here:

* :class:`ArchConfig` — an LM-family transformer architecture (the assigned
  architecture pool) or the paper's own forest model (``caloforest``).
* :class:`ForestConfig` — hyperparameters of the ForestFlow / ForestDiffusion
  core (paper Table 9 rows map 1:1 onto fields here).

Configs are plain frozen dataclasses so they can be hashed into jit static
arguments and printed into experiment logs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """An LM-family architecture description.

    ``family`` selects the block assembly:
      - ``dense``: pre-LN GQA transformer (llama-style, SwiGLU)
      - ``moe``: dense attention + top-k routed experts (dbrx-style)
      - ``mla_moe``: MLA attention + shared/routed experts (deepseek-v2-style)
      - ``vlm``: dense backbone consuming stub patch embeddings + tokens
      - ``audio_encdec``: whisper-style encoder/decoder over stub frames
      - ``ssm``: xLSTM (mLSTM/sLSTM blocks)
      - ``hybrid``: recurrentgemma (RG-LRU blocks + interleaved local attention)
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_k_dense: int = 0       # deepseek: first layer(s) use a dense FFN
    d_ff_dense: int = 0          # width of that dense FFN
    # --- MLA (deepseek-v2) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0
    # --- recurrent / hybrid ---
    rnn_width: int = 0           # RG-LRU / xLSTM inner width
    attn_window: int = 0         # local attention window (hybrid)
    pattern: Tuple[str, ...] = ()  # repeating block pattern, e.g. ("rec","rec","attn")
    conv1d_width: int = 4        # temporal conv width in recurrent blocks
    # --- modality stubs ---
    n_patches: int = 0           # vlm: stub image patches prepended to the sequence
    # --- misc ---
    tie_embeddings: bool = False
    norm: str = "rmsnorm"        # or "layernorm"
    act: str = "swiglu"          # or "geglu", "gelu"
    rope_theta: float = 10000.0
    sub_quadratic: bool = False  # eligible for long_500k
    notes: str = ""

    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: seq_len x global_batch and which step lowers."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four LM shapes assigned to every architecture in the pool.
LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in LM_SHAPES}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell is runnable; reason if not.

    ``long_500k`` needs sub-quadratic attention: only SSM/hybrid archs qualify
    (see DESIGN.md section 4). Every arch in the pool has a decoder, so decode
    shapes always apply.
    """
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, (
            "skipped: full-attention arch; 524288-token KV/attention is "
            "quadratic (documented in DESIGN.md)"
        )
    return True, ""


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Trainer knobs shared across architectures."""

    dtype: str = "bfloat16"        # activation/compute dtype
    param_dtype: str = "float32"   # master params
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    remat_policy: str = "full"     # "full" | "dots" | "none"  (perf-iteration axis)
    scan_layers: bool = True
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ForestConfig:
    """ForestFlow / ForestDiffusion hyperparameters (paper Table 9)."""

    method: str = "flow"       # "flow" (CFM) | "diffusion" (VP-SDE score matching)
    n_t: int = 50              # timestep discretisation
    duplicate_k: int = 100     # K-fold duplication for expectation coverage
    n_trees: int = 100         # max boosting rounds per ensemble
    max_depth: int = 7
    learning_rate: float = 0.3  # eta
    reg_lambda: float = 0.0
    min_child_weight: float = 1e-6
    n_bins: int = 64           # histogram bins (XGBoost default max_bin=256; 64 keeps CPU tests fast)
    multi_output: bool = False  # MO trees (vector leaves) vs SO (per-feature ensembles)
    early_stop_rounds: int = 0  # 0 disables (paper n_ES=20 when enabled)
    sigma: float = 0.0          # CFM bridge noise
    eps_diff: float = 1e-3      # diffusion min time (paper epsilon)
    diff_sampler: str = "ddim"  # "ddim" (stable exp-integrator) | "em" (paper)
    per_class_scalers: bool = True
    label_sampler: str = "label"  # "label" (empirical) | "multinomial"
    t_schedule: str = "uniform"  # | "cosine" (denser near t=0; paper C.2's
                                 # suggested non-uniform partitioning)
    split_reduce: str = "allreduce"  # | "reduce_scatter" (feature-sharded)
    hist_bf16: bool = False     # bf16 histogram collective payload
    int8_codes: bool = False    # store bin codes at int8 (4x HBM reduction)
    predict_impl: Optional[str] = None  # tree-predict backend for generation:
                                 # "xla" | "pallas" | "pallas_interpret";
                                 # None defers to REPRO_TREE_PREDICT_IMPL
                                 # (resolved per sample/impute call)
    seed: int = 0
