"""Pure-jnp oracle for packed-forest inference."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def forest_predict_ref(x, feat, thr_val, leaf, depth: int):
    """x: [n, p]; feat/thr_val: [T, H]; leaf: [T, L, out]. Returns [n, out]."""

    def one_tree(acc, tr):
        f_h, t_h, l_h = tr
        node = jnp.zeros((x.shape[0],), jnp.int32)
        for level in range(depth):
            heap = node + (2 ** level - 1)
            f = f_h[heap]
            t = t_h[heap]
            c = jnp.take_along_axis(x, f[:, None], axis=1)[:, 0]
            node = node * 2 + (c > t).astype(jnp.int32)
        return acc + l_h[node], None

    acc0 = jnp.zeros((x.shape[0], leaf.shape[-1]), jnp.float32)
    acc, _ = jax.lax.scan(one_tree, acc0, (feat, thr_val, leaf))
    return acc
