"""Pallas TPU kernel: packed-forest inference (generation hot spot, App. B.2).

Gather-free traversal: per level, the per-row (feature, threshold) pair is
selected with a one-hot matmul over the heap arrays, and the feature value is
selected with a one-hot mask over the row tile — every step is an MXU/VPU
contraction, no scalar gathers (TPU adaptation of the level-by-level compare
that XGBoost's C++ inference performs pointer-chasing for).

Grid: (row_blocks, trees); trees accumulate into the same output block.
VMEM per step: [R, p] row tile + [R, max(H, p, L)] one-hot — with R=256,
p<=640, depth 7 (H=127, L=128) comfortably under v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _onehot(idx, size):
    iota = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], size), 1)
    return (idx[:, None] == iota).astype(jnp.float32)


def _predict_kernel(x_ref, feat_ref, thr_ref, leaf_ref, out_ref, *,
                    depth: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]                       # [R, p]
    feat = feat_ref[...][0]              # [H]
    # +inf sentinels ("never go right") must be finite: 0 * inf = NaN in the
    # one-hot select matmul. 1e30 exceeds any scaled feature value.
    thr = jnp.clip(thr_ref[...][0], -1e30, 1e30)
    leaf = leaf_ref[...][0]              # [L, out]
    n_heap = feat.shape[0]
    p = x.shape[1]
    node = jnp.zeros((x.shape[0],), jnp.int32)
    for level in range(depth):
        heap = node + (2 ** level - 1)
        sel = _onehot(heap, n_heap)                       # [R, H]
        f = jnp.round(sel @ feat.astype(jnp.float32)).astype(jnp.int32)
        tv = sel @ thr                                    # [R]
        xv = jnp.sum(x * _onehot(f, p), axis=1)           # [R]
        node = node * 2 + (xv > tv).astype(jnp.int32)
    out_ref[...] += _onehot(node, leaf.shape[0]) @ leaf   # [R, out]


def forest_predict_pallas(x, feat, thr_val, leaf, depth: int,
                          rows_block: int = 256, interpret: bool = False):
    """Same contract as ref.forest_predict_ref — any row count works.

    Rows are padded up to the next ``rows_block`` multiple before the call
    and the padding is sliced off the output, so serving-path batch shapes
    (odd buckets, oversize exact-size requests) never hit a grid-divisibility
    assert. Padded rows traverse with x=0 — every value is finite (the +inf
    sentinels are clipped inside the kernel), the garbage rows just get
    dropped.
    """
    n, p = x.shape
    n_trees, n_heap = feat.shape
    n_leaves, out = leaf.shape[1], leaf.shape[2]
    rows_block = min(rows_block, n)
    n_pad = pl.cdiv(n, rows_block) * rows_block
    if n_pad != n:
        x = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    grid = (n_pad // rows_block, n_trees)
    kernel = functools.partial(_predict_kernel, depth=depth)
    res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_block, p), lambda r, t: (r, 0)),
            pl.BlockSpec((1, n_heap), lambda r, t: (t, 0)),
            pl.BlockSpec((1, n_heap), lambda r, t: (t, 0)),
            pl.BlockSpec((1, n_leaves, out), lambda r, t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((rows_block, out), lambda r, t: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, out), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), feat.astype(jnp.int32),
      thr_val.astype(jnp.float32), leaf.astype(jnp.float32))
    return res if n_pad == n else res[:n]
