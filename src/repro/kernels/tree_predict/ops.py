"""Jit'd dispatch wrapper for packed-forest inference."""
from __future__ import annotations

import functools

import jax

from repro.kernels.tree_predict.ref import forest_predict_ref
from repro.kernels.tree_predict.tree_kernel import forest_predict_pallas


@functools.partial(jax.jit, static_argnames=("depth", "impl"))
def forest_predict(x, feat, thr_val, leaf, depth: int, impl: str = "xla"):
    """impl: 'xla' | 'pallas' | 'pallas_interpret'."""
    if impl == "xla":
        return forest_predict_ref(x, feat, thr_val, leaf, depth)
    return forest_predict_pallas(x, feat, thr_val, leaf, depth,
                                 interpret=(impl == "pallas_interpret"))
