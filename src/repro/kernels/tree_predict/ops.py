"""Jit'd dispatch wrapper for packed-forest inference.

``forest_predict`` is the one entry point every traversal goes through
(:func:`repro.forest.packed.predict_forest` routes here, so samplers,
imputation, and serving inherit whichever impl is selected). The impl is
resolved per call — explicit argument first, then the
``REPRO_TREE_PREDICT_IMPL`` environment variable, then ``xla`` — and passed
to the jitted core as a static argument, so each impl compiles its own
program and switching at runtime just selects a different cache entry.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.dispatch import resolve_impl
from repro.kernels.tree_predict.ref import forest_predict_ref
from repro.kernels.tree_predict.tree_kernel import forest_predict_pallas

ENV_VAR = "REPRO_TREE_PREDICT_IMPL"


@functools.partial(jax.jit, static_argnames=("depth", "impl"))
def _forest_predict(x, feat, thr_val, leaf, depth: int, impl: str):
    if impl == "xla":
        return forest_predict_ref(x, feat, thr_val, leaf, depth)
    return forest_predict_pallas(x, feat, thr_val, leaf, depth,
                                 interpret=(impl == "pallas_interpret"))


def forest_predict(x, feat, thr_val, leaf, depth: int,
                   impl: Optional[str] = None):
    """impl: 'xla' | 'pallas' | 'pallas_interpret' (None -> env -> 'xla')."""
    impl = resolve_impl(impl, env_var=ENV_VAR)
    return _forest_predict(x, feat, thr_val, leaf, depth, impl=impl)
