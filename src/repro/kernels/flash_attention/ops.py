"""Jit'd dispatch wrapper for flash attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.fa_kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "impl"))
def flash_attention(q, k, v, causal: bool = True, impl: str = "xla"):
    """impl: 'xla' (oracle / dry-run path) | 'pallas' | 'pallas_interpret'."""
    if impl == "xla":
        return attention_ref(q, k, v, causal)
    return flash_attention_pallas(q, k, v, causal=causal,
                                  interpret=(impl == "pallas_interpret"))
