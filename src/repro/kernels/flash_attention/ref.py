"""Pure-jnp oracle for flash attention (GQA-aware, causal/full)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q: [B, Hq, Sq, d]; k, v: [B, Hkv, Skv, d]; Hq = G * Hkv."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)
