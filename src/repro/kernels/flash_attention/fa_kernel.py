"""Pallas TPU kernel: block-tiled online-softmax (flash) attention.

Grid (B*Hq, q_blocks, kv_blocks); the kv dimension is the innermost
("arbitrary") axis and accumulates into VMEM scratch (acc, m, l); the output
tile is written on the last kv step. GQA is zero-copy: the K/V BlockSpec
index maps fold the query-head -> kv-head mapping, so grouped heads read the
same K/V tiles without materialising repeats.

Block sizes default to (bq, bk) = (256, 512): fp32 scores tile 256x512 (512
KiB) + q/k/v/acc tiles fit VMEM with double buffering; all dims multiples of
the 8x128 VPU lane layout for d_head in {64, 128, 160, 256}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, causal: bool, bq: int, bk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = True
    if causal:
        run = ki * bk <= qi * bq + bq - 1  # block intersects the causal band

    @pl.when(run)
    def _update():
        q = q_ref[...][0].astype(jnp.float32) * scale   # [bq, d]
        k = k_ref[...][0].astype(jnp.float32)           # [bk, d]
        v = v_ref[...][0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq,bk]
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...][:, 0]
        l_prev = l_ref[...][:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        l = l_ref[...][:, 0]
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l, 1e-30)[:, None])[None].astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, bq: int = 256,
                           bk: int = 512, interpret: bool = False):
    """q: [B, Hq, Sq, d]; k, v: [B, Hkv, Skv, d]. Returns [B, Hq, Sq, d]."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)
    grid = (b * hq, sq // bq, skv // bk)

    def kv_map(bh, qi, ki):
        return ((bh // hq) * hkv + (bh % hq) // g, ki, 0)

    kernel = functools.partial(_fa_kernel, scale=1.0 / (d ** 0.5),
                               causal=causal, bq=bq, bk=bk)
    try:
        from jax.experimental.pallas import tpu as pltpu
        scratch = [pltpu.VMEM((bq, d), jnp.float32),
                   pltpu.VMEM((bq, 1), jnp.float32),
                   pltpu.VMEM((bq, 1), jnp.float32)]
    except ImportError:  # pragma: no cover
        scratch = [pl.VMEM((bq, d), jnp.float32)]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
