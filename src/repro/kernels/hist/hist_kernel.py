"""Pallas TPU kernel: GBDT histogram build as a one-hot MXU matmul.

Design (DESIGN.md §2 "trees on a systolic-array machine"): the scatter-add
XGBoost performs per (node, feature, bin) is re-expressed as

    hist[:, j] = onehot(node_id * n_bins + codes[:, j])^T  @  (g * w)

so the accumulation runs on the MXU instead of a serial scatter unit. The
grid is (features, row_blocks); row blocks accumulate into the same output
block (revisited output), features are independent ("parallel").

VMEM budget per step: rows_block x (n_nodes*n_bins) one-hot (fp32) plus the
[rows_block, out] gradient tile; with rows_block=512, 64 nodes x 64 bins,
that is 512*4096*4 = 8 MiB — sized to fit v5e VMEM (~16 MiB usable) with
double buffering of the small operand tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(codes_ref, nid_ref, g_ref, w_ref, hist_ref, cnt_ref, *,
                 n_nodes: int, n_bins: int):
    rb = pl.program_id(1)

    @pl.when(rb == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    codes = codes_ref[...][:, 0].astype(jnp.int32)        # [R]
    nid = nid_ref[...].astype(jnp.int32)                  # [R]
    w = w_ref[...]                                        # [R]
    g = g_ref[...]                                        # [R, out]
    nb = n_nodes * n_bins
    key = nid * n_bins + codes                            # [R]
    iota = jax.lax.broadcasted_iota(jnp.int32, (key.shape[0], nb), 1)
    onehot = (key[:, None] == iota).astype(jnp.float32)   # [R, NB]
    gw = g * w[:, None]
    acc = jax.lax.dot_general(onehot, gw, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [NB, out]
    cnt = jax.lax.dot_general(onehot, w[:, None], (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [NB, 1]
    hist_ref[...] += acc.reshape(n_nodes, 1, n_bins, -1)
    cnt_ref[...] += cnt.reshape(n_nodes, 1, n_bins)


def histogram_pallas(codes, node_id, g, w, n_nodes: int, n_bins: int,
                     rows_block: int = 512, interpret: bool = False):
    """Same contract as ref.histogram_ref. codes int32 [n, p]."""
    n, p = codes.shape
    out = g.shape[1]
    rows_block = min(rows_block, n)
    assert n % rows_block == 0, (n, rows_block)
    n_rb = n // rows_block
    grid = (p, n_rb)

    kernel = functools.partial(_hist_kernel, n_nodes=n_nodes, n_bins=n_bins)
    sum_g, cnt = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_block, 1), lambda j, r: (r, j)),      # codes col
            pl.BlockSpec((rows_block,), lambda j, r: (r,)),          # node_id
            pl.BlockSpec((rows_block, out), lambda j, r: (r, 0)),    # g
            pl.BlockSpec((rows_block,), lambda j, r: (r,)),          # w
        ],
        out_specs=[
            pl.BlockSpec((n_nodes, 1, n_bins, out), lambda j, r: (0, j, 0, 0)),
            pl.BlockSpec((n_nodes, 1, n_bins), lambda j, r: (0, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_nodes, p, n_bins, out), jnp.float32),
            jax.ShapeDtypeStruct((n_nodes, p, n_bins), jnp.float32),
        ],
        interpret=interpret,
    )(codes.astype(jnp.int32), node_id.astype(jnp.int32),
      g.astype(jnp.float32), w.astype(jnp.float32))
    return sum_g, cnt
