"""Jit'd dispatch wrapper for the histogram kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.hist.hist_kernel import histogram_pallas
from repro.kernels.hist.ref import histogram_ref


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins", "impl"))
def histogram(codes, node_id, g, w, n_nodes: int, n_bins: int,
              impl: str = "xla"):
    """impl: 'xla' (segment-sum ref), 'pallas' (TPU), 'pallas_interpret'."""
    if impl == "xla":
        return histogram_ref(codes, node_id, g, w, n_nodes, n_bins)
    return histogram_pallas(codes, node_id, g, w, n_nodes, n_bins,
                            interpret=(impl == "pallas_interpret"))
