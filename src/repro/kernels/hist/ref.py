"""Pure-jnp oracle for the histogram-build kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def histogram_ref(codes, node_id, g, w, n_nodes: int, n_bins: int):
    """codes [n, p] int; node_id [n] int32; g [n, out] fp32; w [n] fp32.

    Returns (sum_g [n_nodes, p, n_bins, out], count [n_nodes, p, n_bins]).
    """
    seg_base = node_id.astype(jnp.int32) * n_bins

    def per_feature(codes_j):
        seg = seg_base + codes_j.astype(jnp.int32)
        sums = jax.ops.segment_sum(g * w[:, None], seg,
                                   num_segments=n_nodes * n_bins)
        cnt = jax.ops.segment_sum(w, seg, num_segments=n_nodes * n_bins)
        return sums.reshape(n_nodes, n_bins, -1), cnt.reshape(n_nodes, n_bins)

    sums, cnt = jax.vmap(per_feature, in_axes=1, out_axes=1)(codes)
    return sums, cnt
