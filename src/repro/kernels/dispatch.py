"""Per-call kernel-impl resolution, shared by every Pallas/XLA switch.

One convention for picking an implementation (``hist``, ``tree_predict``, …):

    impl = resolve_impl(call_arg, config_field, env_var="REPRO_<OP>_IMPL")

The first non-empty candidate wins, then the environment variable, then the
``xla`` default. Resolution happens at *call* time — the old module-level
``_IMPL = os.environ.get(...)`` pattern froze the switch at import time, so
setting the variable after the first import was silently ignored and tests
could not toggle implementations.

Note the env var is still read when the surrounding program *traces*: a
jitted trainer compiled under one setting keeps its compiled choice until
its cache key changes (callers that want a jit-visible switch thread the
resolved impl through as a static argument, as ``repro.tabgen.sample``
does).
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

VALID_IMPLS = ("xla", "pallas", "pallas_interpret")


def resolve_impl(*candidates: Optional[str], env_var: str,
                 default: str = "xla",
                 valid: Sequence[str] = VALID_IMPLS) -> str:
    """First non-empty candidate, else ``os.environ[env_var]``, else default.

    Candidates are explicit call arguments and config fields, most specific
    first; ``None`` (and ``""``) mean "not specified". The winning value is
    validated against ``valid`` (default :data:`VALID_IMPLS`; switches with
    their own vocabulary, e.g. attention's ``blocked``/``packed``, pass
    theirs) so a typo'd env var fails loudly at the call that would have
    silently used the wrong path.
    """
    impl = next((c for c in candidates if c), None) \
        or os.environ.get(env_var) or default
    if impl not in valid:
        raise ValueError(
            f"unknown kernel impl {impl!r} (via {env_var} or caller); "
            f"expected one of {tuple(valid)}")
    return impl
