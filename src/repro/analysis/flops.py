"""Analytic cost model: FLOPs / HBM bytes / collective bytes per cell.

Why analytic: XLA's ``compiled.cost_analysis()`` counts a While body ONCE
(verified empirically — scan of 4 matmuls reports 1/4 the flops), and every
production-shaped program here is scanned (layers, blocked attention, chunked
loss). The compiled artifact still supplies the ground truth for peak memory
and for which collectives exist; execution counts come from this model, which
mirrors the module structure in ``repro.models`` term by term and is
validated against HLO flops on unrolled probes in
``tests/test_flops_model.py``.

Conventions: one MAC = 2 FLOPs; attention is counted at full S^2 (the blocked
XLA path computes masked full blocks; the causal-skip optimisation enters as
a §Perf iteration); bf16 activations / fp32 master+opt states.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.config import ArchConfig, ShapeConfig
from repro.models import blocks

# TPU v5e constants (per task spec)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def hlo_cost_analysis(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` across jax versions.

    Older jaxlibs return one properties dict; newer ones return a list with
    one dict per partition (and may return None when the backend provides no
    analysis). Normalises to a single flat dict, summing numeric entries
    across partitions.
    """
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return dict(cost)
    out: Dict[str, float] = {}
    for part in cost:
        for k, v in part.items():
            if isinstance(v, (int, float)):
                out[k] = out.get(k, 0) + v
            else:
                out.setdefault(k, v)
    return out


def _attn_proj_flops(cfg, n_tok):
    h, kv, d, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_model, cfg.d_head
    return 2 * n_tok * d * (h * hd) + 2 * n_tok * d * (kv * hd) * 2 \
        + 2 * n_tok * (h * hd) * d


def _mlp_flops(cfg, n_tok, ff):
    mats = 3 if cfg.act in ("swiglu", "geglu") else 2
    return 2 * n_tok * cfg.d_model * ff * mats


def _moe_flops(cfg, n_tok, group=512, cf=1.25):
    d, e, k, ffe = cfg.d_model, cfg.n_experts, cfg.top_k, cfg.d_ff_expert
    mats = 3 if cfg.act in ("swiglu", "geglu") else 2
    expert = 2 * n_tok * k * cf * d * ffe * mats
    router = 2 * n_tok * d * e
    # dispatch + combine einsums: 2 * G*S*E*C*D each, C = S*k/E*cf
    dispatch = 2 * (2 * n_tok * group * k * cf * d)
    return expert + router + dispatch


def _layer_fwd_flops(cfg: ArchConfig, kind: str, n_tok: int, s_ctx: int,
                     mla_absorb: bool = False, decode: bool = False,
                     attn_packed: bool = False) -> float:
    d = cfg.d_model
    # packed causal attention computes S^2/2 + one diagonal block
    ctx_fac = 0.5 + 1024.0 / max(s_ctx, 1024) / 2 if attn_packed else 1.0
    f = 0.0
    if kind in ("dense", "moe", "enc", "attn"):
        f += _attn_proj_flops(cfg, n_tok)
        eff = s_ctx * (ctx_fac if kind != "enc" else 1.0)
        f += 2 * n_tok * eff * cfg.n_heads * cfg.d_head * 2  # qk + pv
    if kind == "lattn":
        f += _attn_proj_flops(cfg, n_tok)
        win = min(cfg.attn_window, s_ctx)
        f += 2 * n_tok * win * cfg.n_heads * cfg.d_head * 2
    if kind in ("mla_dense", "mla_moe"):
        qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
        nope, rp, vd, h = (cfg.nope_head_dim, cfg.rope_head_dim,
                           cfg.v_head_dim, cfg.n_heads)
        f += 2 * n_tok * d * qr + 2 * n_tok * qr * h * (nope + rp)
        f += 2 * n_tok * d * kvr + 2 * n_tok * d * rp
        if decode and mla_absorb:
            # fold wk_b into q, wv_b into out: per-token work scales with kvr
            f += 2 * n_tok * h * nope * kvr * 2      # q absorb + out absorb
            f += 2 * n_tok * s_ctx * h * kvr * 2     # scores + context on latent
            f += 2 * n_tok * s_ctx * h * rp          # rope scores
        else:
            # expand k/v from the latent for the whole context
            ctx_tok = n_tok if not decode else n_tok * s_ctx
            f += 2 * ctx_tok * kvr * h * nope + 2 * ctx_tok * kvr * h * vd
            eff = s_ctx * (ctx_fac if not decode else 1.0)
            f += 2 * n_tok * eff * h * (nope + rp) + 2 * n_tok * eff * h * vd
        f += 2 * n_tok * h * vd * d
    if kind == "dec":
        f += _attn_proj_flops(cfg, n_tok) * 2          # self + cross projs
        f += 2 * n_tok * s_ctx * cfg.n_heads * cfg.d_head * 2        # self
        f += 2 * n_tok * 1500 * cfg.n_heads * cfg.d_head * 2         # cross
    if kind == "rec":
        w = cfg.rnn_width
        f += 2 * n_tok * d * w * 2 + 2 * n_tok * cfg.conv1d_width * w
        f += 2 * n_tok * w * w * 2 + 10 * n_tok * w + 2 * n_tok * w * d
    if kind == "mlstm":
        w = cfg.rnn_width
        hd = w // cfg.n_heads
        chunk = min(256, s_ctx)
        f += 2 * n_tok * d * w * 2 + 2 * n_tok * cfg.conv1d_width * w
        f += 2 * n_tok * w * w * 3                      # q, k, v
        f += 2 * n_tok * chunk * w * 2                  # intra-chunk quadratic
        f += 2 * n_tok * hd * w * 2 * 2                 # state update + query
        f += 2 * n_tok * w * d
    if kind == "slstm":
        f += 2 * n_tok * d * d * 3 + 12 * n_tok * d
    # FFN halves
    if kind in ("dense", "enc", "dec", "lattn", "attn"):
        f += _mlp_flops(cfg, n_tok, cfg.d_ff)
    if kind == "mla_dense":
        f += _mlp_flops(cfg, n_tok, cfg.d_ff_dense or cfg.d_ff)
    if kind == "rec":
        f += _mlp_flops(cfg, n_tok, cfg.d_ff)
    if kind == "moe":
        f += _moe_flops(cfg, n_tok)
    if kind == "mla_moe":
        f += _moe_flops(cfg, n_tok)
        if cfg.n_shared_experts:
            f += _mlp_flops(cfg, n_tok, cfg.n_shared_experts * cfg.d_ff_expert)
    return f


def _all_kinds(cfg: ArchConfig):
    out = []
    for kinds, n in blocks.segments_for(cfg):
        out += list(kinds) * n
    return out


def param_count(cfg: ArchConfig) -> float:
    """Exact parameter count by walking the init shapes (cheap eval_shape)."""
    import jax
    import jax.numpy as jnp
    from repro.models import lm
    shapes = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))
    import numpy as np
    return float(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes)))


def _non_expert_params(cfg: ArchConfig) -> float:
    """Params outside routed-expert stacks (attention, norms, embeddings...)."""
    mats = 3 if cfg.act in ("swiglu", "geglu") else 2
    per_expert = mats * cfg.d_model * cfg.d_ff_expert
    n_moe_layers = sum(1 for k in _all_kinds(cfg) if k in ("moe", "mla_moe"))
    return param_count(cfg) - n_moe_layers * cfg.n_experts * per_expert


def active_param_count(cfg: ArchConfig) -> float:
    """Params touched per token (MoE: top-k experts only)."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    # subtract inactive expert weights
    mats = 3 if cfg.act in ("swiglu", "geglu") else 2
    per_expert = mats * cfg.d_model * cfg.d_ff_expert
    n_moe_layers = sum(1 for k in _all_kinds(cfg) if k in ("moe", "mla_moe"))
    inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive


@dataclasses.dataclass
class CellCost:
    fwd_flops: float
    total_flops: float          # incl. bwd + remat for train
    hbm_bytes: float            # global bytes moved per step
    coll_bytes: float           # global collective payload bytes per step
    model_flops: float          # 6 N D (dense) / 6 N_active D


def cell_cost(cfg: ArchConfig, shape: ShapeConfig, *, chips: int,
              dp_size: int, tp_size: int, remat_policy: str = "full",
              mla_absorb: bool = False, attn_packed: bool = False,
              moe_w8: bool = False) -> CellCost:
    b, s = shape.global_batch, shape.seq_len
    kinds = _all_kinds(cfg)

    if shape.kind == "decode":
        n_tok = b  # one token per sequence
        s_ctx = s
        decode = True
    else:
        if cfg.family == "audio_encdec":
            n_tok = b * (s // 2)
        else:
            n_tok = b * s
        s_ctx = s if cfg.family != "audio_encdec" else s // 2
        decode = False

    fwd = 0.0
    if cfg.family == "audio_encdec":
        for _ in range(cfg.n_layers):
            fwd += _layer_fwd_flops(cfg, "enc", n_tok, s_ctx)
        for _ in range(cfg.n_layers):
            fwd += _layer_fwd_flops(cfg, "dec", n_tok, s_ctx, decode=decode,
                                    attn_packed=attn_packed)
    else:
        for k in kinds:
            fwd += _layer_fwd_flops(cfg, k, n_tok, s_ctx,
                                    mla_absorb=mla_absorb, decode=decode,
                                    attn_packed=attn_packed)
    # unembed
    fwd += 2 * n_tok * cfg.d_model * cfg.vocab

    p_total = param_count(cfg)
    if shape.kind == "train":
        mult = {"full": 4.0, "dots": 3.3, "none": 3.0}[remat_policy]
        total = fwd * mult
        # bytes: params bf16 fwd+bwd reads, fp32 master/m/v r+w, grads,
        # activations r/w ~ 12 tensors of [n_tok, d] per layer + remat reread
        act_bytes = len(kinds) * n_tok * cfg.d_model * 2 * 12
        if remat_policy == "full":
            act_bytes *= 1.5
        hbm = p_total * (2 + 2 + 2) + p_total * 4 * 6 + act_bytes
        # collectives: grad psum over dp (ring 2(n-1)/n), fsdp weight
        # all-gather fwd+bwd, per-layer TP activation reduces (2 per layer)
        dp_fac = 2 * (dp_size - 1) / dp_size
        ag_fac = (dp_size - 1) / dp_size
        coll = p_total * 4 * dp_fac                      # grad all-reduce fp32
        coll += p_total * 2 * ag_fac * 2                 # fsdp AG fwd + bwd
        coll += len(kinds) * 2 * n_tok * cfg.d_model * 2 * (tp_size - 1) / tp_size
    elif shape.kind == "prefill":
        total = fwd
        act_bytes = len(kinds) * n_tok * cfg.d_model * 2 * 8
        hbm = p_total * 2 + act_bytes
        ag_fac = (dp_size - 1) / dp_size
        coll = p_total * 2 * ag_fac
        coll += len(kinds) * 2 * n_tok * cfg.d_model * 2 * (tp_size - 1) / tp_size
    else:  # decode
        total = fwd
        cache = _cache_bytes(cfg, b, s)
        # batch decode touches ~E*(1-(1-k/E)^(B)) experts per MoE layer
        if cfg.n_experts:
            frac = 1.0 - (1.0 - cfg.top_k / cfg.n_experts) ** b
            expert_read = frac * (param_count(cfg) - active_param_count(cfg)) \
                + (active_param_count(cfg) - _non_expert_params(cfg))
            dense_read = _non_expert_params(cfg)
            # int8 weight-only experts: 1 byte/weight instead of bf16's 2
            hbm_w = expert_read * (1 if moe_w8 else 2) + dense_read * 2
        else:
            hbm_w = param_count(cfg) * 2
        hbm = hbm_w + cache + n_tok * cfg.d_model * 2 * 8
        coll = len(kinds) * 2 * n_tok * cfg.d_model * 2 * (tp_size - 1) / tp_size
    # 6ND counts fwd+bwd (train); inference steps are forward-only: 2ND
    nd_factor = 6 if shape.kind == "train" else 2
    model_flops = nd_factor * active_param_count(cfg) * n_tok
    return CellCost(fwd, total, hbm, coll, model_flops)


def _cache_bytes(cfg: ArchConfig, b: int, s: int) -> float:
    kinds = _all_kinds(cfg)
    total = 0.0
    for k in kinds:
        if k in ("dense", "moe", "attn", "enc"):
            total += b * cfg.n_kv_heads * s * cfg.d_head * 2 * 2
        elif k == "dec":
            total += b * cfg.n_kv_heads * (s + 1500) * cfg.d_head * 2 * 2
        elif k == "lattn":
            total += b * cfg.n_kv_heads * min(s, cfg.attn_window) \
                * cfg.d_head * 2 * 2
        elif k in ("mla_dense", "mla_moe"):
            total += b * s * (cfg.kv_lora_rank + cfg.rope_head_dim) * 2
        elif k == "rec":
            total += b * cfg.rnn_width * 4
        elif k == "mlstm":
            hd = cfg.rnn_width // cfg.n_heads
            total += b * cfg.n_heads * (hd * hd + hd) * 4
        elif k == "slstm":
            total += b * cfg.d_model * 4
    return total * 2  # read + write


def forest_cost(*, n_rows: int, p: int, fcfg, chips: int, data_shards: int,
                out_dim: int = 1) -> CellCost:
    """Analytic cost of ONE distributed SO boosting round (one tree for each
    of the 16 ensembles in a model-axis slice, vmapped over p outputs).

    FLOPs: histogram accumulation (n*(out+1) adds per feature per level) +
    split search (nodes*p*bins*out) + traversal compares. Bytes: codes read
    per level + gradient vectors. Collectives: per-level histogram reduction
    (all-reduce = 2(n-1)/n * size; reduce-scatter = (n-1)/n * size + tiny
    argmax gather) summed over levels and outputs.
    """
    n_local_rows = n_rows // data_shards * fcfg.duplicate_k
    n_global = n_rows * fcfg.duplicate_k
    depth, bins = fcfg.max_depth, fcfg.n_bins
    n_ens_slice = 16  # one model-axis slice
    n_sub = p if not fcfg.multi_output else 1
    o = out_dim if not fcfg.multi_output else p
    flops = 0.0
    hist_coll = 0.0
    hbm = 0.0
    hist_elem_bytes = 2 if fcfg.hist_bf16 else 4
    code_bytes = 1 if getattr(fcfg, "int8_codes", False) else 4
    for level in range(depth):
        nodes = 2 ** level
        flops += n_global * p * (o + 1) * 2          # hist accumulation
        flops += nodes * p * bins * o * 6            # split search
        flops += n_global * 4                        # node-id update
        hbm += n_global * p * code_bytes + n_global * (o + 2) * 4
        size = nodes * p * bins * (o + 1) * hist_elem_bytes
        if fcfg.split_reduce == "reduce_scatter":
            hist_coll += size * (data_shards - 1) / data_shards
            hist_coll += nodes * 3 * 4 * data_shards  # argmax gather
        else:
            hist_coll += 2 * size * (data_shards - 1) / data_shards
    per_tree = CellCost(flops, flops, hbm, hist_coll, flops)
    scale = n_sub * n_ens_slice * fcfg.n_trees
    return CellCost(per_tree.fwd_flops * scale, per_tree.total_flops * scale,
                    per_tree.hbm_bytes * scale, per_tree.coll_bytes * scale,
                    per_tree.model_flops * scale)


def chip_memory_estimate(cfg: ArchConfig, shape: ShapeConfig, *, chips: int,
                         remat_policy: str = "full",
                         moe_w8: bool = False,
                         opt_bf16: bool = False) -> Dict[str, float]:
    """Analytic peak HBM per chip (the fits-in-16-GiB argument).

    The CPU host-platform buffer assignment behind memory_analysis() is not
    representative of the TPU compiler (it keeps unsharded fp32 temporaries
    resident — a 135M-param train step reports hundreds of GiB), so the
    capacity check is made from first principles: sharded params + optimizer
    states + grads + checkpointed residuals (+ cache for decode), divided
    across chips.
    """
    p_total = param_count(cfg)
    kinds = _all_kinds(cfg)
    b, s = shape.global_batch, shape.seq_len
    n_tok = b * s if shape.kind != "decode" else b
    if shape.kind == "train":
        params_b = p_total * 4                     # fp32 master
        opt_b = p_total * (4 if opt_bf16 else 8)   # m + v
        grads_b = p_total * 4
        # checkpointed residual per layer: the scan carry in bf16
        resid = len(kinds) * n_tok * cfg.d_model * 2
        if remat_policy == "dots":
            resid *= 2.2                           # saved matmul outputs
        # live working set during one layer's bwd: ~8 activation tensors
        work = n_tok * cfg.d_model * 2 * 8
        # one chunked-loss logits tile in fp32
        loss_tile = b * min(2048, s) * cfg.vocab * 4
        total = params_b + opt_b + grads_b + resid + work + loss_tile
    elif shape.kind == "prefill":
        params_b = p_total * 2                     # bf16 serving weights
        resid = len(kinds) * n_tok * cfg.d_model * 2
        work = n_tok * cfg.d_model * 2 * 8
        cache = _cache_bytes(cfg, b, s) / 2        # one copy (no rw double)
        total = params_b + resid + work + cache
    else:
        params_b = p_total * (1.2 if moe_w8 else 2)
        cache = _cache_bytes(cfg, b, s) / 2        # donated in/out alias
        work = n_tok * cfg.d_model * 2 * 16
        total = params_b + cache + work
    per_chip = total / chips
    return {"per_chip_bytes": per_chip,
            "per_chip_gib": per_chip / 2 ** 30,
            "fits_16GiB": bool(per_chip < 16 * 2 ** 30)}


def roofline(cost: CellCost, chips: int) -> Dict[str, float]:
    t_comp = cost.total_flops / (chips * PEAK_FLOPS)
    t_mem = cost.hbm_bytes / (chips * HBM_BW)
    t_coll = cost.coll_bytes / (chips * ICI_BW)
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])
    bound = max(t_comp, t_mem, t_coll)
    t_model = cost.model_flops / (chips * PEAK_FLOPS)
    return {
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dominant[0],
        # fraction of the step the chips could spend doing compiled compute
        "roofline_fraction": t_comp / bound if bound > 0 else 0.0,
        # upper bound on model-FLOPs utilisation (the reported perf score)
        "mfu_bound": t_model / bound if bound > 0 else 0.0,
        "useful_flops_ratio": (cost.model_flops / cost.total_flops
                               if cost.total_flops else 0.0),
    }
