"""jaxlint core: findings, suppressions, baseline, file walking, rule registry.

The framework is deliberately stdlib-only (``ast`` + ``json``): the CI lane
that runs it needs no jax install, and importing it can never trigger device
probing. Rules live in :mod:`repro.analysis.lint.rules`; each encodes one bug
class this repo has actually shipped and later fixed (see the rule docstrings
for the PR history).

Three escape hatches, in order of preference:

1. **Fix the code.** The rules flag patterns that were real bugs here.
2. **Inline suppression** — append ``# jaxlint: disable=RULE`` (or
   ``disable=RULE1,RULE2`` / ``disable=all``) to the flagged line, or put it
   on its own comment line directly above. Use when the pattern is deliberate
   (e.g. a one-shot ``jax.jit(f)(x)`` in a test).
3. **Baseline** — ``python -m repro.analysis.lint --write-baseline`` records
   every current finding in ``.jaxlint_baseline.json``; baselined findings
   are reported as grandfathered and do not fail the build. The baseline is
   keyed on (path, rule, line), so unrelated edits that shift lines require
   regenerating it — which is the point: grandfathered debt should be loud,
   not comfortable.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Set, Tuple

#: ``# jaxlint: disable=JX001`` / ``disable=JX001,TH001`` / ``disable=all``
_SUPPRESS_RE = re.compile(r"#\s*jaxlint:\s*disable=([A-Za-z0-9_,\s]+|all)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, pinned to a file and line."""
    rule: str
    path: str
    line: int
    col: int
    message: str

    def baseline_key(self) -> Tuple[str, str, int]:
        return (self.path.replace(os.sep, "/"), self.rule, self.line)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


#: rule id -> (one-line description, check function)
RULES: Dict[str, Tuple[str, Callable[[ast.Module, str, str], Iterable[Finding]]]] = {}


def rule(rule_id: str, description: str):
    """Register a check: ``fn(tree, source, path) -> iterable[Finding]``."""
    def deco(fn):
        RULES[rule_id] = (description, fn)
        return fn
    return deco


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def parse_suppressions(source: str) -> Dict[int, Set[str]]:
    """Line number -> set of suppressed rule ids ({'all'} suppresses any).

    A suppression comment applies to its own line; a *standalone* comment
    line also applies to the next line, so long expressions can carry the
    pragma above them instead of trailing past the line-length limit.
    """
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        spec = m.group(1).strip()
        rules = ({"all"} if spec == "all"
                 else {r.strip() for r in spec.split(",") if r.strip()})
        out.setdefault(i, set()).update(rules)
        if text.lstrip().startswith("#"):        # standalone pragma line
            out.setdefault(i + 1, set()).update(rules)
    return out


def _suppressed(finding: Finding, suppressions: Dict[int, Set[str]]) -> bool:
    rules = suppressions.get(finding.line, set())
    return "all" in rules or finding.rule in rules


# ---------------------------------------------------------------------------
# per-file / per-tree entry points
# ---------------------------------------------------------------------------

def lint_source(source: str, path: str = "<string>",
                rule_ids: Optional[Iterable[str]] = None
                ) -> Tuple[List[Finding], int]:
    """Lint one source string. Returns (active findings, n_suppressed).

    Import of :mod:`repro.analysis.lint.rules` is deferred so the registry
    is populated exactly once, wherever the caller entered from.
    """
    from repro.analysis.lint import rules as _rules  # noqa: F401 — registers
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as err:
        return [Finding("JX000", path, err.lineno or 1, err.offset or 0,
                        f"syntax error: {err.msg} (jaxlint cannot analyse "
                        "this file)")], 0
    wanted = set(rule_ids) if rule_ids is not None else set(RULES)
    suppressions = parse_suppressions(source)
    findings: List[Finding] = []
    n_suppressed = 0
    for rule_id in sorted(wanted):
        _, check = RULES[rule_id]
        for f in check(tree, source, path):
            if _suppressed(f, suppressions):
                n_suppressed += 1
            else:
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, n_suppressed


def lint_file(path: str, rule_ids: Optional[Iterable[str]] = None
              ) -> Tuple[List[Finding], int]:
    with open(path, encoding="utf-8") as fh:
        return lint_source(fh.read(), path, rule_ids)


_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", ".ruff_cache",
              "build", "dist", ".eggs"}


def iter_py_files(paths: Iterable[str]) -> Iterator[str]:
    """Expand files/directories into a sorted, de-duplicated .py file list."""
    seen: Set[str] = set()
    for p in paths:
        if os.path.isfile(p):
            if p not in seen:
                seen.add(p)
                yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if d not in _SKIP_DIRS and not d.endswith(".egg-info"))
            for name in sorted(files):
                if name.endswith(".py"):
                    full = os.path.join(root, name)
                    if full not in seen:
                        seen.add(full)
                        yield full


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

def load_baseline(path: str) -> Set[Tuple[str, str, int]]:
    if not path or not os.path.exists(path):
        return set()
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    return {(e["path"], e["rule"], int(e["line"])) for e in data["findings"]}


def write_baseline(path: str, findings: Iterable[Finding]) -> None:
    entries = [{"path": f.path.replace(os.sep, "/"), "rule": f.rule,
                "line": f.line, "message": f.message}
               for f in sorted(findings, key=lambda f: f.baseline_key())]
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"comment": "grandfathered jaxlint findings; regenerate "
                              "with: python -m repro.analysis.lint "
                              "--write-baseline",
                   "findings": entries}, fh, indent=2)
        fh.write("\n")


def split_baselined(findings: Iterable[Finding],
                    baseline: Set[Tuple[str, str, int]]
                    ) -> Tuple[List[Finding], List[Finding]]:
    """(new, grandfathered) — grandfathered findings don't fail the build."""
    new, old = [], []
    for f in findings:
        (old if f.baseline_key() in baseline else new).append(f)
    return new, old
