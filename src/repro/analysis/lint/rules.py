"""jaxlint rules — this repo's shipped-and-fixed bug classes, as AST checks.

Every rule here is grounded in a concrete regression from this repo's
history (see ``git log`` / CHANGES.md):

* **JX001** — PR 2: ``itp.sample_bridge`` consumed one PRNG key for both the
  noise draw and the CFM bridge jitter, making the "independent" jitter
  exactly ``sigma * x1`` (same key + same shape => same normal draw).
* **JX002** — PR 4: ``forest/hist.py`` snapshotted ``REPRO_HIST_IMPL`` into
  a module constant at import time, so setting the env var after the first
  import was silently ignored and tests could not toggle implementations.
* **JX003** — recompile leaks: a ``jax.jit`` wrapper built inside a hot
  path owns a fresh, empty cache every call, and unhashable defaults
  feeding jit signatures fragment (or break) the cache keying.
* **TH001** — PR 4: ``ForestServer.stats`` was mutated by the dispatcher
  thread and read/written unlocked from the submit path. Extended in PR 8
  after the ``AdmissionController`` per-tenant ``setdefault`` slipped past
  it: a locked *read* now also marks an attribute as lock-guarded, so a
  class whose only locked accesses are snapshot reads still gets its
  unlocked mutations flagged.
* **PL001** — PR 4: the tree-predict ``pallas_call`` asserted
  ``n % rows_block == 0``, which crashed odd serving buckets and oversize
  exact-size requests until the wrapper learned to pad.
* **OB001** — PR 10: a ``Tracer.start()`` span that is not ``.end()``ed
  on every path never records — an early ``return`` or an exception
  between start and end silently drops the span from the ring (and its
  request from ``/v1/trace``), skewing queue-wait histograms low.

The rules are lexical-order heuristics, not a dataflow engine: they favour
catching the historical pattern with near-zero false positives on this tree.
``# jaxlint: disable=RULE`` handles the deliberate exceptions.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.lint.core import Finding, rule

# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of a callee: ``jax.random.normal`` ->
    'jax.random.normal'; anything non-name-like contributes ''."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def _last_attr(node: ast.AST) -> str:
    return _dotted(node).rsplit(".", 1)[-1]


def _is_jax_jit(node: ast.AST) -> bool:
    """Matches ``jax.jit`` / bare ``jit`` references and
    ``[functools.]partial(jax.jit, ...)`` calls."""
    name = _dotted(node)
    if name in ("jax.jit", "jit", "jax.pmap", "pmap"):
        return True
    if isinstance(node, ast.Call) and _last_attr(node.func) == "partial":
        return bool(node.args) and _is_jax_jit(node.args[0])
    return False


def _functions(tree: ast.Module) -> Iterator[ast.AST]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


# ---------------------------------------------------------------------------
# JX001 — PRNG key reuse
# ---------------------------------------------------------------------------

#: calls that *derive* new keys (safe to hand the same key repeatedly)
_DERIVING = {"split", "fold_in", "PRNGKey", "key", "key_data",
             "wrap_key_data", "clone"}

#: parameter names treated as PRNG keys even without a visible assignment
_KEY_PARAM_RE = re.compile(r"^(key|rng|prng_key|root_key|subkey|k\d*)$"
                           r"|(_key|_keys|_rng)$")

_LOOPS = (ast.For, ast.AsyncFor, ast.While)
_COMPREHENSIONS = (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)
_TERMINATORS = (ast.Return, ast.Raise, ast.Break, ast.Continue)


def _has_prng_evidence(fn: ast.AST) -> bool:
    """True when the function visibly touches the PRNG: references
    ``random``/``PRNGKey``/``fold_in``, or calls ``split``/``fold_in`` on a
    key-named argument. Parameters named ``key``/``k``/... are only treated
    as PRNG keys in such functions — attention's K tensor and dict-style
    ``__getitem__(self, key)`` share the names but never the PRNG."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and node.attr in (
                "random", "PRNGKey", "fold_in", "wrap_key_data"):
            return True
        if isinstance(node, ast.Name) and node.id in ("PRNGKey", "fold_in"):
            return True
        if (isinstance(node, ast.Call)
                and _last_attr(node.func) in ("split", "fold_in")
                and node.args and isinstance(node.args[0], ast.Name)
                and _KEY_PARAM_RE.search(node.args[0].id)):
            return True
    return False


class _KeyScope:
    """Per-function lexical walk tracking key variables and their versions.

    A *version* is bumped on every rebinding; each consumption records the
    (name, version) it saw plus the loop nesting it happened under. Two
    consumptions of one version => reuse. A consumption strictly deeper in
    loops than its version's binding => reuse across iterations.
    """

    def __init__(self, fn, path: str):
        self.fn = fn
        self.path = path
        self.findings: List[Finding] = []
        self.version: Dict[str, int] = {}
        self.def_loops: Dict[Tuple[str, int], Tuple[int, ...]] = {}
        self.consumed: Dict[Tuple[str, int], int] = {}
        self.loop_stack: Tuple[int, ...] = ()
        args = fn.args
        params = [*args.posonlyargs, *args.args, *args.kwonlyargs]
        if args.vararg:
            params.append(args.vararg)
        if args.kwarg:
            params.append(args.kwarg)
        if _has_prng_evidence(fn):
            for p in params:
                if _KEY_PARAM_RE.search(p.arg):
                    self._bind(p.arg)

    # -- bookkeeping --------------------------------------------------------

    def _bind(self, name: str) -> None:
        self.version[name] = self.version.get(name, 0) + 1
        self.def_loops[(name, self.version[name])] = self.loop_stack

    def _is_key(self, name: str) -> bool:
        return name in self.version

    def _consume(self, name: str, node: ast.AST) -> None:
        ver = self.version[name]
        k = (name, ver)
        self.consumed[k] = self.consumed.get(k, 0) + 1
        use_loops = self.loop_stack
        def_loops = self.def_loops.get(k, ())
        if self.consumed[k] > 1:
            self.findings.append(Finding(
                "JX001", self.path, node.lineno, node.col_offset,
                f"PRNG key '{name}' is consumed by more than one jax.random "
                "call without an intervening split/fold_in — identical keys "
                "give identical draws (the PR-2 CFM-jitter bug). Split the "
                "key, or fold_in a distinct constant per consumer."))
        elif (len(use_loops) > len(def_loops)
              and use_loops[:len(def_loops)] == def_loops):
            self.findings.append(Finding(
                "JX001", self.path, node.lineno, node.col_offset,
                f"PRNG key '{name}' was bound outside this loop but is "
                "consumed inside it — every iteration draws with the same "
                "key. split() before the loop or fold_in the loop index."))

    # -- assignment targets -------------------------------------------------

    def _targets(self, node: ast.AST) -> Iterator[str]:
        if isinstance(node, ast.Name):
            yield node.id
        elif isinstance(node, (ast.Tuple, ast.List)):
            for elt in node.elts:
                yield from self._targets(elt)
        elif isinstance(node, ast.Starred):
            yield from self._targets(node.value)

    def _rhs_is_key_source(self, value: ast.AST) -> bool:
        """RHS that plainly produces PRNG keys (split/fold_in/PRNGKey...).

        A bare ``.split``/``.fold_in`` only counts when the callee is rooted
        in ``random`` or its first argument is a tracked key — otherwise
        ``name, n = args.calo.split(":")`` would mint key variables."""
        if isinstance(value, ast.Call):
            if _last_attr(value.func) not in _DERIVING:
                return False
            dotted = _dotted(value.func)
            parts = dotted.split(".")
            if "random" in parts or "PRNGKey" in parts or dotted in (
                    "PRNGKey", "fold_in", "key", "key_data", "wrap_key_data"):
                return True
            return bool(value.args and isinstance(value.args[0], ast.Name)
                        and self._is_key(value.args[0].id))
        if isinstance(value, ast.Name):
            return self._is_key(value.id)
        if isinstance(value, ast.Subscript):
            return (isinstance(value.value, ast.Name)
                    and self._is_key(value.value.id))
        return False

    # -- walking ------------------------------------------------------------

    def run(self) -> List[Finding]:
        self._run_body(self.fn.body)
        return self.findings

    def _run_body(self, body) -> None:
        for stmt in body:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # nested scopes get their own _KeyScope
        if isinstance(stmt, ast.If):
            self._branches([stmt.body, stmt.orelse], extra_exprs=[stmt.test])
        elif isinstance(stmt, ast.Try):
            branches = [stmt.body, *[h.body for h in stmt.handlers],
                        stmt.orelse]
            self._branches(branches)
            self._run_body(stmt.finalbody)
        elif isinstance(stmt, _LOOPS):
            if isinstance(stmt, (ast.For, ast.AsyncFor)):
                self._expr(stmt.iter)
            else:
                self._expr(stmt.test)
            outer = self.loop_stack
            self.loop_stack = outer + (id(stmt),)
            if isinstance(stmt, (ast.For, ast.AsyncFor)):
                for name in self._targets(stmt.target):
                    if self._is_key(name):
                        self._bind(name)
            self._run_body(stmt.body)
            self.loop_stack = outer
            self._run_body(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._expr(item.context_expr)
            self._run_body(stmt.body)
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self._expr(child)
            self._assignments(stmt)

    def _branches(self, branch_bodies, extra_exprs=()) -> None:
        """if/try arms: at most one arm executes, so a consumption in each
        arm is not reuse. Take the max per-(name, version) count across
        arms; conservatively re-bind anything an arm rebound. An arm that
        terminates (return/raise/break/continue) never reaches the code
        after the branch, so its counts do not merge into the fall-through
        path — reuse *within* the arm was already recorded while walking it."""
        for e in extra_exprs:
            self._expr(e)
        base = dict(self.consumed)
        merged = dict(self.consumed)
        bound_after: Set[str] = set()
        base_version = dict(self.version)
        base_defs = dict(self.def_loops)
        for body in branch_bodies:
            self.consumed = dict(base)
            self.version = dict(base_version)
            self.def_loops = dict(base_defs)
            self._run_body(body)
            if body and isinstance(body[-1], _TERMINATORS):
                continue
            for k, v in self.consumed.items():
                if v > merged.get(k, 0):
                    merged[k] = v
            for name, ver in self.version.items():
                if ver != base_version.get(name, 0):
                    bound_after.add(name)
        self.consumed = merged
        self.version = dict(base_version)
        self.def_loops = dict(base_defs)
        for name in bound_after:
            self._bind(name)

    def _assignments(self, stmt: ast.stmt) -> None:
        targets: List[ast.AST] = []
        value: Optional[ast.AST] = None
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        elif isinstance(stmt, ast.AugAssign):
            targets, value = [stmt.target], stmt.value
        if value is None:
            return
        is_key_rhs = self._rhs_is_key_source(value)
        for t in targets:
            for name in self._targets(t):
                if is_key_rhs or self._is_key(name):
                    self._bind(name)

    def _expr(self, node: ast.AST, comp_depth: int = 0) -> None:
        """Record consumptions; comprehensions count as loop nesting."""
        if isinstance(node, ast.Call):
            deriving = _last_attr(node.func) in _DERIVING
            for arg in [*node.args, *[kw.value for kw in node.keywords]]:
                if (not deriving and isinstance(arg, ast.Name)
                        and self._is_key(arg.id)):
                    self._consume(arg.id, arg)
                else:
                    self._expr(arg, comp_depth)
            self._expr(node.func, comp_depth)
            return
        if isinstance(node, _COMPREHENSIONS):
            outer = self.loop_stack
            self.loop_stack = outer + (id(node),)
            for child in ast.iter_child_nodes(node):
                self._expr(child, comp_depth + 1)
            self.loop_stack = outer
            return
        if isinstance(node, ast.NamedExpr):
            self._expr(node.value, comp_depth)
            if (isinstance(node.target, ast.Name)
                    and (self._rhs_is_key_source(node.value)
                         or self._is_key(node.target.id))):
                self._bind(node.target.id)
            return
        if isinstance(node, (ast.Lambda, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            return  # separate scope
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.expr, ast.keyword, ast.comprehension)):
                self._expr(child, comp_depth)


@rule("JX001", "PRNG key consumed by >=2 jax.random calls without split/fold_in")
def check_prng_reuse(tree: ast.Module, source: str, path: str):
    for fn in _functions(tree):
        yield from _KeyScope(fn, path).run()


# ---------------------------------------------------------------------------
# JX002 — import-time os.environ snapshot
# ---------------------------------------------------------------------------

def _module_level_statements(tree: ast.Module) -> Iterator[ast.stmt]:
    """Top-level statements plus module-level if/try arms and class bodies —
    everything that executes at import time. Function bodies are excluded."""
    stack: List[ast.stmt] = list(tree.body)
    while stack:
        stmt = stack.pop()
        yield stmt
        if isinstance(stmt, ast.If):
            stack.extend(stmt.body)
            stack.extend(stmt.orelse)
        elif isinstance(stmt, ast.Try):
            stack.extend(stmt.body)
            stack.extend(stmt.finalbody)
            stack.extend(stmt.orelse)
            for h in stmt.handlers:
                stack.extend(h.body)
        elif isinstance(stmt, ast.ClassDef):
            stack.extend(stmt.body)
        elif isinstance(stmt, (ast.With, ast.For, ast.While)):
            stack.extend(stmt.body)
            stack.extend(getattr(stmt, "orelse", []))


def _env_reads(node: ast.AST) -> Iterator[ast.AST]:
    """Yield sub-nodes that read the process environment."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            callee = _dotted(sub.func)
            if callee in ("os.environ.get", "os.getenv", "environ.get",
                          "getenv"):
                yield sub
        elif isinstance(sub, ast.Subscript):
            if (_dotted(sub.value) in ("os.environ", "environ")
                    and isinstance(sub.ctx, ast.Load)):
                yield sub


@rule("JX002", "import-time os.environ read frozen into a module constant")
def check_env_snapshot(tree: ast.Module, source: str, path: str):
    for stmt in _module_level_statements(tree):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        # writes (os.environ[k] = v, setdefault) configure the process —
        # only *reads* snapshot state that can then go stale
        if isinstance(stmt, ast.Assign):
            sources: List[ast.AST] = [stmt.value]
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            sources = [stmt.value] if stmt.value is not None else []
        elif isinstance(stmt, (ast.If, ast.While)):
            # compound statements: _module_level_statements already yields
            # their bodies; only the header expression runs at import here.
            # Walking the whole node would descend into method bodies (a
            # per-call env read inside a class method is *not* a snapshot).
            sources = [stmt.test]
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            sources = [stmt.iter]
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            sources = [item.context_expr for item in stmt.items]
        elif isinstance(stmt, ast.ClassDef):
            sources = [*stmt.bases, *[k.value for k in stmt.keywords],
                       *stmt.decorator_list]
        elif isinstance(stmt, ast.Try):
            sources = []
        else:
            sources = [stmt]
        for src_node in sources:
            for read in _env_reads(src_node):
                yield Finding(
                    "JX002", path, read.lineno, read.col_offset,
                    "module-level os.environ read freezes the value at "
                    "import time (the PR-4 REPRO_HIST_IMPL bug) — resolve "
                    "per call instead, e.g. via "
                    "repro.kernels.dispatch.resolve_impl for impl switches.")


# ---------------------------------------------------------------------------
# JX003 — jit-cache fragmentation / recompile leaks
# ---------------------------------------------------------------------------

_ARRAY_CTORS = {"array", "asarray", "zeros", "ones", "full", "arange",
                "linspace", "eye"}


def _bad_default(node: ast.AST) -> Optional[str]:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return "a mutable (unhashable) literal"
    if isinstance(node, ast.Call) and _last_attr(node.func) in _ARRAY_CTORS:
        return "a freshly constructed array"
    return None


@rule("JX003", "jit wrapper built per call / unhashable defaults in a jit signature")
def check_jit_cache(tree: ast.Module, source: str, path: str):
    # (a) jit-decorated function with unhashable / array defaults
    for fn in _functions(tree):
        if not any(_is_jax_jit(d) for d in fn.decorator_list):
            continue
        args = fn.args
        defaults = list(args.defaults) + [d for d in args.kw_defaults
                                          if d is not None]
        for d in defaults:
            why = _bad_default(d)
            if why:
                yield Finding(
                    "JX003", path, d.lineno, d.col_offset,
                    f"jit-compiled '{fn.name}' has {why} as a default "
                    "argument — unhashable values fragment (or break) the "
                    "jit cache key; pass arrays explicitly and keep "
                    "defaults hashable.")
    # (b) jax.jit(...) built and immediately used inside a function body —
    # a fresh wrapper (empty cache) per invocation, and (c) built per loop
    # iteration anywhere
    for fn in _functions(tree):
        for node in ast.walk(fn):
            target = None
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Call):
                target = node.func           # jax.jit(f)(x)
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Attribute)
                  and isinstance(node.func.value, ast.Call)):
                target = node.func.value     # jax.jit(f).lower(x)
            if target is not None and _is_jax_jit(target.func):
                yield Finding(
                    "JX003", path, target.lineno, target.col_offset,
                    "jax.jit(...) is created and invoked in one expression "
                    "inside a function — every call builds a fresh wrapper "
                    "with an empty cache and recompiles (the serving "
                    "hot-path leak). Hoist the jitted callable out and "
                    "reuse it.")
    for loop in ast.walk(tree):
        if not isinstance(loop, _LOOPS):
            continue
        for node in ast.walk(loop):
            if (isinstance(node, ast.Call) and _is_jax_jit(node.func)
                    and not isinstance(node.func, ast.Call)):
                yield Finding(
                    "JX003", path, node.lineno, node.col_offset,
                    "jax.jit(...) wrapper constructed inside a loop — each "
                    "iteration gets a fresh empty jit cache and recompiles. "
                    "Build the wrapper once outside the loop.")


# ---------------------------------------------------------------------------
# TH001 — lock discipline
# ---------------------------------------------------------------------------

_LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}
_MUTATORS = {"add", "append", "extend", "update", "remove", "discard",
             "clear", "insert", "appendleft", "popleft", "setdefault"}


def _lock_attrs(cls: ast.ClassDef) -> Set[str]:
    """self.X assigned a threading.Lock()/RLock()/Condition() anywhere."""
    out: Set[str] = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Assign):
            continue
        if not (isinstance(node.value, ast.Call)
                and _last_attr(node.value.func) in _LOCK_CTORS):
            continue
        for t in node.targets:
            if (isinstance(t, ast.Attribute) and isinstance(t.value, ast.Name)
                    and t.value.id == "self"):
                out.add(t.attr)
    return out


def _self_attr_of_store(target: ast.AST) -> Optional[str]:
    """'stats' for ``self.stats = ...`` / ``self.stats[...] = ...``."""
    if isinstance(target, ast.Subscript):
        target = target.value
    if (isinstance(target, ast.Attribute)
            and isinstance(target.value, ast.Name) and target.value.id == "self"):
        return target.attr
    return None


class _MethodWrites(ast.NodeVisitor):
    """Collect (attr, locked, node) writes to self.* in one method body,
    plus the set of attrs *read* while a lock is held — a locked read is
    as much a claim that the lock guards the attribute as a locked write
    (the PR-8 admission pattern: mutate via an unlocked ``setdefault``
    helper, read the same dict under the lock in the snapshot path)."""

    def __init__(self, lock_attrs: Set[str]):
        self.lock_attrs = lock_attrs
        self.depth = 0
        self.writes: List[Tuple[str, bool, ast.AST]] = []
        self.locked_reads: Set[str] = set()

    def _record(self, attr: Optional[str], node: ast.AST) -> None:
        if attr is not None:
            self.writes.append((attr, self.depth > 0, node))

    def visit_With(self, node: ast.With) -> None:
        holds = any(
            isinstance(item.context_expr, ast.Attribute)
            and isinstance(item.context_expr.value, ast.Name)
            and item.context_expr.value.id == "self"
            and item.context_expr.attr in self.lock_attrs
            for item in node.items)
        if holds:
            self.depth += 1
        self.generic_visit(node)
        if holds:
            self.depth -= 1

    visit_AsyncWith = visit_With

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._record(_self_attr_of_store(t), node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record(_self_attr_of_store(node.target), node)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._record(_self_attr_of_store(node.target), node)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        # self.attr.add(...) — container mutation through a method
        f = node.func
        if (isinstance(f, ast.Attribute) and f.attr in _MUTATORS
                and isinstance(f.value, ast.Attribute)
                and isinstance(f.value.value, ast.Name)
                and f.value.value.id == "self"):
            self._record(f.value.attr, node)
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        # a Load of self.attr while the lock is held claims the lock
        # guards it — e.g. a stats snapshot built under the lock
        if (self.depth > 0 and isinstance(node.ctx, ast.Load)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
                and node.attr not in self.lock_attrs):
            self.locked_reads.add(node.attr)
        self.generic_visit(node)

    def visit_FunctionDef(self, node):  # nested defs: out of scope
        return

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef


@rule("TH001", "attribute mutated both inside and outside the owning lock")
def check_lock_discipline(tree: ast.Module, source: str, path: str):
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        locks = _lock_attrs(cls)
        if not locks:
            continue
        per_method: Dict[str, List[Tuple[str, bool, ast.AST]]] = {}
        guarded: Set[str] = set()
        for item in cls.body:
            if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if item.name == "__init__":
                continue  # construction precedes concurrency
            visitor = _MethodWrites(locks)
            for stmt in item.body:
                visitor.visit(stmt)
            per_method[item.name] = visitor.writes
            # locked reads count as guard evidence too (the PR-8 admission
            # setdefault bug: the only locked access was the snapshot read)
            guarded |= visitor.locked_reads
        for writes in per_method.values():
            guarded |= {attr for attr, locked, _ in writes
                        if locked and attr not in locks}
        for name, writes in per_method.items():
            if name.endswith("_locked"):
                continue  # convention: caller holds the lock
            for attr, locked, node in writes:
                if attr in guarded and not locked:
                    yield Finding(
                        "TH001", path, node.lineno, node.col_offset,
                        f"'{cls.name}.{attr}' is accessed under a lock "
                        f"elsewhere but mutated without one in '{name}' — "
                        "the PR-4 stats race. Hold the lock here, or rename "
                        "the method '*_locked' if every caller already "
                        "holds it.")


# ---------------------------------------------------------------------------
# PL001 — Pallas block-shape divisibility
# ---------------------------------------------------------------------------

def _has_floordiv(node: ast.AST) -> bool:
    return any(isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.FloorDiv)
               for sub in ast.walk(node))


def _has_divisibility_guard(fn: ast.AST) -> bool:
    """A padding/divisibility guard the kernel wrappers in this repo use:
    pl.cdiv + pad, an ``assert ... % ... == 0``, or ceil-div ``-(-n // b)``."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            callee = _last_attr(node.func)
            # jnp.pad / pl.cdiv and padding helpers (pad_rows, _pad_to_block)
            if callee == "cdiv" or "pad" in callee:
                return True
        if isinstance(node, ast.Assert):
            if any(isinstance(s, ast.BinOp) and isinstance(s.op, ast.Mod)
                   for s in ast.walk(node.test)):
                return True
        # -(-n // block): ceil-div spelled with unary minus
        if (isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub)
                and isinstance(node.operand, ast.BinOp)
                and isinstance(node.operand.op, ast.FloorDiv)
                and isinstance(node.operand.left, ast.UnaryOp)
                and isinstance(node.operand.left.op, ast.USub)):
            return True
    # an explicit if-raise on modulo also counts
    for node in ast.walk(fn):
        if isinstance(node, ast.If):
            test_has_mod = any(isinstance(s, ast.BinOp)
                               and isinstance(s.op, ast.Mod)
                               for s in ast.walk(node.test))
            if test_has_mod and any(isinstance(s, ast.Raise)
                                    for b in node.body for s in ast.walk(b)):
                return True
    return False


# ---------------------------------------------------------------------------
# OB001 — span leaks
# ---------------------------------------------------------------------------

#: receivers that look like tracers: ``tracer.start``, ``self.tracer.start``,
#: ``self._tracer.start`` — the heuristic key that keeps ``thread.start()``
#: and ``profiler.start_trace`` out of scope
_TRACER_RECV_RE = re.compile(r"(^|[._])tracer$", re.IGNORECASE)

#: parents under which a bare read of the span variable does NOT hand it to
#: someone else: attribute access (``sp.end()`` / ``sp.attrs``), truthiness
#: and comparison tests.  Anything else — call argument, keyword, return,
#: yield, container literal, plain aliasing assignment — is an *escape*:
#: ownership (and the duty to end) may have moved, so the rule stays quiet.
_NONESCAPE_PARENTS = (ast.Attribute, ast.Compare, ast.BoolOp, ast.UnaryOp,
                      ast.Expr, ast.If, ast.While, ast.Assert)


def _own_scope(fn: ast.AST) -> Iterator[ast.AST]:
    """Walk ``fn``'s own statements, not nested def/lambda bodies."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _is_end_attr(node: ast.AST, var: str) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == "end"
            and isinstance(node.value, ast.Name) and node.value.id == var)


def _span_suffix(body: List[ast.stmt], assign: ast.stmt
                 ) -> Optional[List[ast.stmt]]:
    """The statements that execute after ``assign``: the rest of its block,
    then the rest of each enclosing block (straight-line approximation)."""
    for i, s in enumerate(body):
        if s is assign:
            return list(body[i + 1:])
        blocks = []
        if isinstance(s, (ast.If, ast.For, ast.AsyncFor, ast.While)):
            blocks = [s.body, s.orelse]
        elif isinstance(s, (ast.With, ast.AsyncWith)):
            blocks = [s.body]
        elif isinstance(s, ast.Try):
            blocks = [s.body, *[h.body for h in s.handlers],
                      s.orelse, s.finalbody]
        for blk in blocks:
            rest = _span_suffix(blk, assign)
            if rest is not None:
                return rest + list(body[i + 1:])
    return None


def _span_states(var: str, stmts, states: Set[Tuple[bool, bool]]
                 ) -> Set[Tuple[bool, bool]]:
    """Fold ``stmts`` over a set of (ended, exited) states.  Loops count
    for nothing (zero iterations is always a possible path); an exited
    state passes through unchanged."""
    for s in stmts:
        nxt: Set[Tuple[bool, bool]] = set()
        for (ended, exited) in states:
            if exited:
                nxt.add((ended, exited))
            else:
                nxt |= _span_stmt(var, s, ended)
        states = nxt
    return states


def _span_stmt(var: str, s: ast.stmt, ended: bool) -> Set[Tuple[bool, bool]]:
    if isinstance(s, _TERMINATORS):
        return {(ended, True)}
    if isinstance(s, ast.If):
        seed = {(ended, False)}
        return (_span_states(var, s.body, seed)
                | _span_states(var, s.orelse, seed))
    if isinstance(s, ast.Try):
        seed = {(ended, False)}
        mid = _span_states(var, s.body + s.orelse, seed)
        for h in s.handlers:
            mid |= _span_states(var, h.body, seed)
        out: Set[Tuple[bool, bool]] = set()
        for (e, x) in mid:
            for (e2, x2) in _span_states(var, s.finalbody, {(e, False)}):
                out.add((e2, x or x2))
        return out
    if isinstance(s, (ast.With, ast.AsyncWith)):
        return _span_states(var, s.body, {(ended, False)})
    if isinstance(s, (ast.For, ast.AsyncFor, ast.While,
                      ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        return {(ended, False)}
    # simple statement: does it end the span?
    if any(_is_end_attr(node, var) for node in ast.walk(s)):
        return {(True, False)}
    return {(ended, False)}


@rule("OB001", "Tracer.start() span not .end()ed on every path")
def check_span_leaks(tree: ast.Module, source: str, path: str):
    for fn in _functions(tree):
        parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(fn):
            for child in ast.iter_child_nodes(node):
                parents[child] = node
        own_list = list(_own_scope(fn))
        own = set(own_list)
        # candidates: var = <something ending in "tracer">.start(...)
        for assign in own_list:
            if not (isinstance(assign, ast.Assign)
                    and len(assign.targets) == 1
                    and isinstance(assign.targets[0], ast.Name)
                    and isinstance(assign.value, ast.Call)
                    and isinstance(assign.value.func, ast.Attribute)
                    and assign.value.func.attr == "start"
                    and _TRACER_RECV_RE.search(
                        _dotted(assign.value.func.value))):
                continue
            var = assign.targets[0].id
            in_nested = skip = rebound = False
            ends_own = False
            for node in ast.walk(fn):
                if node is assign.targets[0]:
                    continue
                if isinstance(node, ast.Name) and node.id == var:
                    if node not in own:
                        in_nested = True  # closure capture: can't reason
                        continue
                    if isinstance(node.ctx, ast.Store):
                        rebound = True
                        continue
                    parent = parents.get(node)
                    if _is_end_attr(parent, var):
                        ends_own = True
                    elif not isinstance(parent, _NONESCAPE_PARENTS):
                        skip = True  # escaped: handed to someone else
            if skip or rebound or in_nested:
                continue
            if not ends_own:
                yield Finding(
                    "OB001", path, assign.lineno, assign.col_offset,
                    f"span '{var}' from Tracer.start() is never .end()ed — "
                    "an unended span never records (it silently vanishes "
                    "from the ring and /v1/trace). Use `with tracer.span("
                    "...)` for scoped work, or end it on every path.")
                continue
            suffix = _span_suffix(fn.body, assign)
            if suffix is None:
                continue
            states = _span_states(var, suffix, {(False, False)})
            if any(not e for (e, _) in states):
                yield Finding(
                    "OB001", path, assign.lineno, assign.col_offset,
                    f"span '{var}' from Tracer.start() is not .end()ed on "
                    "every path — an early return/raise between start and "
                    "end drops the span (and its request's trace) on the "
                    "floor. Use `with tracer.span(...)`, or end the span "
                    "in a finally/on every branch.")


@rule("PL001", "pallas_call grid divides an input dim with no padding guard")
def check_pallas_grid(tree: ast.Module, source: str, path: str):
    for fn in _functions(tree):
        calls = [node for node in ast.walk(fn)
                 if isinstance(node, ast.Call)
                 and _last_attr(node.func) == "pallas_call"]
        if not calls:
            continue
        grid_exprs = []
        for call in calls:
            for kw in call.keywords:
                if kw.arg == "grid":
                    grid_exprs.append((call, kw.value))
        if not grid_exprs:
            continue
        if _has_divisibility_guard(fn):
            continue
        fn_has_floordiv = _has_floordiv(fn)
        for call, grid in grid_exprs:
            if _has_floordiv(grid) or fn_has_floordiv:
                yield Finding(
                    "PL001", path, call.lineno, call.col_offset,
                    "pallas_call grid is computed with // from an input "
                    "dimension but the wrapper has no padding guard (pad + "
                    "pl.cdiv, or an explicit `n % block == 0` check) — odd "
                    "batch shapes silently drop or misread the tail (the "
                    "PR-4 odd-bucket crash).")
