"""jaxlint command line: ``python -m repro.analysis.lint [paths...]``.

Exit codes: 0 — clean (or every finding suppressed/baselined); 1 — at least
one new finding; 2 — usage error. CI runs this over
``src tests benchmarks scripts`` and fails the build on exit 1.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.analysis.lint.core import (Finding, RULES, iter_py_files,
                                      lint_file, load_baseline,
                                      split_baselined, write_baseline)

DEFAULT_PATHS = ("src", "tests", "benchmarks", "scripts")
DEFAULT_BASELINE = ".jaxlint_baseline.json"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="JAX-aware static analysis encoding this repo's shipped "
                    "bug classes (PRNG reuse, env snapshots, jit-cache "
                    "leaks, lock discipline, Pallas grid divisibility).")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files or directories to lint "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file of grandfathered findings "
                         f"(default: {DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file: report everything")
    ap.add_argument("--write-baseline", action="store_true",
                    help="record all current findings as the new baseline "
                         "and exit 0")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="findings only, no summary line")
    args = ap.parse_args(argv)

    # rule registration happens on import of the rules module
    from repro.analysis.lint import rules as _rules  # noqa: F401

    if args.list_rules:
        for rule_id in sorted(RULES):
            desc, _ = RULES[rule_id]
            print(f"{rule_id}  {desc}")
        return 0

    rule_ids = None
    if args.select:
        rule_ids = [r.strip() for r in args.select.split(",") if r.strip()]
        unknown = sorted(set(rule_ids) - set(RULES))
        if unknown:
            print(f"unknown rule id(s): {', '.join(unknown)}; "
                  f"known: {', '.join(sorted(RULES))}", file=sys.stderr)
            return 2

    findings: List[Finding] = []
    n_suppressed = 0
    n_files = 0
    for path in iter_py_files(args.paths):
        n_files += 1
        fs, sup = lint_file(path, rule_ids)
        findings.extend(fs)
        n_suppressed += sup

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(args.baseline)
    new, grandfathered = split_baselined(findings, baseline)

    for f in new:
        print(f.render())
    if not args.quiet:
        extra = []
        if grandfathered:
            extra.append(f"{len(grandfathered)} baselined")
        if n_suppressed:
            extra.append(f"{n_suppressed} suppressed inline")
        tail = f" ({', '.join(extra)})" if extra else ""
        print(f"jaxlint: {len(new)} finding(s) in {n_files} file(s){tail}")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
