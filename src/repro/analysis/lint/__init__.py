"""jaxlint: JAX-aware static analysis encoding this repo's bug classes.

Usage::

    python -m repro.analysis.lint [paths...] [--baseline FILE]

Rules (``--list-rules``):

=======  ==================================================================
JX001    PRNG key consumed by >=2 ``jax.random`` calls without an
         intervening ``split``/``fold_in`` (PR-2 CFM-jitter bug)
JX002    module-level ``os.environ`` read frozen into an import-time
         constant (PR-4 ``REPRO_HIST_IMPL`` bug) — route through
         :func:`repro.kernels.dispatch.resolve_impl`
JX003    ``jax.jit`` wrapper built per call / per loop iteration, or
         unhashable defaults feeding a jit signature (recompile leaks)
TH001    attribute mutated both inside and outside the owning
         ``with self._lock`` (PR-4 serving stats race)
PL001    ``pallas_call`` grid floor-divides an input dim with no padding
         guard (PR-4 odd-bucket crash)
=======  ==================================================================

See :mod:`repro.analysis.lint.core` for suppression (``# jaxlint:
disable=RULE``) and baseline semantics, and
:mod:`repro.analysis.runtime` for the runtime complement
(``recompile_budget``).
"""
from repro.analysis.lint.core import (Finding, RULES, iter_py_files,  # noqa: F401
                                      lint_file, lint_source,
                                      load_baseline, parse_suppressions,
                                      split_baselined, write_baseline)
from repro.analysis.lint import rules as _rules  # noqa: F401 — registers rules
from repro.analysis.lint.cli import main  # noqa: F401
