"""Runtime complement to jaxlint: pin jit compile counts over a code region.

jaxlint's JX003 catches recompile leaks *statically* (a ``jax.jit`` wrapper
built per call owns a fresh, empty cache). This module catches them
*dynamically*: :func:`capture_compiles` listens to :func:`jax.log_compiles`
output and :func:`recompile_budget` asserts a compile budget — the serving
tests use budget 0 to pin "warmup compiled everything; steady state reuses
cached programs".

Usage (directly, or through the ``recompile_budget`` pytest fixture in
``tests/conftest.py``)::

    with recompile_budget(0):
        server.generate(50, seed=11)      # must hit only warm caches

    with recompile_budget(2) as watch:
        f(x); f(y)                        # at most two fresh programs
    print(watch.compile_events)
"""
from __future__ import annotations

import contextlib
import logging
from typing import Iterator, List


class CompileWatch:
    """Log lines the ``jax`` logger emitted inside a watched region."""

    def __init__(self) -> None:
        self.messages: List[str] = []

    @property
    def compile_events(self) -> List[str]:
        """Every compilation *or tracing* line — 'Compiling', 'Finished
        XLA compilation', 'Finished tracing' across jax versions."""
        return [m for m in self.messages if "ompil" in m or "tracing" in m]

    @property
    def n_compiles(self) -> int:
        """Programs actually compiled (one 'Compiling <fn>' line each);
        excludes re-tracing lines, so it is the budget-friendly count."""
        return sum(1 for m in self.messages if "ompiling" in m)


class _CompileLog(logging.Handler):
    def __init__(self, sink: List[str]):
        super().__init__(level=logging.DEBUG)
        self._sink = sink

    def emit(self, record: logging.LogRecord) -> None:
        self._sink.append(record.getMessage())


@contextlib.contextmanager
def capture_compiles() -> Iterator[CompileWatch]:
    """Collect jax compile/tracing log lines for the with-block; no assert."""
    import jax

    watch = CompileWatch()
    handler = _CompileLog(watch.messages)
    logger = logging.getLogger("jax")
    logger.addHandler(handler)
    try:
        with jax.log_compiles():
            yield watch
    finally:
        logger.removeHandler(handler)


@contextlib.contextmanager
def recompile_budget(budget: int = 0) -> Iterator[CompileWatch]:
    """Assert at most ``budget`` compiles happen inside the with-block.

    ``budget=0`` is strict: *any* compile or tracing activity fails — the
    zero-recompile pin the serving tests rely on. A positive budget counts
    compiled programs only (re-traces that hit the cache are free).
    Exceptions raised by the block propagate unchanged (no masking).
    """
    with capture_compiles() as watch:
        yield watch
    if budget == 0:
        assert not watch.compile_events, (
            "expected zero jit compiles/traces in this region, got "
            f"{len(watch.compile_events)}: {watch.compile_events}")
    else:
        assert watch.n_compiles <= budget, (
            f"compile budget {budget} exceeded: {watch.n_compiles} programs "
            f"compiled: {[m for m in watch.messages if 'ompiling' in m]}")
