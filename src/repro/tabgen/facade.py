"""TabularGenerator: schema-aware fit / generate / impute / save / load.

The front door for tabular data. Composes:

* :class:`TabularSchema` (``core/mixed_types.py``) — categorical columns are
  one-hot encoded before fitting and re-argmaxed after generation, integer
  columns rounded/clipped (paper App. D.1);
* :func:`fit_artifacts` — the batched ensemble trainer;
* :func:`sample` — the jitted class-vmapped sampler (registry-selected);
* :func:`impute` — bridge-clamped conditional solve;
* :class:`ForestArtifacts` ``save``/``load`` — the schema rides along in the
  JSON sidecar, so a serving host reconstructs the full generator from the
  artifact pair alone.

    gen = TabularGenerator(ForestConfig(n_t=8), cat_cols=[2], int_cols=[1])
    gen.fit(X, y).save("model")
    Xg, yg = TabularGenerator.load("model").generate(1000, seed=1)
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.config import ForestConfig
from repro.core.mixed_types import TabularSchema, _isnan
from repro.tabgen.artifacts import ForestArtifacts
from repro.tabgen.fitting import fit_artifacts
from repro.tabgen.imputation import impute as _impute
from repro.tabgen.sampling import sample_async as _sample_async


class _DecodingHandle:
    """Schema-aware wrapper over an in-flight sample: decode on resolve.
    Trace context (``tag``/``batch_id``/``trace_ids``) passes through to
    the wrapped :class:`~repro.tabgen.sampling.SampleHandle`."""

    def __init__(self, handle, schema: TabularSchema):
        self._handle = handle
        self._schema = schema

    def result(self):
        X, y = self._handle.result()
        return self._schema.decode(X), y

    def tag(self, **kwargs):
        self._handle.tag(**kwargs)
        return self

    @property
    def batch_id(self):
        return self._handle.batch_id

    @property
    def trace_ids(self):
        return self._handle.trace_ids


class TabularGenerator:
    def __init__(self, fcfg: ForestConfig = ForestConfig(), *,
                 cat_cols: Sequence[int] = (), int_cols: Sequence[int] = (),
                 schema: Optional[TabularSchema] = None):
        self.fcfg = fcfg
        self.schema = schema or (TabularSchema(cat_cols, int_cols)
                                 if (cat_cols or int_cols) else None)
        self.artifacts: Optional[ForestArtifacts] = None

    # -- lifecycle ----------------------------------------------------------

    def fit(self, X, y=None, *, seed: int = 0,
            checkpoint_dir: Optional[str] = None, resume: bool = False,
            ensembles_per_batch: int = 0, mesh=None,
            pipeline="auto") -> "TabularGenerator":
        """``mesh`` routes training through the shard_map trainer: a
        :class:`jax.sharding.Mesh`, ``"auto"`` (one mesh over every visible
        device), or ``None`` for the single-device path. ``pipeline``
        (``"auto"`` | :class:`~repro.tabgen.fitting.PipelineConfig` |
        ``None``) picks the double-buffered vs serial distributed loop.

        ``X`` may be a :class:`repro.data.store.DatasetStore` for
        out-of-core fits (see :func:`repro.tabgen.fit_artifacts`) — but
        only schema-free: a schema re-encodes raw rows in memory, so
        encode before ingesting and fit the store without one."""
        if self.schema is not None:
            from repro.data.store import DatasetStore
            if isinstance(X, DatasetStore):
                raise ValueError(
                    "schema-aware fit needs raw in-memory rows (the schema "
                    "one-hot/integer-encodes them before training); encode "
                    "with TabularSchema before ingesting, then fit the "
                    "store without cat_cols/int_cols/schema")
            self.schema.fit(X)
            X = self.schema.encode(X)
        self.artifacts = fit_artifacts(
            X, y, self.fcfg, seed=seed, checkpoint_dir=checkpoint_dir,
            resume=resume, ensembles_per_batch=ensembles_per_batch,
            mesh=mesh, pipeline=pipeline)
        return self

    def generate(self, n: int, *, sampler: Optional[str] = None,
                 seed: int = 0, pad_to: Optional[int] = None, mesh=None,
                 impl: Optional[str] = None):
        """``mesh`` (``"auto"`` | Mesh | None) shards the solve across
        devices; ``impl`` picks the tree-predict backend (xla | pallas |
        pallas_interpret) — both forwarded to :func:`repro.tabgen.sample`.

        Implemented as ``generate_async(...).result()`` so the synchronous
        path and the serving control plane's in-flight path share one jit
        cache and one decode path by construction."""
        return self.generate_async(n, sampler=sampler, seed=seed,
                                   pad_to=pad_to, mesh=mesh,
                                   impl=impl).result()

    def generate_async(self, n: int, *, sampler: Optional[str] = None,
                       seed: int = 0, pad_to: Optional[int] = None,
                       mesh=None, impl: Optional[str] = None):
        """Non-blocking generate: dispatches the device program and returns
        a handle whose ``result()`` finishes the call (block on device,
        unpad/shuffle, schema decode). The seam the serving scheduler's
        in-flight batching is built on — dispatch batch ``k+1`` while a
        waiter thread resolves batch ``k``."""
        assert self.artifacts is not None, "fit() or load() first"
        handle = _sample_async(self.artifacts, n, sampler=sampler, seed=seed,
                               pad_to=pad_to, mesh=mesh, impl=impl)
        if self.schema is None:
            return handle
        return _DecodingHandle(handle, self.schema)

    def impute(self, X_missing, y=None, *, seed: int = 0,
               refine_rounds: int = 3, impl: Optional[str] = None):
        assert self.artifacts is not None, "fit() or load() first"
        if self.schema is None:
            return _impute(self.artifacts, X_missing, y, seed=seed,
                           refine_rounds=refine_rounds, impl=impl)
        Z = self.schema.encode_with_missing(X_missing)
        filled = _impute(self.artifacts, Z, y, seed=seed,
                         refine_rounds=refine_rounds, impl=impl)
        out = self.schema.decode(filled)
        # observed raw cells are authoritative — only NaN cells get imputed
        X_missing = np.asarray(X_missing)
        return np.where(_isnan(X_missing), out, X_missing)

    # -- persistence --------------------------------------------------------

    def save(self, path: str) -> str:
        assert self.artifacts is not None, "fit() first"
        extra = {"schema": self.schema.to_dict()} if self.schema else {}
        return self.artifacts.save(path, extra_meta=extra)

    @classmethod
    def load(cls, path: str) -> "TabularGenerator":
        meta = ForestArtifacts.load_meta(path)
        artifacts = ForestArtifacts.load(path, meta=meta)
        schema = (TabularSchema.from_dict(meta["schema"])
                  if meta.get("schema") else None)
        gen = cls(artifacts.config, schema=schema)
        gen.artifacts = artifacts
        return gen
