"""Sampler registry: named ODE/SDE solvers behind one calling convention.

The seed code dispatched solvers with an ``if/elif`` chain inside
``generate()``; adding a solver meant editing the trainer class. Here each
solver registers itself under a name with the interpolant family it
integrates, and :func:`repro.tabgen.sampling.sample` looks it up — new
solvers are one decorated function away.

Unified signature (extra knobs arrive as keywords and may be ignored):

    fn(x1, forests, *, depth, n_t, ts, key, eps, impl) -> x0

``impl`` is the tree-predict backend (``xla`` | ``pallas`` |
``pallas_interpret``) that :func:`repro.tabgen.sampling.sample` resolves
per call; solvers just forward it to :func:`~repro.forest.packed.predict_forest`.

``forests`` is a :class:`PackedForest` whose arrays carry a leading
``[n_t]`` timestep axis; ``ts`` is the (possibly non-uniform) grid the
forests were trained on.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Tuple

from repro.core import generate as G


class SamplerSpec(NamedTuple):
    fn: Callable            # unified-signature solver
    method: str             # "flow" | "diffusion" — interpolant it solves
    stochastic: bool        # consumes the PRNG key


_REGISTRY: Dict[str, SamplerSpec] = {}


def register_sampler(name: str, *, method: str, stochastic: bool = False):
    """Decorator: register ``fn`` under ``name``. Last registration wins so
    downstream code can override a stock solver."""

    def deco(fn: Callable) -> Callable:
        _REGISTRY[name] = SamplerSpec(fn, method, stochastic)
        return fn

    return deco


def get_sampler(name: str) -> SamplerSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown sampler {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def list_samplers(method: str = None) -> Tuple[str, ...]:
    return tuple(sorted(n for n, s in _REGISTRY.items()
                        if method is None or s.method == method))


def default_sampler(method: str, diff_sampler: str = "ddim") -> str:
    """The config-implied sampler name (mirrors the old if/elif dispatch)."""
    return "euler" if method == "flow" else diff_sampler


# ---------------------------------------------------------------------------
# stock solvers
# ---------------------------------------------------------------------------

@register_sampler("euler", method="flow")
def _euler(x1, forests, *, depth, n_t, ts, key=None, eps=0.0, impl=None):
    return G.flow_euler(x1, forests, depth, n_t, ts=ts, impl=impl)


@register_sampler("heun", method="flow")
def _heun(x1, forests, *, depth, n_t, ts, key=None, eps=0.0, impl=None):
    return G.flow_heun(x1, forests, depth, n_t, ts=ts, impl=impl)


@register_sampler("ddim", method="diffusion")
def _ddim(x1, forests, *, depth, n_t, ts, key=None, eps=1e-3, impl=None):
    return G.diffusion_ddim(x1, forests, depth, n_t, eps, ts=ts, impl=impl)


@register_sampler("em", method="diffusion", stochastic=True)
def _em(x1, forests, *, depth, n_t, ts, key, eps=1e-3, impl=None):
    return G.diffusion_em(x1, forests, depth, n_t, eps, key, ts=ts, impl=impl)
