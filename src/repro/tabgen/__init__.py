"""Composable tabular-generation API (paper's ForestFlow/ForestDiffusion).

Layers, bottom-up:

* :mod:`repro.tabgen.artifacts`  — :class:`ForestArtifacts`, the trained
  model as a registered JAX pytree with ``save``/``load``.
* :mod:`repro.tabgen.samplers`   — named solver registry
  (``euler``/``heun`` for flow, ``ddim``/``em`` for diffusion);
  ``@register_sampler`` adds more without touching the trainer.
* :mod:`repro.tabgen.fitting`    — :func:`fit_artifacts`; ``mesh=`` routes
  through the shard_map trainer (:mod:`repro.forest.distributed`) with
  streamed row shards and the ensemble grid sharded on the model axis,
  double-buffered by default (:class:`PipelineConfig`: prefetch thread for
  input build, writer thread for gather + async checkpointing).
* :mod:`repro.tabgen.sampling`   — :func:`sample`, one jitted class-vmapped
  device program per generate call; ``mesh=`` shards it (classes on the
  model axis, rows on the data axes) and ``impl=`` picks the tree-predict
  backend (XLA reference vs the Pallas kernel), resolved per call.
* :mod:`repro.tabgen.imputation` — :func:`impute`.
* :mod:`repro.tabgen.facade`     — :class:`TabularGenerator`, the
  schema-aware fit/generate/impute/save/load front door.

``repro.core.forest_flow.ForestGenerativeModel`` remains as a deprecation
shim over these pieces.
"""
from repro.tabgen.artifacts import ForestArtifacts  # noqa: F401
from repro.tabgen.facade import TabularGenerator  # noqa: F401
from repro.tabgen.fitting import (  # noqa: F401
    PipelineConfig, class_stats_streaming, extend_artifacts, fit_artifacts,
    prepare_classes)
from repro.tabgen.imputation import impute  # noqa: F401
from repro.tabgen.samplers import (  # noqa: F401
    default_sampler, get_sampler, list_samplers, register_sampler)
from repro.tabgen.sampling import (  # noqa: F401
    SampleHandle, sample, sample_async, sample_labels, sample_loop_reference)
