"""Generation: one jitted, class-vmapped solve per call.

The seed ``generate()`` looped over classes in Python, re-wrapped (and
re-uploaded) each class's forests into a :class:`PackedForest`, and launched
one solver program per class — ``n_y`` device dispatches per call. Here the
whole call is a single program: noise is drawn on device, the chosen sampler
integrates all classes at once (``vmap`` over the stacked ``[n_y]`` axis of
:class:`ForestArtifacts`), per-class unscaling happens inside the same
program, and padding rows (classes get unequal row counts) are dropped on
the host afterwards.

``pad_to`` rounds the per-class row budget up to a fixed bucket so a serving
host (:mod:`repro.launch.serve_forest`) can pre-compile one program per
(sampler, bucket) and reuse it for every request size below the bucket.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import interpolants as itp
from repro.forest.packed import PackedForest
from repro.tabgen.artifacts import ForestArtifacts, unscale
from repro.tabgen.samplers import default_sampler, get_sampler


def sample_labels(counts: np.ndarray, n: int, rng: np.random.Generator,
                  mode: str = "label") -> np.ndarray:
    """Class indices for ``n`` rows. ``label`` = deterministic empirical
    proportions (paper C.4); ``multinomial`` = iid draws."""
    counts = np.asarray(counts)
    if mode == "multinomial":
        probs = counts / counts.sum()
        idx = rng.choice(len(counts), size=n, p=probs)
    else:
        reps = np.floor(n * counts / counts.sum()).astype(int)
        rem = n - reps.sum()
        frac = n * counts / counts.sum() - reps
        extra = np.argsort(-frac)[:rem]
        reps[extra] += 1
        idx = np.repeat(np.arange(len(counts)), reps)
    idx.sort()
    return idx


@partial(jax.jit, static_argnames=("solver_fn", "m", "depth", "n_t",
                                   "multi_output", "eps"))
def _solve_all_classes(feat, thr_val, leaf, keys, mins, maxs, ts, *,
                       solver_fn, m: int, depth: int, n_t: int,
                       multi_output: bool, eps: float):
    """[n_t, n_y, ...] forests -> [n_y, m, p] unscaled samples; one program.

    The jit cache key is (solver fn, bucket m, forest shapes) — repeat calls
    at the same bucket reuse the compiled program, and keying on the
    resolved *function* (not its registry name) means re-registering a
    sampler under an existing name correctly invalidates the cache.
    """

    def one_class(feat_c, thr_c, leaf_c, key_c, mn, mx):
        k_x1, k_solve = jax.random.split(key_c)
        # counter-based per-row noise: row i draws the same x1 whatever the
        # bucket m, so deterministic samplers are padding-invariant (a
        # request served at bucket 256 equals the same request at 1024)
        row_keys = jax.vmap(jax.random.fold_in, (None, 0))(k_x1, jnp.arange(m))
        x1 = jax.vmap(
            lambda k: jax.random.normal(k, (mn.shape[0],), jnp.float32)
        )(row_keys)
        forests = PackedForest(feat_c, thr_c, leaf_c, multi_output)
        x0 = solver_fn(x1, forests, depth=depth, n_t=n_t, ts=ts,
                       key=k_solve, eps=eps)
        return unscale(x0, mn, mx)

    return jax.vmap(one_class, in_axes=(1, 1, 1, 0, 0, 0))(
        feat, thr_val, leaf, keys, mins, maxs)


def _resolve_sampler(fcfg, sampler: Optional[str]):
    """Name -> spec, validated against the artifacts' interpolant family."""
    name = sampler or default_sampler(fcfg.method, fcfg.diff_sampler)
    spec = get_sampler(name)
    if spec.method != fcfg.method:
        raise ValueError(
            f"sampler {name!r} integrates {spec.method!r} but artifacts "
            f"were trained with method={fcfg.method!r}")
    return name, spec


def sample(artifacts: ForestArtifacts, n: int, *,
           sampler: Optional[str] = None, seed: int = 0,
           pad_to: Optional[int] = None):
    """Generate ``n`` rows (and their labels) from trained artifacts.

    One device dispatch regardless of the number of classes. ``pad_to``
    fixes the per-class row bucket (>= the largest per-class request) for
    jit-cache-friendly serving.
    """
    fcfg = artifacts.config
    _, spec = _resolve_sampler(fcfg, sampler)
    rng = np.random.default_rng(seed)
    label_idx = sample_labels(artifacts.counts, n, rng, fcfg.label_sampler)
    n_y = artifacts.n_y
    per_class = np.bincount(label_idx, minlength=n_y)
    m = int(per_class.max())
    if pad_to is not None:
        if pad_to < m:
            raise ValueError(f"pad_to={pad_to} < largest class batch {m}")
        m = int(pad_to)
    ts = jnp.asarray(itp.timesteps(fcfg.method, fcfg.n_t, fcfg.eps_diff,
                                   fcfg.t_schedule))
    keys = jax.random.split(jax.random.PRNGKey(seed + 7), n_y)
    x_all = _solve_all_classes(
        artifacts.feat, artifacts.thr_val, artifacts.leaf, keys,
        artifacts.mins, artifacts.maxs, ts,
        solver_fn=spec.fn, m=m, depth=fcfg.max_depth, n_t=fcfg.n_t,
        multi_output=fcfg.multi_output, eps=fcfg.eps_diff)
    x_all = np.asarray(x_all)                       # [n_y, m, p]
    X = np.concatenate([x_all[yi, :c] for yi, c in enumerate(per_class)])
    y = np.repeat(np.asarray(artifacts.classes), per_class)
    perm = rng.permutation(len(X))
    return X[perm], y[perm]


def sample_loop_reference(artifacts: ForestArtifacts, n: int, *,
                          sampler: Optional[str] = None, seed: int = 0
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """The pre-redesign path: one solver dispatch per class, host-side
    unscaling. Kept as the baseline for ``benchmarks/bench_generation.py``
    (and as executable documentation of what the vmapped path replaced)."""
    fcfg = artifacts.config
    _, spec = _resolve_sampler(fcfg, sampler)
    rng = np.random.default_rng(seed)
    label_idx = sample_labels(artifacts.counts, n, rng, fcfg.label_sampler)
    key = jax.random.PRNGKey(seed + 7)
    ts = jnp.asarray(itp.timesteps(fcfg.method, fcfg.n_t, fcfg.eps_diff,
                                   fcfg.t_schedule))
    mins = np.asarray(artifacts.mins)
    maxs = np.asarray(artifacts.maxs)
    outs, labels = [], []
    for yi in range(artifacts.n_y):
        n_c = int((label_idx == yi).sum())
        if n_c == 0:
            continue
        key, k1, k2 = jax.random.split(key, 3)
        x1 = jax.random.normal(k1, (n_c, artifacts.p), jnp.float32)
        x0 = spec.fn(x1, artifacts.class_forest(yi), depth=fcfg.max_depth,
                     n_t=fcfg.n_t, ts=ts, key=k2, eps=fcfg.eps_diff)
        outs.append(unscale(np.asarray(x0), mins[yi], maxs[yi]))
        labels.append(np.full((n_c,), artifacts.classes[yi]))
    X = np.concatenate(outs, axis=0)
    y = np.concatenate(labels, axis=0)
    perm = rng.permutation(len(X))
    return X[perm], y[perm]
