"""Generation: one jitted, class-vmapped solve per call.

The seed ``generate()`` looped over classes in Python, re-wrapped (and
re-uploaded) each class's forests into a :class:`PackedForest`, and launched
one solver program per class — ``n_y`` device dispatches per call. Here the
whole call is a single program: noise is drawn on device, the chosen sampler
integrates all classes at once (``vmap`` over the stacked ``[n_y]`` axis of
:class:`ForestArtifacts`), per-class unscaling happens inside the same
program, and padding rows (classes get unequal row counts) are dropped on
the host afterwards.

``pad_to`` rounds the per-class row budget up to a fixed bucket so a serving
host (:mod:`repro.launch.serve_forest`) can pre-compile one program per
(sampler, bucket) and reuse it for every request size below the bucket.

``mesh`` shards the solve the way ``fit_artifacts`` shards training: the
class-vmapped axis over the ``model`` mesh axis, rows over the data axes
(GSPMD sharding constraints inside the one jitted program — noise is drawn
per (class, row) counter, so the sharded solve is value-identical to the
single-device one). ``impl`` picks the tree-traversal backend and is
resolved per call (argument > ``ForestConfig.predict_impl`` >
``REPRO_TREE_PREDICT_IMPL`` > ``xla``).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core import interpolants as itp
from repro.forest.packed import PackedForest
from repro.kernels.dispatch import resolve_impl
from repro.kernels.tree_predict.ops import ENV_VAR as _PREDICT_ENV
from repro.tabgen.artifacts import ForestArtifacts, solve_axes, unscale
from repro.tabgen.samplers import default_sampler, get_sampler


def sample_labels(counts: np.ndarray, n: int, rng: np.random.Generator,
                  mode: str = "label") -> np.ndarray:
    """Class indices for ``n`` rows. ``label`` = deterministic empirical
    proportions (paper C.4); ``multinomial`` = iid draws."""
    counts = np.asarray(counts)
    if mode == "multinomial":
        probs = counts / counts.sum()
        idx = rng.choice(len(counts), size=n, p=probs)
    else:
        reps = np.floor(n * counts / counts.sum()).astype(int)
        rem = n - reps.sum()
        frac = n * counts / counts.sum() - reps
        extra = np.argsort(-frac)[:rem]
        reps[extra] += 1
        idx = np.repeat(np.arange(len(counts)), reps)
    idx.sort()
    return idx


def resolve_mesh(mesh):
    """``"auto"`` | Mesh | None -> Mesh | None (mirrors ``fit_artifacts``).

    Public: the serving host (:mod:`repro.launch.serve_forest`) resolves its
    ``mesh=`` knob through the same contract as :func:`sample`.
    """
    if mesh is None or isinstance(mesh, Mesh):
        return mesh
    if mesh == "auto":
        from repro.launch.mesh import auto_forest_mesh
        return auto_forest_mesh()
    raise ValueError(f"mesh={mesh!r}: expected a Mesh, None, or 'auto'")


@partial(jax.jit, static_argnames=("solver_fn", "m", "depth", "n_t",
                                   "multi_output", "eps", "impl", "mesh"))
def _solve_all_classes(feat, thr_val, leaf, keys, mins, maxs, ts, *,
                       solver_fn, m: int, depth: int, n_t: int,
                       multi_output: bool, eps: float, impl: str = "xla",
                       mesh: Optional[Mesh] = None):
    """[n_t, n_y, ...] forests -> [n_y, m, p] unscaled samples; one program.

    The jit cache key is (solver fn, bucket m, forest shapes, impl, mesh) —
    repeat calls at the same bucket reuse the compiled program, and keying
    on the resolved *function* (not its registry name) means re-registering
    a sampler under an existing name correctly invalidates the cache.

    With a ``mesh``, sharding constraints partition the program: the class
    axis over ``model`` (when divisible), rows over the data axes. All the
    math is per-(class, row) deterministic, so the partitioned program
    computes the same values as the single-device one.
    """
    if mesh is not None:
        model, rows = solve_axes(mesh, feat.shape[1])

        def cns(arr, *spec):
            return jax.lax.with_sharding_constraint(
                arr, NamedSharding(mesh, PartitionSpec(*spec)))

        feat = cns(feat, None, model)
        thr_val = cns(thr_val, None, model)
        leaf = cns(leaf, None, model)
        keys = cns(keys, model)
        mins = cns(mins, model)
        maxs = cns(maxs, model)

    def one_class(feat_c, thr_c, leaf_c, key_c, mn, mx):
        k_x1, k_solve = jax.random.split(key_c)
        # counter-based per-row noise: row i draws the same x1 whatever the
        # bucket m, so deterministic samplers are padding-invariant (a
        # request served at bucket 256 equals the same request at 1024)
        row_keys = jax.vmap(jax.random.fold_in, (None, 0))(k_x1, jnp.arange(m))
        x1 = jax.vmap(
            lambda k: jax.random.normal(k, (mn.shape[0],), jnp.float32)
        )(row_keys)
        forests = PackedForest(feat_c, thr_c, leaf_c, multi_output)
        x0 = solver_fn(x1, forests, depth=depth, n_t=n_t, ts=ts,
                       key=k_solve, eps=eps, impl=impl)
        return unscale(x0, mn, mx)

    out = jax.vmap(one_class, in_axes=(1, 1, 1, 0, 0, 0))(
        feat, thr_val, leaf, keys, mins, maxs)
    if mesh is not None:
        out = cns(out, model, rows, None)
    return out


def _resolve_sampler(fcfg, sampler: Optional[str]):
    """Name -> spec, validated against the artifacts' interpolant family."""
    name = sampler or default_sampler(fcfg.method, fcfg.diff_sampler)
    spec = get_sampler(name)
    if spec.method != fcfg.method:
        raise ValueError(
            f"sampler {name!r} integrates {spec.method!r} but artifacts "
            f"were trained with method={fcfg.method!r}")
    return name, spec


class SampleHandle:
    """An in-flight :func:`sample`: device work dispatched, host finish
    deferred.

    Holds the (asynchronously executing) ``[n_y, m, p]`` device array plus
    the host-side bookkeeping needed to finish the call. ``result()`` blocks
    until the device values are ready, then unpads and shuffles exactly the
    way the synchronous path does — so ``sample_async(...).result()`` is
    bit-identical to ``sample(...)``. A serving waiter thread can resolve
    handles while the dispatcher admits the next batch (in-flight batching:
    queue wait no longer stacks on device time)."""

    def __init__(self, x_dev, per_class, classes, rng):
        self._x_dev = x_dev
        self._per_class = per_class
        self._classes = classes
        self._rng = rng
        # trace context, stamped by the serving scheduler via tag(): which
        # coalesced batch this dispatch is, and which request traces ride it
        self.batch_id: Optional[int] = None
        self.trace_ids: Tuple[str, ...] = ()

    def tag(self, *, batch_id: Optional[int] = None,
            trace_ids: Sequence[str] = ()) -> "SampleHandle":
        """Attach serving trace context (best-effort metadata; never read
        by the sampling math).  Returns self for chaining."""
        self.batch_id = batch_id
        self.trace_ids = tuple(trace_ids)
        return self

    def result(self) -> Tuple[np.ndarray, np.ndarray]:
        x_all = np.asarray(self._x_dev)             # blocks: [n_y, m, p]
        X = np.concatenate([x_all[yi, :c]
                            for yi, c in enumerate(self._per_class)])
        y = np.repeat(self._classes, self._per_class)
        perm = self._rng.permutation(len(X))
        return X[perm], y[perm]


def sample_async(artifacts: ForestArtifacts, n: int, *,
                 sampler: Optional[str] = None, seed: int = 0,
                 pad_to: Optional[int] = None, mesh=None,
                 impl: Optional[str] = None) -> SampleHandle:
    """Dispatch a generate call without blocking on the device.

    Everything up to (and including) the jitted solve runs here — jax
    dispatch is asynchronous, so this returns as soon as the program is
    enqueued. The returned :class:`SampleHandle` finishes the call;
    :func:`sample` is literally ``sample_async(...).result()``, so both
    paths share one jit cache and one output distribution by construction.
    """
    fcfg = artifacts.config
    _, spec = _resolve_sampler(fcfg, sampler)
    impl = resolve_impl(impl, fcfg.predict_impl, env_var=_PREDICT_ENV)
    mesh = resolve_mesh(mesh)
    rng = np.random.default_rng(seed)
    label_idx = sample_labels(artifacts.counts, n, rng, fcfg.label_sampler)
    n_y = artifacts.n_y
    per_class = np.bincount(label_idx, minlength=n_y)
    m = int(per_class.max())
    if pad_to is not None:
        if pad_to < m:
            raise ValueError(f"pad_to={pad_to} < largest class batch {m}")
        m = int(pad_to)
    ts = jnp.asarray(itp.timesteps(fcfg.method, fcfg.n_t, fcfg.eps_diff,
                                   fcfg.t_schedule))
    keys = jax.random.split(jax.random.PRNGKey(seed + 7), n_y)
    x_all = _solve_all_classes(
        artifacts.feat, artifacts.thr_val, artifacts.leaf, keys,
        artifacts.mins, artifacts.maxs, ts,
        solver_fn=spec.fn, m=m, depth=fcfg.max_depth, n_t=fcfg.n_t,
        multi_output=fcfg.multi_output, eps=fcfg.eps_diff, impl=impl,
        mesh=mesh)
    return SampleHandle(x_all, per_class, np.asarray(artifacts.classes), rng)


def sample(artifacts: ForestArtifacts, n: int, *,
           sampler: Optional[str] = None, seed: int = 0,
           pad_to: Optional[int] = None, mesh=None,
           impl: Optional[str] = None):
    """Generate ``n`` rows (and their labels) from trained artifacts.

    One device dispatch regardless of the number of classes. ``pad_to``
    fixes the per-class row bucket (>= the largest per-class request) for
    jit-cache-friendly serving. ``mesh`` (``"auto"`` | Mesh | None) shards
    the solve — classes on the model axis, rows on the data axes — for a
    fixed seed the output matches the single-device solve. ``impl`` picks
    the tree-predict backend; pre-shard the artifacts once with
    :meth:`ForestArtifacts.shard` to avoid a per-call reshard when serving.
    """
    return sample_async(artifacts, n, sampler=sampler, seed=seed,
                        pad_to=pad_to, mesh=mesh, impl=impl).result()


def sample_loop_reference(artifacts: ForestArtifacts, n: int, *,
                          sampler: Optional[str] = None, seed: int = 0
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """The pre-redesign path: one solver dispatch per class, host-side
    unscaling. Kept as the baseline for ``benchmarks/bench_generation.py``
    (and as executable documentation of what the vmapped path replaced)."""
    fcfg = artifacts.config
    _, spec = _resolve_sampler(fcfg, sampler)
    rng = np.random.default_rng(seed)
    label_idx = sample_labels(artifacts.counts, n, rng, fcfg.label_sampler)
    key = jax.random.PRNGKey(seed + 7)
    ts = jnp.asarray(itp.timesteps(fcfg.method, fcfg.n_t, fcfg.eps_diff,
                                   fcfg.t_schedule))
    mins = np.asarray(artifacts.mins)
    maxs = np.asarray(artifacts.maxs)
    outs, labels = [], []
    for yi in range(artifacts.n_y):
        n_c = int((label_idx == yi).sum())
        if n_c == 0:
            continue
        key, k1, k2 = jax.random.split(key, 3)
        x1 = jax.random.normal(k1, (n_c, artifacts.p), jnp.float32)
        x0 = spec.fn(x1, artifacts.class_forest(yi), depth=fcfg.max_depth,
                     n_t=fcfg.n_t, ts=ts, key=k2, eps=fcfg.eps_diff)
        outs.append(unscale(np.asarray(x0), mins[yi], maxs[yi]))
        labels.append(np.full((n_c,), artifacts.classes[yi]))
    X = np.concatenate(outs, axis=0)
    y = np.concatenate(labels, axis=0)
    perm = rng.permutation(len(X))
    return X[perm], y[perm]
