"""Imputation: REPAINT-style clamping of observed features along the
reverse solve (the companion capability of Jolicoeur-Martineau et al.).

Observed features are clamped to a fixed-noise bridge at every solver step;
the whole solve is then repeated ``refine_rounds`` times from annealed
restart times (re-noising the previous imputation) so the conditioning —
which only becomes informative at small t — propagates back through the
trajectory (RePaint-style refinement for a deterministic solver).

Forests come from the cached :class:`ForestArtifacts` device arrays
(``class_forest`` is a device slice), and ``predict_forest`` is imported
once at module scope — the seed code re-imported it and re-uploaded the
forests inside the per-class loop.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import interpolants as itp
from repro.forest.packed import PackedForest, predict_forest
from repro.kernels.dispatch import resolve_impl
from repro.kernels.tree_predict.ops import ENV_VAR as _PREDICT_ENV
from repro.tabgen.artifacts import ForestArtifacts, rescale, unscale


def impute(artifacts: ForestArtifacts, X_missing, y=None, *, seed: int = 0,
           refine_rounds: int = 3, impl: Optional[str] = None) -> np.ndarray:
    """Fill NaNs in ``X_missing``; observed cells are returned untouched.

    ``impl`` selects the tree-predict backend for every solver step of the
    clamped solve (argument > ``ForestConfig.predict_impl`` > env > xla) —
    the imputation loop inherits the kernel exactly like the samplers do.
    """
    fcfg = artifacts.config
    impl = resolve_impl(impl, fcfg.predict_impl, env_var=_PREDICT_ENV)
    X_missing = np.asarray(X_missing, np.float32)
    n, p = X_missing.shape
    if y is None:
        assert artifacts.n_y == 1, "labels required for conditional models"
        y_idx = np.zeros((n,), int)
    else:
        lut = {c: i for i, c in enumerate(np.asarray(artifacts.classes))}
        y_idx = np.asarray([lut[v] for v in np.asarray(y)])
    mins = np.asarray(artifacts.mins)
    maxs = np.asarray(artifacts.maxs)
    out = X_missing.copy()
    key = jax.random.PRNGKey(seed + 31)
    ts = np.asarray(itp.timesteps(fcfg.method, fcfg.n_t, fcfg.eps_diff,
                                  fcfg.t_schedule))
    for yi in range(artifacts.n_y):
        sel = np.where(y_idx == yi)[0]
        if len(sel) == 0:
            continue
        rows = X_missing[sel]
        mask = ~np.isnan(rows)                      # observed
        obs = rescale(np.nan_to_num(rows), mins[yi], maxs[yi])
        key, k_fix = jax.random.split(key)
        m = jnp.asarray(mask)
        obs_d = jnp.asarray(obs)
        # one fixed noise draw -> observed coords follow a single
        # consistent bridge path across all solver steps
        eps_fix = jax.random.normal(k_fix, (len(sel), p), jnp.float32)
        stacked = artifacts.class_forest(yi)

        x0_est = jnp.zeros((len(sel), p), jnp.float32)
        for r in range(max(1, refine_rounds)):
            # annealed restart: round 0 from pure noise at t=1; later
            # rounds re-noise the previous estimate from smaller t
            frac = 1.0 if r == 0 else float(ts[-1]) * (0.6 ** r)
            i_start = int(np.argmin(np.abs(ts - frac)))
            i_start = max(i_start, 1)
            key, kr = jax.random.split(key)
            eps_r = jax.random.normal(kr, (len(sel), p), jnp.float32)
            t0 = float(ts[i_start])
            if fcfg.method == "flow":
                x = t0 * eps_r + (1 - t0) * x0_est
            else:
                a0, s0 = itp.vp_alpha_sigma(jnp.float32(t0))
                x = a0 * x0_est + s0 * eps_r
            for i in range(i_start, 0, -1):
                t = float(ts[i])
                h_i = float(ts[i] - ts[i - 1])
                f = PackedForest(stacked.feat[i], stacked.thr_val[i],
                                 stacked.leaf[i], fcfg.multi_output)
                if fcfg.method == "flow":
                    bridge = t * eps_fix + (1 - t) * obs_d
                    x = jnp.where(m, bridge, x)
                    x = x - h_i * predict_forest(x, f, fcfg.max_depth,
                                                 impl=impl)
                else:
                    a, s_ = itp.vp_alpha_sigma(jnp.float32(t))
                    x = jnp.where(m, a * obs_d + s_ * eps_fix, x)
                    score = predict_forest(x, f, fcfg.max_depth,
                                           impl=impl)
                    t_next = float(ts[i - 1])
                    a2, s2 = itp.vp_alpha_sigma(jnp.float32(t_next))
                    eps_hat = -s_ * score
                    x0_hat = jnp.clip((x - s_ * eps_hat) / a, -1.5, 1.5)
                    eps_hat = (x - a * x0_hat) / s_
                    x = a2 * x0_hat + s2 * eps_hat
            x0_est = jnp.where(m, obs_d, x)
        vals = unscale(np.asarray(x0_est), mins[yi], maxs[yi])
        filled = np.where(mask, rows, vals)
        out[sel] = filled
    return out
