"""Training: data prep + the batched ensemble fit, producing ForestArtifacts.

Memory discipline (paper §3.3, re-expressed for accelerators):

* Issue 1 — the [n_t, nK, p] array of noised inputs is never built. Each
  ensemble batch constructs its own x_t inside the jitted fit.
* Issue 2 — exactly one copy of X0 lives in memory; noise X1 is *never stored
  at all*: it is regenerated on device from a counter-based PRNG key (a
  strictly stronger version of the shared-memmap fix).
* Issue 3 — trained ensembles are streamed to disk per batch
  (``checkpoint_dir``) and training resumes from the manifest after failure.
  The manifest carries a config fingerprint so a resume can never silently
  mix batches trained under a different configuration.
* Issues 5-7 — classes are sorted/padded into dense [n_y, n_max, p] blocks
  (static-shape slices, no boolean-mask copies), one quantised code matrix is
  shared by all p outputs of an ensemble (DMatrix reuse), and everything is
  fp32.

Algorithmic additions from §3.4: multi-output trees, early stopping on a
fresh-noise validation set, per-class min-max scalers, empirical label
sampling.

Scaling (paper §3.3's 370x-larger-datasets claim): ``fit_artifacts`` also
routes through the shard_map trainer (:mod:`repro.forest.distributed`) when
given a ``mesh`` — rows sharded over the data axes with weight-masked class
conditioning (no padded per-class blocks), the (timestep, class) ensemble
grid sharded over the model axis, and host→device streaming of row chunks so
X never has to fit on a single device. ``mesh="auto"`` builds one from
``jax.devices()``; ``mesh=None`` keeps the single-device path.

Memory model (PR 5): two data routes with different peak-residency classes.

* In-memory single-device route (``mesh=None``, host arrays): peak host
  memory is O(dataset + padded class blocks) — ``prepare_classes`` streams
  the class stats in row chunks and gathers rows straight into the padded
  ``[n_y, n_max, p]`` blocks, so the old full class-sorted intermediate
  copy is gone, but the padded blocks themselves remain. Use it for data
  that comfortably fits in RAM.
* Out-of-core route (``X`` is a :class:`repro.data.store.DatasetStore`):
  always runs the sharded trainer (a 1x1 mesh is built if none is given).
  Class stats and quantile summaries come precomputed from the store
  manifest (no fit-time stats pass at all), and ``build_row_shards``
  gathers each device's row slice directly from the on-disk shards — peak
  *host* memory is O(shard + batch) staging on top of the device-resident
  row shards (which on TPU live in HBM, and in aggregate hold the dataset
  exactly once). No dataset-sized host copy, padded block, or full-column
  sort exists anywhere on this route.

Pipelining (PR 3): the distributed fit loop is a staged producer/consumer
pipeline — a prefetch thread builds batch ``b+1``'s host-side inputs (the
sharded row arrays on first use, per-batch timesteps/classes/PRNG keys)
while batch ``b`` runs on the devices, the main thread only dispatches, and
a writer thread does the deferred ``jax.block_until_ready`` bookkeeping:
gathering each ``BoostResult`` and streaming ``batch_*.npz`` checkpoints +
manifest updates off the critical path. :class:`PipelineConfig` carries the
knobs (prefetch depth, async checkpointing); ``pipeline=None`` falls back to
the PR-2 serial loop, and both paths build bit-identical batches.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import threading
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ForestConfig
from repro.core import interpolants as itp
from repro.data.store import DatasetStore
from repro.forest.binning import edges_with_sentinel, pack_codes, transform
from repro.forest.boosting import fit_ensemble
from repro.obs import default_registry, default_tracer
from repro.tabgen.artifacts import (RESULT_FIELDS, ForestArtifacts,
                                    rescale)
from repro.train import checkpoint as _ckpt


def weighted_edges(x, w, n_bins: int):
    """Quantile edges over the rows with positive weight (padded rows excluded).

    x: [n, p]; w: [n]. Returns [p, n_bins - 1] fp32.
    """
    big = jnp.where(w[:, None] > 0, x, jnp.inf)
    s = jnp.sort(big, axis=0)
    n_real = jnp.sum(w > 0).astype(jnp.float32)
    qs = jnp.arange(1, n_bins, dtype=jnp.float32) / n_bins
    idx = jnp.clip((qs * (n_real - 1.0)).astype(jnp.int32), 0,
                   x.shape[0] - 1)
    return jnp.transpose(s[idx])


def prepare_classes(X: np.ndarray, y: Optional[np.ndarray],
                    row_chunk: int = 65536, stats=None):
    """Gather rows by class into dense padded [n_y, n_max, p] blocks with
    per-class min-max scalers (Issue 5: static-shape blocks, no boolean
    masks inside the device program).

    Class stats come from one chunked streaming pass
    (:func:`class_stats_streaming`) and rows are rescaled + written
    straight into the padded blocks chunk by chunk, so peak extra memory
    is the padded ``[n_y, n_max, p]`` output plus one row chunk — the
    previous implementation first materialised a full class-sorted fp32
    copy of X (argsort + fancy index), doubling the transient footprint.
    Bit-identical output: within-class row order is the original row order
    either way (the old sort was stable).

    ``stats`` (classes, counts, mins, maxs) skips the streaming stats pass
    and pins the per-class scalers — the warm-start path passes the *base
    model's* mins/maxs here so extension rows land in the exact model space
    the base trees route in (``counts`` must still describe this data).

    Returns (Xc, Wc, classes, counts, mins, maxs).
    """
    if not hasattr(X, "shape"):      # plain sequences still accepted
        X = np.asarray(X, np.float32)
    n, p = X.shape
    if y is None:
        y = np.zeros((n,), np.int64)
    y = np.asarray(y)
    if stats is None:
        classes, counts, mins, maxs = class_stats_streaming(X, y, row_chunk)
    else:
        classes, counts, mins, maxs = stats
    n_y = len(classes)
    n_max = int(counts.max())
    Xc = np.zeros((n_y, n_max, p), np.float32)
    Wc = np.zeros((n_y, n_max), np.float32)
    pos = np.zeros((n_y,), np.int64)
    for s in range(0, n, row_chunk):
        xb = np.asarray(X[s:s + row_chunk], np.float32)  # Issue 7: fp32
        cid = np.searchsorted(classes, y[s:s + row_chunk])
        for i in np.unique(cid):
            rows = rescale(xb[cid == i], mins[i], maxs[i])
            Xc[i, pos[i]:pos[i] + len(rows)] = rows
            pos[i] += len(rows)
    for i, c in enumerate(counts):
        Xc[i, c:] = Xc[i, 0] if c else 0.0   # repeat-first-row padding
        Wc[i, :c] = 1.0
    return Xc, Wc, classes, counts, mins, maxs


def class_stats_streaming(X, y, row_chunk: int = 65536):
    """Classes / counts / per-class min-max scalers in one streaming pass
    over row chunks — never materialises a class-sorted or padded copy of X
    (the sharded-trainer replacement for :func:`prepare_classes`).
    """
    n, p = X.shape
    if y is None:
        y = np.zeros((n,), np.int64)
    classes = np.unique(np.asarray(y))
    n_y = len(classes)
    counts = np.zeros((n_y,), np.int64)
    mins = np.full((n_y, p), np.inf, np.float32)
    maxs = np.full((n_y, p), -np.inf, np.float32)
    for s in range(0, n, row_chunk):
        xb = np.asarray(X[s:s + row_chunk], np.float32)
        cid = np.searchsorted(classes, np.asarray(y[s:s + row_chunk]))
        for i in np.unique(cid):
            sel = xb[cid == i]
            counts[i] += len(sel)
            mins[i] = np.minimum(mins[i], sel.min(axis=0))
            maxs[i] = np.maximum(maxs[i], sel.max(axis=0))
    return classes, counts, mins, maxs


# ---------------------------------------------------------------------------
# warm start (the incremental freshness loop)
# ---------------------------------------------------------------------------

def _check_warm_start(base: ForestArtifacts, fcfg: ForestConfig,
                      p: int) -> None:
    """Refuse an extension whose config/data can't continue ``base``.

    Every :class:`ForestConfig` field but ``n_trees`` must match (the trees
    being replayed were grown under those hyperparameters), ``n_trees`` must
    strictly grow, and the feature count must agree. Errors name every
    differing field with both values.
    """
    bc = dataclasses.asdict(base.config)
    nc = dataclasses.asdict(fcfg)
    diffs = [k for k in nc if k != "n_trees" and bc.get(k) != nc[k]]
    if diffs:
        raise ValueError(
            "warm_start config mismatch — an extension may only change "
            "n_trees; differing fields: " + "; ".join(
                f"{k}: base={bc.get(k)!r} != new={nc[k]!r}" for k in diffs))
    if fcfg.n_trees <= base.config.n_trees:
        raise ValueError(
            f"warm_start needs n_trees > the base model's "
            f"{base.config.n_trees} (got {fcfg.n_trees}); use "
            "extend_artifacts(..., extra_trees=K) to grow by K rounds")
    if base.p != p:
        raise ValueError(f"warm_start base was fit on p={base.p} features "
                         f"but this data has p={p}")


def _check_warm_classes(base: ForestArtifacts, classes) -> None:
    """The extension data's label set must be exactly the base model's —
    each (timestep, class) ensemble continues an existing one; a new class
    would need ensembles that don't exist yet (full refit territory)."""
    if not np.array_equal(np.asarray(classes), np.asarray(base.classes)):
        raise ValueError(
            f"warm_start class mismatch: base model has classes "
            f"{np.asarray(base.classes).tolist()} but this data has "
            f"{np.asarray(classes).tolist()}; extension data must cover "
            "exactly the base label set (retrain from scratch otherwise)")


def _warm_host_arrays(base: ForestArtifacts):
    """Base model buffers as host numpy, in ``fit_boosted`` warm order:
    (feat, thr_val, leaf, val_curve, best_round), each ``[n_t, n_y, n_sub,
    ...]`` — sliced per (timestep, class) cell by the batch drivers."""
    return tuple(np.asarray(getattr(base, f)) for f in
                 ("feat", "thr_val", "leaf", "val_curve", "best_round"))


def _build_lineage(X, n_rows: int, p: int, fcfg: ForestConfig,
                   base: Optional[ForestArtifacts]) -> dict:
    """Data provenance recorded on the trained artifacts (and persisted in
    the save sidecar): enough for a serving host to detect a stale
    model-vs-store pairing at swap time."""
    lin = {"rows": int(n_rows), "p": int(p), "store": None, "base": None}
    if isinstance(X, DatasetStore):
        lin["store"] = {"fingerprint": X.fingerprint,
                        "version": int(X.version),
                        "n_rows": int(X.n_rows)}
    if base is not None:
        # one level of history: the base's own lineage minus *its* base,
        # so a nightly refresh chain doesn't nest without bound
        prev = {k: v for k, v in (base.lineage or {}).items() if k != "base"}
        lin["base"] = {
            "round_range": [int(base.config.n_trees), int(fcfg.n_trees)],
            "lineage": prev or None,
        }
    return lin


def extend_artifacts(base: ForestArtifacts, X, y=None, *, extra_trees: int,
                     **kwargs) -> ForestArtifacts:
    """Grow ``base`` by ``extra_trees`` boosting rounds per ensemble.

    Boosting is additive, so an extension from round R to R + K never
    recomputes the first R rounds: the base trees seed every ensemble and
    their running predictions are replayed (see
    :mod:`repro.forest.boosting`). The base's per-class scalers are reused
    — extension rows are binned in the model space the base trees route in
    — and on the *same* data the result is bit-identical to
    :func:`fit_artifacts` run straight to R + K with the same seed.

    ``X`` may be fresh (e.g. a :class:`~repro.data.store.DatasetStore`
    grown by :meth:`~repro.data.store.DatasetStore.append`); the new rounds
    then fit the residuals of the base trees on the new data. ``kwargs``
    forward to :func:`fit_artifacts` (mesh, checkpoint_dir, seed, ...).
    """
    if extra_trees <= 0:
        raise ValueError(f"extra_trees must be positive, got {extra_trees}")
    fcfg = dataclasses.replace(
        base.config, n_trees=base.config.n_trees + int(extra_trees))
    return fit_artifacts(X, y, fcfg, warm_start=base, **kwargs)


# ---------------------------------------------------------------------------
# checkpoint manifest
# ---------------------------------------------------------------------------

def _manifest_fingerprint(fcfg: ForestConfig, *, n_t: int, n_y: int,
                          batch_size: int, n_rows: int, p: int,
                          trainer: str, warm_rounds: int = 0) -> dict:
    """Everything that determines which ensemble lands in which batch file.

    Resuming under a different ``ensembles_per_batch`` or ``ForestConfig``
    used to silently mix stale ``batch_*.npz`` files with fresh ones; now the
    manifest pins the full grid layout and the config, and a mismatch refuses
    to resume. Deliberately *not* fingerprinted: the seed (resume may finish
    another run's grid — completed batches never retrain) and the sharded
    trainer's mesh shape (batches are whole trained ensembles, so a
    checkpoint may be resumed on a different device count — elastic resume).

    A warm-start fit adds ``warm_start: <base round count>`` so its batch
    files never mix with a cold run's; cold fingerprints are unchanged
    (byte-compatible with pre-warm-start manifests).
    """
    fp = {
        "config": dataclasses.asdict(fcfg),
        "grid": [n_t, n_y],
        "ensembles_per_batch": batch_size,
        "data_shape": [int(n_rows), int(p)],
        "trainer": trainer,
    }
    if warm_rounds:
        fp["warm_start"] = int(warm_rounds)
    return fp


def _manifest_batch_size(checkpoint_dir: str) -> Optional[int]:
    """The batch size an existing checkpoint was written with, if any."""
    path = os.path.join(checkpoint_dir, "manifest.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f).get("fingerprint", {}).get("ensembles_per_batch")


def _run_grid_batches(run_batch, grid, bs: int, *,
                      checkpoint_dir: Optional[str], resume: bool,
                      fingerprint: dict, warm_base: Optional[dict] = None):
    """Drive the (timestep, class) grid in batches with checkpoint/resume.

    ``run_batch(chunk)`` trains ``chunk`` (a list of (ti, yi)) and returns
    ``{field: np.ndarray}`` with leading dim ``len(chunk)``. Shared by the
    single-device and serial sharded trainers, so both get the same Issue-3
    streaming checkpoints and the same manifest safety (the pipelined
    driver below shares the :class:`~repro.train.checkpoint.GridManifest`
    too, so the three paths are resume-compatible).

    ``warm_base`` (a warm-start fit's base-run descriptor) lets
    :meth:`GridManifest.load_done` accept — rather than refuse — a
    checkpoint dir holding the *base* model's committed batches: the
    extension retrains every batch and overwrites them in place.
    """
    manifest = (_ckpt.GridManifest(checkpoint_dir, fingerprint,
                                   warm_base=warm_base)
                if checkpoint_dir else None)
    done = manifest.load_done(resume) if manifest else set()

    results = {}
    for b0 in range(0, len(grid), bs):
        chunk = grid[b0:b0 + bs]
        key_id = (b0, len(chunk))
        if key_id in done:
            res_np = _ckpt.read_batch_npz(checkpoint_dir, b0)
        else:
            with default_tracer().span("fit.batch", batch=b0,
                                       ensembles=len(chunk)):
                res_np = run_batch(chunk)
            if manifest:   # Issue 3: stream to disk, checkpointed
                _ckpt.write_batch_npz(checkpoint_dir, b0, res_np)
                manifest.mark_done(key_id)
        for j, (ti, yi) in enumerate(chunk):
            results[(ti, yi)] = {k: v[j] for k, v in res_np.items()}
    return results


# ---------------------------------------------------------------------------
# pipelined (double-buffered) grid driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Knobs of the double-buffered distributed fit loop.

    ``prefetch_depth`` bounds both inter-stage queues: the prefetch thread
    may run at most this many batches of input-build ahead of the dispatch
    loop (1 = classic double buffering), and at most this many dispatched
    batches of device results may be in flight awaiting the writer — the
    backpressure that keeps host memory bounded.

    ``async_checkpoint`` moves the ``BoostResult`` gather and the
    ``batch_*.npz`` / manifest writes onto the writer thread. Disable it to
    get the PR-2 strictly-synchronous writes (inputs still prefetch) — e.g.
    when the checkpoint filesystem misbehaves under concurrent fsyncs or
    when debugging with deterministic thread interleavings.
    """
    prefetch_depth: int = 2
    async_checkpoint: bool = True


#: Wall/overlap accounting of the most recent pipelined fit in this process
#: (written once, after the pipeline drains — read by bench_training to
#: report overlap efficiency; not part of the stable API).
LAST_PIPELINE_STATS: dict = {}

_STOP = object()


def _run_grid_batches_pipelined(dispatch, collect, grid, bs: int, *,
                                checkpoint_dir: Optional[str], resume: bool,
                                fingerprint: dict, prefetch,
                                pcfg: PipelineConfig,
                                warm_base: Optional[dict] = None):
    """Producer/consumer version of :func:`_run_grid_batches`.

    Three stages over the same batch sequence, bit-identical results:

    * prefetch thread — ``prefetch(chunk) -> inputs`` (host-only input
      build; skipped for batches the manifest already has);
    * main thread — ``dispatch(inputs) -> device result`` (asynchronous
      under jit, so dispatching batch ``b+1`` does not wait for ``b``);
    * writer thread — ``collect(result, n) -> {field: np}`` (the deferred
      ``block_until_ready`` + device→host gather) followed by the durable
      ``batch_*.npz`` write and manifest update.

    Any stage failing sets a shared stop event, the queues drain, and the
    first error re-raises on the caller's thread. The manifest is only ever
    updated after its batch file is durably committed, so a crash between
    writer flushes resumes from the last committed batch.
    """
    manifest = (_ckpt.GridManifest(checkpoint_dir, fingerprint,
                                   warm_base=warm_base)
                if checkpoint_dir else None)
    done = manifest.load_done(resume) if manifest else set()

    batches = [(b0, grid[b0:b0 + bs]) for b0 in range(0, len(grid), bs)]
    depth = max(1, pcfg.prefetch_depth)
    in_q: queue.Queue = queue.Queue(maxsize=depth)
    out_q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()
    errors: list = []
    batch_np: dict = {}
    stats = {"writer_busy_s": 0.0, "prefetch_busy_s": 0.0,
             "n_batches": len(batches), "n_cached": 0,
             "prefetch_depth": depth,
             "async_checkpoint": pcfg.async_checkpoint}
    # stage timing comes from fit.prefetch / fit.dispatch / fit.write spans
    # (busy_s below are their summed durations); the histograms export the
    # same numbers through the process-wide registry for --metrics-dump
    tracer = default_tracer()
    _m = default_registry()
    h_prefetch = _m.histogram(
        "fit_prefetch_seconds", "Per-batch host input-build time "
        "(fit.prefetch span durations)")
    h_dispatch = _m.histogram(
        "fit_dispatch_seconds", "Per-batch async dispatch-enqueue time "
        "(fit.dispatch span durations; device time overlaps the pipeline)")
    h_write = _m.histogram(
        "fit_write_seconds", "Per-batch gather + checkpoint-commit time "
        "(fit.write span durations)")
    c_batches = _m.counter(
        "fit_batches", "Ensemble-grid batches by disposition",
        ("status",))

    def _put(q, item):
        """Bounded put that aborts when another stage failed."""
        while not stop.is_set():
            try:
                q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _get(q):
        while not stop.is_set():
            try:
                return q.get(timeout=0.05)
            except queue.Empty:
                continue
        return _STOP

    def _fail(exc):
        errors.append(exc)
        stop.set()

    def _producer():
        try:
            for b0, chunk in batches:
                if (b0, len(chunk)) in done:
                    item = (b0, chunk, None)     # cached: nothing to build
                else:
                    with tracer.span("fit.prefetch", batch=b0) as sp:
                        inputs = prefetch(chunk)
                    stats["prefetch_busy_s"] += sp.duration_s
                    h_prefetch.observe(sp.duration_s)
                    item = (b0, chunk, inputs)
                if not _put(in_q, item):
                    return
            _put(in_q, _STOP)
        except Exception as exc:  # noqa: BLE001 — re-raised on main thread
            _fail(exc)

    def _finish(b0, chunk, res_dev):
        """Writer-stage work: deferred sync + gather + durable commit."""
        with tracer.span("fit.write", batch=b0) as sp:
            res_np = collect(res_dev, len(chunk))
            if manifest:
                _ckpt.write_batch_npz(checkpoint_dir, b0, res_np)
                manifest.mark_done((b0, len(chunk)))
            batch_np[b0] = res_np
        stats["writer_busy_s"] += sp.duration_s
        h_write.observe(sp.duration_s)

    def _writer():
        try:
            while True:
                item = _get(out_q)
                if item is _STOP:
                    return
                _finish(*item)
        except Exception as exc:  # noqa: BLE001 — re-raised on main thread
            _fail(exc)

    wall0 = time.perf_counter()
    threads = [threading.Thread(target=_producer, name="tabgen-prefetch",
                                daemon=True)]
    if pcfg.async_checkpoint:
        threads.append(threading.Thread(target=_writer, name="tabgen-writer",
                                        daemon=True))
    for t in threads:
        t.start()
    completed = False
    try:
        while True:
            item = _get(in_q)
            if item is _STOP:
                break
            b0, chunk, inputs = item
            if inputs is None:    # committed by a previous (or this) run
                batch_np[b0] = _ckpt.read_batch_npz(checkpoint_dir, b0)
                stats["n_cached"] += 1
                c_batches.inc(1, status="cached")
                continue
            with tracer.span("fit.dispatch", batch=b0) as sp:
                res_dev = dispatch(inputs)   # async: returns device futures
            h_dispatch.observe(sp.duration_s)
            c_batches.inc(1, status="dispatched")
            if pcfg.async_checkpoint:
                if not _put(out_q, (b0, chunk, res_dev)):
                    break
            else:
                _finish(b0, chunk, res_dev)
        if pcfg.async_checkpoint and not stop.is_set():
            _put(out_q, _STOP)
        completed = True
    except Exception as exc:  # noqa: BLE001 — unified error path
        _fail(exc)
    finally:
        # BaseException (KeyboardInterrupt, GeneratorExit) skips the except
        # above: stop the stages here so the joins below can't hang and no
        # polling daemon thread outlives the fit pinning the row shards
        if not completed and not stop.is_set():
            stop.set()
        for t in threads:
            t.join()
    if errors:
        raise errors[0]
    stats["wall_s"] = time.perf_counter() - wall0
    LAST_PIPELINE_STATS.clear()
    LAST_PIPELINE_STATS.update(stats)

    results = {}
    for b0, chunk in batches:
        res_np = batch_np[b0]
        for j, (ti, yi) in enumerate(chunk):
            results[(ti, yi)] = {k: v[j] for k, v in res_np.items()}
    return results


# ---------------------------------------------------------------------------
# single-device trainer
# ---------------------------------------------------------------------------

def fit_artifacts(X, y=None, fcfg: ForestConfig = ForestConfig(), *,
                  seed: int = 0, checkpoint_dir: Optional[str] = None,
                  resume: bool = False, ensembles_per_batch: int = 0,
                  mesh=None, data_axes: Optional[Tuple[str, ...]] = None,
                  model_axis: str = "model", row_chunk: int = 65536,
                  pipeline="auto",
                  warm_start: Optional[ForestArtifacts] = None
                  ) -> ForestArtifacts:
    """Train all (timestep, class) ensembles; returns portable artifacts.

    One jitted+vmapped fit program trains ``ensembles_per_batch`` ensembles
    per dispatch; batches stream to ``checkpoint_dir`` (Issue 3) and
    ``resume=True`` restarts from the manifest.

    ``mesh`` selects the trainer: ``None`` (default) is the single-device
    path; a :class:`jax.sharding.Mesh` routes through the shard_map trainer
    with rows sharded over ``data_axes`` and the ensemble grid over
    ``model_axis``; the string ``"auto"`` builds a mesh from every visible
    device (``repro.launch.mesh.auto_forest_mesh``) and falls back to the
    single-device path when there is only one.

    ``pipeline`` applies to the sharded trainer: ``"auto"`` (default) runs
    the double-buffered pipeline with :class:`PipelineConfig` defaults, an
    explicit :class:`PipelineConfig` pins its knobs, and ``None`` keeps the
    serial PR-2 loop. Both produce bit-identical artifacts for a fixed seed
    and share one manifest format, so a serial checkpoint resumes under the
    pipeline (and vice versa) — the execution style, like the mesh shape,
    is deliberately not fingerprinted. The single-device trainer ignores
    ``pipeline`` (its batches have no host/device overlap to hide).

    Out-of-core data: ``X`` may be a :class:`repro.data.store.DatasetStore`
    (built by :func:`repro.data.store.ingest` / ``repro.launch.ingest``).
    Store-backed fits always run the sharded trainer — when no mesh is
    given (or ``"auto"`` resolves to a single device) a 1x1 mesh is built,
    because the padded single-device route would materialise the dataset.
    Class stats and scalers are read from the store manifest (no fit-time
    stats pass) and row shards are gathered straight from disk; ``y``
    defaults to the store's own labels. A store-backed fit is bit-identical
    to the in-memory sharded fit of the same rows on the same mesh, and
    their checkpoints interoperate.

    ``warm_start`` seeds every ensemble from an existing
    :class:`ForestArtifacts` (same config up to ``n_trees``, same feature
    count and label set) and continues boosting from its trees instead of
    round 0 — see :func:`extend_artifacts` for the usual entry point. The
    base model's per-class scalers are reused so extension rows are binned
    in the space the base trees route in; on identical data the result is
    bit-identical to a cold fit run straight to the new ``n_trees``.
    """
    if isinstance(mesh, str):
        if mesh != "auto":
            raise ValueError(f"mesh={mesh!r}: expected a Mesh, None, or "
                             "'auto'")
        from repro.launch.mesh import auto_forest_mesh
        mesh = auto_forest_mesh()
    if mesh is None and isinstance(X, DatasetStore):
        # out-of-core route: the sharded trainer streams per-device row
        # slices from the store's shards; the single-device route would
        # densify the whole dataset into padded class blocks
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    # validate on every path: a malformed pipeline knob should fail loudly
    # on a single-device box too, not first on the production mesh
    if pipeline == "auto":
        pipeline = PipelineConfig()
    elif not (pipeline is None or isinstance(pipeline, PipelineConfig)):
        raise ValueError(f"pipeline={pipeline!r}: expected 'auto', "
                         "None, or a PipelineConfig")
    if mesh is not None:
        return _fit_artifacts_sharded(
            X, y, fcfg, mesh, seed=seed, checkpoint_dir=checkpoint_dir,
            resume=resume, ensembles_per_batch=ensembles_per_batch,
            data_axes=data_axes, model_axis=model_axis, row_chunk=row_chunk,
            pipeline=pipeline, warm_start=warm_start)

    stats = None
    if warm_start is not None:
        Xs = X if hasattr(X, "shape") else np.asarray(X, np.float32)
        _check_warm_start(warm_start, fcfg, int(np.shape(Xs)[1]))
        classes, counts, _, _ = class_stats_streaming(Xs, y, row_chunk)
        _check_warm_classes(warm_start, classes)
        # pin the base scalers: extension rows must land in the model space
        # the base trees were grown in (fresh counts keep label sampling
        # honest on appended data)
        stats = (classes, counts, np.asarray(warm_start.mins, np.float32),
                 np.asarray(warm_start.maxs, np.float32))
    Xc, Wc, classes, counts, mins, maxs = prepare_classes(X, y, stats=stats)
    n_y, n_max, p = Xc.shape
    Xc_d = jnp.asarray(Xc)
    Wc_d = jnp.asarray(Wc)
    ts = np.asarray(itp.timesteps(fcfg.method, fcfg.n_t, fcfg.eps_diff,
                                  fcfg.t_schedule))
    root = jax.random.PRNGKey(seed)

    K = fcfg.duplicate_k

    def ensemble_inputs(t, y_idx, eid):
        """Noised inputs/codes of the (t, y) ensemble; transient by design."""
        x0 = Xc_d[y_idx]
        w = Wc_d[y_idx]
        x0d = jnp.repeat(x0, K, axis=0)                  # [mK, p]
        wd = jnp.repeat(w, K, axis=0)
        k_tr = jax.random.fold_in(root, eid * 2)
        k_va = jax.random.fold_in(root, eid * 2 + 1)
        _, xt, tgt = itp.sample_bridge(k_tr, x0d, fcfg.method, t, fcfg.sigma)
        edges = weighted_edges(xt, wd, fcfg.n_bins)
        codes = transform(xt, edges)
        _, xtv, tgtv = itp.sample_bridge(k_va, x0d, fcfg.method, t,
                                         fcfg.sigma)
        codes_v = transform(xtv, edges)
        if fcfg.int8_codes:   # QuantileDMatrix-style narrow storage
            codes = pack_codes(codes, fcfg.n_bins)
            codes_v = pack_codes(codes_v, fcfg.n_bins)
        return codes, tgt, wd, edges, codes_v, tgtv, xt, xtv

    def fit_one(t, y_idx, eid):
        """Train the (t, y) ensemble; everything transient lives here."""
        codes, tgt, wd, edges, codes_v, tgtv, _, _ = \
            ensemble_inputs(t, y_idx, eid)
        return fit_ensemble(codes, tgt, wd, edges_with_sentinel(edges),
                            codes_v, tgtv, wd, fcfg)

    def fit_one_warm(t, y_idx, eid, wf, wt, wl, wvc, wbr):
        """Continue the (t, y) ensemble from its base-model slice."""
        codes, tgt, wd, edges, codes_v, tgtv, xt, xtv = \
            ensemble_inputs(t, y_idx, eid)
        return fit_ensemble(codes, tgt, wd, edges_with_sentinel(edges),
                            codes_v, tgtv, wd, fcfg,
                            warm=(wf, wt, wl, wvc, wbr), x_raw=xt,
                            val_raw=xtv)

    if warm_start is None:
        fit_batch = jax.jit(jax.vmap(fit_one, in_axes=(0, 0, 0)))
    else:
        Wfeat, Wthr, Wleaf, Wvc, Wbr = _warm_host_arrays(warm_start)
        fit_batch = jax.jit(jax.vmap(fit_one_warm, in_axes=(0,) * 8))

    grid = [(ti, yi) for ti in range(fcfg.n_t) for yi in range(n_y)]
    bs = ensembles_per_batch or max(1, min(len(grid), 8))

    def run_batch(chunk):
        t_arr = jnp.asarray([ts[ti] for ti, _ in chunk], jnp.float32)
        y_arr = jnp.asarray([yi for _, yi in chunk], jnp.int32)
        e_arr = jnp.asarray([ti * n_y + yi for ti, yi in chunk], jnp.int32)
        if warm_start is None:
            res = fit_batch(t_arr, y_arr, e_arr)
        else:
            tis = [ti for ti, _ in chunk]
            yis = [yi for _, yi in chunk]
            res = fit_batch(t_arr, y_arr, e_arr,
                            jnp.asarray(Wfeat[tis, yis]),
                            jnp.asarray(Wthr[tis, yis]),
                            jnp.asarray(Wleaf[tis, yis]),
                            jnp.asarray(Wvc[tis, yis]),
                            jnp.asarray(Wbr[tis, yis]))
        return {k: np.asarray(getattr(res, k)) for k in RESULT_FIELDS}

    warm_rounds = warm_start.config.n_trees if warm_start else 0
    fingerprint = _manifest_fingerprint(
        fcfg, n_t=fcfg.n_t, n_y=n_y, batch_size=bs,
        n_rows=np.shape(X)[0], p=p, trainer="single",
        warm_rounds=warm_rounds)
    warm_base = (None if warm_start is None else
                 {"config": dataclasses.asdict(warm_start.config),
                  "grid": [fcfg.n_t, n_y]})
    results = _run_grid_batches(run_batch, grid, bs,
                                checkpoint_dir=checkpoint_dir, resume=resume,
                                fingerprint=fingerprint, warm_base=warm_base)
    arts = ForestArtifacts.from_grid_results(results, fcfg.n_t, n_y, mins,
                                             maxs, classes, counts, fcfg)
    arts.lineage = _build_lineage(X, np.shape(X)[0], p, fcfg, warm_start)
    return arts


# ---------------------------------------------------------------------------
# sharded trainer (the paper's §3.3 scaling story, TPU-native)
# ---------------------------------------------------------------------------

def _fit_artifacts_sharded(X, y, fcfg: ForestConfig, mesh, *, seed: int,
                           checkpoint_dir: Optional[str], resume: bool,
                           ensembles_per_batch: int,
                           data_axes: Optional[Tuple[str, ...]],
                           model_axis: str, row_chunk: int,
                           pipeline: Optional[PipelineConfig],
                           warm_start: Optional[ForestArtifacts] = None
                           ) -> ForestArtifacts:
    """shard_map training from host data to :class:`ForestArtifacts`.

    Rows (rescaled per class, weight-masked class conditioning — no padded
    [n_y, n_max, p] blocks) are sharded over the data axes and streamed to
    the devices chunk by chunk via ``build_row_shards``: each device uploads
    only its own row slice, so X never has to fit on one device. The
    (timestep, class) grid is sharded over the model axis in batches of
    ``ensembles_per_batch`` (rounded up to the model-axis size), reusing the
    same checkpoint/resume manifest as the single-device path.

    With a :class:`PipelineConfig` the batch loop runs double-buffered: the
    input build (row-shard upload on first use + per-batch keys) happens on
    a prefetch thread while the previous batch executes, and the gather +
    checkpoint writes happen on a writer thread; ``pipeline=None`` is the
    serial loop. Batches are bit-identical either way.
    """
    from repro.forest.distributed import (build_batch_inputs,
                                          build_grid_key_table,
                                          build_row_shards,
                                          make_distributed_fit)

    # keep memmap/store inputs lazy: only per-shard chunks are ever copied
    if isinstance(X, DatasetStore):
        X_np = X                       # row gathers read straight from disk
        n, p = X.shape
        if y is None:
            y = X.labels()
            # one manifest read replaces the whole fit-time stats pass (the
            # values are exactly what class_stats_streaming would recompute)
            classes, counts, mins, maxs = X.class_stats()
        else:
            # explicit labels override the store's own: the manifest stats
            # were computed under the store's grouping, so re-stream the
            # per-class scalers in chunked reads over the shards
            y = np.asarray(y)
            classes, counts, mins, maxs = class_stats_streaming(X, y,
                                                                row_chunk)
    else:
        X_np = X if isinstance(X, np.ndarray) else np.asarray(X, np.float32)
        n, p = X_np.shape
        if y is None:
            y = np.zeros((n,), np.int64)
        classes, counts, mins, maxs = class_stats_streaming(X_np, y,
                                                            row_chunk)
    if warm_start is not None:
        _check_warm_start(warm_start, fcfg, p)
        _check_warm_classes(warm_start, classes)
        # base scalers, not this data's: the replayed trees route in the
        # base model's [-1, 1] space (fresh counts stay — label sampling)
        mins = np.asarray(warm_start.mins, np.float32)
        maxs = np.asarray(warm_start.maxs, np.float32)
    n_y = len(classes)
    cid_full = np.searchsorted(classes, np.asarray(y)).astype(np.int32)

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if model_axis not in axis_sizes:
        raise ValueError(f"mesh has no {model_axis!r} axis: "
                         f"{mesh.axis_names}")
    if data_axes is None:
        data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    data_axes = tuple(data_axes)   # hashable for the trainer cache
    m_size = axis_sizes[model_axis]

    # Deterministic shuffle so every row shard sees every class: the sketch
    # quantiles gather the head of each shard, and a class-sorted input on a
    # small mesh would starve some ensembles' sketches entirely.
    perm = np.random.default_rng(seed).permutation(n)

    # row-shard build is deferred into the input-build stage: the pipelined
    # driver runs it on the prefetch thread (overlapping the host→device
    # upload with dispatch-side work), and an all-cached resume never pays
    # for it at all
    row_state: dict = {}

    def rows():
        if "arrs" not in row_state:
            row_state["arrs"] = build_row_shards(
                mesh, X_np, cid_full, mins, maxs, perm, data_axes)
        return row_state["arrs"]

    ts = np.asarray(itp.timesteps(fcfg.method, fcfg.n_t, fcfg.eps_diff,
                                  fcfg.t_schedule))
    root = jax.random.PRNGKey(seed)
    grid = [(ti, yi) for ti in range(fcfg.n_t) for yi in range(n_y)]
    bs = ensembles_per_batch or max(m_size, min(len(grid), 8))
    if not ensembles_per_batch and resume and checkpoint_dir:
        # elastic resume: the batch size is part of the checkpoint layout,
        # so when the caller didn't pin one, inherit the manifest's rather
        # than deriving a (possibly different) default from the new mesh
        bs = _manifest_batch_size(checkpoint_dir) or bs
    bs = -(-bs // m_size) * m_size          # model-axis divisibility
    if resume and checkpoint_dir:
        stale = _manifest_batch_size(checkpoint_dir)
        if stale and stale != bs:
            raise ValueError(
                f"checkpoint at {checkpoint_dir} was written with "
                f"ensembles_per_batch={stale} but this run resolves to "
                f"{bs} (the {m_size}-wide model axis needs a multiple of "
                f"{m_size}); resume with ensembles_per_batch={stale} on a "
                "compatible mesh, or retrain with resume=False.")

    warm_rounds = warm_start.config.n_trees if warm_start else 0
    fit = make_distributed_fit(mesh, fcfg, data_axes=data_axes,
                               model_axis=model_axis,
                               warm_rounds=warm_rounds)
    if warm_start is not None:
        Wfeat, Wthr, Wleaf, Wvc, Wbr = _warm_host_arrays(warm_start)

    def warm_slices(chunk):
        """Base-model slices of one (padded) batch: [bs, n_sub, R, ...]."""
        tis = [ti for ti, _ in chunk]
        yis = [yi for _, yi in chunk]
        return (Wfeat[tis, yis], Wthr[tis, yis], Wleaf[tis, yis],
                Wvc[tis, yis], Wbr[tis, yis])

    def pad(chunk):
        # pad the tail batch by repeating entries: one compiled program for
        # every dispatch; the duplicates are sliced off before writing
        return chunk + [chunk[-1]] * (bs - len(chunk))

    fingerprint = _manifest_fingerprint(
        fcfg, n_t=fcfg.n_t, n_y=n_y, batch_size=bs, n_rows=n, p=p,
        trainer="sharded", warm_rounds=warm_rounds)
    warm_base = (None if warm_start is None else
                 {"config": dataclasses.asdict(warm_start.config),
                  "grid": [fcfg.n_t, n_y]})

    # one vectorized dispatch for every ensemble's PRNG keys (devices are
    # idle here; values bit-identical to the per-batch fold_in pairs) —
    # both loops slice plain numpy thereafter, and the pipeline's prefetch
    # thread never contends with in-flight batches for device queues
    key_table = build_grid_key_table(root, fcfg.n_t * n_y)

    if pipeline is None:
        def run_batch(chunk):
            padded = pad(chunk)
            t_np, y_np, keys = build_batch_inputs(padded, ts, n_y, root,
                                                  key_table)
            x0_sh, w_sh, c_sh = rows()
            extra = (() if warm_start is None else
                     tuple(jnp.asarray(a) for a in warm_slices(padded)))
            res = fit(x0_sh, w_sh, c_sh, jnp.asarray(t_np),
                      jnp.asarray(y_np), jnp.asarray(keys), *extra)
            # gather per-model-axis shards back to host, drop pad entries
            return {k: np.asarray(getattr(res, k))[:len(chunk)]
                    for k in RESULT_FIELDS}

        results = _run_grid_batches(run_batch, grid, bs,
                                    checkpoint_dir=checkpoint_dir,
                                    resume=resume, fingerprint=fingerprint,
                                    warm_base=warm_base)
    else:
        def prefetch(chunk):
            # input-build stage: row shards (once) + this batch's grid
            # cells (+ the base-model slices when warm starting)
            padded = pad(chunk)
            extra = () if warm_start is None else warm_slices(padded)
            return (rows() + build_batch_inputs(padded, ts, n_y, root,
                                                key_table) + extra)

        def dispatch(inputs):
            x0_sh, w_sh, c_sh = inputs[:3]
            rest = [jnp.asarray(a) for a in inputs[3:]]
            return fit(x0_sh, w_sh, c_sh, *rest)

        def collect(res, n_real):
            # deferred bookkeeping: one explicit sync for the whole batch,
            # then per-model-axis shards gather back to host; pad entries
            # are sliced off before the batch is written
            res = jax.block_until_ready(res)
            return {k: np.asarray(getattr(res, k))[:n_real]
                    for k in RESULT_FIELDS}

        results = _run_grid_batches_pipelined(
            dispatch, collect, grid, bs, checkpoint_dir=checkpoint_dir,
            resume=resume, fingerprint=fingerprint, prefetch=prefetch,
            pcfg=pipeline, warm_base=warm_base)
    arts = ForestArtifacts.from_grid_results(results, fcfg.n_t, n_y, mins,
                                             maxs, classes, counts, fcfg)
    arts.lineage = _build_lineage(X, n, p, fcfg, warm_start)
    return arts
