"""Training: data prep + the batched ensemble fit, producing ForestArtifacts.

Memory discipline (paper §3.3, re-expressed for accelerators):

* Issue 1 — the [n_t, nK, p] array of noised inputs is never built. Each
  ensemble batch constructs its own x_t inside the jitted fit.
* Issue 2 — exactly one copy of X0 lives in memory; noise X1 is *never stored
  at all*: it is regenerated on device from a counter-based PRNG key (a
  strictly stronger version of the shared-memmap fix).
* Issue 3 — trained ensembles are streamed to disk per batch
  (``checkpoint_dir``) and training resumes from the manifest after failure.
* Issues 5-7 — classes are sorted/padded into dense [n_y, n_max, p] blocks
  (static-shape slices, no boolean-mask copies), one quantised code matrix is
  shared by all p outputs of an ensemble (DMatrix reuse), and everything is
  fp32.

Algorithmic additions from §3.4: multi-output trees, early stopping on a
fresh-noise validation set, per-class min-max scalers, empirical label
sampling.
"""
from __future__ import annotations

import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ForestConfig
from repro.core import interpolants as itp
from repro.forest.binning import edges_with_sentinel, transform
from repro.forest.boosting import fit_ensemble
from repro.tabgen.artifacts import ForestArtifacts, rescale


def weighted_edges(x, w, n_bins: int):
    """Quantile edges over the rows with positive weight (padded rows excluded).

    x: [n, p]; w: [n]. Returns [p, n_bins - 1] fp32.
    """
    big = jnp.where(w[:, None] > 0, x, jnp.inf)
    s = jnp.sort(big, axis=0)
    n_real = jnp.sum(w > 0).astype(jnp.float32)
    qs = jnp.arange(1, n_bins, dtype=jnp.float32) / n_bins
    idx = jnp.clip((qs * (n_real - 1.0)).astype(jnp.int32), 0,
                   x.shape[0] - 1)
    return jnp.transpose(s[idx])


def prepare_classes(X: np.ndarray, y: Optional[np.ndarray]):
    """Sort rows by class into dense padded [n_y, n_max, p] blocks with
    per-class min-max scalers (Issue 5: sort + static-shape slice).

    Returns (Xc, Wc, classes, counts, mins, maxs).
    """
    X = np.asarray(X, np.float32)          # Issue 7: fp32 end-to-end
    n, p = X.shape
    if y is None:
        y = np.zeros((n,), np.int64)
    order = np.argsort(y, kind="stable")
    X, y = X[order], np.asarray(y)[order]
    classes, counts = np.unique(y, return_counts=True)
    n_y = len(classes)
    n_max = int(counts.max())
    Xc = np.zeros((n_y, n_max, p), np.float32)
    Wc = np.zeros((n_y, n_max), np.float32)
    mins = np.zeros((n_y, p), np.float32)
    maxs = np.ones((n_y, p), np.float32)
    start = 0
    for i, c in enumerate(counts):
        rows = X[start:start + c]
        mins[i] = rows.min(axis=0)
        maxs[i] = rows.max(axis=0)
        rows = rescale(rows, mins[i], maxs[i])       # per-class scaler
        Xc[i, :c] = rows
        Xc[i, c:] = rows[0] if c else 0.0
        Wc[i, :c] = 1.0
        start += c
    return Xc, Wc, classes, counts, mins, maxs


def fit_artifacts(X, y=None, fcfg: ForestConfig = ForestConfig(), *,
                  seed: int = 0, checkpoint_dir: Optional[str] = None,
                  resume: bool = False,
                  ensembles_per_batch: int = 0) -> ForestArtifacts:
    """Train all (timestep, class) ensembles; returns portable artifacts.

    One jitted+vmapped fit program trains ``ensembles_per_batch`` ensembles
    per dispatch; batches stream to ``checkpoint_dir`` (Issue 3) and
    ``resume=True`` restarts from the manifest.
    """
    Xc, Wc, classes, counts, mins, maxs = prepare_classes(X, y)
    n_y, n_max, p = Xc.shape
    Xc_d = jnp.asarray(Xc)
    Wc_d = jnp.asarray(Wc)
    ts = np.asarray(itp.timesteps(fcfg.method, fcfg.n_t, fcfg.eps_diff,
                                  fcfg.t_schedule))
    root = jax.random.PRNGKey(seed)

    K = fcfg.duplicate_k

    def fit_one(t, y_idx, eid):
        """Train the (t, y) ensemble; everything transient lives here."""
        x0 = Xc_d[y_idx]
        w = Wc_d[y_idx]
        x0d = jnp.repeat(x0, K, axis=0)                  # [mK, p]
        wd = jnp.repeat(w, K, axis=0)
        k_tr = jax.random.fold_in(root, eid * 2)
        k_va = jax.random.fold_in(root, eid * 2 + 1)
        x1 = jax.random.normal(k_tr, x0d.shape, jnp.float32)
        xt, tgt = itp.make_xt_target(fcfg.method, x0d, x1, t,
                                     fcfg.sigma, k_tr)
        edges = weighted_edges(xt, wd, fcfg.n_bins)
        codes = transform(xt, edges)
        x1v = jax.random.normal(k_va, x0d.shape, jnp.float32)
        xtv, tgtv = itp.make_xt_target(fcfg.method, x0d, x1v, t,
                                       fcfg.sigma, k_va)
        codes_v = transform(xtv, edges)
        res = fit_ensemble(codes, tgt, wd, edges_with_sentinel(edges),
                           codes_v, tgtv, wd, fcfg)
        return res

    fit_batch = jax.jit(jax.vmap(fit_one, in_axes=(0, 0, 0)))

    grid = [(ti, yi) for ti in range(fcfg.n_t) for yi in range(n_y)]
    bs = ensembles_per_batch or max(1, min(len(grid), 8))
    manifest_path = (os.path.join(checkpoint_dir, "manifest.json")
                     if checkpoint_dir else None)
    done = set()
    if resume and manifest_path and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            done = set(tuple(e) for e in json.load(f)["batches"])

    results = {}
    for b0 in range(0, len(grid), bs):
        chunk = grid[b0:b0 + bs]
        key_id = (b0, len(chunk))
        if key_id in done:
            data = np.load(os.path.join(checkpoint_dir, f"batch_{b0}.npz"))
            res_np = {k: data[k] for k in data.files}
        else:
            t_arr = jnp.asarray([ts[ti] for ti, _ in chunk], jnp.float32)
            y_arr = jnp.asarray([yi for _, yi in chunk], jnp.int32)
            e_arr = jnp.asarray([ti * n_y + yi for ti, yi in chunk],
                                jnp.int32)
            res = fit_batch(t_arr, y_arr, e_arr)
            res_np = {
                "feat": np.asarray(res.feat),
                "thr_val": np.asarray(res.thr_val),
                "leaf": np.asarray(res.leaf),
                "best_round": np.asarray(res.best_round),
                "rounds_run": np.asarray(res.rounds_run),
                "val_curve": np.asarray(res.val_curve),
            }
            if checkpoint_dir:   # Issue 3: stream to disk, checkpointed
                os.makedirs(checkpoint_dir, exist_ok=True)
                np.savez(os.path.join(checkpoint_dir, f"batch_{b0}.npz"),
                         **res_np)
                done.add(key_id)
                with open(manifest_path, "w") as f:
                    json.dump({"batches": sorted(done)}, f)
        for j, (ti, yi) in enumerate(chunk):
            results[(ti, yi)] = {k: v[j] for k, v in res_np.items()}

    # stack into [n_t, n_y, ...]
    def stack(field):
        return np.stack([
            np.stack([results[(ti, yi)][field] for yi in range(n_y)])
            for ti in range(fcfg.n_t)])

    forests = {k: stack(k) for k in
               ("feat", "thr_val", "leaf", "best_round", "rounds_run",
                "val_curve")}
    return ForestArtifacts.from_fit(forests, mins, maxs, classes, counts,
                                    fcfg)
