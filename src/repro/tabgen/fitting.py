"""Training: data prep + the batched ensemble fit, producing ForestArtifacts.

Memory discipline (paper §3.3, re-expressed for accelerators):

* Issue 1 — the [n_t, nK, p] array of noised inputs is never built. Each
  ensemble batch constructs its own x_t inside the jitted fit.
* Issue 2 — exactly one copy of X0 lives in memory; noise X1 is *never stored
  at all*: it is regenerated on device from a counter-based PRNG key (a
  strictly stronger version of the shared-memmap fix).
* Issue 3 — trained ensembles are streamed to disk per batch
  (``checkpoint_dir``) and training resumes from the manifest after failure.
  The manifest carries a config fingerprint so a resume can never silently
  mix batches trained under a different configuration.
* Issues 5-7 — classes are sorted/padded into dense [n_y, n_max, p] blocks
  (static-shape slices, no boolean-mask copies), one quantised code matrix is
  shared by all p outputs of an ensemble (DMatrix reuse), and everything is
  fp32.

Algorithmic additions from §3.4: multi-output trees, early stopping on a
fresh-noise validation set, per-class min-max scalers, empirical label
sampling.

Scaling (paper §3.3's 370x-larger-datasets claim): ``fit_artifacts`` also
routes through the shard_map trainer (:mod:`repro.forest.distributed`) when
given a ``mesh`` — rows sharded over the data axes with weight-masked class
conditioning (no padded per-class blocks), the (timestep, class) ensemble
grid sharded over the model axis, and host→device streaming of row chunks so
X never has to fit on a single device. ``mesh="auto"`` builds one from
``jax.devices()``; ``mesh=None`` keeps the single-device path.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ForestConfig
from repro.core import interpolants as itp
from repro.forest.binning import edges_with_sentinel, transform
from repro.forest.boosting import fit_ensemble
from repro.tabgen.artifacts import (RESULT_FIELDS, ForestArtifacts,
                                    rescale)


def weighted_edges(x, w, n_bins: int):
    """Quantile edges over the rows with positive weight (padded rows excluded).

    x: [n, p]; w: [n]. Returns [p, n_bins - 1] fp32.
    """
    big = jnp.where(w[:, None] > 0, x, jnp.inf)
    s = jnp.sort(big, axis=0)
    n_real = jnp.sum(w > 0).astype(jnp.float32)
    qs = jnp.arange(1, n_bins, dtype=jnp.float32) / n_bins
    idx = jnp.clip((qs * (n_real - 1.0)).astype(jnp.int32), 0,
                   x.shape[0] - 1)
    return jnp.transpose(s[idx])


def prepare_classes(X: np.ndarray, y: Optional[np.ndarray]):
    """Sort rows by class into dense padded [n_y, n_max, p] blocks with
    per-class min-max scalers (Issue 5: sort + static-shape slice).

    Returns (Xc, Wc, classes, counts, mins, maxs).
    """
    X = np.asarray(X, np.float32)          # Issue 7: fp32 end-to-end
    n, p = X.shape
    if y is None:
        y = np.zeros((n,), np.int64)
    order = np.argsort(y, kind="stable")
    X, y = X[order], np.asarray(y)[order]
    classes, counts = np.unique(y, return_counts=True)
    n_y = len(classes)
    n_max = int(counts.max())
    Xc = np.zeros((n_y, n_max, p), np.float32)
    Wc = np.zeros((n_y, n_max), np.float32)
    mins = np.zeros((n_y, p), np.float32)
    maxs = np.ones((n_y, p), np.float32)
    start = 0
    for i, c in enumerate(counts):
        rows = X[start:start + c]
        mins[i] = rows.min(axis=0)
        maxs[i] = rows.max(axis=0)
        rows = rescale(rows, mins[i], maxs[i])       # per-class scaler
        Xc[i, :c] = rows
        Xc[i, c:] = rows[0] if c else 0.0
        Wc[i, :c] = 1.0
        start += c
    return Xc, Wc, classes, counts, mins, maxs


def class_stats_streaming(X, y, row_chunk: int = 65536):
    """Classes / counts / per-class min-max scalers in one streaming pass
    over row chunks — never materialises a class-sorted or padded copy of X
    (the sharded-trainer replacement for :func:`prepare_classes`).
    """
    n, p = X.shape
    if y is None:
        y = np.zeros((n,), np.int64)
    classes = np.unique(np.asarray(y))
    n_y = len(classes)
    counts = np.zeros((n_y,), np.int64)
    mins = np.full((n_y, p), np.inf, np.float32)
    maxs = np.full((n_y, p), -np.inf, np.float32)
    for s in range(0, n, row_chunk):
        xb = np.asarray(X[s:s + row_chunk], np.float32)
        cid = np.searchsorted(classes, np.asarray(y[s:s + row_chunk]))
        for i in np.unique(cid):
            sel = xb[cid == i]
            counts[i] += len(sel)
            mins[i] = np.minimum(mins[i], sel.min(axis=0))
            maxs[i] = np.maximum(maxs[i], sel.max(axis=0))
    return classes, counts, mins, maxs


# ---------------------------------------------------------------------------
# checkpoint manifest
# ---------------------------------------------------------------------------

def _manifest_fingerprint(fcfg: ForestConfig, *, n_t: int, n_y: int,
                          batch_size: int, n_rows: int, p: int,
                          trainer: str) -> dict:
    """Everything that determines which ensemble lands in which batch file.

    Resuming under a different ``ensembles_per_batch`` or ``ForestConfig``
    used to silently mix stale ``batch_*.npz`` files with fresh ones; now the
    manifest pins the full grid layout and the config, and a mismatch refuses
    to resume. Deliberately *not* fingerprinted: the seed (resume may finish
    another run's grid — completed batches never retrain) and the sharded
    trainer's mesh shape (batches are whole trained ensembles, so a
    checkpoint may be resumed on a different device count — elastic resume).
    """
    return {
        "config": dataclasses.asdict(fcfg),
        "grid": [n_t, n_y],
        "ensembles_per_batch": batch_size,
        "data_shape": [int(n_rows), int(p)],
        "trainer": trainer,
    }


def _manifest_batch_size(checkpoint_dir: str) -> Optional[int]:
    """The batch size an existing checkpoint was written with, if any."""
    path = os.path.join(checkpoint_dir, "manifest.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f).get("fingerprint", {}).get("ensembles_per_batch")


def _run_grid_batches(run_batch, grid, bs: int, *,
                      checkpoint_dir: Optional[str], resume: bool,
                      fingerprint: dict):
    """Drive the (timestep, class) grid in batches with checkpoint/resume.

    ``run_batch(chunk)`` trains ``chunk`` (a list of (ti, yi)) and returns
    ``{field: np.ndarray}`` with leading dim ``len(chunk)``. Shared by the
    single-device and sharded trainers, so both get the same Issue-3
    streaming checkpoints and the same manifest safety.
    """
    manifest_path = (os.path.join(checkpoint_dir, "manifest.json")
                     if checkpoint_dir else None)
    done = set()
    if resume and manifest_path and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
        stale = manifest.get("fingerprint")
        if stale != fingerprint:
            diff = sorted(k for k in fingerprint
                          if (stale or {}).get(k) != fingerprint[k])
            raise ValueError(
                f"checkpoint at {checkpoint_dir} was written under a "
                f"different run configuration (mismatched: {diff}); "
                "resuming would mix stale batch_*.npz files with new ones. "
                "Pass resume=False (or a fresh checkpoint_dir) to retrain.")
        done = set(tuple(e) for e in manifest["batches"])

    results = {}
    for b0 in range(0, len(grid), bs):
        chunk = grid[b0:b0 + bs]
        key_id = (b0, len(chunk))
        if key_id in done:
            data = np.load(os.path.join(checkpoint_dir, f"batch_{b0}.npz"))
            res_np = {k: data[k] for k in data.files}
        else:
            res_np = run_batch(chunk)
            if checkpoint_dir:   # Issue 3: stream to disk, checkpointed
                os.makedirs(checkpoint_dir, exist_ok=True)
                np.savez(os.path.join(checkpoint_dir, f"batch_{b0}.npz"),
                         **res_np)
                done.add(key_id)
                with open(manifest_path, "w") as f:
                    json.dump({"fingerprint": fingerprint,
                               "batches": sorted(done)}, f)
        for j, (ti, yi) in enumerate(chunk):
            results[(ti, yi)] = {k: v[j] for k, v in res_np.items()}
    return results


# ---------------------------------------------------------------------------
# single-device trainer
# ---------------------------------------------------------------------------

def fit_artifacts(X, y=None, fcfg: ForestConfig = ForestConfig(), *,
                  seed: int = 0, checkpoint_dir: Optional[str] = None,
                  resume: bool = False, ensembles_per_batch: int = 0,
                  mesh=None, data_axes: Optional[Tuple[str, ...]] = None,
                  model_axis: str = "model",
                  row_chunk: int = 65536) -> ForestArtifacts:
    """Train all (timestep, class) ensembles; returns portable artifacts.

    One jitted+vmapped fit program trains ``ensembles_per_batch`` ensembles
    per dispatch; batches stream to ``checkpoint_dir`` (Issue 3) and
    ``resume=True`` restarts from the manifest.

    ``mesh`` selects the trainer: ``None`` (default) is the single-device
    path; a :class:`jax.sharding.Mesh` routes through the shard_map trainer
    with rows sharded over ``data_axes`` and the ensemble grid over
    ``model_axis``; the string ``"auto"`` builds a mesh from every visible
    device (``repro.launch.mesh.auto_forest_mesh``) and falls back to the
    single-device path when there is only one.
    """
    if isinstance(mesh, str):
        if mesh != "auto":
            raise ValueError(f"mesh={mesh!r}: expected a Mesh, None, or "
                             "'auto'")
        from repro.launch.mesh import auto_forest_mesh
        mesh = auto_forest_mesh()
    if mesh is not None:
        return _fit_artifacts_sharded(
            X, y, fcfg, mesh, seed=seed, checkpoint_dir=checkpoint_dir,
            resume=resume, ensembles_per_batch=ensembles_per_batch,
            data_axes=data_axes, model_axis=model_axis, row_chunk=row_chunk)

    Xc, Wc, classes, counts, mins, maxs = prepare_classes(X, y)
    n_y, n_max, p = Xc.shape
    Xc_d = jnp.asarray(Xc)
    Wc_d = jnp.asarray(Wc)
    ts = np.asarray(itp.timesteps(fcfg.method, fcfg.n_t, fcfg.eps_diff,
                                  fcfg.t_schedule))
    root = jax.random.PRNGKey(seed)

    K = fcfg.duplicate_k

    def fit_one(t, y_idx, eid):
        """Train the (t, y) ensemble; everything transient lives here."""
        x0 = Xc_d[y_idx]
        w = Wc_d[y_idx]
        x0d = jnp.repeat(x0, K, axis=0)                  # [mK, p]
        wd = jnp.repeat(w, K, axis=0)
        k_tr = jax.random.fold_in(root, eid * 2)
        k_va = jax.random.fold_in(root, eid * 2 + 1)
        _, xt, tgt = itp.sample_bridge(k_tr, x0d, fcfg.method, t, fcfg.sigma)
        edges = weighted_edges(xt, wd, fcfg.n_bins)
        codes = transform(xt, edges)
        _, xtv, tgtv = itp.sample_bridge(k_va, x0d, fcfg.method, t,
                                         fcfg.sigma)
        codes_v = transform(xtv, edges)
        res = fit_ensemble(codes, tgt, wd, edges_with_sentinel(edges),
                           codes_v, tgtv, wd, fcfg)
        return res

    fit_batch = jax.jit(jax.vmap(fit_one, in_axes=(0, 0, 0)))

    grid = [(ti, yi) for ti in range(fcfg.n_t) for yi in range(n_y)]
    bs = ensembles_per_batch or max(1, min(len(grid), 8))

    def run_batch(chunk):
        t_arr = jnp.asarray([ts[ti] for ti, _ in chunk], jnp.float32)
        y_arr = jnp.asarray([yi for _, yi in chunk], jnp.int32)
        e_arr = jnp.asarray([ti * n_y + yi for ti, yi in chunk], jnp.int32)
        res = fit_batch(t_arr, y_arr, e_arr)
        return {k: np.asarray(getattr(res, k)) for k in RESULT_FIELDS}

    fingerprint = _manifest_fingerprint(
        fcfg, n_t=fcfg.n_t, n_y=n_y, batch_size=bs,
        n_rows=np.asarray(X).shape[0], p=p, trainer="single")
    results = _run_grid_batches(run_batch, grid, bs,
                                checkpoint_dir=checkpoint_dir, resume=resume,
                                fingerprint=fingerprint)
    return ForestArtifacts.from_grid_results(results, fcfg.n_t, n_y, mins,
                                             maxs, classes, counts, fcfg)


# ---------------------------------------------------------------------------
# sharded trainer (the paper's §3.3 scaling story, TPU-native)
# ---------------------------------------------------------------------------

def _fit_artifacts_sharded(X, y, fcfg: ForestConfig, mesh, *, seed: int,
                           checkpoint_dir: Optional[str], resume: bool,
                           ensembles_per_batch: int,
                           data_axes: Optional[Tuple[str, ...]],
                           model_axis: str,
                           row_chunk: int) -> ForestArtifacts:
    """shard_map training from host data to :class:`ForestArtifacts`.

    Rows (rescaled per class, weight-masked class conditioning — no padded
    [n_y, n_max, p] blocks) are sharded over the data axes and streamed to
    the devices chunk by chunk via ``make_array_from_callback``: each device
    uploads only its own row slice, so X never has to fit on one device.
    The (timestep, class) grid is sharded over the model axis in batches of
    ``ensembles_per_batch`` (rounded up to the model-axis size), reusing the
    same checkpoint/resume manifest as the single-device path.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.forest.distributed import make_distributed_fit

    # keep memmap-style inputs lazy: only per-shard chunks are ever copied
    X_np = X if isinstance(X, np.ndarray) else np.asarray(X, np.float32)
    n, p = X_np.shape
    if y is None:
        y = np.zeros((n,), np.int64)
    classes, counts, mins, maxs = class_stats_streaming(X_np, y, row_chunk)
    n_y = len(classes)
    cid_full = np.searchsorted(classes, np.asarray(y)).astype(np.int32)

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if model_axis not in axis_sizes:
        raise ValueError(f"mesh has no {model_axis!r} axis: "
                         f"{mesh.axis_names}")
    if data_axes is None:
        data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    m_size = axis_sizes[model_axis]
    d_size = int(np.prod([axis_sizes[a] for a in data_axes], dtype=np.int64))

    # Deterministic shuffle so every row shard sees every class: the sketch
    # quantiles gather the head of each shard, and a class-sorted input on a
    # small mesh would starve some ensembles' sketches entirely.
    perm = np.random.default_rng(seed).permutation(n)
    n_pad = -(-n // d_size) * d_size       # rows padded to w=0 tail

    def _rows(idx, fill, build):
        """Materialise one device's row slice of a [n_pad, ...] array."""
        sl = idx[0]
        lo = sl.start or 0
        hi = n_pad if sl.stop is None else sl.stop
        take = perm[lo:min(hi, n)]
        out = build(take)
        if hi > n:                          # tail padding rows
            pad_shape = (hi - max(lo, n),) + out.shape[1:]
            out = np.concatenate([out, np.full(pad_shape, fill, out.dtype)])
        return out

    # host→device streaming: each callback touches only its shard's chunk of
    # X (one advanced-index copy of n_pad/d_size rows), rescaled with that
    # row's own per-class scaler
    def x_cb(idx):
        return _rows(idx, 0.0, lambda take: rescale(
            np.asarray(X_np[take], np.float32), mins[cid_full[take]],
            maxs[cid_full[take]]).astype(np.float32))

    def w_cb(idx):
        return _rows(idx, 0.0,
                     lambda take: np.ones((len(take),), np.float32))

    def c_cb(idx):
        return _rows(idx, 0, lambda take: cid_full[take])

    row_sh = NamedSharding(mesh, P(data_axes))
    x0_sh = jax.make_array_from_callback((n_pad, p), row_sh, x_cb)
    w_sh = jax.make_array_from_callback((n_pad,), row_sh, w_cb)
    c_sh = jax.make_array_from_callback((n_pad,), row_sh, c_cb)

    ts = np.asarray(itp.timesteps(fcfg.method, fcfg.n_t, fcfg.eps_diff,
                                  fcfg.t_schedule))
    root = jax.random.PRNGKey(seed)
    grid = [(ti, yi) for ti in range(fcfg.n_t) for yi in range(n_y)]
    bs = ensembles_per_batch or max(m_size, min(len(grid), 8))
    if not ensembles_per_batch and resume and checkpoint_dir:
        # elastic resume: the batch size is part of the checkpoint layout,
        # so when the caller didn't pin one, inherit the manifest's rather
        # than deriving a (possibly different) default from the new mesh
        bs = _manifest_batch_size(checkpoint_dir) or bs
    bs = -(-bs // m_size) * m_size          # model-axis divisibility
    if resume and checkpoint_dir:
        stale = _manifest_batch_size(checkpoint_dir)
        if stale and stale != bs:
            raise ValueError(
                f"checkpoint at {checkpoint_dir} was written with "
                f"ensembles_per_batch={stale} but this run resolves to "
                f"{bs} (the {m_size}-wide model axis needs a multiple of "
                f"{m_size}); resume with ensembles_per_batch={stale} on a "
                "compatible mesh, or retrain with resume=False.")

    fit = make_distributed_fit(mesh, fcfg, data_axes=data_axes,
                               model_axis=model_axis)

    def run_batch(chunk):
        # pad the tail batch by repeating entries: one compiled program for
        # every dispatch; the duplicates are sliced off before writing
        full = chunk + [chunk[-1]] * (bs - len(chunk))
        t_arr = jnp.asarray([ts[ti] for ti, _ in full], jnp.float32)
        y_arr = jnp.asarray([yi for _, yi in full], jnp.int32)
        keys = np.stack([np.stack([
            np.asarray(jax.random.fold_in(root, (ti * n_y + yi) * 2),
                       np.uint32),
            np.asarray(jax.random.fold_in(root, (ti * n_y + yi) * 2 + 1),
                       np.uint32)]) for ti, yi in full])
        res = fit(x0_sh, w_sh, c_sh, t_arr, y_arr, jnp.asarray(keys))
        # gather per-model-axis shards back to host, drop the pad entries
        return {k: np.asarray(getattr(res, k))[:len(chunk)]
                for k in RESULT_FIELDS}

    fingerprint = _manifest_fingerprint(
        fcfg, n_t=fcfg.n_t, n_y=n_y, batch_size=bs, n_rows=n, p=p,
        trainer="sharded")
    results = _run_grid_batches(run_batch, grid, bs,
                                checkpoint_dir=checkpoint_dir, resume=resume,
                                fingerprint=fingerprint)
    return ForestArtifacts.from_grid_results(results, fcfg.n_t, n_y, mins,
                                             maxs, classes, counts, fcfg)
