"""ForestArtifacts: the trained model as a registered JAX pytree.

Everything a sampler or serving host needs lives here, resident on device
exactly once:

* the stacked packed forests ``[n_t, n_y, n_sub, T, ...]`` (all timesteps,
  all classes — sliced on device, never re-uploaded per call; the seed
  code re-wrapped host arrays into a :class:`PackedForest` on every
  ``generate``),
* per-class min/max scalers ``[n_y, p]``,
* the class table / empirical counts for label sampling,
* early-stopping diagnostics (``best_round`` / ``val_curve``),
* the :class:`ForestConfig` as static aux data (hashable, so an artifacts
  object can cross a ``jit`` boundary whole).

``save``/``load`` round-trip through a single ``.npz`` plus a JSON sidecar,
making trained models portable to the serving path
(:mod:`repro.launch.serve_forest`).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ForestConfig
from repro.forest.packed import PackedForest

FORMAT_VERSION = 1


def solve_axes(mesh, n_y: int, model_axis: str = "model"):
    """(class-dim axis | None, row-dim axes tuple | None) — THE placement
    policy shared by :meth:`ForestArtifacts.shard` and the sharded solve in
    :mod:`repro.tabgen.sampling` (one source of truth, so pre-placed serving
    arrays always match the solve's sharding constraints).

    Classes go on the model axis only when they divide it evenly (a 3-class
    model on a 2-wide model axis replicates classes instead of failing);
    rows always shard over the remaining (data) axes — GSPMD handles uneven
    row counts by padding internally.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = (model_axis if model_axis in sizes
             and n_y % sizes[model_axis] == 0 else None)
    rows = tuple(a for a in mesh.axis_names if a != model_axis) or None
    return model, rows


def scaler_span(mins, maxs):
    """``max - min`` with degenerate columns (max <= min) pinned to 1 — THE
    per-class scaler convention shared by fit, sample, and impute. Bool
    arithmetic instead of ``where`` so it evaluates identically on numpy
    and jax arrays."""
    gt = maxs > mins
    return (maxs - mins) * gt + (1 - gt)


def rescale(x, mins, maxs):
    """Data space -> model space [-1, 1]."""
    return (x - mins) / scaler_span(mins, maxs) * 2.0 - 1.0


def unscale(x, mins, maxs):
    """Model space [-1, 1] -> data space."""
    return (x + 1.0) / 2.0 * scaler_span(mins, maxs) + mins

# the BoostResult fields a trainer saves per ensemble — the single source
# of truth shared by run_batch checkpoints and from_grid_results assembly
RESULT_FIELDS = ("feat", "thr_val", "leaf", "best_round", "rounds_run",
                 "val_curve")

# device arrays = pytree leaves, in flatten order; classes/counts are host
# metadata and travel in the static aux data instead
_LEAF_FIELDS = RESULT_FIELDS + ("mins", "maxs")
_ARRAY_FIELDS = _LEAF_FIELDS + ("classes", "counts")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ForestArtifacts:
    feat: jnp.ndarray        # [n_t, n_y, n_sub, T, H] int32
    thr_val: jnp.ndarray     # [n_t, n_y, n_sub, T, H] fp32
    leaf: jnp.ndarray        # [n_t, n_y, n_sub, T, L, out] fp32
    best_round: jnp.ndarray  # [n_t, n_y, n_sub] int32
    rounds_run: jnp.ndarray  # [n_t, n_y, n_sub] int32
    val_curve: jnp.ndarray   # [n_t, n_y, n_sub, T] fp32
    mins: jnp.ndarray        # [n_y, p] fp32 per-class scaler lows
    maxs: jnp.ndarray        # [n_y, p] fp32 per-class scaler highs
    classes: np.ndarray      # [n_y] original label values (host)
    counts: np.ndarray       # [n_y] class counts (host)
    config: ForestConfig     # static
    # data lineage: {"rows", "store" {fingerprint, version, n_rows} | None,
    # "base" {round_range, ...} | None} — host metadata for staleness checks
    # at swap time. Not a pytree leaf and not aux data (dicts aren't
    # hashable), so it does not survive a jit boundary; persistence is via
    # the save/load sidecar.
    lineage: Optional[dict] = None

    # -- pytree protocol ----------------------------------------------------
    # classes/counts go into aux data (as hashable tuples) so a whole
    # artifacts object can cross a jit boundary: only device arrays trace

    def tree_flatten(self):
        aux = (self.config, tuple(np.asarray(self.classes).tolist()),
               tuple(np.asarray(self.counts).tolist()))
        return tuple(getattr(self, f) for f in _LEAF_FIELDS), aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        config, classes, counts = aux
        return cls(*leaves, classes=np.asarray(classes),
                   counts=np.asarray(counts), config=config)

    # -- shape helpers ------------------------------------------------------

    @property
    def n_t(self) -> int:
        return self.feat.shape[0]

    @property
    def n_y(self) -> int:
        return self.feat.shape[1]

    @property
    def p(self) -> int:
        return self.mins.shape[1]

    def class_forest(self, yi: int) -> PackedForest:
        """Packed forest stack [n_t, ...] for one class — a device-side
        slice of the cached arrays, no host round-trip."""
        return PackedForest(self.feat[:, yi], self.thr_val[:, yi],
                            self.leaf[:, yi], self.config.multi_output)

    def trees_at_best_iteration(self) -> np.ndarray:
        """Paper Fig. 3: trees kept per timestep (mean over y, sub)."""
        return np.mean(np.asarray(self.best_round) + 1, axis=(1, 2))

    def shard(self, mesh, model_axis: str = "model") -> "ForestArtifacts":
        """Device-place the arrays for mesh-sharded sampling.

        The class dim goes over ``model_axis`` per :func:`solve_axes` (the
        same policy the sharded solve constrains with), everything else is
        replicated; rows are sharded inside the sampling program itself. A
        serving host calls this once at load time so repeated
        :func:`~repro.tabgen.sample` calls with the same mesh skip the
        per-call reshard.
        """
        ax, _ = solve_axes(mesh, self.n_y, model_axis)

        def put(arr, *spec):
            sh = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(*spec))
            return jax.device_put(arr, sh)

        return dataclasses.replace(
            self,
            feat=put(self.feat, None, ax), thr_val=put(self.thr_val, None, ax),
            leaf=put(self.leaf, None, ax), best_round=put(self.best_round, None, ax),
            rounds_run=put(self.rounds_run, None, ax),
            val_curve=put(self.val_curve, None, ax),
            mins=put(self.mins, ax), maxs=put(self.maxs, ax))

    def extend(self, X, y=None, *, extra_trees: int, **kwargs):
        """Warm-start continuation: grow every ensemble by ``extra_trees``
        boosting rounds on (possibly freshly appended) data, reusing this
        model's scalers and seeded from its trees. Bit-identical to a cold
        fit run straight to ``n_trees + extra_trees`` on the same data.

        Thin delegate to :func:`repro.tabgen.fitting.extend_artifacts`
        (imported lazily — fitting imports this module).
        """
        from repro.tabgen.fitting import extend_artifacts
        return extend_artifacts(self, X, y, extra_trees=extra_trees,
                                **kwargs)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_grid_results(cls, results: dict, n_t: int, n_y: int, mins, maxs,
                          classes, counts,
                          config: ForestConfig) -> "ForestArtifacts":
        """Assemble per-ensemble fit outputs into stacked artifacts.

        ``results`` maps ``(ti, yi)`` to ``{field: array}`` — the host-side
        per-ensemble slices produced by either trainer (for the sharded
        trainer these are the gathered per-model-axis shards). Restacks to
        ``[n_t, n_y, ...]`` and bundles the per-class scalers.
        """
        def stack(field):
            return np.stack([
                np.stack([results[(ti, yi)][field] for yi in range(n_y)])
                for ti in range(n_t)])

        forests = {k: stack(k) for k in RESULT_FIELDS}
        return cls.from_fit(forests, mins, maxs, classes, counts, config)

    @classmethod
    def from_fit(cls, forests: dict, mins, maxs, classes, counts,
                 config: ForestConfig) -> "ForestArtifacts":
        """Bundle raw fit outputs; forest arrays go to device once, here."""
        return cls(
            feat=jnp.asarray(forests["feat"], jnp.int32),
            thr_val=jnp.asarray(forests["thr_val"], jnp.float32),
            leaf=jnp.asarray(forests["leaf"], jnp.float32),
            best_round=jnp.asarray(forests["best_round"], jnp.int32),
            rounds_run=jnp.asarray(forests["rounds_run"], jnp.int32),
            val_curve=jnp.asarray(forests["val_curve"], jnp.float32),
            mins=jnp.asarray(mins, jnp.float32),
            maxs=jnp.asarray(maxs, jnp.float32),
            classes=np.asarray(classes),
            counts=np.asarray(counts),
            config=config)

    # -- persistence --------------------------------------------------------

    def save(self, path: str, extra_meta: Optional[dict] = None) -> str:
        """Write ``<path>.npz`` (arrays) + ``<path>.json`` (config + meta).

        ``extra_meta`` lets callers (e.g. :class:`TabularGenerator`) ride
        schema information along in the same sidecar. Returns the base path.
        """
        base = path[:-4] if path.endswith(".npz") else path
        d = os.path.dirname(base)
        if d:
            os.makedirs(d, exist_ok=True)
        arrays = {f: np.asarray(getattr(self, f)) for f in _ARRAY_FIELDS}
        if arrays["classes"].dtype == object:
            # np.load(allow_pickle=False) rejects pickled object arrays.
            # Re-inferring from the list recovers a concrete dtype (e.g.
            # pandas-style object-of-int labels round-trip as int64);
            # genuinely mixed labels fall back to fixed-width unicode.
            coerced = np.asarray(arrays["classes"].tolist())
            arrays["classes"] = (coerced if coerced.dtype != object
                                 else arrays["classes"].astype(str))
        np.savez(base + ".npz", **arrays)
        meta = {
            "format_version": FORMAT_VERSION,
            "config": dataclasses.asdict(self.config),
        }
        if self.lineage is not None:
            meta["lineage"] = self.lineage
        if extra_meta:
            meta.update(extra_meta)
        with open(base + ".json", "w") as f:
            json.dump(meta, f, indent=1)
        return base

    @classmethod
    def load(cls, path: str, meta: Optional[dict] = None) -> "ForestArtifacts":
        """``meta`` lets a caller that already read the sidecar (e.g.
        :class:`TabularGenerator`) skip the second JSON parse."""
        base = path[:-4] if path.endswith(".npz") else path
        if meta is None:
            with open(base + ".json") as f:
                meta = json.load(f)
        if meta.get("format_version", 0) > FORMAT_VERSION:
            raise ValueError(
                f"artifacts at {base} were written by a newer format "
                f"({meta['format_version']} > {FORMAT_VERSION})")
        config = ForestConfig(**meta["config"])
        kw = {}
        with np.load(base + ".npz", allow_pickle=False) as data:
            for f in _ARRAY_FIELDS:
                arr = data[f]
                if f in ("classes", "counts"):
                    kw[f] = arr
                else:
                    kw[f] = jnp.asarray(arr)
        return cls(config=config, lineage=meta.get("lineage"), **kw)

    @staticmethod
    def load_meta(path: str) -> dict:
        """Read just the JSON sidecar (schema, config) without the arrays."""
        base = path[:-4] if path.endswith(".npz") else path
        with open(base + ".json") as f:
            return json.load(f)
