"""Dataset ingestion driver: stream any row source into a DatasetStore.

One pass over the source writes the columnar row-sharded store plus the
precomputed statistics every fit needs (class histogram, per-class min/max
scalers, mergeable per-feature quantile sketches) — see
:mod:`repro.data.store`. ``train_forest --data-dir`` then fits out-of-core
from the result.

Sources (exactly one):

  --synthetic NxPxC   paper D.1 generator, e.g. ``--synthetic 1000000x32x4``
  --calo NAME:N       synthetic CaloChallenge showers, e.g.
                      ``--calo photons_mini:120000``
  --npz FILE          an .npz with ``X [n, p]`` (optionally ``y [n]``) —
                      loaded once by numpy, so it must fit in RAM; a plain
                      ``.npy`` feature file streams via memmap instead
                      (never fully resident)
  --csv FILE          numeric CSV, streamed line-chunk by line-chunk
                      (``--label-col`` marks an integer label column)

Examples::

  PYTHONPATH=src python -m repro.launch.ingest \
      --out data/synth1m --synthetic 1000000x32x4 --shard-rows 65536

  PYTHONPATH=src python -m repro.launch.ingest \
      --out data/synth1m --synthetic 1000000x32x4 --resume   # after a crash

A crash mid-ingest leaves a consistent partial store; re-running with
``--resume`` (same source spec — fingerprint-checked) skips the committed
shards and finishes the stream.
"""
from __future__ import annotations

import argparse
import io
import json
import time

import numpy as np


def _npz_batches(path: str, batch_rows: int):
    """.npy sources stream via a true memmap (only the yielded chunk is
    ever resident); .npz archives are zip members numpy loads whole —
    fine up to RAM, use .npy (or re-save) for larger-than-RAM inputs."""
    if path.endswith(".npy"):
        X, y = np.load(path, mmap_mode="r"), None
    else:
        with np.load(path) as d:   # np.load ignores mmap_mode inside .npz
            X = d["X"]
            y = d["y"] if "y" in d.files else None
    for s in range(0, X.shape[0], batch_rows):
        xb = np.asarray(X[s:s + batch_rows], np.float32)
        yield (xb, np.asarray(y[s:s + batch_rows])) if y is not None \
            else xb


def _csv_batches(path: str, batch_rows: int, label_col):
    """Stream a numeric CSV without loading it whole; non-numeric first
    line is treated as a header and skipped."""
    def parse(lines):
        arr = np.loadtxt(io.StringIO("".join(lines)), delimiter=",",
                         ndmin=2, dtype=np.float64)
        if label_col is None:
            return arr.astype(np.float32)
        y = arr[:, label_col].astype(np.int64)
        X = np.delete(arr, label_col % arr.shape[1], axis=1)
        return X.astype(np.float32), y

    with open(path) as f:
        first = f.readline()
        buf = []
        try:
            np.loadtxt(io.StringIO(first), delimiter=",")
            buf.append(first)
        except ValueError:
            pass                                   # header line
        for line in f:
            if line.strip():
                buf.append(line)
            if len(buf) >= batch_rows:
                yield parse(buf)
                buf = []
        if buf:
            yield parse(buf)


def _source_batches(args):
    """(batches iterator, fingerprintable source description)."""
    if args.synthetic:
        from repro.data.tabular import synthetic_resource_batches
        n, p, n_y = (int(v) for v in args.synthetic.split("x"))
        # batch_rows is part of the stream identity: batch b draws from
        # PRNG stream [seed, b], so a resume under a different --batch-rows
        # would skip rows of a *different* stream — fingerprint it
        spec = {"kind": "synthetic", "n": n, "p": p, "n_y": n_y,
                "seed": args.seed, "batch_rows": args.batch_rows}
        return (synthetic_resource_batches(
            n, p, n_y, batch_rows=args.batch_rows, seed=args.seed), spec)
    if args.calo:
        from repro.data.calorimeter import generate_batches
        name, n = args.calo.split(":")
        spec = {"kind": "calo", "dataset": name, "n": int(n),
                "seed": args.seed, "batch_rows": args.batch_rows}
        return (generate_batches(name, int(n), batch_rows=args.batch_rows,
                                 seed=args.seed), spec)
    if args.npz:
        return (_npz_batches(args.npz, args.batch_rows),
                {"kind": "npz", "path": args.npz})
    if args.csv:
        return (_csv_batches(args.csv, args.batch_rows, args.label_col),
                {"kind": "csv", "path": args.csv,
                 "label_col": args.label_col})
    raise SystemExit("pick a source: --synthetic / --calo / --npz / --csv")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True,
                    help="store directory to create (or resume)")
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--synthetic", default=None, metavar="NxPxC")
    src.add_argument("--calo", default=None, metavar="NAME:N")
    src.add_argument("--npz", default=None,
                     help=".npz with X/y (RAM-resident) or a .npy feature "
                          "file (memmap-streamed)")
    src.add_argument("--csv", default=None)
    ap.add_argument("--label-col", type=int, default=None,
                    help="CSV column holding integer labels")
    ap.add_argument("--batch-rows", type=int, default=8192,
                    help="rows per source batch (peak ingest memory knob)")
    ap.add_argument("--shard-rows", type=int, default=65536,
                    help="rows per on-disk shard")
    ap.add_argument("--sketch-entries", type=int, default=2048,
                    help="quantile-sketch summary size per feature (exact "
                         "below this many rows; ~1/entries rank error "
                         "beyond)")
    ap.add_argument("--resume", action="store_true",
                    help="continue a crashed ingest (fingerprint-checked)")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="after the ingest, write the process metrics "
                         "registry as Prometheus text ('-' for stdout)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.data.store import ingest

    batches, spec = _source_batches(args)
    t0 = time.time()
    store = ingest(batches, args.out, shard_rows=args.shard_rows,
                   resume=args.resume, source=spec,
                   sketch_entries=args.sketch_entries)
    wall = time.time() - t0
    classes, counts, _, _ = store.class_stats()
    summary = {
        "store": args.out,
        "n_rows": store.n_rows,
        "p": store.p,
        "n_shards": store.n_shards,
        "dataset_bytes": store.nbytes,
        "classes": {int(c): int(k) for c, k in zip(classes, counts)},
        "wall_s": round(wall, 3),
        "rows_per_sec": round(store.n_rows / max(wall, 1e-9)),
    }
    print(json.dumps(summary))
    print(f"ingested {store.n_rows} rows x {store.p} cols into "
          f"{store.n_shards} shards at {args.out} "
          f"(train: python -m repro.launch.train_forest --data-dir "
          f"{args.out})")
    if args.metrics_dump:
        from repro.launch.metrics import dump
        dump(args.metrics_dump)
    return store


if __name__ == "__main__":
    main()
