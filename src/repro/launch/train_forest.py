"""Forest training driver: the CLI mirror of ``serve_forest``.

Fits the (timestep, class) ensemble grid — on one device or across a mesh
(`--mesh`), with streaming checkpoints (`--checkpoint-dir` / `--resume`) —
and saves portable :class:`ForestArtifacts` that ``serve_forest`` can load.

CPU demo on a virtual 8-device mesh (rows sharded 4-way, grid 2-way):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.train_forest --demo --mesh 4x2 --out model

Training real data (X [n, p] float, optional y [n] labels, in an .npz):

  PYTHONPATH=src python -m repro.launch.train_forest \
      --data table.npz --mesh auto --checkpoint-dir ckpt --resume --out model

Out-of-core training from an ingested DatasetStore (``repro.launch.ingest``)
— row shards stream from disk, class stats/scalers come precomputed from
the store manifest, and no host copy of the dataset is ever materialised:

  PYTHONPATH=src python -m repro.launch.train_forest \
      --data-dir data/synth1m --mesh auto --checkpoint-dir ckpt --out model

Environment knobs: ``REPRO_HIST_IMPL=pallas`` selects the Pallas histogram
kernel on TPU (default ``xla``); ``--int8-codes`` stores bin codes at int8
(4x HBM reduction at n_bins ≤ 127).

The distributed fit loop runs double-buffered by default (prefetch thread
for input build, writer thread for gather + checkpoint streaming — see
``repro.tabgen.PipelineConfig``): tune with ``--prefetch-depth``, force
synchronous writes with ``--sync-checkpoint``, or fall back to the serial
PR-2 loop with ``--serial`` (bit-identical artifacts either way).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def parse_mesh(spec: str):
    """``auto`` | ``none`` | ``DxM`` (e.g. ``4x2`` — data x model)."""
    import jax

    if spec == "none":
        return None
    if spec == "auto":
        from repro.launch.mesh import auto_forest_mesh
        return auto_forest_mesh()
    dims = tuple(int(d) for d in spec.split("x"))
    if len(dims) != 2:
        raise ValueError(f"--mesh {spec!r}: expected 'auto', 'none' or DxM")
    return jax.make_mesh(dims, ("data", "model"))


def _demo_data(n: int, p: int, n_y: int, seed: int):
    from repro.data.tabular import synthetic_resource_dataset
    return synthetic_resource_dataset(n, p, n_y, seed=seed)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", default=None,
                    help=".npz with X [n, p] (and optionally y [n])")
    ap.add_argument("--data-dir", default=None,
                    help="DatasetStore directory from repro.launch.ingest — "
                         "out-of-core fit: row shards stream from disk, "
                         "stats come precomputed from the store manifest "
                         "(overrides --data/--demo)")
    ap.add_argument("--demo", action="store_true",
                    help="train on a synthetic dataset instead of --data")
    ap.add_argument("--demo-rows", type=int, default=2048)
    ap.add_argument("--demo-cols", type=int, default=8)
    ap.add_argument("--demo-classes", type=int, default=2)
    ap.add_argument("--mesh", default="auto",
                    help="'auto' (all devices), 'none' (single device), or "
                         "DxM e.g. 4x2")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ensembles-per-batch", type=int, default=0)
    # pipeline knobs (distributed trainer only; see tabgen.PipelineConfig)
    ap.add_argument("--serial", action="store_true",
                    help="disable the double-buffered pipeline: serial "
                         "per-batch build -> dispatch -> gather -> write")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="bounded-queue depth between the input-build, "
                         "dispatch, and writer stages (1 = classic double "
                         "buffering)")
    ap.add_argument("--sync-checkpoint", action="store_true",
                    help="gather + write batch_*.npz on the dispatch "
                         "thread instead of the async writer thread")
    ap.add_argument("--out", default=None,
                    help="base path for the saved .npz/.json artifact pair")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="after the fit, write the process metrics "
                         "registry as Prometheus text ('-' for stdout); "
                         "see docs/observability.md")
    ap.add_argument("--seed", type=int, default=0)
    # ForestConfig knobs (paper Table 9 names)
    ap.add_argument("--method", default="flow",
                    choices=("flow", "diffusion"))
    ap.add_argument("--n-t", type=int, default=10)
    ap.add_argument("--duplicate-k", type=int, default=20)
    ap.add_argument("--n-trees", type=int, default=40)
    ap.add_argument("--max-depth", type=int, default=5)
    ap.add_argument("--n-bins", type=int, default=64)
    ap.add_argument("--learning-rate", type=float, default=0.3)
    ap.add_argument("--reg-lambda", type=float, default=1.0)
    ap.add_argument("--sigma", type=float, default=0.0)
    ap.add_argument("--multi-output", action="store_true")
    ap.add_argument("--early-stop-rounds", type=int, default=0)
    ap.add_argument("--int8-codes", action="store_true")
    args = ap.parse_args(argv)

    import jax

    from repro.config import ForestConfig
    from repro.tabgen import PipelineConfig, fit_artifacts

    if args.data_dir:
        from repro.data.store import DatasetStore
        X, y = DatasetStore(args.data_dir), None
        print(f"store {args.data_dir}: {X.n_rows} rows x {X.p} cols in "
              f"{X.n_shards} shards ({X.nbytes / 2**20:.1f} MiB on disk, "
              "streamed — not resident)")
    elif args.demo or args.data is None:
        X, y = _demo_data(args.demo_rows, args.demo_cols, args.demo_classes,
                          args.seed)
        print(f"demo dataset: X {X.shape}, {args.demo_classes} classes")
    else:
        with np.load(args.data) as d:
            X = d["X"]
            y = d["y"] if "y" in d.files else None
        print(f"loaded {args.data}: X {X.shape}"
              + (f", y {y.shape}" if y is not None else ", unlabeled"))

    fcfg = ForestConfig(
        method=args.method, n_t=args.n_t, duplicate_k=args.duplicate_k,
        n_trees=args.n_trees, max_depth=args.max_depth, n_bins=args.n_bins,
        learning_rate=args.learning_rate, reg_lambda=args.reg_lambda,
        sigma=args.sigma, multi_output=args.multi_output,
        early_stop_rounds=args.early_stop_rounds, int8_codes=args.int8_codes)

    mesh = parse_mesh(args.mesh)
    pipeline = (None if args.serial else PipelineConfig(
        prefetch_depth=args.prefetch_depth,
        async_checkpoint=not args.sync_checkpoint))
    if mesh is None and args.data_dir:
        print("trainer: out-of-core store fit on a 1x1 mesh "
              f"({jax.devices()[0].platform}; sharded trainer, rows "
              "streamed from disk)")
    elif mesh is None:
        print(f"trainer: single-device ({jax.devices()[0].platform})")
    else:
        shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        mode = ("serial" if pipeline is None else
                f"pipelined (prefetch_depth={pipeline.prefetch_depth}, "
                f"async_checkpoint={pipeline.async_checkpoint})")
        print(f"trainer: shard_map over {mesh.devices.size} devices "
              f"{shape}, {mode}")

    t0 = time.time()
    art = fit_artifacts(X, y, fcfg, seed=args.seed,
                        checkpoint_dir=args.checkpoint_dir,
                        resume=args.resume,
                        ensembles_per_batch=args.ensembles_per_batch,
                        mesh=mesh, pipeline=pipeline)
    wall = time.time() - t0
    n_ens = art.n_t * art.n_y
    # throughput over the work actually done: every ensemble trains on all
    # n rows duplicated K-fold
    rows = X.shape[0] * fcfg.duplicate_k * n_ens
    print(f"trained {n_ens} ensembles ({art.n_t} timesteps x {art.n_y} "
          f"classes) in {wall:.2f}s -> "
          f"{rows / wall:,.0f} ensemble-rows/sec")
    print(json.dumps({"wall_s": round(wall, 3),
                      "ensemble_rows_per_sec": round(rows / wall),
                      "rows_per_sec": round(X.shape[0] * n_ens / wall)}))

    if args.out:
        base = art.save(args.out)
        print(f"artifacts saved to {base}.npz / {base}.json "
              f"(serve: python -m repro.launch.serve_forest "
              f"--artifacts {base})")

    if args.metrics_dump:
        from repro.launch.metrics import dump
        dump(args.metrics_dump)


if __name__ == "__main__":
    main()
