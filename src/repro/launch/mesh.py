"""Production meshes. Defined as functions so importing never touches jax
device state (the dry-run must set XLA_FLAGS before any initialisation)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256 chips, one v5e pod) or 2x16x16 (512 chips, two pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 4, n_model: int = 2):
    """Small host-device mesh for tests (requires matching device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def auto_forest_mesh(model_axis_max: int = 8):
    """(data, model) mesh over every visible device for forest training.

    The model axis (ensemble-grid parallelism) gets the largest power of two
    that divides the device count, is at most ``model_axis_max``, and stays
    ≤ the data-axis size — rows usually outnumber ensembles per batch, so
    the data axes keep the majority of the devices. Returns ``None`` on a
    single device (callers fall back to the single-device trainer).
    """
    n = len(jax.devices())
    if n == 1:
        return None
    model = 1
    while (model * 2 <= model_axis_max and (model * 2) ** 2 <= n
           and n % (model * 2) == 0):
        model *= 2
    return jax.make_mesh((n // model, model), ("data", "model"))
