"""Incremental freshness loop: append rows, extend the forest, hot-swap.

One command drives the whole refresh path end to end:

1. **append** — stream a row source (same flags as ``repro.launch.ingest``)
   into an *existing* :class:`~repro.data.store.DatasetStore` via
   :meth:`DatasetStore.append`: sketches merge, class stats update, the
   manifest version bumps, and readers of the old snapshot keep working.
2. **fit** — warm-start extend the base model on the grown store with
   :func:`repro.tabgen.extend_artifacts`: the base trees are reused
   verbatim and only ``--extra-trees`` new boosting rounds train, through
   the same pipelined dispatch/writer loop as a cold fit.
3. **save** — write the extended artifact pair (base schema rides along),
   with lineage metadata (store fingerprint/version/rows, base round
   range) in the JSON sidecar.
4. **swap** — ``POST /v1/models/<name>/reload`` against a running
   ``repro.launch.serve_http`` instance, which loads the new artifacts and
   atomically swaps them into the registry; in-flight requests finish on
   the old version.

Steps 1 and 4 are optional: omit the source flags to refit on the store
as-is, omit ``--server`` for an offline extend (swap later by hand).

Example — nightly refresh of a served model::

  PYTHONPATH=src python -m repro.launch.refresh \
      --store data/synth1m --synthetic 100000x32x4 --seed 1 \
      --artifacts models/synth --out models/synth_v2 --extra-trees 10 \
      --server http://127.0.0.1:8433 --model synth

Observability: the run is wrapped in ``refresh.append`` / ``refresh.fit``
/ ``refresh.save`` / ``refresh.swap`` spans on the process tracer, and
records ``refresh_runs{status}``, ``refresh_rows_appended``,
``refresh_trees_added`` and the ``refresh_fit_seconds`` histogram in the
process metrics registry (``--metrics-dump`` exports them).
"""
from __future__ import annotations

import argparse
import json
import time
import urllib.error
import urllib.request


def swap_model(server: str, model: str, path: str, timeout: float = 60.0
               ) -> dict:
    """``POST {server}/v1/models/{model}/reload`` — returns the response
    body (new version/nbytes/lineage) or raises with the server's error."""
    url = f"{server.rstrip('/')}/v1/models/{model}/reload"
    body = json.dumps({"path": path}).encode()
    req = urllib.request.Request(
        url, data=body, headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return json.loads(resp.read())
    except urllib.error.HTTPError as e:
        detail = e.read().decode(errors="replace")
        raise RuntimeError(
            f"reload rejected by {url}: HTTP {e.code} {detail}") from e


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", required=True,
                    help="existing DatasetStore directory to append to / "
                         "refit from")
    ap.add_argument("--artifacts", required=True,
                    help="base model artifact path (from train_forest "
                         "--out or a previous refresh)")
    ap.add_argument("--out", required=True,
                    help="path for the extended artifact pair")
    ap.add_argument("--extra-trees", type=int, required=True,
                    help="boosting rounds to add on top of the base model")
    # append source — same flags as repro.launch.ingest; all optional:
    # omitting them skips the append and refits on the store as-is
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--synthetic", default=None, metavar="NxPxC")
    src.add_argument("--calo", default=None, metavar="NAME:N")
    src.add_argument("--npz", default=None)
    src.add_argument("--csv", default=None)
    ap.add_argument("--label-col", type=int, default=None)
    ap.add_argument("--batch-rows", type=int, default=8192)
    ap.add_argument("--resume", action="store_true",
                    help="finish a crashed refresh: resume the append "
                         "(fingerprint-checked) and the fit checkpoint")
    # fit knobs (subset of train_forest)
    ap.add_argument("--mesh", default="none",
                    help="'auto', 'none' (default) or DxM e.g. 4x2")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="streaming fit checkpoints; a dir holding the "
                         "*base* run's checkpoint is accepted (warm-base "
                         "fingerprint match) and overwritten")
    ap.add_argument("--seed", type=int, default=0)
    # swap target — optional: omit for an offline extend
    ap.add_argument("--server", default=None,
                    help="base URL of a running serve_http, e.g. "
                         "http://127.0.0.1:8433")
    ap.add_argument("--model", default=None,
                    help="registry name to hot-swap on --server")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="write the process metrics registry as Prometheus "
                         "text ('-' for stdout)")
    args = ap.parse_args(argv)
    if bool(args.server) != bool(args.model):
        raise SystemExit("--server and --model go together")

    from repro.data.store import DatasetStore
    from repro.launch.ingest import _source_batches
    from repro.launch.train_forest import parse_mesh
    from repro.obs import default_registry, default_tracer
    from repro.tabgen import TabularGenerator, extend_artifacts

    reg, tracer = default_registry(), default_tracer()
    c_runs = reg.counter("refresh_runs", "Refresh loop runs", ("status",))
    c_rows = reg.counter("refresh_rows_appended",
                         "Rows appended to stores by refresh runs")
    c_trees = reg.counter("refresh_trees_added",
                          "Boosting rounds added by refresh runs")
    h_fit = reg.histogram("refresh_fit_seconds",
                          "Warm-start extension fit wall time",
                          buckets=(1.0, 5.0, 15.0, 60.0, 300.0, 1800.0))

    summary = {"store": args.store, "base": args.artifacts, "out": args.out}
    try:
        store = DatasetStore(args.store)
        base_rows = store.n_rows
        has_source = any((args.synthetic, args.calo, args.npz, args.csv))
        if has_source or args.resume:
            with tracer.span("refresh.append", store=args.store):
                batches, spec = (_source_batches(args) if has_source
                                 else (iter(()), None))
                store = store.append(batches, source=spec,
                                     resume=args.resume, metrics=reg,
                                     tracer=tracer)
        appended = store.n_rows - base_rows
        c_rows.inc(int(appended))
        summary.update(rows=store.n_rows, rows_appended=appended,
                       store_version=store.version)
        print(f"store {args.store}: +{appended} rows -> {store.n_rows} "
              f"(version {store.version})")

        base = TabularGenerator.load(args.artifacts)
        t0 = time.time()
        with tracer.span("refresh.fit", extra_trees=args.extra_trees):
            ext = extend_artifacts(
                base.artifacts, store, extra_trees=args.extra_trees,
                seed=args.seed, mesh=parse_mesh(args.mesh),
                checkpoint_dir=args.checkpoint_dir, resume=args.resume)
        fit_wall = time.time() - t0
        h_fit.observe(fit_wall)
        c_trees.inc(args.extra_trees)
        summary.update(
            fit_wall_s=round(fit_wall, 3),
            n_trees=ext.config.n_trees,
            rows_per_sec=round(store.n_rows * ext.n_t * ext.n_y
                               / max(fit_wall, 1e-9)))
        print(f"extended {base.artifacts.config.n_trees} -> "
              f"{ext.config.n_trees} trees in {fit_wall:.2f}s")

        with tracer.span("refresh.save", path=args.out):
            out_gen = TabularGenerator(ext.config, schema=base.schema)
            out_gen.artifacts = ext
            out_gen.save(args.out)
        summary["lineage"] = ext.lineage

        if args.server:
            with tracer.span("refresh.swap", model=args.model):
                resp = swap_model(args.server, args.model, args.out)
            summary.update(swapped=args.model,
                           served_version=resp.get("version"))
            print(f"swapped {args.model} on {args.server} -> "
                  f"version {resp.get('version')}")
    except Exception:
        c_runs.inc(1, status="error")
        raise
    c_runs.inc(1, status="ok")

    print(json.dumps(summary))
    if args.metrics_dump:
        from repro.launch.metrics import dump
        dump(args.metrics_dump)
    return summary


if __name__ == "__main__":
    main()
