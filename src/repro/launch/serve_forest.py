"""Forest serving driver: warm, pre-jitted tabular generation + imputation.

Since PR 6 this is a thin single-model front end over the
:mod:`repro.serving` control plane: a one-entry
:class:`~repro.serving.ModelRegistry`, an
:class:`~repro.serving.AdmissionController` (permissive by default — no
rate limits, generous queue bounds), and the
:class:`~repro.serving.InflightScheduler`. The multi-model, multi-tenant
HTTP tier lives in :mod:`repro.launch.serve_http`; both share every
control-plane behavior by construction.

Serving properties (carried over from PR 4, upgraded in PR 6):

* ``warmup()`` pre-compiles one program per (sampler, bucket) through the
  same :class:`TabularGenerator` facade that serves requests — warmed
  programs can't diverge from served ones;
* ``submit()`` queues a request and returns a future; the scheduler
  coalesces concurrent same-sampler requests into one bucketed device
  dispatch **and keeps admitting the next batch while the current one is
  in flight** (a waiter thread resolves futures — queue wait no longer
  stacks on device time);
* ``generate()`` stays synchronous and exactly per-(n, seed) deterministic;
* unknown sampler names raise ``ValueError`` at ``submit()``/``generate()``
  time, to the caller — not inside the dispatcher after a wasted dispatch;
* ``stats`` carries per-sampler splits and a queue-wait vs device-time
  breakdown next to the PR-4 aggregate counters — since PR 8 it is a view
  over one shared :class:`~repro.obs.MetricsRegistry` (``server.metrics``)
  fed by ``serve.queue``/``serve.device``/``serve.sync`` spans on
  ``server.tracer``; ``--metrics-dump`` writes the same numbers as
  Prometheus text and ``--trace-jsonl`` dumps the span ring (see
  docs/observability.md).

CPU demo (fits a small model, saves, loads, serves):

  PYTHONPATH=src python -m repro.launch.serve_forest --demo --requests 16

Serving a trained model across 8 virtual devices:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve_forest --artifacts model --mesh 4x2
"""
from __future__ import annotations

import argparse
import os
import tempfile
from concurrent.futures import Future
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.obs import MetricsRegistry, Tracer
from repro.serving import (AdmissionController, InflightScheduler,
                           ModelRegistry)
from repro.serving.registry import DEFAULT_BUCKETS  # noqa: F401 — re-export
from repro.serving.scheduler import Request as _Request  # noqa: F401
from repro.tabgen import ForestArtifacts, TabularGenerator


class ForestServer:
    """Single-host, single-model tabular-generation server.

    A convenience wrapper: one registered model named ``"default"``, the
    in-flight scheduler underneath. Reach into ``server.registry`` /
    ``server.scheduler`` for the multi-model and admission knobs (e.g.
    ``server.registry.swap("default", new_artifacts)`` for a zero-downtime
    artifact hot-swap).
    """

    MODEL = "default"

    def __init__(self, artifacts: ForestArtifacts, *,
                 samplers: Sequence[str] = (),
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 schema=None, mesh=None, impl: Optional[str] = None,
                 max_coalesce_rows: Optional[int] = None,
                 coalesce_window_s: float = 0.002,
                 inflight_depth: int = 2,
                 sync_resolve: bool = False,
                 admission: Optional[AdmissionController] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 slo=None, slo_error_budget: float = 0.01, slow_log=None):
        # one registry + tracer shared by every component of this server:
        # scheduler, admission, and model registry export one family set
        self.metrics = metrics or MetricsRegistry()
        self.tracer = tracer or Tracer()
        self.registry = ModelRegistry(mesh=mesh, impl=impl, buckets=buckets,
                                      metrics=self.metrics)
        self.registry.register(self.MODEL, artifacts, schema=schema,
                               samplers=samplers)
        self.scheduler = InflightScheduler(
            self.registry,
            admission or AdmissionController(metrics=self.metrics),
            max_coalesce_rows=max_coalesce_rows,
            coalesce_window_s=coalesce_window_s,
            inflight_depth=inflight_depth, sync_resolve=sync_resolve,
            metrics=self.metrics, tracer=self.tracer,
            slo=slo, slo_error_budget=slo_error_budget, slow_log=slow_log)
        self.mesh = self.registry.mesh
        self.impl = impl
        self.schema = schema

    @classmethod
    def from_path(cls, path: str, **kw) -> "ForestServer":
        gen = TabularGenerator.load(path)
        return cls(gen.artifacts, schema=gen.schema, **kw)

    # -- model-facing views --------------------------------------------------

    @property
    def _handle(self):
        return self.registry.peek(self.MODEL)

    @property
    def artifacts(self) -> ForestArtifacts:
        return self._handle.artifacts

    @property
    def samplers(self) -> Tuple[str, ...]:
        return self._handle.samplers

    @property
    def buckets(self) -> Tuple[int, ...]:
        return self._handle.buckets

    @property
    def max_coalesce_rows(self) -> int:
        return self.scheduler.max_coalesce_rows

    @property
    def stats(self) -> Dict[str, float]:
        return self.scheduler.stats

    # -- request path -------------------------------------------------------

    def _validate_sampler(self, sampler: Optional[str]) -> str:
        name = sampler or self.samplers[0]
        if name not in self.samplers:
            raise ValueError(
                f"server does not serve sampler {name!r}; "
                f"served: {list(self.samplers)}")
        return name

    def warmup(self) -> float:
        """Compile every (sampler, bucket) program; returns wall seconds."""
        dt = self.registry.warmup(self.MODEL)
        self.scheduler.record_warm(dt)
        return dt

    def generate(self, n: int, *, sampler: Optional[str] = None,
                 seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """Synchronous path: exact per-(n, seed) deterministic output."""
        name = self._validate_sampler(sampler)
        handle = self.registry.acquire(self.MODEL)
        with self.tracer.span("serve.sync", model=self.MODEL, sampler=name,
                              rows=int(n)) as sp:
            X, y = handle.generate(n, name, seed=seed)
        self.scheduler.record_sync(n=n, sampler=name, tenant="default",
                                   wall_s=sp.duration_s)
        return X, y

    def submit(self, n: int, *, sampler: Optional[str] = None,
               tenant: str = "default", priority: str = "interactive",
               deadline_s: Optional[float] = None) -> Future:
        """Queue a generation request; resolves to ``(X, y)``.

        Concurrent submissions coalesce into shared device dispatches, and
        the next batch is admitted while the current one is in flight.
        Unknown samplers raise ``ValueError`` here; admission rejections
        (when the server was built with rate limits / tight queue bounds)
        raise ``RateLimited`` / ``QueueFull`` here too.
        """
        return self.scheduler.submit(
            int(n), model=self.MODEL,
            sampler=self._validate_sampler(sampler),
            tenant=tenant, priority=priority, deadline_s=deadline_s)

    def start(self) -> None:
        """Start the scheduler threads (idempotent; ``submit`` auto-starts)."""
        self.scheduler.start()

    def stop(self, timeout: float = 10.0) -> None:
        """Drain the queue and stop the scheduler threads."""
        self.scheduler.stop(timeout)

    def _serve_batch(self, batch) -> None:
        """Dispatch + resolve one pre-formed batch synchronously (test seam
        kept from PR 4; production traffic goes through ``submit``)."""
        self.scheduler.serve_batch_sync(batch)

    # -- misc ---------------------------------------------------------------

    def impute(self, X_missing, y=None, *, seed: int = 0,
               refine_rounds: int = 3) -> np.ndarray:
        return self.registry.acquire(self.MODEL).impute(
            X_missing, y, seed=seed, refine_rounds=refine_rounds)

    def rows_per_sec(self) -> float:
        return self.scheduler.rows_per_sec()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _demo_artifacts(path: str) -> str:
    """Fit a small two-moons model and save it — the zero-setup demo."""
    from repro.config import ForestConfig
    from repro.data.tabular import two_moons
    X, y = two_moons(600, seed=0)
    fcfg = ForestConfig(method="flow", n_t=8, duplicate_k=10, n_trees=20,
                        max_depth=4, n_bins=32, reg_lambda=1.0)
    gen = TabularGenerator(fcfg).fit(X, y, seed=0)
    return gen.save(path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default=None,
                    help="base path of a saved model (.npz/.json pair)")
    ap.add_argument("--demo", action="store_true",
                    help="fit+save a small two-moons model first")
    ap.add_argument("--sampler", default=None)
    ap.add_argument("--buckets", default="64,256,1024")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="none",
                    help="'auto' | 'none' | DxM — shard the solve "
                         "(classes on model, rows on data)")
    ap.add_argument("--impl", default=None,
                    help="tree-predict backend: xla | pallas | "
                         "pallas_interpret (default: config/env)")
    ap.add_argument("--sync", action="store_true",
                    help="serve via the synchronous generate() path instead "
                         "of the micro-batching queue")
    ap.add_argument("--drain", action="store_true",
                    help="disable in-flight batching (PR-4 drain-then-serve "
                         "reference behavior)")
    ap.add_argument("--coalesce-window-ms", type=float, default=2.0)
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="after serving, write the metrics registry as "
                         "Prometheus text ('-' for stdout)")
    ap.add_argument("--trace-jsonl", default=None, metavar="PATH",
                    help="after serving, dump the span ring as JSON lines")
    args = ap.parse_args()

    path = args.artifacts
    if args.demo or path is None:
        path = _demo_artifacts(os.path.join(tempfile.mkdtemp(), "demo"))
        print(f"demo artifacts saved to {path}")

    from repro.launch.train_forest import parse_mesh
    samplers = (args.sampler,) if args.sampler else ()
    buckets = tuple(int(b) for b in args.buckets.split(","))
    server = ForestServer.from_path(
        path, samplers=samplers, buckets=buckets,
        mesh=parse_mesh(args.mesh), impl=args.impl,
        coalesce_window_s=args.coalesce_window_ms / 1e3,
        sync_resolve=args.drain)
    warm = server.warmup()
    print(f"warmed {len(server.samplers)} sampler(s) x {len(buckets)} "
          f"bucket(s) in {warm:.2f}s"
          + (f" on mesh {dict(zip(server.mesh.axis_names, server.mesh.devices.shape))}"
             if server.mesh is not None else ""))

    rng = np.random.default_rng(args.seed)
    sizes = rng.integers(1, max(buckets) + 1, size=args.requests)
    if args.sync:
        for i, n in enumerate(sizes):
            server.generate(int(n), seed=args.seed + i)
    else:
        futs = [server.submit(int(n)) for n in sizes]
        for f, n in zip(futs, sizes):
            X, y = f.result(timeout=300)
            assert len(X) == n
        server.stop()
    s = server.stats
    print(f"served {int(s['requests'])} requests / {int(s['rows'])} rows "
          f"in {int(s['batches'])} dispatch(es) "
          f"({int(s['coalesced_requests'])} coalesced) "
          f"in {s['gen_s']:.3f}s -> {server.rows_per_sec():.0f} rows/sec; "
          f"queue-wait {s['queue_wait_s']:.3f}s vs device {s['device_s']:.3f}s")
    if args.metrics_dump:
        from repro.launch.metrics import dump
        dump(args.metrics_dump, registries=[server.metrics])
    if args.trace_jsonl:
        n_spans = server.tracer.export_jsonl(args.trace_jsonl)
        print(f"wrote {n_spans} spans to {args.trace_jsonl}")


if __name__ == "__main__":
    main()
