"""Forest serving driver: warm, pre-jitted tabular generation + imputation.

Loads :class:`ForestArtifacts` (or a full :class:`TabularGenerator` with a
schema sidecar) from disk and answers batched requests. Request sizes are
rounded up to a small set of batch buckets so every (sampler, bucket) pair
compiles exactly once at warm-up — after that each request is one cached
device program (the tabgen sampler is class-vmapped, so this holds for any
number of classes).

Scaling knobs (PR 4):

* ``mesh=`` shards every solve the way training shards fits — classes on
  the model axis, rows on the data axes (artifacts are pre-placed once at
  construction, so requests never pay a reshard);
* ``impl=`` selects the tree-predict backend (``xla`` | ``pallas`` |
  ``pallas_interpret``) for all served traffic;
* ``submit()`` queues a request and returns a future — a dispatcher thread
  coalesces concurrent same-sampler requests into one bucketed device
  dispatch (micro-batching), so many small callers share one program launch.

CPU demo (fits a small model, saves, loads, serves):

  PYTHONPATH=src python -m repro.launch.serve_forest --demo --requests 16

Serving a trained model across 8 virtual devices:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve_forest --artifacts model --mesh 4x2
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import queue
import tempfile
import threading
import time
from concurrent.futures import Future
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.tabgen import (ForestArtifacts, TabularGenerator, default_sampler,
                          sample_labels)
from repro.tabgen.sampling import resolve_mesh

DEFAULT_BUCKETS = (64, 256, 1024)

#: Seed base of the micro-batched path: coalesced batches draw their own
#: sample seeds from a server-local counter offset far from the ones users
#: hand to ``generate(seed=...)``, so the two paths never collide in the
#: label-draw RNG space.
_BATCH_SEED_BASE = 1 << 20


@dataclasses.dataclass
class _Request:
    n: int
    sampler: str
    future: Future


_SHUTDOWN = object()


class ForestServer:
    """Single-host tabular-generation server over loaded artifacts.

    ``warmup()`` pre-compiles one sampler program per (sampler, bucket);
    ``generate()`` buckets the request, reuses the cached program, and
    accounts rows/sec — all through the :class:`TabularGenerator` facade,
    the same code path as every other consumer (warmed programs can't
    diverge from served ones). ``submit()`` is the concurrent front end:
    requests land on a queue and a dispatcher thread coalesces them into
    micro-batches. Stats counters are guarded by a lock, so concurrent
    submitters and the dispatcher can't lose updates.

    Micro-batch semantics: coalesced requests share one shuffled sample, so
    each request gets an exchangeable random slice — per-request label
    proportions are approximate within a batch (law of large numbers), while
    the synchronous ``generate()`` path keeps exact per-(n, seed) determinism.
    """

    def __init__(self, artifacts: ForestArtifacts, *,
                 samplers: Sequence[str] = (),
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 schema=None, mesh=None, impl: Optional[str] = None,
                 max_coalesce_rows: Optional[int] = None,
                 coalesce_window_s: float = 0.002):
        cfg = artifacts.config
        self.mesh = resolve_mesh(mesh)
        if self.mesh is not None:
            # place the class-sharded arrays once; every request reuses them
            artifacts = artifacts.shard(self.mesh)
        self.artifacts = artifacts
        self.schema = schema
        self.impl = impl
        self.samplers = tuple(samplers) or (
            default_sampler(cfg.method, cfg.diff_sampler),)
        self.buckets = tuple(sorted(buckets))
        # default row cap = the largest bucket: coalescing past it would
        # push the merged batch into oversize exact-size territory and
        # compile a fresh program per distinct total — the opposite of what
        # micro-batching is for (worst per-class slice <= total rows, so
        # capping totals at the bucket keeps pad_to inside warmed programs)
        self.max_coalesce_rows = int(max_coalesce_rows or max(self.buckets))
        self.coalesce_window_s = float(coalesce_window_s)
        self.stats: Dict[str, float] = {
            "requests": 0, "rows": 0, "gen_s": 0.0, "warm_s": 0.0,
            "batches": 0, "coalesced_requests": 0}
        self._stats_lock = threading.Lock()
        self._batch_seed = 0
        # requests delegate to the facade so server output can never
        # diverge from TabularGenerator's (schema decode, impute masking)
        self._gen = TabularGenerator(cfg, schema=schema)
        self._gen.artifacts = artifacts
        self._queue: "queue.Queue" = queue.Queue()
        self._dispatcher: Optional[threading.Thread] = None
        self._lifecycle_lock = threading.Lock()

    @classmethod
    def from_path(cls, path: str, **kw) -> "ForestServer":
        gen = TabularGenerator.load(path)
        return cls(gen.artifacts, schema=gen.schema, **kw)

    # -- request path -------------------------------------------------------

    def _bucket(self, n: int, seed: int) -> int:
        """Smallest bucket covering the largest per-class slice of an
        ``n``-row request. Exact: replays the (cheap, deterministic) label
        draw that ``sample`` will make for this (n, seed)."""
        rng = np.random.default_rng(seed)
        label_idx = sample_labels(np.asarray(self.artifacts.counts), n, rng,
                                  self.artifacts.config.label_sampler)
        worst = int(np.bincount(label_idx,
                                minlength=self.artifacts.n_y).max())
        for b in self.buckets:
            if b >= worst:
                return b
        return worst  # oversize request: exact (compiles once per size)

    def _generate_raw(self, n: int, sampler: str, seed: int,
                      pad_to: int) -> Tuple[np.ndarray, np.ndarray]:
        """THE serving dispatch: facade + this server's mesh/impl. Warmup,
        ``generate()``, and the micro-batcher all go through here, so they
        share one jit cache by construction."""
        return self._gen.generate(n, sampler=sampler, seed=seed,
                                  pad_to=pad_to, mesh=self.mesh,
                                  impl=self.impl)

    def warmup(self) -> float:
        """Compile every (sampler, bucket) program; returns wall seconds."""
        t0 = time.time()
        for name in self.samplers:
            for b in self.buckets:
                n = min(b, int(np.asarray(self.artifacts.counts).sum()))
                self._generate_raw(max(n, 1), name, seed=0, pad_to=b)
        dt = time.time() - t0
        with self._stats_lock:
            self.stats["warm_s"] += dt
        return dt

    def generate(self, n: int, *, sampler: Optional[str] = None,
                 seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """Synchronous path: exact per-(n, seed) deterministic output."""
        name = sampler or self.samplers[0]
        t0 = time.time()
        X, y = self._generate_raw(n, name, seed=seed,
                                  pad_to=self._bucket(n, seed))
        dt = time.time() - t0
        with self._stats_lock:
            self.stats["requests"] += 1
            self.stats["rows"] += n
            self.stats["gen_s"] += dt
            self.stats["batches"] += 1
        return X, y

    # -- concurrent front end ----------------------------------------------

    def _start_locked(self) -> None:
        if self._dispatcher is None or not self._dispatcher.is_alive():
            self._dispatcher = threading.Thread(
                target=self._dispatch_loop, name="forest-serve-dispatch",
                daemon=True)
            self._dispatcher.start()

    def start(self) -> None:
        """Start the dispatcher thread (idempotent; ``submit`` auto-starts)."""
        with self._lifecycle_lock:
            self._start_locked()

    def stop(self, timeout: float = 10.0) -> None:
        """Drain the queue and stop the dispatcher thread."""
        with self._lifecycle_lock:
            if self._dispatcher is None:
                return
            self._queue.put(_SHUTDOWN)
            self._dispatcher.join(timeout)
            self._dispatcher = None

    def submit(self, n: int, *, sampler: Optional[str] = None) -> Future:
        """Queue a generation request; resolves to ``(X, y)``.

        Concurrent submissions coalesce: the dispatcher waits up to
        ``coalesce_window_s`` for more same-sampler requests (bounded by
        ``max_coalesce_rows``, default: the largest bucket) and serves the
        whole group from a single bucketed device dispatch.
        """
        fut: Future = Future()
        # enqueue under the lifecycle lock: a submit racing with stop()
        # could otherwise land its request *behind* the shutdown sentinel
        # with no dispatcher left to serve it — the lock serialises the two,
        # so the request either precedes the sentinel or gets a fresh thread
        with self._lifecycle_lock:
            self._start_locked()
            self._queue.put(_Request(int(n), sampler or self.samplers[0],
                                     fut))
        return fut

    def _dispatch_loop(self) -> None:
        carry = None          # request that closed the previous batch
        while True:
            req = carry if carry is not None else self._queue.get()
            carry = None
            if req is _SHUTDOWN:
                return
            batch, rows = [req], req.n
            deadline = time.monotonic() + self.coalesce_window_s
            while rows < self.max_coalesce_rows:
                left = deadline - time.monotonic()
                if left <= 0:
                    break
                try:
                    nxt = self._queue.get(timeout=left)
                except queue.Empty:
                    break
                if (nxt is _SHUTDOWN or nxt.sampler != req.sampler
                        or rows + nxt.n > self.max_coalesce_rows):
                    # different program, shutdown, or the request would push
                    # the merged total past the cap (-> oversize exact-size
                    # compile): it opens the next batch instead
                    carry = nxt
                    break
                batch.append(nxt)
                rows += nxt.n
            self._serve_batch(batch)
            if carry is _SHUTDOWN:
                return

    def _serve_batch(self, batch) -> None:
        """One coalesced device dispatch; split rows back per request."""
        # claim each future first: a client that cancelled while queued is
        # dropped here — set_result on a cancelled Future raises and would
        # otherwise kill the dispatcher thread, stranding the whole batch
        batch = [r for r in batch if r.future.set_running_or_notify_cancel()]
        if not batch:
            return
        total = sum(r.n for r in batch)
        with self._stats_lock:
            seed = _BATCH_SEED_BASE + self._batch_seed
            self._batch_seed += 1
        t0 = time.time()
        try:
            X, y = self._generate_raw(total, batch[0].sampler, seed=seed,
                                      pad_to=self._bucket(total, seed))
        except BaseException as exc:  # noqa: BLE001 — delivered via futures
            for r in batch:
                r.future.set_exception(exc)
            return
        dt = time.time() - t0
        off = 0
        for r in batch:
            r.future.set_result((X[off:off + r.n], y[off:off + r.n]))
            off += r.n
        with self._stats_lock:
            self.stats["requests"] += len(batch)
            self.stats["rows"] += total
            self.stats["gen_s"] += dt
            self.stats["batches"] += 1
            self.stats["coalesced_requests"] += len(batch) - 1

    # -- misc ---------------------------------------------------------------

    def impute(self, X_missing, y=None, *, seed: int = 0,
               refine_rounds: int = 3) -> np.ndarray:
        return self._gen.impute(X_missing, y, seed=seed,
                                refine_rounds=refine_rounds, impl=self.impl)

    def rows_per_sec(self) -> float:
        with self._stats_lock:
            return self.stats["rows"] / max(self.stats["gen_s"], 1e-9)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _demo_artifacts(path: str) -> str:
    """Fit a small two-moons model and save it — the zero-setup demo."""
    from repro.config import ForestConfig
    from repro.data.tabular import two_moons
    X, y = two_moons(600, seed=0)
    fcfg = ForestConfig(method="flow", n_t=8, duplicate_k=10, n_trees=20,
                        max_depth=4, n_bins=32, reg_lambda=1.0)
    gen = TabularGenerator(fcfg).fit(X, y, seed=0)
    return gen.save(path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default=None,
                    help="base path of a saved model (.npz/.json pair)")
    ap.add_argument("--demo", action="store_true",
                    help="fit+save a small two-moons model first")
    ap.add_argument("--sampler", default=None)
    ap.add_argument("--buckets", default="64,256,1024")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="none",
                    help="'auto' | 'none' | DxM — shard the solve "
                         "(classes on model, rows on data)")
    ap.add_argument("--impl", default=None,
                    help="tree-predict backend: xla | pallas | "
                         "pallas_interpret (default: config/env)")
    ap.add_argument("--sync", action="store_true",
                    help="serve via the synchronous generate() path instead "
                         "of the micro-batching queue")
    ap.add_argument("--coalesce-window-ms", type=float, default=2.0)
    args = ap.parse_args()

    path = args.artifacts
    if args.demo or path is None:
        path = _demo_artifacts(os.path.join(tempfile.mkdtemp(), "demo"))
        print(f"demo artifacts saved to {path}")

    from repro.launch.train_forest import parse_mesh
    samplers = (args.sampler,) if args.sampler else ()
    buckets = tuple(int(b) for b in args.buckets.split(","))
    server = ForestServer.from_path(
        path, samplers=samplers, buckets=buckets,
        mesh=parse_mesh(args.mesh), impl=args.impl,
        coalesce_window_s=args.coalesce_window_ms / 1e3)
    warm = server.warmup()
    print(f"warmed {len(server.samplers)} sampler(s) x {len(buckets)} "
          f"bucket(s) in {warm:.2f}s"
          + (f" on mesh {dict(zip(server.mesh.axis_names, server.mesh.devices.shape))}"
             if server.mesh is not None else ""))

    rng = np.random.default_rng(args.seed)
    sizes = rng.integers(1, max(buckets) + 1, size=args.requests)
    if args.sync:
        for i, n in enumerate(sizes):
            server.generate(int(n), seed=args.seed + i)
    else:
        futs = [server.submit(int(n)) for n in sizes]
        for f, n in zip(futs, sizes):
            X, y = f.result(timeout=300)
            assert len(X) == n
        server.stop()
    s = server.stats
    print(f"served {int(s['requests'])} requests / {int(s['rows'])} rows "
          f"in {int(s['batches'])} dispatch(es) "
          f"({int(s['coalesced_requests'])} coalesced) "
          f"in {s['gen_s']:.3f}s -> {server.rows_per_sec():.0f} rows/sec")


if __name__ == "__main__":
    main()
