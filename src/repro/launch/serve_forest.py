"""Forest serving driver: warm, pre-jitted tabular generation + imputation.

Loads :class:`ForestArtifacts` (or a full :class:`TabularGenerator` with a
schema sidecar) from disk and answers batched requests. Request sizes are
rounded up to a small set of batch buckets so every (sampler, bucket) pair
compiles exactly once at warm-up — after that each request is one cached
device program (the tabgen sampler is class-vmapped, so this holds for any
number of classes).

CPU demo (fits a small model, saves, loads, serves):

  PYTHONPATH=src python -m repro.launch.serve_forest --demo --requests 16

Serving a trained model:

  PYTHONPATH=src python -m repro.launch.serve_forest \
      --artifacts /path/to/model --sampler euler --requests 64
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.tabgen import (ForestArtifacts, TabularGenerator, default_sampler,
                          sample, sample_labels)

DEFAULT_BUCKETS = (64, 256, 1024)


class ForestServer:
    """Single-host tabular-generation server over loaded artifacts.

    ``warmup()`` pre-compiles one sampler program per (sampler, bucket);
    ``generate()`` buckets the request, reuses the cached program, and
    accounts rows/sec. A schema (if the artifact sidecar carries one)
    decodes mixed-type columns on the way out.
    """

    def __init__(self, artifacts: ForestArtifacts, *,
                 samplers: Sequence[str] = (),
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 schema=None):
        cfg = artifacts.config
        self.artifacts = artifacts
        self.schema = schema
        self.samplers = tuple(samplers) or (
            default_sampler(cfg.method, cfg.diff_sampler),)
        self.buckets = tuple(sorted(buckets))
        self.stats: Dict[str, float] = {"requests": 0, "rows": 0,
                                        "gen_s": 0.0, "warm_s": 0.0}
        # requests delegate to the facade so server output can never
        # diverge from TabularGenerator's (schema decode, impute masking)
        self._gen = TabularGenerator(cfg, schema=schema)
        self._gen.artifacts = artifacts

    @classmethod
    def from_path(cls, path: str, **kw) -> "ForestServer":
        gen = TabularGenerator.load(path)
        return cls(gen.artifacts, schema=gen.schema, **kw)

    # -- request path -------------------------------------------------------

    def _bucket(self, n: int, seed: int) -> int:
        """Smallest bucket covering the largest per-class slice of an
        ``n``-row request. Exact: replays the (cheap, deterministic) label
        draw that ``sample`` will make for this (n, seed)."""
        rng = np.random.default_rng(seed)
        label_idx = sample_labels(np.asarray(self.artifacts.counts), n, rng,
                                  self.artifacts.config.label_sampler)
        worst = int(np.bincount(label_idx,
                                minlength=self.artifacts.n_y).max())
        for b in self.buckets:
            if b >= worst:
                return b
        return worst  # oversize request: exact (compiles once per size)

    def warmup(self) -> float:
        """Compile every (sampler, bucket) program; returns wall seconds."""
        t0 = time.time()
        for name in self.samplers:
            for b in self.buckets:
                n = min(b, int(np.asarray(self.artifacts.counts).sum()))
                sample(self.artifacts, max(n, 1), sampler=name, seed=0,
                       pad_to=b)
        dt = time.time() - t0
        self.stats["warm_s"] += dt
        return dt

    def generate(self, n: int, *, sampler: Optional[str] = None,
                 seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        name = sampler or self.samplers[0]
        t0 = time.time()
        X, y = self._gen.generate(n, sampler=name, seed=seed,
                                  pad_to=self._bucket(n, seed))
        dt = time.time() - t0
        self.stats["requests"] += 1
        self.stats["rows"] += n
        self.stats["gen_s"] += dt
        return X, y

    def impute(self, X_missing, y=None, *, seed: int = 0,
               refine_rounds: int = 3) -> np.ndarray:
        return self._gen.impute(X_missing, y, seed=seed,
                                refine_rounds=refine_rounds)

    def rows_per_sec(self) -> float:
        return self.stats["rows"] / max(self.stats["gen_s"], 1e-9)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _demo_artifacts(path: str) -> str:
    """Fit a small two-moons model and save it — the zero-setup demo."""
    from repro.config import ForestConfig
    from repro.data.tabular import two_moons
    X, y = two_moons(600, seed=0)
    fcfg = ForestConfig(method="flow", n_t=8, duplicate_k=10, n_trees=20,
                        max_depth=4, n_bins=32, reg_lambda=1.0)
    gen = TabularGenerator(fcfg).fit(X, y, seed=0)
    return gen.save(path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default=None,
                    help="base path of a saved model (.npz/.json pair)")
    ap.add_argument("--demo", action="store_true",
                    help="fit+save a small two-moons model first")
    ap.add_argument("--sampler", default=None)
    ap.add_argument("--buckets", default="64,256,1024")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    path = args.artifacts
    if args.demo or path is None:
        path = _demo_artifacts(os.path.join(tempfile.mkdtemp(), "demo"))
        print(f"demo artifacts saved to {path}")

    samplers = (args.sampler,) if args.sampler else ()
    buckets = tuple(int(b) for b in args.buckets.split(","))
    server = ForestServer.from_path(path, samplers=samplers, buckets=buckets)
    warm = server.warmup()
    print(f"warmed {len(server.samplers)} sampler(s) x {len(buckets)} "
          f"bucket(s) in {warm:.2f}s")

    rng = np.random.default_rng(args.seed)
    sizes = rng.integers(1, max(buckets) + 1, size=args.requests)
    for i, n in enumerate(sizes):
        X, y = server.generate(int(n), seed=args.seed + i)
    s = server.stats
    print(f"served {int(s['requests'])} requests / {int(s['rows'])} rows "
          f"in {s['gen_s']:.3f}s -> {server.rows_per_sec():.0f} rows/sec")


if __name__ == "__main__":
    main()
