"""Serving driver: batched prefill + decode with continuous batching slots.

CPU demo (reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 8 --max-new 16

Production: same step functions lowered by the dry-run for the 16x16 mesh
(decode_32k / long_500k cells); the scheduler here is the single-host
reference implementation.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.models import lm


def serve_batch(cfg, params, prompts, max_new: int, cache_size: int,
                dtype=jnp.float32, greedy: bool = True, seed: int = 0):
    """Prefill a batch of equal-length prompts, then decode max_new tokens."""
    b, s = prompts.shape
    logits, pcaches = lm.prefill_step(params, {"tokens": prompts}, cfg,
                                      dtype=dtype)
    # move prefill caches into full-size decode caches
    full = lm.init_cache(cfg, b, cache_size, dtype)

    def merge(dst, src):
        if hasattr(dst, "ndim") and dst.shape != src.shape:
            sl = [slice(None)] * dst.ndim
            for ax in range(dst.ndim):
                if src.shape[ax] != dst.shape[ax]:
                    sl[ax] = slice(0, src.shape[ax])
            return dst.at[tuple(sl)].set(src.astype(dst.dtype))
        return src.astype(dst.dtype)

    cache = jax.tree_util.tree_map(merge, full, pcaches)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg,
                                                       dtype=dtype))
    key = jax.random.PRNGKey(seed)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(max_new - 1):
        logits, cache = step(params, cache, tok, jnp.int32(s + i))
        if greedy:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1])[:, None]
            tok = tok.astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    return np.asarray(gen), {"decode_s": dt,
                             "tok_per_s": b * (max_new - 1) / max(dt, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.requests, args.prompt_len)),
        jnp.int32)
    gen, stats = serve_batch(cfg, params, prompts, args.max_new,
                             cache_size=args.prompt_len + args.max_new)
    print(f"generated {gen.shape} tokens; "
          f"{stats['tok_per_s']:.1f} tok/s decode")


if __name__ == "__main__":
    main()
