"""Stdlib-only HTTP front end over the :mod:`repro.serving` control plane.

Drives the heavy-traffic story end to end: many named models hot in one
process (LRU device placement), interactive/bulk priority classes,
per-tenant rate limits with explicit backpressure, in-flight micro-batched
dispatch, request-scoped tracing — all behind these endpoints:

  POST /v1/generate   {"model": "demo", "n": 128, "sampler": "euler",
                       "tenant": "t0", "priority": "interactive",
                       "deadline_ms": 500, "timeout_s": 60}
      -> 200 {"model", "version", "n", "rows", "labels", "request_id"}
      -> 400 bad arguments / unknown sampler     (ValueError, eager)
      -> 404 unknown model
      -> 429 + Retry-After header                (RateLimited / QueueFull)
      -> 504 deadline exceeded before dispatch
      Every response (success or error) carries the request's trace id in
      the body (``request_id``) and the ``X-Repro-Request-Id`` header.
  GET  /v1/trace/<id> the per-request timeline from the span ring: the
                      ``serve.queue`` span (admission, queue depth, wait,
                      batch id) plus the linked ``serve.device`` batch
                      span (device time, sync, co-batched request count).
                      404 when the id is unknown *or evicted* — the ring
                      is bounded; scrape traces promptly.
  POST /debug/profile {"duration_ms": 500} — bounded jax.profiler capture
                      into the server's --profile-dir (403 when disabled,
                      409 while another capture runs, admin-token guarded
                      via the X-Repro-Admin-Token header when configured)
  POST /v1/impute     {"model": "demo", "rows": [[1.0, null, ...]],
                       "labels": [...]}   — null marks a missing cell;
      served synchronously (bridge-clamped solve is per-row conditional,
      not micro-batched) but still metered against the tenant's row bucket
  GET  /v1/models     registry contents: hot/cold, bytes, versions, data
                      lineage (source-store fingerprint/version), stats
  POST /v1/models/<name>/reload   {"path": "..."} (path optional when the
                      model was registered from one) — zero-downtime
                      hot-swap of freshly saved artifacts into the running
                      registry; the receiving end of ``repro.launch.refresh``
  GET  /healthz       {"ok": true} once the plane is serving
  GET  /statz         scheduler + admission + registry stats (per-sampler,
                      per-tenant, queue-wait vs device-time breakdown)
  GET  /metrics       the same numbers in Prometheus text format — /statz
                      is a view over the one :mod:`repro.obs` registry
                      behind this endpoint, so the two cannot disagree
                      (see docs/observability.md for the scrape config)

Run a demo instance (fits a tiny model, registers it as "demo"):

  PYTHONPATH=src python -m repro.launch.serve_http --demo --port 8099

Multiple models, sharded, with per-tenant limits:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
    python -m repro.launch.serve_http --model calo=calo_model \
      --model fraud=fraud_model --mesh 4x2 --rate 500000 --burst 2000000

The server prints ``serving on http://HOST:PORT`` once ready (``--port 0``
binds an ephemeral port — the line is the machine-readable contract the CI
smoke and the tests parse).
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import threading
import uuid
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional, Tuple

import numpy as np

from repro.obs import CONTENT_TYPE as _METRICS_CONTENT_TYPE
from repro.obs import (MetricsRegistry, ProfileInProgress, Profiler,
                       ResourceMonitor, SlowLog, Tracer, render_prometheus)
from repro.serving import (AdmissionController, DeadlineExceeded,
                           InflightScheduler, ModelRegistry, QueueFull,
                           RateLimited, UnknownModel)


class ServingApp:
    """The control plane bundle the HTTP handler dispatches into.

    Framework-free by design: tests drive it in-process, the CLI wraps it
    in a :class:`ThreadingHTTPServer`.
    """

    def __init__(self, registry: ModelRegistry,
                 admission: Optional[AdmissionController] = None, *,
                 coalesce_window_s: float = 0.002,
                 max_coalesce_rows: Optional[int] = None,
                 default_timeout_s: float = 300.0,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 model_paths: Optional[dict] = None,
                 slo: Optional[Dict[str, float]] = None,
                 slo_error_budget: float = 0.01,
                 slow_log: Optional[SlowLog] = None,
                 profiler: Optional[Profiler] = None,
                 monitor: Optional[ResourceMonitor] = None,
                 admin_token: Optional[str] = None):
        self.registry = registry
        self.admission = admission or AdmissionController(metrics=metrics)
        self.scheduler = InflightScheduler(
            registry, self.admission,
            coalesce_window_s=coalesce_window_s,
            max_coalesce_rows=max_coalesce_rows,
            metrics=metrics, tracer=tracer,
            slo=slo, slo_error_budget=slo_error_budget, slow_log=slow_log)
        self.default_timeout_s = float(default_timeout_s)
        # name -> artifact path of disk-registered models: the default a
        # bodyless POST /v1/models/<name>/reload re-reads from
        self.model_paths = dict(model_paths or {})
        # GET /v1/trace reads the scheduler's tracer even when the caller
        # left this app on the private default pair
        self.tracer = tracer or self.scheduler.tracer
        self.profiler = profiler
        self.monitor = monitor
        self.admin_token = admin_token
        self._m_reloads = (metrics or registry.metrics).counter(
            "serve_reloads", "Admin model hot-swaps via "
            "POST /v1/models/<name>/reload", ("model", "status"))

    # -- endpoint bodies (status_code, payload) ------------------------------

    def generate(self, body: dict) -> Tuple[int, dict]:
        # the trace id is minted at ingress — before validation — so even
        # a rejected request is addressable in logs and error responses
        rid = uuid.uuid4().hex[:16]
        try:
            n = int(body.get("n", 0))
            if n <= 0:
                raise ValueError(f"n={body.get('n')!r}: need a positive row count")
            model = str(body.get("model", "default"))
            deadline_ms = body.get("deadline_ms")
            fut = self.scheduler.submit(
                n, model=model, sampler=body.get("sampler"),
                tenant=str(body.get("tenant", "default")),
                priority=str(body.get("priority", "interactive")),
                deadline_s=None if deadline_ms is None
                else float(deadline_ms) / 1e3,
                request_id=rid)
        except UnknownModel:
            return 404, {"error": f"unknown model {body.get('model')!r}",
                         "models": self.registry.names(),
                         "request_id": rid}
        except (RateLimited, QueueFull) as exc:
            return 429, {"error": str(exc),
                         "retry_after_s": exc.retry_after_s,
                         "request_id": rid}
        except (ValueError, TypeError) as exc:
            return 400, {"error": str(exc), "request_id": rid}
        try:
            X, y = fut.result(timeout=float(
                body.get("timeout_s", self.default_timeout_s)))
        except DeadlineExceeded as exc:
            return 504, {"error": str(exc), "request_id": rid}
        handle = self.registry.peek(model)
        return 200, {"model": model, "version": handle.version, "n": n,
                     "rows": np.asarray(X).tolist(),
                     "labels": np.asarray(y).tolist(),
                     "request_id": rid}

    def impute(self, body: dict) -> Tuple[int, dict]:
        try:
            rows = body.get("rows")
            if not rows:
                raise ValueError("rows: need a non-empty list of rows "
                                 "(null marks a missing cell)")
            X = np.array([[np.nan if v is None else float(v) for v in row]
                          for row in rows])
            y = body.get("labels")
            model = str(body.get("model", "default"))
            tenant = str(body.get("tenant", "default"))
            handle = self.registry.peek(model)  # 404 before metering
            if y is None and handle.artifacts.n_y > 1:
                raise ValueError(
                    f"model {model!r} is class-conditional "
                    f"({handle.artifacts.n_y} classes): imputation needs "
                    "\"labels\"")
            self.admission.charge(tenant, len(X))
            handle = self.registry.acquire(model)
            filled = handle.impute(
                X, None if y is None else np.asarray(y),
                seed=int(body.get("seed", 0)),
                refine_rounds=int(body.get("refine_rounds", 3)))
        except UnknownModel:
            return 404, {"error": f"unknown model {body.get('model')!r}",
                         "models": self.registry.names()}
        except RateLimited as exc:
            return 429, {"error": str(exc),
                         "retry_after_s": exc.retry_after_s}
        except (ValueError, TypeError) as exc:
            return 400, {"error": str(exc)}
        return 200, {"model": model, "version": handle.version,
                     "rows": np.asarray(filled).tolist()}

    def models(self) -> Tuple[int, dict]:
        return 200, {"models": self.registry.describe(),
                     "hot": self.registry.hot_names()}

    def reload_model(self, name: str, body: dict) -> Tuple[int, dict]:
        """Zero-downtime hot-swap: load freshly saved artifacts from disk
        and :meth:`ModelRegistry.swap` them under ``name``. In-flight
        requests finish on the old version; no request is dropped, and a
        same-shape swap reuses every compiled program (zero recompiles).
        The live end of the ``repro.launch.refresh`` freshness loop."""
        from repro.tabgen import TabularGenerator
        try:
            path = body.get("path") or self.model_paths.get(name)
            if not path:
                raise ValueError(
                    f"model {name!r} was not registered from a path; the "
                    "reload body must carry {\"path\": ...}")
            self.registry.peek(name)            # 404 before touching disk
            gen = TabularGenerator.load(path)
            handle = self.registry.swap(name, gen.artifacts,
                                        schema=gen.schema,
                                        keep_schema=gen.schema is None)
        except UnknownModel:
            self._m_reloads.inc(1, model=name, status="unknown_model")
            return 404, {"error": f"unknown model {name!r}",
                         "models": self.registry.names()}
        except (OSError, ValueError, TypeError, KeyError) as exc:
            self._m_reloads.inc(1, model=name, status="error")
            return 400, {"error": f"reload of {name!r} from "
                                  f"{body.get('path') or path!r} failed: "
                                  f"{exc}"}
        self.model_paths[name] = path
        self._m_reloads.inc(1, model=name, status="ok")
        lineage = self.registry.describe()[name]["lineage"]
        return 200, {"model": name, "version": handle.version,
                     "path": path, "nbytes": handle.nbytes,
                     "lineage": lineage}

    def trace(self, request_id: str) -> Tuple[int, dict]:
        """Per-request timeline from the span ring: the request's own
        ``serve.queue`` span plus every ``serve.device`` batch span that
        *links* it.  The summary reconciles with ``/statz`` because both
        read the same spans/instruments."""
        spans = self.tracer.trace(request_id)
        if not spans:
            return 404, {"error": f"unknown (or evicted) request id "
                                  f"{request_id!r}; the span ring is "
                                  "bounded — scrape traces promptly",
                         "request_id": request_id}
        summary: dict = {}
        for s in spans:
            if s.name == "serve.queue" and s.trace_id == request_id:
                summary.update({k: s.attrs[k] for k in
                                ("model", "sampler", "tenant", "priority",
                                 "rows", "admission_s", "queue_depth",
                                 "batch_id", "outcome") if k in s.attrs})
                summary["queue_wait_s"] = s.duration_s
        for s in spans:
            if s.name == "serve.device" and request_id in s.links:
                summary["batch"] = {
                    "batch_id": s.attrs.get("batch_id"),
                    "rows": s.attrs.get("rows"),
                    "requests": s.attrs.get("requests"),
                    "device_s": s.duration_s,
                    "sync_s": s.attrs.get("sync_s"),
                    "outcome": s.attrs.get("outcome"),
                }
        return 200, {"request_id": request_id,
                     "spans": [s.to_dict() for s in spans],
                     "summary": summary}

    def profile(self, body: dict) -> Tuple[int, dict]:
        """Bounded on-demand ``jax.profiler`` capture (POST /debug/profile).
        One capture at a time; the duration is clamped server-side."""
        if self.profiler is None:
            return 403, {"error": "profiling disabled; start serve_http "
                                  "with --profile-dir"}
        try:
            duration_s = float(body.get("duration_ms", 200.0)) / 1e3
            result = self.profiler.capture(duration_s)
        except ProfileInProgress as exc:
            return 409, {"error": str(exc)}
        except (ValueError, TypeError) as exc:
            return 400, {"error": str(exc)}
        except Exception as exc:  # noqa: BLE001 — surfaced, not raised
            return 500, {"error": f"profiler capture failed: {exc}"}
        return 200, result

    def healthz(self) -> Tuple[int, dict]:
        return 200, {"ok": True, "models": self.registry.names()}

    def statz(self) -> Tuple[int, dict]:
        return 200, {"scheduler": self.scheduler.stats_snapshot(),
                     "admission": self.admission.stats_snapshot(),
                     "registry": self.registry.stats_snapshot()}

    def metrics_text(self) -> Tuple[int, str]:
        """Prometheus text over every component registry.  When the caller
        wired one shared :class:`~repro.obs.MetricsRegistry` through (as
        ``main()`` does) this is a single registry; components left on
        private registries are unioned — instrument names are namespaced
        per subsystem, so families never collide."""
        regs = [self.scheduler.metrics, self.admission.metrics,
                self.registry.metrics]
        if self.monitor is not None:
            regs.append(self.monitor.metrics)  # dedup by id in the renderer
        return 200, render_prometheus(*regs)

    def stop(self) -> None:
        self.scheduler.stop()


def make_handler(app: ServingApp, *, quiet: bool = True):
    class Handler(BaseHTTPRequestHandler):
        server_version = "repro-serving/1.0"
        protocol_version = "HTTP/1.1"

        def log_message(self, fmt, *args):  # noqa: A003
            if not quiet:
                BaseHTTPRequestHandler.log_message(self, fmt, *args)

        def _reply(self, status: int, payload: dict,
                   retry_after: Optional[float] = None) -> None:
            blob = json.dumps(payload).encode()
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(blob)))
            if retry_after is not None:
                self.send_header("Retry-After", f"{retry_after:.3f}")
            rid = payload.get("request_id") if isinstance(payload, dict) else None
            if rid:
                self.send_header("X-Repro-Request-Id", str(rid))
            self.end_headers()
            self.wfile.write(blob)

        def _reply_text(self, status: int, text: str,
                        content_type: str) -> None:
            blob = text.encode()
            self.send_response(status)
            self.send_header("Content-Type", content_type)
            self.send_header("Content-Length", str(len(blob)))
            self.end_headers()
            self.wfile.write(blob)

        def do_GET(self):  # noqa: N802
            if self.path == "/metrics":
                status, text = app.metrics_text()
                self._reply_text(status, text, _METRICS_CONTENT_TYPE)
                return
            if self.path.startswith("/v1/trace/"):
                rid = self.path[len("/v1/trace/"):]
                self._reply(*app.trace(rid))
                return
            routes = {"/healthz": app.healthz, "/statz": app.statz,
                      "/v1/models": app.models}
            fn = routes.get(self.path)
            if fn is None:
                self._reply(404, {"error": f"no route {self.path!r}",
                                  "routes": sorted(routes)
                                  + ["/metrics", "/v1/trace/<id>"]})
                return
            self._reply(*fn())

        def do_POST(self):  # noqa: N802
            routes = {"/v1/generate": app.generate, "/v1/impute": app.impute,
                      "/debug/profile": app.profile}
            admin = {"/debug/profile"}
            fn = routes.get(self.path)
            if fn is None:
                # path-parameter admin route: /v1/models/<name>/reload
                parts = self.path.strip("/").split("/")
                if (len(parts) == 4 and parts[:2] == ["v1", "models"]
                        and parts[3] == "reload"):
                    name = parts[2]
                    fn = lambda body: app.reload_model(name, body)  # noqa: E731
            if fn is None:
                self._reply(404, {"error": f"no route {self.path!r}",
                                  "routes": sorted(routes)
                                  + ["/v1/models/<name>/reload"]})
                return
            if (self.path in admin and app.admin_token is not None
                    and self.headers.get("X-Repro-Admin-Token")
                    != app.admin_token):
                self._reply(401, {"error": "missing or wrong "
                                           "X-Repro-Admin-Token header"})
                return
            try:
                length = int(self.headers.get("Content-Length", 0))
                body = json.loads(self.rfile.read(length) or b"{}")
                if not isinstance(body, dict):
                    raise ValueError("body must be a JSON object")
            except (ValueError, json.JSONDecodeError) as exc:
                self._reply(400, {"error": f"bad JSON body: {exc}"})
                return
            status, payload = fn(body)
            self._reply(status, payload,
                        retry_after=payload.get("retry_after_s")
                        if status == 429 else None)

    return Handler


def make_server(app: ServingApp, host: str = "127.0.0.1",
                port: int = 0, *, quiet: bool = True) -> ThreadingHTTPServer:
    """Bind (port 0 = ephemeral); caller runs ``serve_forever``."""
    return ThreadingHTTPServer((host, port), make_handler(app, quiet=quiet))


def serve_in_thread(app: ServingApp, host: str = "127.0.0.1",
                    port: int = 0) -> Tuple[ThreadingHTTPServer, threading.Thread]:
    """In-process server for tests: returns (httpd, daemon thread)."""
    httpd = make_server(app, host, port)
    t = threading.Thread(target=httpd.serve_forever, daemon=True,
                         name="serve-http")
    t.start()
    return httpd, t


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", action="append", default=[],
                    metavar="NAME=PATH",
                    help="register a saved artifact pair under NAME "
                         "(repeatable)")
    ap.add_argument("--demo", action="store_true",
                    help="fit+register a small two-moons model as 'demo'")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8099,
                    help="0 binds an ephemeral port (printed when ready)")
    ap.add_argument("--buckets", default="64,256,1024")
    ap.add_argument("--mesh", default="none",
                    help="'auto' | 'none' | DxM — shard every model's solve")
    ap.add_argument("--impl", default=None,
                    help="tree-predict backend: xla | pallas | pallas_interpret")
    ap.add_argument("--device-budget-mb", type=float, default=None,
                    help="LRU device-placement budget over all hot models")
    ap.add_argument("--max-hot", type=int, default=None,
                    help="cap the number of device-placed models")
    ap.add_argument("--rate", type=float, default=None,
                    help="default per-tenant rate limit (rows/sec)")
    ap.add_argument("--burst", type=float, default=None,
                    help="per-tenant burst size in rows (default 4x rate)")
    ap.add_argument("--queue-limit-interactive", type=int, default=256)
    ap.add_argument("--queue-limit-bulk", type=int, default=1024)
    ap.add_argument("--coalesce-window-ms", type=float, default=2.0)
    ap.add_argument("--no-warm", action="store_true",
                    help="skip the (sampler, bucket) warmup compile pass")
    ap.add_argument("--trace-jsonl", default=None, metavar="PATH",
                    help="on shutdown, dump the span ring (serve.queue / "
                         "serve.device / serve.sync) as JSON lines")
    ap.add_argument("--slo-interactive-ms", type=float, default=None,
                    help="latency objective for the interactive class; "
                         "requests over it count as SLO violations")
    ap.add_argument("--slo-bulk-ms", type=float, default=None,
                    help="latency objective for the bulk class")
    ap.add_argument("--slo-budget", type=float, default=0.01,
                    help="allowed violation rate (error budget); "
                         "/statz reports burn = rate / budget")
    ap.add_argument("--slow-log", default=None, metavar="PATH",
                    help="append requests over --slow-threshold-ms (their "
                         "full span timeline) to this JSONL file")
    ap.add_argument("--slow-threshold-ms", type=float, default=None,
                    help="slow-request threshold (default: the interactive "
                         "SLO objective when set, else 1000ms)")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="enable POST /debug/profile; captures land in "
                         "numbered subdirectories of DIR")
    ap.add_argument("--admin-token", default=None,
                    help="require X-Repro-Admin-Token on admin endpoints "
                         "(/debug/profile)")
    ap.add_argument("--resource-interval-s", type=float, default=5.0,
                    help="ResourceMonitor sampling period for the "
                         "resource_* gauges on /metrics; 0 disables")
    ap.add_argument("--verbose", action="store_true",
                    help="log one line per HTTP request")
    args = ap.parse_args(argv)

    specs = []
    for item in args.model:
        name, _, path = item.partition("=")
        if not path:
            ap.error(f"--model {item!r}: expected NAME=PATH")
        specs.append((name, path))
    if args.demo or not specs:
        from repro.launch.serve_forest import _demo_artifacts
        path = _demo_artifacts(os.path.join(tempfile.mkdtemp(), "demo"))
        print(f"demo artifacts saved to {path}", flush=True)
        specs.append(("demo", path))

    from repro.launch.train_forest import parse_mesh
    # one shared registry + tracer across every component: GET /metrics is
    # then a single family set and /statz a view over the same instruments
    metrics = MetricsRegistry()
    tracer = Tracer(capacity=4096)
    registry = ModelRegistry(
        mesh=parse_mesh(args.mesh), impl=args.impl,
        buckets=tuple(int(b) for b in args.buckets.split(",")),
        device_budget_bytes=None if args.device_budget_mb is None
        else int(args.device_budget_mb * 2**20),
        max_hot=args.max_hot, metrics=metrics)
    for name, path in specs:
        registry.register(name, path=path)
        print(f"registered model {name!r} from {path}", flush=True)
    admission = AdmissionController(
        queue_limits={"interactive": args.queue_limit_interactive,
                      "bulk": args.queue_limit_bulk},
        default_rate=None if args.rate is None
        else (args.rate, args.burst or 4 * args.rate),
        metrics=metrics)
    slo = {}
    if args.slo_interactive_ms is not None:
        slo["interactive"] = args.slo_interactive_ms / 1e3
    if args.slo_bulk_ms is not None:
        slo["bulk"] = args.slo_bulk_ms / 1e3
    slow_log = None
    if args.slow_log:
        threshold_s = (args.slow_threshold_ms / 1e3
                       if args.slow_threshold_ms is not None
                       else slo.get("interactive", 1.0))
        slow_log = SlowLog(args.slow_log, threshold_s)
        print(f"slow-log (> {threshold_s * 1e3:.0f}ms) -> {args.slow_log}",
              flush=True)
    profiler = (Profiler(args.profile_dir) if args.profile_dir else None)
    monitor = None
    if args.resource_interval_s > 0:
        monitor = ResourceMonitor(metrics,
                                  interval_s=args.resource_interval_s,
                                  admission=admission, registry=registry)
    app = ServingApp(registry, admission,
                     coalesce_window_s=args.coalesce_window_ms / 1e3,
                     metrics=metrics, tracer=tracer,
                     model_paths=dict(specs),
                     slo=slo or None, slo_error_budget=args.slo_budget,
                     slow_log=slow_log, profiler=profiler, monitor=monitor,
                     admin_token=args.admin_token)
    if not args.no_warm:
        print(f"warming {len(specs)} model(s)...", flush=True)
        dt = registry.warmup()
        app.scheduler.record_warm(dt)
        print(f"warmed in {dt:.2f}s", flush=True)
    if monitor is not None:
        # one eager pass before "serving on": the first /metrics scrape
        # already carries the resource_* gauges (ci_smoke asserts this)
        monitor.sample()
        monitor.start()

    httpd = make_server(app, args.host, args.port, quiet=not args.verbose)
    host, port = httpd.server_address[:2]
    print(f"serving on http://{host}:{port}", flush=True)
    try:
        httpd.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        print("shutting down...", flush=True)
        httpd.server_close()
        app.stop()
        if monitor is not None:
            monitor.stop()
        if args.trace_jsonl:
            n = tracer.export_jsonl(args.trace_jsonl)
            print(f"wrote {n} spans to {args.trace_jsonl}", flush=True)
        print("bye", flush=True)


if __name__ == "__main__":
    main()
