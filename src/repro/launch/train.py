"""Training launcher.

CPU (this container): reduced configs, real optimisation:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 100 --ckpt-dir /tmp/ckpt

TPU pod (production): full config on the 16x16 / 2x16x16 mesh — pass
--mesh single|multi; parameters and batches are sharded with
repro.sharding.rules. On real hardware also set:
  REPRO_HIST_IMPL=pallas
  LIBTPU_INIT_ARGS="--xla_tpu_enable_async_collective_fusion=true \
     --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true"
(the compute/comm-overlap flags; see DESIGN.md §5).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.configs import ARCH_IDS, get_arch
from repro.data.tokens import FastTokenStream
from repro.train.loop import run_with_retries, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-135m")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=args.steps // 10,
                       total_steps=args.steps, remat_policy=args.remat)
    stream = FastTokenStream(cfg.vocab, args.seq, args.batch, seed=0)

    def data_fn(i):
        b = stream.batch_at(i)
        if cfg.family == "vlm":
            import numpy as np
            rng = np.random.default_rng(i)
            n_img = cfg.n_patches
            return {"patches": rng.normal(
                        size=(args.batch, n_img, cfg.d_model)).astype("float32"),
                    "tokens": b["tokens"], "labels": b["labels"]}
        if cfg.family == "audio_encdec":
            import numpy as np
            rng = np.random.default_rng(i)
            return {"frames": rng.normal(
                        size=(args.batch, args.seq, cfg.d_model)).astype("float32"),
                    "tokens": b["tokens"], "labels": b["labels"]}
        return b

    def job():
        return train(cfg, tcfg, data_fn, steps=args.steps,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     accum=args.accum)

    params, opt_state, history = run_with_retries(job)
    if history:
        first, last = history[0]["loss"], history[-1]["loss"]
        print(f"loss {first:.4f} -> {last:.4f} over {args.steps} steps")


if __name__ == "__main__":
    main()
