"""Offline metrics dump: Prometheus text without an HTTP server.

Long-running services scrape ``GET /metrics`` (see
:mod:`repro.launch.serve_http`); batch runs — ``train_forest``,
``ingest``, ``serve_forest`` — have no server to scrape, so they dump the
same exposition format at exit instead:

  PYTHONPATH=src python -m repro.launch.train_forest --demo \
      --metrics-dump metrics.prom
  PYTHONPATH=src python -m repro.launch.ingest --out s --synthetic 4096x8x2 \
      --metrics-dump -          # '-' writes to stdout

Both flags call :func:`dump`, which renders the process-wide
:func:`repro.obs.default_registry` (the registry the fit pipeline and
``DatasetStore.ingest`` instrument) — pass ``registries=`` to dump a
component-scoped registry instead, as ``serve_forest --metrics-dump``
does with its server's shared registry.

The module is also a tiny CLI for smoke tests and docs examples:

  PYTHONPATH=src python -m repro.launch.metrics --demo

fabricates a counter/histogram pair in a scratch registry and prints the
rendered exposition, exercising the full render path with no model fit.
``--resource`` takes one :class:`repro.obs.ResourceMonitor` sample first,
so the dump answers "what does this process hold right now" (RSS, device
buffers, jit-cache entries) without standing up a server.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from repro.obs import MetricsRegistry, default_registry, render_prometheus


def dump(path: Optional[str] = None, *,
         registries: Optional[Sequence[MetricsRegistry]] = None) -> str:
    """Render ``registries`` (default: the process-wide default registry)
    to Prometheus text; write to ``path`` (``"-"``/``None`` = stdout) and
    return the text."""
    regs = list(registries) if registries else [default_registry()]
    text = render_prometheus(*regs)
    if path is None or path == "-":
        sys.stdout.write(text)
    else:
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote metrics to {path}")
    return text


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="dump a metrics registry in Prometheus text format")
    ap.add_argument("--out", default="-", metavar="PATH",
                    help="output file ('-' = stdout)")
    ap.add_argument("--demo", action="store_true",
                    help="populate a scratch registry with sample "
                         "instruments and dump it (render-path smoke)")
    ap.add_argument("--resource", action="store_true",
                    help="take one ResourceMonitor sample (RSS, device "
                         "memory, jit-cache size) onto the default "
                         "registry before dumping")
    args = ap.parse_args(argv)

    if args.resource:
        from repro.obs import ResourceMonitor
        ResourceMonitor().sample()

    if args.demo:
        reg = MetricsRegistry()
        c = reg.counter("demo_requests", "Demo requests served",
                        ("tenant",))
        c.inc(3, tenant="a")
        c.inc(2, tenant="b")
        h = reg.histogram("demo_latency_seconds", "Demo latencies",
                          buckets=(0.01, 0.1, 1.0))
        for v in (0.005, 0.05, 0.5, 5.0):
            h.observe(v)
        reg.gauge("demo_inflight", "Demo in-flight work").set(1)
        dump(args.out, registries=[reg])
        return
    dump(args.out)


if __name__ == "__main__":
    main()
