import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the cell's step function (train_step / prefill_step / decode_step,
     or one distributed-forest boosting round for --arch caloforest) with
     ShapeDtypeStruct stand-ins — no arrays are ever allocated,
  3. compiles it (proving the sharding is coherent and collectives lower),
  4. records memory_analysis (fits-in-HBM proof), raw cost_analysis, the HLO
     collective inventory, and the analytic roofline terms (see
     repro/analysis/flops.py for why FLOPs are analytic),
  5. writes a JSON artifact to --out (default experiments/dryrun/).

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.analysis import flops as fl
from repro.config import (LM_SHAPES, SHAPES_BY_NAME, ForestConfig,
                          TrainConfig, shape_applicable)
from repro.configs import ARCH_IDS, get_arch
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.sharding import rules
from repro.train.optim import adamw_update

_DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32}

# HLO instruction: %name = type[dims]{layout} op(operands). Async variants
# (all-reduce-start) return tuples; count the first element's payload.
_COLL_RE = re.compile(
    r"=\s*\(?([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2}


def collective_inventory(hlo_text: str):
    """Sum payload bytes of every collective op in the HLO, per op kind.

    Collectives inside While bodies (the layer scan) appear once in the text;
    the caller scales per-iteration entries by the known trip count. We
    attribute an op to 'scanned' when its enclosing computation is not the
    entry computation (scan bodies are emitted as named sub-computations).
    """
    per_kind = {}
    scanned_flag = {}
    current_comp = ""
    entry = None
    for line in hlo_text.splitlines():
        header = re.match(r"\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->", line)
        if header:
            current_comp = header.group(2)
            if header.group(1):
                entry = current_comp
            continue
        for m in _COLL_RE.finditer(line):
            dt, dims, kind = m.group(1), m.group(2), m.group(3)
            if dt not in _TYPE_BYTES:
                continue
            size = _TYPE_BYTES[dt]
            for d in dims.split(","):
                if d:
                    size *= int(d)
            in_scan = current_comp != entry
            key = (kind, in_scan)
            per_kind[key] = per_kind.get(key, 0) + size
    return {f"{k}{'.scanned' if s else ''}": v
            for (k, s), v in per_kind.items()}


def _scan_trip_count(cfg):
    from repro.models import blocks
    segs = blocks.segments_for(cfg)
    return max(n for _, n in segs)


def run_cell(arch_id: str, shape_name: str, multi_pod: bool, out_dir: Path,
             remat_policy: str = "full", mla_absorb: bool = False,
             attn_impl: str = "blocked", layout: str = "2d",
             moe_w8: bool = False, opt_bf16: bool = False,
             tag: str = "") -> dict:
    t0 = time.time()
    from repro.models import attention as attn_mod
    attn_mod._ATTN_IMPL = attn_impl
    cfg = get_arch(arch_id)
    if mla_absorb:
        # frozen dataclass; decode path reads getattr(cfg, "mla_absorb", False)
        object.__setattr__(cfg, "mla_absorb", True)
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    rec = {"arch": arch_id, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "remat": remat_policy, "tag": tag}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    dp, tp = rules.axes_for_mesh(multi_pod)
    dp_size = chips // 16
    tp_size = 16
    if layout == "dp_only":
        # small-model layout: pure data parallel — batch spans both axes,
        # params FSDP over both axes, no tensor parallelism (no per-layer
        # activation reduces). The smollm-135m hillclimb (§Perf).
        dp = dp + (tp,)
        dp_size = chips
        tp = "model"       # unused: tp_size=1 below blocks tp assignment
        tp_size = 1
    dtype = jnp.bfloat16

    specs = lm.input_specs(cfg, shape, dtype)

    def _init():
        params = lm.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        if moe_w8:
            from repro.models.moe import quantize_expert_weights
            for seg in params.get("segments", []):
                for key, sub in seg.items():
                    if isinstance(sub, dict) and "moe" in sub:
                        sub["moe"] = quantize_expert_weights(sub["moe"])
        return params

    params_shape = jax.eval_shape(_init)
    pspecs = rules.param_specs(params_shape, cfg, dp, tp, dp_size, tp_size)
    p_shard = jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), pspecs)

    tcfg = TrainConfig(remat_policy=remat_policy)

    if shape.kind == "train":
        mdt = jnp.bfloat16 if opt_bf16 else jnp.float32
        mom = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape, mdt), params_shape)
        opt_shape = {"m": mom, "v": mom,
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}
        opt_shard = {"m": p_shard, "v": p_shard,
                     "step": jax.sharding.NamedSharding(
                         mesh, jax.sharding.PartitionSpec())}
        bspecs = rules.batch_specs(specs, dp, tp, dp_size)
        b_shard = jax.tree_util.tree_map(
            lambda s: jax.sharding.NamedSharding(mesh, s), bspecs)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: lm.loss_fn(p, batch, cfg, dtype=dtype,
                                     remat_policy=remat_policy),
                has_aux=True)(params)
            params, opt_state, om = adamw_update(grads, opt_state, params, tcfg)
            return params, opt_state, loss

        fn = jax.jit(train_step,
                     in_shardings=(p_shard, opt_shard, b_shard),
                     donate_argnums=(0, 1))
        args = (params_shape, opt_shape, specs)
    elif shape.kind == "prefill":
        bspecs = rules.batch_specs(specs, dp, tp, dp_size)
        b_shard = jax.tree_util.tree_map(
            lambda s: jax.sharding.NamedSharding(mesh, s), bspecs)

        def prefill(params, batch):
            return lm.prefill_step(params, batch, cfg, dtype=dtype)

        fn = jax.jit(prefill, in_shardings=(p_shard, b_shard))
        args = (params_shape, specs)
    else:  # decode
        cache_shape = specs["cache"]
        cspecs = rules.cache_specs(cache_shape, dp, tp, dp_size, tp_size)
        c_shard = jax.tree_util.tree_map(
            lambda s: jax.sharding.NamedSharding(mesh, s), cspecs)
        tok_shard = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(
                dp if shape.global_batch % dp_size == 0
                and shape.global_batch > 1 else None, None))
        pos_shard = jax.sharding.NamedSharding(mesh,
                                               jax.sharding.PartitionSpec())

        def decode(params, cache, tokens, pos):
            return lm.decode_step(params, cache, tokens, pos, cfg,
                                  dtype=dtype)

        fn = jax.jit(decode,
                     in_shardings=(p_shard, c_shard, tok_shard, pos_shard),
                     donate_argnums=(1,))
        args = (params_shape, cache_shape, specs["tokens"], specs["pos"])

    try:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    except Exception as e:  # noqa - record the failure, don't crash the sweep
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        return rec

    mem = compiled.memory_analysis()
    cost = fl.hlo_cost_analysis(compiled)
    # compiled.as_text() is post-SPMD classic HLO (collectives materialised);
    # lowered.as_text() would be StableHLO with shardings still symbolic.
    hlo = compiled.as_text()
    inv = collective_inventory(hlo)
    trips = _scan_trip_count(cfg)
    coll_hlo = sum(v * (trips if k.endswith(".scanned") else 1)
                   for k, v in inv.items())

    acost = fl.cell_cost(cfg, shape, chips=chips, dp_size=dp_size,
                         tp_size=tp_size, remat_policy=remat_policy,
                         mla_absorb=mla_absorb,
                         attn_packed=(attn_impl == "packed"),
                         moe_w8=moe_w8)
    roof = fl.roofline(acost, chips)

    rec.update(
        status="ok",
        compile_s=round(time.time() - t0, 1),
        chips=chips,
        memory_analysis={
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "peak_bytes_per_device": (
                getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)),
            "repr": str(mem)[:2000],
        },
        cost_analysis_raw={k: cost[k] for k in
                           ("flops", "bytes accessed", "transcendentals")
                           if k in cost},
        collective_inventory=inv,
        collective_bytes_hlo_scaled=coll_hlo,
        scan_trip_count=trips,
        analytic={
            "fwd_flops": acost.fwd_flops,
            "total_flops": acost.total_flops,
            "hbm_bytes": acost.hbm_bytes,
            "coll_bytes": acost.coll_bytes,
            "model_flops": acost.model_flops,
        },
        roofline=roof,
    )
    return rec


def run_forest_cell(dataset: str, multi_pod: bool, out_dir: Path,
                    split_reduce: str = "allreduce", hist_bf16: bool = False,
                    int8_codes: bool = False, tag: str = "") -> dict:
    """caloforest: one distributed boosting slice at CaloChallenge scale."""
    from repro.forest.distributed import (input_specs_forest,
                                          make_distributed_fit)
    t0 = time.time()
    p = {"photons": 368, "pions": 533}[dataset]
    n_rows = 122880          # ~121k padded to divide the data axes
    fcfg = ForestConfig(n_t=100, duplicate_k=20, n_trees=2, max_depth=7,
                        learning_rate=1.5, n_bins=64, reg_lambda=1.0,
                        split_reduce=split_reduce, hist_bf16=hist_bf16,
                        int8_codes=int8_codes)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    data_axes = ("pod", "data") if multi_pod else ("data",)
    rec = {"arch": "caloforest", "shape": dataset,
           "mesh": "2x16x16" if multi_pod else "16x16", "tag": tag,
           "split_reduce": split_reduce, "hist_bf16": hist_bf16}
    try:
        fit = make_distributed_fit(mesh, fcfg, data_axes=data_axes)
        n_ens = 16  # one grid slice: 16 ensembles across the model axis
        args = input_specs_forest(fcfg, n_rows, p, n_ens)
        lowered = fit.lower(*args)
        compiled = lowered.compile()
    except Exception as e:
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        return rec
    mem = compiled.memory_analysis()
    inv = collective_inventory(compiled.as_text())
    acost = fl.forest_cost(n_rows=n_rows, p=p, fcfg=fcfg, chips=chips,
                           data_shards=(chips // 16 if not multi_pod
                                        else chips // 16),
                           out_dim=1)
    roof = fl.roofline(acost, chips)
    rec.update(
        status="ok", compile_s=round(time.time() - t0, 1), chips=chips,
        memory_analysis={"repr": str(mem)[:2000]},
        collective_inventory=inv,
        analytic={"total_flops": acost.total_flops,
                  "hbm_bytes": acost.hbm_bytes,
                  "coll_bytes": acost.coll_bytes},
        roofline=roof,
        note=("one 2-round ensemble slice; full run loops n_t*n_y/16 slices, "
              "hist reduction over data axes is the only hot-loop collective"),
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--attn", default="blocked",
                    choices=("blocked", "packed"))
    ap.add_argument("--layout", default="2d", choices=("2d", "dp_only"))
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--split-reduce", default="allreduce",
                    choices=("allreduce", "reduce_scatter"))
    ap.add_argument("--hist-bf16", action="store_true")
    ap.add_argument("--int8-codes", action="store_true")
    ap.add_argument("--moe-w8", action="store_true",
                    help="int8 weight-only routed experts (decode cells)")
    ap.add_argument("--opt-bf16", action="store_true",
                    help="bf16 AdamW moments (halves optimizer HBM)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in LM_SHAPES:
                cells.append((arch, shape.name))
        cells.append(("caloforest", "photons"))
        cells.append(("caloforest", "pions"))
    else:
        cells.append((args.arch, args.shape))

    for arch, shape in cells:
        for mp in meshes:
            if arch == "caloforest":
                rec = run_forest_cell(shape, mp, out_dir,
                                      split_reduce=args.split_reduce,
                                      hist_bf16=args.hist_bf16,
                                      int8_codes=args.int8_codes,
                                      tag=args.tag)
            else:
                rec = run_cell(arch, shape, mp, out_dir,
                               remat_policy=args.remat,
                               mla_absorb=args.mla_absorb,
                               attn_impl=args.attn, layout=args.layout,
                               moe_w8=args.moe_w8, opt_bf16=args.opt_bf16,
                               tag=args.tag)
            suffix = ("multi" if mp else "single")
            if args.tag:
                suffix += f"_{args.tag}"
            path = out_dir / f"{arch}_{shape}_{suffix}.json"
            path.write_text(json.dumps(rec, indent=1, default=str))
            status = rec["status"]
            extra = ""
            if status == "ok" and "roofline" in rec:
                r = rec["roofline"]
                extra = (f" dominant={r['dominant']}"
                         f" mfu_bound={r['mfu_bound']:.3f}")
            print(f"[{status}] {arch} x {shape} x {rec['mesh']}"
                  f" ({rec.get('compile_s', '-')}s){extra}", flush=True)
            if status == "failed":
                print(rec["error"], flush=True)


if __name__ == "__main__":
    main()
