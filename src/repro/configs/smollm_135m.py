"""smollm-135m — llama-arch small dense model.

[hf:HuggingFaceTB/SmolLM-135M; hf]
30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="smollm-135m",
        family="dense",
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        d_head=64,
        d_ff=1536,
        vocab=49152,
        tie_embeddings=True,
        norm="rmsnorm",
        act="swiglu",
        rope_theta=10000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="smollm-135m-reduced",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=3,
        n_kv_heads=1,
        d_head=16,
        d_ff=128,
        vocab=256,
        tie_embeddings=True,
        norm="rmsnorm",
        act="swiglu",
    )
