"""phi4-mini-3.8b — dense, RoPE SwiGLU GQA, 200k vocab.

[arXiv:2412.08905; hf]
32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="phi4-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab=200064,
        tie_embeddings=True,
        norm="rmsnorm",
        act="swiglu",
        rope_theta=10000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="phi4-mini-3.8b-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=160,
        vocab=512,
        tie_embeddings=True,
        norm="rmsnorm",
        act="swiglu",
    )
