"""llava-next-34b — VLM; dense backbone, anyres tiling frontend (stub).

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
The vision frontend is a STUB per the task spec: ``input_specs()`` provides
precomputed patch embeddings which the backbone prepends to the token stream.
"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llava-next-34b",
        family="vlm",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_head=128,
        d_ff=20480,
        vocab=64000,
        n_patches=576,
        norm="rmsnorm",
        act="swiglu",
        rope_theta=5000000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="llava-next-34b-reduced",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab=256,
        n_patches=8,
        norm="rmsnorm",
        act="swiglu",
    )
