"""whisper-tiny — encoder-decoder audio backbone; conv frontend is a STUB.

[arXiv:2212.04356; unverified]
4L (encoder) + 4L (decoder) d_model=384 6H d_ff=1536 vocab=51865.
``input_specs()`` provides precomputed frame embeddings in place of the
log-mel + conv1d stem, per the task spec.
"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny",
        family="audio_encdec",
        n_layers=4,  # per stack (4 encoder + 4 decoder)
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_head=64,
        d_ff=1536,
        vocab=51865,
        norm="layernorm",
        act="gelu",
        notes="enc-dec; absolute (encoder) / learned (decoder) positions",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny-reduced",
        family="audio_encdec",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab=256,
        norm="layernorm",
        act="gelu",
    )
