"""granite-3-8b — dense GQA.

[hf:ibm-granite/granite-3.0-2b-base; hf]
40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=12800,
        vocab=49155,
        tie_embeddings=True,
        norm="rmsnorm",
        act="swiglu",
        rope_theta=10000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="granite-3-8b-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=192,
        vocab=256,
        tie_embeddings=True,
        norm="rmsnorm",
        act="swiglu",
    )
