"""dbrx-132b — fine-grained MoE, 16 experts top-4.

[hf:databricks/dbrx-base; unverified]
40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4.
"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_head=128,
        d_ff=10752,
        vocab=100352,
        n_experts=16,
        top_k=4,
        d_ff_expert=10752,
        norm="layernorm",
        act="swiglu",
        rope_theta=500000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="dbrx-132b-reduced",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=96,
        vocab=256,
        n_experts=4,
        top_k=2,
        d_ff_expert=96,
        norm="layernorm",
        act="swiglu",
    )
