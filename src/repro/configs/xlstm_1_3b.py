"""xlstm-1.3b — sLSTM + mLSTM blocks (SSM family).

[arXiv:2405.04517; unverified]
48L d_model=2048 4H d_ff=0 (blocks carry their own up/down projections)
vocab=50304. Pattern: 1 sLSTM per 8 blocks (7 mLSTM : 1 sLSTM), both
expressed as associative-scannable linear recurrences (see DESIGN.md §8 on
the parallelizable sLSTM approximation). Sub-quadratic: runs long_500k.
"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_head=512,
        d_ff=0,
        vocab=50304,
        rnn_width=4096,  # 2x up-projection inside mLSTM blocks
        pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm"),
        conv1d_width=4,
        norm="rmsnorm",
        act="swiglu",
        sub_quadratic=True,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="xlstm-1.3b-reduced",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        d_head=32,
        d_ff=0,
        vocab=256,
        rnn_width=128,
        pattern=("mlstm", "slstm"),
        conv1d_width=4,
        norm="rmsnorm",
        act="swiglu",
        sub_quadratic=True,
    )
