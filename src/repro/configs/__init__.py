"""Architecture registry: ``--arch <id>`` resolution.

Every assigned architecture from the public pool has a module here exporting
``config()`` (the exact published configuration) and ``reduced()`` (a tiny
same-family config for CPU smoke tests). ``caloforest`` is the paper's own
model family.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.config import ArchConfig

_ARCH_MODULES: Dict[str, str] = {
    "dbrx-132b": "repro.configs.dbrx_132b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "smollm-135m": "repro.configs.smollm_135m",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_arch(arch_id: str, reduced: bool = False) -> ArchConfig:
    """Resolve an architecture id to its (full or reduced) config."""
    if arch_id not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {', '.join(ARCH_IDS)}"
        )
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return mod.reduced() if reduced else mod.config()
