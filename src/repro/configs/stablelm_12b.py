"""stablelm-12b — dense GQA.

[hf:stabilityai/stablelm-2-1_6b; hf]
40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="stablelm-12b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_head=160,
        d_ff=13824,
        vocab=100352,
        norm="layernorm",
        act="swiglu",
        rope_theta=10000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="stablelm-12b-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=192,
        vocab=256,
        norm="layernorm",
        act="swiglu",
    )
