"""recurrentgemma-9b — Griffin: RG-LRU recurrent blocks + local attention, 1:2.

[arXiv:2402.19427; unverified]
38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window 2048.
Pattern (rec, rec, attn) repeating; 38 = 12*(3) + 2 trailing recurrent.
Sub-quadratic: runs long_500k (bounded-window KV + constant RG-LRU state).
"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        d_head=256,
        d_ff=12288,
        vocab=256000,
        rnn_width=4096,
        attn_window=2048,
        pattern=("rec", "rec", "attn"),
        conv1d_width=4,
        norm="rmsnorm",
        act="geglu",
        rope_theta=10000.0,
        sub_quadratic=True,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-9b-reduced",
        family="hybrid",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_head=16,
        d_ff=128,
        vocab=256,
        rnn_width=64,
        attn_window=16,
        pattern=("rec", "rec", "attn"),
        conv1d_width=4,
        norm="rmsnorm",
        act="geglu",
        sub_quadratic=True,
    )
