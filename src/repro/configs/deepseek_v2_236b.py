"""deepseek-v2-236b — MLA attention + fine-grained MoE (2 shared + 160 routed, top-6).

[arXiv:2405.04434; hf]
60L d_model=5120 128H d_ff=1536 (per routed expert) vocab=102400,
MLA kv_lora=512, first layer dense FFN (12288).
"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b",
        family="mla_moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,   # MLA: heads share one latent; kv head count == q heads
        d_head=128,       # nope dim (v head dim matches)
        d_ff=1536,
        vocab=102400,
        n_experts=160,
        top_k=6,
        n_shared_experts=2,
        d_ff_expert=1536,
        first_k_dense=1,
        d_ff_dense=12288,
        q_lora_rank=1536,
        kv_lora_rank=512,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
        norm="rmsnorm",
        act="swiglu",
        rope_theta=10000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b-reduced",
        family="mla_moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=32,
        vocab=256,
        n_experts=8,
        top_k=2,
        n_shared_experts=1,
        d_ff_expert=32,
        first_k_dense=1,
        d_ff_dense=128,
        q_lora_rank=32,
        kv_lora_rank=16,
        rope_head_dim=8,
        nope_head_dim=16,
        v_head_dim=16,
        norm="rmsnorm",
        act="swiglu",
    )
