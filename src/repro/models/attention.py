"""Attention: GQA / MQA / local-window / MLA, training + prefill + cached decode.

Training/prefill use a pure-JAX blocked online-softmax attention
(:func:`mea_attention`) so the peak live intermediate is one
``[B, heads, q_block, kv_block]`` tile instead of the quadratic ``[S, S]``
score matrix — the same memory discipline the paper enforces for tabular
arrays (never materialise the O(n_t · nK · p) object), applied to sequence
length. The Pallas flash-attention kernel in ``repro/kernels/flash_attention``
is the TPU production path; this module is the XLA path that the multi-pod
dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_impl
from repro.models.layers import _normal, apply_rope

NEG_INF = -1e30

# 'blocked' (default): full-grid blocked attention (computes masked blocks).
# 'packed': causal triangle packing — only the n_q(n_q+1)/2 visible block
# pairs are computed, realising the S^2/2 causal FLOP saving (§Perf).
ATTN_IMPLS = ("blocked", "packed")


def _attn_impl(impl: Optional[str] = None) -> str:
    """Resolve the attention impl per call (arg > REPRO_ATTN_IMPL > default);
    a module-level snapshot would freeze the env var at import time."""
    return resolve_impl(impl, env_var="REPRO_ATTN_IMPL", default="blocked",
                        valid=ATTN_IMPLS)


# ---------------------------------------------------------------------------
# Blocked online-softmax attention (XLA path)
# ---------------------------------------------------------------------------

def _attn_reference(q, k, v, causal: bool, window: int, q_offset: int):
    """Naive attention; used for short sequences and as the test oracle.

    q: [B, Hq, Sq, d], k/v: [B, Hkv, Skv, d] with Hq = G*Hkv.
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) / jnp.sqrt(d).astype(jnp.float32)
    skv = k.shape[2]
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def mea_attention_packed(q, k, v, *, block: int = 1024):
    """Causal attention over only the visible block pairs.

    Scans the flattened lower-triangle [(i, j) for i in q_blocks for j <= i]
    — nq(nq+1)/2 pairs instead of nq*nkv — so the compiled FLOPs are S^2/2 +
    diagonal, the real causal saving the blocked path masks away. Running
    (acc, m, l) statistics for every q block live across the scan (fp32,
    output-sized). Requires Sq == Skv (self-attention training/prefill).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    block = min(block, sq)
    assert sq % block == 0, (sq, block)
    nb = sq // block
    qp = q.reshape(b, hkv, g, nb, block, d)
    kp = k.reshape(b, hkv, nb, block, d)
    vp = v.reshape(b, hkv, nb, block, d)
    scale = 1.0 / (d ** 0.5)
    pairs = jnp.asarray([(i, j) for i in range(nb) for j in range(i + 1)],
                        jnp.int32)

    acc0 = jnp.zeros((nb, b, hkv, g, block, d), jnp.float32)
    m0 = jnp.full((nb, b, hkv, g, block), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nb, b, hkv, g, block), jnp.float32)
    diag = (jnp.arange(block)[:, None] >= jnp.arange(block)[None, :])

    def step(carry, pair):
        acc, m, l = carry
        i, j = pair[0], pair[1]
        qb = qp[:, :, :, i].astype(jnp.float32) * scale
        kb = kp[:, :, j].astype(jnp.float32)
        vb = vp[:, :, j].astype(jnp.float32)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb)
        s = jnp.where((i == j) & ~diag[None, None, None], NEG_INF, s)
        mi = m[i]
        m_new = jnp.maximum(mi, jnp.max(s, axis=-1))
        alpha = jnp.exp(mi - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l[i] * alpha + jnp.sum(p, axis=-1)
        a_new = acc[i] * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vb)
        return (acc.at[i].set(a_new), m.at[i].set(m_new),
                l.at[i].set(l_new)), None

    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), pairs)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 0, 3)  # [b, hkv, g, nb, block, d]
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def mea_attention(q, k, v, *, causal: bool = True, window: int = 0,
                  q_block: int = 512, kv_block: int = 1024, q_offset: int = 0,
                  impl: Optional[str] = None):
    """Memory-efficient attention with GQA head grouping.

    q: [B, Hq, Sq, d]; k, v: [B, Hkv, Skv, d].
    Online softmax over kv blocks inside a scan over q blocks; fp32 running
    statistics. ``window > 0`` adds a sliding-window band to the causal mask.
    ``impl`` picks 'blocked'/'packed' per call (else REPRO_ATTN_IMPL).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if (_attn_impl(impl) == "packed" and causal and window <= 0 and sq == skv
            and q_offset == 0 and sq > kv_block):
        return mea_attention_packed(q, k, v, block=kv_block)
    if sq <= q_block and skv <= kv_block:
        return _attn_reference(q, k, v, causal, window, q_offset)
    g = hq // hkv
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    # Pad to block multiples (static shapes).
    sq_p = -(-sq // q_block) * q_block
    skv_p = -(-skv // kv_block) * kv_block
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    qp = qp.reshape(b, hkv, g, sq_p // q_block, q_block, d)
    kp = kp.reshape(b, hkv, skv_p // kv_block, kv_block, d)
    vp = vp.reshape(b, hkv, skv_p // kv_block, kv_block, d)
    n_q, n_kv = sq_p // q_block, skv_p // kv_block
    scale = 1.0 / (d ** 0.5)

    kv_valid = jnp.arange(skv_p) < skv  # mask padded kv rows

    def q_step(_, qi):
        qb = qp[:, :, :, qi] * scale  # [b, hkv, g, qblk, d]
        q_pos = qi * q_block + jnp.arange(q_block) + q_offset

        def kv_step(carry, ki):
            acc, m, l = carry
            kb = kp[:, :, ki]
            vb = vp[:, :, ki]
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qb.astype(jnp.float32), kb.astype(jnp.float32)
            )
            k_pos = ki * kv_block + jnp.arange(kv_block)
            msk = kv_valid[ki * kv_block + jnp.arange(kv_block)][None, :]
            if causal:
                msk = msk & (q_pos[:, None] >= k_pos[None, :])
            if window > 0:
                msk = msk & (q_pos[:, None] - k_pos[None, :] < window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32)
            )
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, hkv, g, q_block, d), jnp.float32)
        m0 = jnp.full((b, hkv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block), jnp.float32)
        if causal and window <= 0:
            # Only kv blocks at or before this q block contribute.
            n_needed = jnp.minimum(
                ( (qi + 1) * q_block + q_offset + kv_block - 1) // kv_block, n_kv)
        else:
            n_needed = n_kv

        def masked_kv_step(carry, ki):
            new_carry, _ = kv_step(carry, ki)
            take = ki < n_needed
            carry = jax.tree_util.tree_map(
                lambda n, o: jnp.where(take, n, o), new_carry, carry)
            return carry, None

        (acc, m, l), _ = jax.lax.scan(
            masked_kv_step, (acc0, m0, l0), jnp.arange(n_kv))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(n_q))
    # outs: [n_q, b, hkv, g, q_block, d]
    out = jnp.moveaxis(outs, 0, 3).reshape(b, hkv, g, sq_p, d)
    out = out.reshape(b, hq, sq_p, d)[:, :, :sq]
    return out


# ---------------------------------------------------------------------------
# GQA attention layer (params + apply)
# ---------------------------------------------------------------------------

def init_gqa(key, d_model: int, n_heads: int, n_kv: int, d_head: int,
             dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d_model ** -0.5
    so = (n_heads * d_head) ** -0.5
    return {
        "wq": _normal(k1, (d_model, n_heads, d_head), s, dtype),
        "wk": _normal(k2, (d_model, n_kv, d_head), s, dtype),
        "wv": _normal(k3, (d_model, n_kv, d_head), s, dtype),
        "wo": _normal(k4, (n_heads, d_head, d_model), so, dtype),
    }


def apply_gqa(p, x, positions, *, theta: float, causal: bool = True,
              window: int = 0, rope: bool = True,
              cache: Optional[dict] = None, cache_index=None,
              cross_kv: Optional[tuple] = None):
    """GQA attention.

    Training/prefill: ``cache is None`` → full-sequence blocked attention; if
    the caller wants a cache back it uses :func:`make_kv_cache` + the returned
    k/v. Decode: ``cache`` holds k/v of shape [B, Hkv, S_cache, d]; the new
    token's kv is written at ``cache_index``.
    ``cross_kv``: (k, v) from an encoder — used by whisper's cross-attention
    (keys are precomputed; no cache update).
    """
    dt = x.dtype
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(dt))
    if cross_kv is not None:
        k, v = cross_kv
    else:
        k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"].astype(dt))
        if rope:
            q = jnp.swapaxes(apply_rope(jnp.swapaxes(q, 1, 2), positions, theta), 1, 2)
            k = jnp.swapaxes(apply_rope(jnp.swapaxes(k, 1, 2), positions, theta), 1, 2)

    new_cache = None
    if cache is not None and cross_kv is None:
        # decode: s == 1; insert at cache_index (ring-buffer for windowed attn)
        size = cache["k"].shape[2]
        idx = cache_index % size if window > 0 else cache_index
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, idx, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, idx, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck.astype(dt), cv.astype(dt)
        out = _decode_attention(q, k, v, cache_index, window)
    elif cache is not None and cross_kv is not None:
        new_cache = cache
        out = mea_attention(q, k, v, causal=False)
    else:
        q_off = 0
        out = mea_attention(q, k, v, causal=causal, window=window, q_offset=q_off)
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(dt))
    if cache is not None:
        return y, new_cache
    return y, (k, v)


def _decode_attention(q, k, v, cache_index, window: int):
    """Single-token attention against a cache. q: [B,Hq,1,d], k/v: [B,Hkv,S,d]."""
    b, hq, _, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, 1, d).astype(jnp.float32)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32))
    scores = scores / (d ** 0.5)
    kpos = jnp.arange(s)
    if window > 0:
        # ring buffer: valid entries are the window positions written so far
        valid = kpos < jnp.minimum(cache_index + 1, s)
    else:
        valid = kpos <= cache_index
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, 1, d).astype(q.dtype)


def make_kv_cache(batch: int, n_kv: int, size: int, d_head: int, dtype):
    return {
        "k": jnp.zeros((batch, n_kv, size, d_head), dtype),
        "v": jnp.zeros((batch, n_kv, size, d_head), dtype),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    d, h = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope_d, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    s = d ** -0.5
    return {
        "wq_a": _normal(ks[0], (d, qr), s, dtype),
        "q_norm": {"scale": jnp.ones((qr,), dtype)},
        "wq_b": _normal(ks[1], (qr, h, nope + rope_d), qr ** -0.5, dtype),
        "wkv_a": _normal(ks[2], (d, kvr), s, dtype),
        "kv_norm": {"scale": jnp.ones((kvr,), dtype)},
        "wk_rope": _normal(ks[3], (d, rope_d), s, dtype),
        "wk_b": _normal(ks[4], (kvr, h, nope), kvr ** -0.5, dtype),
        "wv_b": _normal(ks[5], (kvr, h, vd), kvr ** -0.5, dtype),
        "wo": _normal(ks[6], (h, vd, d), (h * vd) ** -0.5, dtype),
    }


def apply_mla(p, x, positions, cfg, *, cache: Optional[dict] = None,
              cache_index=None, absorb: bool = False):
    """MLA attention. Cache stores the compressed latent + shared rope key:
    ``{"c": [B, S, kv_lora], "k_rope": [B, S, rope_d]}`` — the memory win that
    motivates MLA.

    ``absorb``: decode-time low-rank absorption (fold wk_b into the query and
    wv_b into the output) so per-step FLOPs scale with kv_lora, not with
    expanding the full K/V — a beyond-paper perf optimisation (§Perf).
    """
    from repro.models.layers import apply_norm  # local import to avoid cycle

    dt = x.dtype
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope_d = cfg.nope_head_dim, cfg.rope_head_dim

    ql = apply_norm(p["q_norm"], x @ p["wq_a"].astype(dt), "rmsnorm")
    q = jnp.einsum("bsr,rhk->bshk", ql, p["wq_b"].astype(dt))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c = apply_norm(p["kv_norm"], x @ p["wkv_a"].astype(dt), "rmsnorm")  # [b,s,kvr]
    k_rope = apply_rope((x @ p["wk_rope"].astype(dt))[:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0]  # [b,s,rope_d]

    new_cache = None
    if cache is not None:
        c_all = jax.lax.dynamic_update_slice(
            cache["c"], c.astype(cache["c"].dtype), (0, cache_index, 0))
        kr_all = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, cache_index, 0))
        new_cache = {"c": c_all, "k_rope": kr_all}
        skv = c_all.shape[1]
        valid = jnp.arange(skv) <= cache_index
        if absorb:
            # scores = q_nope^T (wk_b c) = (wk_b^T q_nope)^T c : do the small side
            q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"].astype(dt))
            s_nope = jnp.einsum("bshr,btr->bhst", q_eff, c_all.astype(dt))
            s_rope = jnp.einsum("bshk,btk->bhst", q_rope, kr_all.astype(dt))
            scores = (s_nope + s_rope).astype(jnp.float32) / ((nope + rope_d) ** 0.5)
            scores = jnp.where(valid[None, None, None], scores, NEG_INF)
            w = jax.nn.softmax(scores, axis=-1).astype(dt)
            ctx = jnp.einsum("bhst,btr->bshr", w, c_all.astype(dt))
            out = jnp.einsum("bshr,rhv->bshv", ctx, p["wv_b"].astype(dt))
        else:
            k_nope = jnp.einsum("btr,rhk->bthk", c_all.astype(dt), p["wk_b"].astype(dt))
            vv = jnp.einsum("btr,rhv->bthv", c_all.astype(dt), p["wv_b"].astype(dt))
            s_nope = jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
            s_rope = jnp.einsum("bshk,btk->bhst", q_rope, kr_all.astype(dt))
            scores = (s_nope + s_rope).astype(jnp.float32) / ((nope + rope_d) ** 0.5)
            scores = jnp.where(valid[None, None, None], scores, NEG_INF)
            w = jax.nn.softmax(scores, axis=-1).astype(dt)
            out = jnp.einsum("bhst,bthv->bshv", w, vv)
        y = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(dt))
        return y, new_cache

    # training / prefill: expand k/v and use blocked attention
    k_nope = jnp.einsum("bsr,rhk->bshk", c, p["wk_b"].astype(dt))
    v = jnp.einsum("bsr,rhv->bshv", c, p["wv_b"].astype(dt))
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, rope_d))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    vd = cfg.v_head_dim
    pad = nope + rope_d - vd
    v_padded = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad))) if pad > 0 else v
    out = mea_attention(jnp.swapaxes(q_full, 1, 2), jnp.swapaxes(k_full, 1, 2),
                        jnp.swapaxes(v_padded, 1, 2), causal=True)
    out = jnp.swapaxes(out, 1, 2)[..., :vd]
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(dt))
    return y, (c, k_rope)


def make_mla_cache(batch: int, size: int, cfg, dtype):
    return {
        "c": jnp.zeros((batch, size, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, size, cfg.rope_head_dim), dtype),
    }
