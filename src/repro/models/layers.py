"""Core layers: norms, MLPs, embeddings, RoPE — pure-JAX, explicit params.

Parameters are plain nested dicts of ``jnp.ndarray``; init functions build
them, apply functions consume them. Weights are stored in ``param_dtype``
(fp32 master) and cast to the compute dtype at the point of use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(d: int, kind: str, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    """RMSNorm / LayerNorm with fp32 statistics."""
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, act: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = f ** -0.5
    if act in ("swiglu", "geglu"):
        return {
            "wi": _normal(k1, (d, f), s_in, dtype),
            "wg": _normal(k2, (d, f), s_in, dtype),
            "wo": _normal(k3, (f, d), s_out, dtype),
        }
    return {
        "wi": _normal(k1, (d, f), s_in, dtype),
        "wo": _normal(k3, (f, d), s_out, dtype),
    }


def apply_mlp(p, x, act: str):
    dt = x.dtype
    h = x @ p["wi"].astype(dt)
    if act == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(dt)) * h
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["wg"].astype(dt)) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    return h @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d: int, dtype=jnp.float32):
    return {"tokens": _normal(key, (vocab, d), 1.0, dtype)}


def embed_tokens(p, tokens, dtype):
    return jnp.take(p["tokens"], tokens, axis=0).astype(dtype)


def unembed(p_embed, p_head, x, tie: bool):
    """Project to logits in fp32 for a stable softmax-xent."""
    xf = x
    if tie:
        w = p_embed["tokens"].astype(x.dtype)
        return (xf @ w.T).astype(jnp.float32)
    return (xf @ p_head["w"].astype(x.dtype)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))


def apply_rope(x, positions, theta: float):
    """x: [..., S, n_heads, d_head]; positions: [..., S] int32."""
    d_head = x.shape[-1]
    inv = jnp.asarray(rope_frequencies(d_head, theta))
    ang = positions.astype(jnp.float32)[..., None] * inv  # [..., S, d/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels):
    """Mean cross entropy. logits [..., V] fp32, labels [...] int32."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
