from repro.models import lm  # noqa: F401
