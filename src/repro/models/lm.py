"""Top-level LM assembly: init, train loss, prefill, decode, input specs.

One module serves all ten assigned architectures; ``ArchConfig.family``
selects the segment program (see blocks.py). The three step functions lowered
by the multi-pod dry-run live here:

* ``loss_fn``      — full train-step objective (xent over next tokens)
* ``prefill_step`` — full-sequence forward emitting logits + KV/state cache
* ``decode_step``  — one new token against a seq_len-sized cache
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig, ShapeConfig
from repro.models import blocks
from repro.models.layers import embed_tokens, init_embed, init_norm, apply_norm, _normal


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 8)
    params: Dict = {"embed": init_embed(ks[0], cfg.vocab, cfg.d_model, dtype)}
    if cfg.family == "audio_encdec":
        params["enc_segments"] = [
            blocks.init_segment(ks[1], cfg, ("enc",), cfg.n_layers, dtype)]
        params["dec_segments"] = [
            blocks.init_segment(ks[2], cfg, ("dec",), cfg.n_layers, dtype)]
        params["enc_norm"] = init_norm(cfg.d_model, cfg.norm, dtype)
    else:
        segs = blocks.segments_for(cfg)
        params["segments"] = [
            blocks.init_segment(jax.random.fold_in(ks[1], i), cfg, kinds, n, dtype)
            for i, (kinds, n) in enumerate(segs)]
    params["final_norm"] = init_norm(cfg.d_model, cfg.norm, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": _normal(ks[3], (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5,
                         dtype)}
    return params


def _head_weight(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"]["tokens"].T
    return params["lm_head"]["w"]


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def chunked_xent(x, w, labels, mask, chunk: int = 2048):
    """Cross entropy without materialising [B, S, V] logits.

    x: [B, S, D] activations; w: [D, V]; labels [B, S] int32; mask [B, S].
    Scans over S in ``chunk``-sized slices — the same never-materialise-the-
    big-array discipline the paper applies to its [n_t, nK, p] tensor.
    """
    b, s, d = x.shape
    if s <= chunk:
        logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        per_tok = (logz - gold) * mask
        return jnp.sum(per_tok) / jnp.maximum(jnp.sum(mask), 1.0)
    n_chunks = s // chunk
    assert n_chunks * chunk == s, f"seq {s} not divisible by chunk {chunk}"
    xs = jnp.moveaxis(x.reshape(b, n_chunks, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, n_chunks, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(b, n_chunks, chunk), 1, 0)

    def body(carry, inp):
        xc, lc, mc = inp
        logits = (xc @ w.astype(xc.dtype)).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        tot, cnt = carry
        return (tot + jnp.sum((logz - gold) * mc), cnt + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def _sinusoidal(positions, d):
    half = d // 2
    freqs = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * jnp.asarray(freqs)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# forward bodies
# ---------------------------------------------------------------------------

def _backbone(params, x, positions, cfg, remat_policy):
    aux = jnp.zeros((), jnp.float32)
    for (kinds, _), seg in zip(blocks.segments_for(cfg), params["segments"]):
        x, a = blocks.apply_segment(seg, x, positions, cfg, kinds,
                                    remat_policy=remat_policy)
        aux = aux + a
    return apply_norm(params["final_norm"], x, cfg.norm), aux


def _encode(params, frames, cfg, remat_policy):
    pos = jnp.arange(frames.shape[1])[None]
    x = frames + _sinusoidal(pos, cfg.d_model).astype(frames.dtype)
    for seg in params["enc_segments"]:
        x, _ = blocks.apply_segment(seg, x, pos, cfg, ("enc",),
                                    remat_policy=remat_policy)
    return apply_norm(params["enc_norm"], x, cfg.norm)


def loss_fn(params, batch, cfg: ArchConfig, *, dtype=jnp.bfloat16,
            remat_policy: str = "full", aux_weight: float = 0.01):
    """Train objective. Returns (loss, metrics)."""
    if cfg.family == "audio_encdec":
        frames = batch["frames"].astype(dtype)
        enc_out = _encode(params, frames, cfg, remat_policy)
        tokens = batch["tokens"]
        x = embed_tokens(params["embed"], tokens, dtype)
        x = x + _sinusoidal(jnp.arange(x.shape[1])[None], cfg.d_model).astype(dtype)
        pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], tokens.shape)
        aux = jnp.zeros((), jnp.float32)
        for seg in params["dec_segments"]:
            x, a = blocks.apply_segment(seg, x, pos, cfg, ("dec",),
                                        remat_policy=remat_policy,
                                        enc_out=enc_out)
            aux = aux + a
        x = apply_norm(params["final_norm"], x, cfg.norm)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
    elif cfg.family == "vlm":
        tokens = batch["tokens"]
        tok_emb = embed_tokens(params["embed"], tokens, dtype)
        x = jnp.concatenate([batch["patches"].astype(dtype), tok_emb], axis=1)
        s = x.shape[1]
        pos = jnp.broadcast_to(jnp.arange(s)[None], (x.shape[0], s))
        x, aux = _backbone(params, x, pos, cfg, remat_policy)
        n_img = batch["patches"].shape[1]
        labels = jnp.concatenate(
            [jnp.full((tokens.shape[0], n_img), -1, jnp.int32), batch["labels"]],
            axis=1)
        mask = (labels >= 0).astype(jnp.float32)
    else:
        tokens = batch["tokens"]
        x = embed_tokens(params["embed"], tokens, dtype)
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None], tokens.shape)
        x, aux = _backbone(params, x, pos, cfg, remat_policy)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)

    w = _head_weight(params, cfg)
    safe_labels = jnp.maximum(labels, 0)
    xent = chunked_xent(x, w, safe_labels, mask)
    loss = xent + aux_weight * aux
    return loss, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def prefill_step(params, batch, cfg: ArchConfig, *, dtype=jnp.bfloat16):
    """Full forward, returning last-position logits + cache for decode."""
    if cfg.family == "audio_encdec":
        enc_out = _encode(params, batch["frames"].astype(dtype), cfg, "none")
        tokens = batch["tokens"]
        x = embed_tokens(params["embed"], tokens, dtype)
        x = x + _sinusoidal(jnp.arange(x.shape[1])[None], cfg.d_model).astype(dtype)
        pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], tokens.shape)
        caches = []
        for seg in params["dec_segments"]:
            x, c = blocks.apply_segment_prefill(seg, x, pos, cfg, ("dec",),
                                                enc_out=enc_out)
            caches.append(c)
        x = apply_norm(params["final_norm"], x, cfg.norm)
    else:
        if cfg.family == "vlm":
            tok_emb = embed_tokens(params["embed"], batch["tokens"], dtype)
            x = jnp.concatenate([batch["patches"].astype(dtype), tok_emb], axis=1)
        else:
            x = embed_tokens(params["embed"], batch["tokens"], dtype)
        s = x.shape[1]
        pos = jnp.broadcast_to(jnp.arange(s)[None], (x.shape[0], s))
        caches = []
        for (kinds, _), seg in zip(blocks.segments_for(cfg), params["segments"]):
            x, c = blocks.apply_segment_prefill(seg, x, pos, cfg, kinds)
            caches.append(c)
        x = apply_norm(params["final_norm"], x, cfg.norm)
    w = _head_weight(params, cfg)
    logits = (x[:, -1:] @ w.astype(x.dtype)).astype(jnp.float32)
    return logits, caches


def decode_step(params, cache, tokens, pos, cfg: ArchConfig, *,
                dtype=jnp.bfloat16):
    """One token. tokens: [B, 1] int32; pos: scalar int32; cache from
    init_cache / prefill_step. Returns (logits [B,1,V], new_cache)."""
    x = embed_tokens(params["embed"], tokens, dtype)
    if cfg.family == "audio_encdec":
        x = x + _sinusoidal(jnp.full((1, 1), pos), cfg.d_model).astype(dtype)
        new_caches = []
        for seg, c in zip(params["dec_segments"], cache):
            x, nc = blocks.apply_segment_decode(seg, c, x, pos, cfg, ("dec",))
            new_caches.append(nc)
        x = apply_norm(params["final_norm"], x, cfg.norm)
    else:
        new_caches = []
        for (kinds, _), seg, c in zip(blocks.segments_for(cfg),
                                      params["segments"], cache):
            x, nc = blocks.apply_segment_decode(seg, c, x, pos, cfg, kinds)
            new_caches.append(nc)
        x = apply_norm(params["final_norm"], x, cfg.norm)
    w = _head_weight(params, cfg)
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    return logits, new_caches


def init_cache(cfg: ArchConfig, batch: int, size: int, dtype=jnp.bfloat16,
               enc_len: int = 1500):
    if cfg.family == "audio_encdec":
        return [blocks.init_segment_cache(cfg, ("dec",), cfg.n_layers, batch,
                                          size, dtype, enc_len)]
    return [blocks.init_segment_cache(cfg, kinds, n, batch, size, dtype)
            for kinds, n in blocks.segments_for(cfg)]


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig, dtype=jnp.bfloat16) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.family == "vlm":
            n_img = cfg.n_patches
            return {"patches": sds((b, n_img, cfg.d_model), dtype),
                    "tokens": sds((b, s - n_img), i32),
                    "labels": sds((b, s - n_img), i32)}
        if cfg.family == "audio_encdec":
            return {"frames": sds((b, s // 2, cfg.d_model), dtype),
                    "tokens": sds((b, s // 2), i32),
                    "labels": sds((b, s // 2), i32)}
        return {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
    if shape.kind == "prefill":
        if cfg.family == "vlm":
            n_img = cfg.n_patches
            return {"patches": sds((b, n_img, cfg.d_model), dtype),
                    "tokens": sds((b, s - n_img), i32)}
        if cfg.family == "audio_encdec":
            return {"frames": sds((b, s // 2, cfg.d_model), dtype),
                    "tokens": sds((b, s // 2), i32)}
        return {"tokens": sds((b, s), i32)}
    # decode: one new token against a seq_len cache
    cache = jax.eval_shape(
        lambda: init_cache(cfg, b, s, dtype))
    return {"cache": cache, "tokens": sds((b, 1), i32),
            "pos": sds((), i32)}
