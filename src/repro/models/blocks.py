"""Block assembly: per-layer-kind init/apply + scan-over-layers segments.

Every architecture is described as a list of *segments*; a segment is a
repeating group of layer kinds scanned with stacked weights, so HLO size is
O(1) in depth (fast compiles, PP-ready structure):

    dense:        [(("dense",), n_layers)]
    dbrx:         [(("moe",), n_layers)]
    deepseek-v2:  [(("mla_dense",), 1), (("mla_moe",), n_layers - 1)]
    xlstm:        [(7 x "mlstm" + "slstm", n_layers // 8)]
    recurrentgemma: [(("rec","rec","attn"), 12), (("rec","rec"), 1)]
    whisper:      encoder [("enc",), L] and decoder [("dec",), L] stacks
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import attention as attn
from repro.models import recurrent as rec
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm
from repro.models.moe import apply_moe, init_moe, init_shared_experts


def segments_for(cfg: ArchConfig) -> List[Tuple[Tuple[str, ...], int]]:
    if cfg.family in ("dense", "vlm"):
        return [(("dense",), cfg.n_layers)]
    if cfg.family == "moe":
        return [(("moe",), cfg.n_layers)]
    if cfg.family == "mla_moe":
        segs = []
        if cfg.first_k_dense:
            segs.append((("mla_dense",), cfg.first_k_dense))
        segs.append((("mla_moe",), cfg.n_layers - cfg.first_k_dense))
        return segs
    if cfg.family == "ssm":
        plen = len(cfg.pattern)
        assert cfg.n_layers % plen == 0, "ssm layers must tile the pattern"
        return [(tuple(cfg.pattern), cfg.n_layers // plen)]
    if cfg.family == "hybrid":
        plen = len(cfg.pattern)
        n_full = cfg.n_layers // plen
        segs = [(tuple(cfg.pattern), n_full)]
        rem = cfg.n_layers - n_full * plen
        if rem:
            segs.append((tuple(cfg.pattern[:rem]), 1))
        return segs
    if cfg.family == "audio_encdec":
        # handled by whisper.py (two stacks)
        return [(("enc",), cfg.n_layers), (("dec",), cfg.n_layers)]
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# per-kind init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ArchConfig, kind: str, dtype=jnp.float32) -> Dict:
    k = jax.random.split(key, 6)
    d = cfg.d_model
    p: Dict = {}
    if kind in ("dense", "moe", "enc", "lattn", "attn"):
        p["norm_attn"] = init_norm(d, cfg.norm, dtype)
        p["attn"] = attn.init_gqa(k[0], d, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.d_head, dtype)
    if kind in ("mla_dense", "mla_moe"):
        p["norm_attn"] = init_norm(d, cfg.norm, dtype)
        p["attn"] = attn.init_mla(k[0], cfg, dtype)
    if kind == "dec":
        p["norm_attn"] = init_norm(d, cfg.norm, dtype)
        p["attn"] = attn.init_gqa(k[0], d, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.d_head, dtype)
        p["norm_cross"] = init_norm(d, cfg.norm, dtype)
        p["cross"] = attn.init_gqa(k[3], d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.d_head, dtype)
    if kind in ("dense", "enc", "dec", "lattn", "attn", "mla_dense"):
        p["norm_mlp"] = init_norm(d, cfg.norm, dtype)
        ff = cfg.d_ff_dense if (kind == "mla_dense" and cfg.d_ff_dense) else cfg.d_ff
        p["mlp"] = init_mlp(k[1], d, ff, cfg.act, dtype)
    if kind == "moe":
        p["norm_mlp"] = init_norm(d, cfg.norm, dtype)
        p["moe"] = init_moe(k[1], d, cfg.d_ff_expert, cfg.n_experts, cfg.act, dtype)
    if kind == "mla_moe":
        p["norm_mlp"] = init_norm(d, cfg.norm, dtype)
        p["moe"] = init_moe(k[1], d, cfg.d_ff_expert, cfg.n_experts, cfg.act, dtype)
        if cfg.n_shared_experts:
            p["shared"] = init_shared_experts(k[2], d, cfg.d_ff_expert,
                                              cfg.n_shared_experts, cfg.act, dtype)
    if kind == "rec":
        p["norm_rec"] = init_norm(d, cfg.norm, dtype)
        p["rec"] = rec.init_rglru_block(k[0], d, cfg.rnn_width,
                                        cfg.conv1d_width, dtype)
        p["norm_mlp"] = init_norm(d, cfg.norm, dtype)
        p["mlp"] = init_mlp(k[1], d, cfg.d_ff, cfg.act, dtype)
    if kind == "mlstm":
        p["norm"] = init_norm(d, cfg.norm, dtype)
        p["block"] = rec.init_mlstm_block(k[0], d, cfg.rnn_width, cfg.n_heads,
                                          cfg.conv1d_width, dtype)
    if kind == "slstm":
        p["norm"] = init_norm(d, cfg.norm, dtype)
        p["block"] = rec.init_slstm_block(k[0], d, cfg.n_heads, dtype)
    return p


# ---------------------------------------------------------------------------
# per-kind apply (full sequence: training / prefill)
# ---------------------------------------------------------------------------

def apply_layer(p, x, positions, cfg: ArchConfig, kind: str, *,
                enc_out=None, collect_kv: bool = False,
                moe_cf: Optional[float] = None):
    """Residual layer body over a full sequence.

    Returns (x, aux_loss, kv) where kv is the per-layer cache contribution
    when ``collect_kv`` (prefill), else None. ``moe_cf`` overrides the MoE
    capacity factor (prefill uses the no-drop E/k; training drops at 1.25).
    """
    aux = jnp.zeros((), jnp.float32)
    kv = None
    if kind in ("dense", "moe", "enc", "attn"):
        h, kv_pair = attn.apply_gqa(
            p["attn"], apply_norm(p["norm_attn"], x, cfg.norm), positions,
            theta=cfg.rope_theta, causal=(kind != "enc"),
            rope=(kind != "enc"))
        x = x + h
        if collect_kv:
            kv = {"k": kv_pair[0], "v": kv_pair[1]}
    elif kind == "lattn":
        h, kv_pair = attn.apply_gqa(
            p["attn"], apply_norm(p["norm_attn"], x, cfg.norm), positions,
            theta=cfg.rope_theta, causal=True, window=cfg.attn_window)
        x = x + h
        if collect_kv:
            kv = {"k": kv_pair[0][:, :, -cfg.attn_window:],
                  "v": kv_pair[1][:, :, -cfg.attn_window:]}
    elif kind in ("mla_dense", "mla_moe"):
        h, kv_pair = attn.apply_mla(
            p["attn"], apply_norm(p["norm_attn"], x, cfg.norm), positions, cfg)
        x = x + h
        if collect_kv:
            kv = {"c": kv_pair[0], "k_rope": kv_pair[1]}
    elif kind == "dec":
        h, kv_pair = attn.apply_gqa(
            p["attn"], apply_norm(p["norm_attn"], x, cfg.norm), positions,
            theta=cfg.rope_theta, causal=True, rope=False)
        x = x + h
        if collect_kv:
            kv = {"k": kv_pair[0], "v": kv_pair[1]}
        dt = x.dtype
        ck = jnp.einsum("bsd,dhk->bhsk", enc_out, p["cross"]["wk"].astype(dt))
        cv = jnp.einsum("bsd,dhk->bhsk", enc_out, p["cross"]["wv"].astype(dt))
        h, _ = attn.apply_gqa(
            p["cross"], apply_norm(p["norm_cross"], x, cfg.norm), positions,
            theta=cfg.rope_theta, causal=False, rope=False, cross_kv=(ck, cv))
        x = x + h
        if collect_kv:
            kv["cross_k"], kv["cross_v"] = ck, cv
    elif kind == "rec":
        res = rec.apply_rglru_block(
            p["rec"], apply_norm(p["norm_rec"], x, cfg.norm),
            return_state=collect_kv)
        if collect_kv:
            h, kv = res
        else:
            h = res
        x = x + h
    elif kind == "mlstm":
        res = rec.apply_mlstm_block(p["block"], apply_norm(p["norm"], x, cfg.norm),
                                    cfg.n_heads, return_state=collect_kv)
        if collect_kv:
            h, kv = res
        else:
            h = res
        return x + h, aux, kv
    elif kind == "slstm":
        res = rec.apply_slstm_block(p["block"], apply_norm(p["norm"], x, cfg.norm),
                                    cfg.n_heads, return_state=collect_kv)
        if collect_kv:
            h, kv = res
        else:
            h = res
        return x + h, aux, kv
    else:
        raise ValueError(kind)

    # FFN / MoE half
    if kind in ("dense", "enc", "dec", "lattn", "attn", "mla_dense", "rec"):
        x = x + apply_mlp(p["mlp"], apply_norm(p["norm_mlp"], x, cfg.norm), cfg.act)
    elif kind == "moe":
        kw = {} if moe_cf is None else {"capacity_factor": moe_cf}
        y, a = apply_moe(p["moe"], apply_norm(p["norm_mlp"], x, cfg.norm),
                         n_experts=cfg.n_experts, top_k=cfg.top_k, act=cfg.act,
                         **kw)
        x = x + y
        aux = aux + a
    elif kind == "mla_moe":
        kw = {} if moe_cf is None else {"capacity_factor": moe_cf}
        xin = apply_norm(p["norm_mlp"], x, cfg.norm)
        y, a = apply_moe(p["moe"], xin, n_experts=cfg.n_experts,
                         top_k=cfg.top_k, act=cfg.act, **kw)
        if "shared" in p:
            y = y + apply_mlp(p["shared"], xin, cfg.act)
        x = x + y
        aux = aux + a
    return x, aux, kv


# ---------------------------------------------------------------------------
# per-kind apply (single-token decode against cache/state)
# ---------------------------------------------------------------------------

def apply_layer_decode(p, x, pos, cfg: ArchConfig, kind: str, cache):
    """x: [B, 1, D]. cache: this layer's cache pytree. Returns (x, new_cache)."""
    if kind in ("dense", "moe", "attn", "lattn", "dec"):
        window = cfg.attn_window if kind == "lattn" else 0
        h, new_kv = attn.apply_gqa(
            p["attn"], apply_norm(p["norm_attn"], x, cfg.norm),
            jnp.full((x.shape[0], 1), pos, jnp.int32),
            theta=cfg.rope_theta, causal=True, window=window,
            rope=(kind != "dec"),
            cache={"k": cache["k"], "v": cache["v"]}, cache_index=pos)
        x = x + h
        new_cache = dict(cache)
        new_cache.update(new_kv)
        if kind == "dec":
            h, _ = attn.apply_gqa(
                p["cross"], apply_norm(p["norm_cross"], x, cfg.norm),
                jnp.zeros((x.shape[0], 1), jnp.int32),
                theta=cfg.rope_theta, causal=False, rope=False,
                cross_kv=(cache["cross_k"], cache["cross_v"]))
            x = x + h
    elif kind in ("mla_dense", "mla_moe"):
        h, new_kv = attn.apply_mla(
            p["attn"], apply_norm(p["norm_attn"], x, cfg.norm),
            jnp.full((x.shape[0], 1), pos, jnp.int32), cfg,
            cache={"c": cache["c"], "k_rope": cache["k_rope"]},
            cache_index=pos, absorb=getattr(cfg, "mla_absorb", False))
        x = x + h
        new_cache = dict(new_kv)
    elif kind == "rec":
        h, new_cache = rec.apply_rglru_decode(
            p["rec"], apply_norm(p["norm_rec"], x, cfg.norm), cache)
        x = x + h
    elif kind == "mlstm":
        h, new_cache = rec.apply_mlstm_decode(
            p["block"], apply_norm(p["norm"], x, cfg.norm), cache, cfg.n_heads)
        return x + h, new_cache
    elif kind == "slstm":
        h, new_cache = rec.apply_slstm_decode(
            p["block"], apply_norm(p["norm"], x, cfg.norm), cache, cfg.n_heads)
        return x + h, new_cache
    else:
        raise ValueError(kind)

    if kind in ("dense", "attn", "lattn", "dec", "mla_dense", "rec"):
        x = x + apply_mlp(p["mlp"], apply_norm(p["norm_mlp"], x, cfg.norm), cfg.act)
    elif kind == "moe":
        # decode: capacity == batch so no token is ever dropped at s=1
        cf = float(cfg.n_experts) / cfg.top_k
        y, _ = apply_moe(p["moe"], apply_norm(p["norm_mlp"], x, cfg.norm),
                         n_experts=cfg.n_experts, top_k=cfg.top_k, act=cfg.act,
                         group_size=x.shape[0], capacity_factor=cf)
        x = x + y
    elif kind == "mla_moe":
        cf = float(cfg.n_experts) / cfg.top_k
        xin = apply_norm(p["norm_mlp"], x, cfg.norm)
        y, _ = apply_moe(p["moe"], xin, n_experts=cfg.n_experts,
                         top_k=cfg.top_k, act=cfg.act,
                         group_size=x.shape[0], capacity_factor=cf)
        if "shared" in p:
            y = y + apply_mlp(p["shared"], xin, cfg.act)
        x = x + y
    return x, new_cache


# ---------------------------------------------------------------------------
# cache init per kind
# ---------------------------------------------------------------------------

def init_layer_cache(cfg: ArchConfig, kind: str, batch: int, size: int, dtype,
                     enc_len: int = 0):
    if kind in ("dense", "moe", "attn"):
        return attn.make_kv_cache(batch, cfg.n_kv_heads, size, cfg.d_head, dtype)
    if kind == "lattn":
        return attn.make_kv_cache(batch, cfg.n_kv_heads,
                                  min(size, cfg.attn_window), cfg.d_head, dtype)
    if kind in ("mla_dense", "mla_moe"):
        return attn.make_mla_cache(batch, size, cfg, dtype)
    if kind == "dec":
        c = attn.make_kv_cache(batch, cfg.n_kv_heads, size, cfg.d_head, dtype)
        c["cross_k"] = jnp.zeros((batch, cfg.n_kv_heads, enc_len, cfg.d_head), dtype)
        c["cross_v"] = jnp.zeros((batch, cfg.n_kv_heads, enc_len, cfg.d_head), dtype)
        return c
    if kind == "rec":
        return rec.rglru_init_state(batch, cfg.rnn_width, cfg.conv1d_width, dtype)
    if kind == "mlstm":
        return rec.mlstm_init_state(batch, cfg.rnn_width, cfg.n_heads,
                                    cfg.conv1d_width)
    if kind == "slstm":
        return rec.slstm_init_state(batch, cfg.d_model, cfg.n_heads)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# segment scan
# ---------------------------------------------------------------------------

def init_segment(key, cfg: ArchConfig, kinds: Tuple[str, ...], n_groups: int,
                 dtype=jnp.float32):
    """Stacked params: one pytree whose leaves have leading dim n_groups."""

    def one_group(k):
        ks = jax.random.split(k, len(kinds))
        return {f"{i}_{kind}": init_layer(ks[i], cfg, kind, dtype)
                for i, kind in enumerate(kinds)}

    keys = jax.random.split(key, n_groups)
    groups = [one_group(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *groups)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save nothing


def apply_segment(seg_params, x, positions, cfg: ArchConfig,
                  kinds: Tuple[str, ...], *, remat_policy: str = "full",
                  enc_out=None):
    """Scan the segment over its stacked groups. Returns (x, aux_sum)."""

    def group_body(carry, gp):
        xc, aux = carry
        for i, kind in enumerate(kinds):
            xc, a, _ = apply_layer(gp[f"{i}_{kind}"], xc, positions, cfg, kind,
                                   enc_out=enc_out)
            aux = aux + a
        return (xc, aux), None

    body = _remat(group_body, remat_policy)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), seg_params)
    return x, aux


def apply_segment_prefill(seg_params, x, positions, cfg: ArchConfig,
                          kinds: Tuple[str, ...], *, enc_out=None):
    """Full-sequence forward that also emits the per-layer cache (stacked)."""

    no_drop_cf = float(cfg.n_experts) / cfg.top_k if cfg.n_experts else None

    def group_body(xc, gp):
        kvs = {}
        for i, kind in enumerate(kinds):
            key = f"{i}_{kind}"
            xc, _, kv = apply_layer(gp[key], xc, positions, cfg, kind,
                                    enc_out=enc_out, collect_kv=True,
                                    moe_cf=no_drop_cf)
            kvs[key] = kv
        return xc, kvs

    x, cache = jax.lax.scan(group_body, x, seg_params)
    return x, cache


def apply_segment_decode(seg_params, seg_cache, x, pos, cfg: ArchConfig,
                         kinds: Tuple[str, ...]):
    """Scanned decode step; caches are stacked like params."""

    def group_body(xc, scan_in):
        gp, gc = scan_in
        new_gc = {}
        for i, kind in enumerate(kinds):
            key = f"{i}_{kind}"
            xc, new_gc[key] = apply_layer_decode(gp[key], xc, pos, cfg, kind,
                                                 gc[key])
        return xc, new_gc

    x, new_cache = jax.lax.scan(group_body, x, (seg_params, seg_cache))
    return x, new_cache


def init_segment_cache(cfg: ArchConfig, kinds: Tuple[str, ...], n_groups: int,
                       batch: int, size: int, dtype, enc_len: int = 0):
    one = {f"{i}_{kind}": init_layer_cache(cfg, kind, batch, size, dtype, enc_len)
           for i, kind in enumerate(kinds)}
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape).copy(), one)
