"""Mixture-of-Experts: top-k routing with grouped capacity dispatch (GShard-style).

Dispatch shape discipline mirrors the paper's memory lesson: never build the
full ``[tokens, E, C_global]`` dispatch tensor. Tokens are split into groups of
``group_size`` and capacity is per-group, so the dispatch tensor is
``[G, S_g, E, C_g]`` with ``C_g = S_g * top_k / E * capacity_factor`` — total
bytes scale with ``tokens * S_g * top_k``, independent of E.

Experts live on the ``model`` mesh axis (expert parallelism); GSPMD inserts the
all-to-alls for the g→e resharding of the dispatch einsums.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _normal


def init_moe(key, d_model: int, d_ff: int, n_experts: int, act: str,
             dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    p = {
        "router": _normal(k1, (d_model, n_experts), s_in, dtype),
        "wi": _normal(k2, (n_experts, d_model, d_ff), s_in, dtype),
        "wo": _normal(k4, (n_experts, d_ff, d_model), s_out, dtype),
    }
    if act in ("swiglu", "geglu"):
        p["wg"] = _normal(k3, (n_experts, d_model, d_ff), s_in, dtype)
    return p


def _expert_ffn(p, x, act: str):
    """x: [E, G, C, D] -> [E, G, C, D]. Transparently handles int8-quantised
    expert weights (see quantize_expert_weights below)."""
    return _expert_ffn_maybe_q(p, x, act)


def apply_moe(p, x, *, n_experts: int, top_k: int, act: str,
              group_size: int = 512, capacity_factor: float = 1.25):
    """x: [B, S, D] -> [B, S, D], plus aux load-balancing loss."""
    dt = x.dtype
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    t = tokens.shape[0]
    g_size = min(group_size, t)
    n_groups = t // g_size
    xt = tokens[: n_groups * g_size].reshape(n_groups, g_size, d)

    logits = jnp.einsum("gsd,de->gse", xt, p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # [g, s, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    capacity = max(1, int(g_size * top_k / n_experts * capacity_factor))
    # positions within each expert's buffer, per group
    onehot = jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.int32)  # [g,s,k,e]
    # priority: earlier tokens, earlier k-slots first
    flat = onehot.reshape(n_groups, g_size * top_k, n_experts)
    pos = jnp.cumsum(flat, axis=1) - flat  # [g, s*k, e]
    pos = pos.reshape(n_groups, g_size, top_k, n_experts)
    within_cap = pos < capacity
    keep = (onehot > 0) & within_cap
    pos_in_expert = jnp.sum(pos * onehot, axis=-1)  # [g, s, k]

    # dispatch/combine tensors [g, s, e, c]
    cap_oh = jax.nn.one_hot(pos_in_expert, capacity, dtype=dt)  # [g,s,k,c]
    keep_f = keep.astype(dt)
    dispatch = jnp.einsum("gske,gskc->gsec", keep_f * onehot.astype(dt), cap_oh)
    combine = jnp.einsum("gske,gskc->gsec",
                         keep_f * onehot.astype(dt) * gate_vals[..., None].astype(dt),
                         cap_oh)

    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, xt)
    expert_out = _expert_ffn(p, expert_in, act)
    yt = jnp.einsum("gsec,egcd->gsd", combine, expert_out)

    y = yt.reshape(n_groups * g_size, d)
    if n_groups * g_size < t:
        y = jnp.concatenate([y, tokens[n_groups * g_size:]], axis=0)
    # aux load-balance loss (Switch): mean_e(frac_tokens_e * mean_prob_e) * E
    frac = jnp.mean(jnp.sum(onehot[:, :, 0], axis=1) / g_size, axis=0)  # top-1 share
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(frac * mean_p) * n_experts
    return y.reshape(b, s, d), aux


def init_shared_experts(key, d_model: int, d_ff: int, n_shared: int, act: str,
                        dtype=jnp.float32):
    """DeepSeek shared experts = one dense gated MLP of width n_shared * d_ff."""
    from repro.models.layers import init_mlp
    return init_mlp(key, d_model, n_shared * d_ff, act, dtype)


# ---------------------------------------------------------------------------
# int8 weight-only experts (decode-time memory optimisation, §Perf cell A.2)
# ---------------------------------------------------------------------------

def quantize_expert_weights(p):
    """Per-(expert, out-channel) symmetric int8 quantisation of wi/wg/wo.

    Batch-decode of a large MoE reads essentially every expert every step, so
    the step is bound by expert-weight HBM bytes; int8 storage halves them
    vs bf16 (4x vs fp32). Returns params with {name: int8, name_scale: f32}.
    """
    out = {k: v for k, v in p.items() if k not in ("wi", "wg", "wo")}
    for name in ("wi", "wg", "wo"):
        if name not in p:
            continue
        w = p[name]                         # [E, in, out] (or scan-stacked)
        scale = jnp.max(jnp.abs(w), axis=-2, keepdims=True) / 127.0
        q = jnp.clip(jnp.round(w / jnp.maximum(scale, 1e-12)), -127, 127)
        out[name] = q.astype(jnp.int8)
        out[name + "_scale"] = scale.astype(jnp.float32)
    return out


def _dequant(p, name, dt):
    return (p[name].astype(dt)
            * p[name + "_scale"].astype(dt)) if name + "_scale" in p \
        else p[name].astype(dt)


def _expert_ffn_maybe_q(p, x, act: str):
    dt = x.dtype
    h = jnp.einsum("egcd,edf->egcf", x, _dequant(p, "wi", dt))
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", x,
                                   _dequant(p, "wg", dt))) * h
    elif act == "geglu":
        h = jax.nn.gelu(jnp.einsum("egcd,edf->egcf", x,
                                   _dequant(p, "wg", dt))) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("egcf,efd->egcd", h, _dequant(p, "wo", dt))
