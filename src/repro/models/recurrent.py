"""Recurrent blocks: mLSTM / sLSTM (xLSTM) and RG-LRU (RecurrentGemma/Griffin).

All recurrences are expressed in parallel-scannable form:

* mLSTM — chunkwise-parallel linear attention with matrix memory and
  stabilised exponential gating (intra-chunk quadratic + inter-chunk state),
  O(S·T_c) memory instead of O(S^2).
* sLSTM — scalar-memory exponential-gate recurrence; gates are computed from
  the inputs only (the parallelizable approximation noted in DESIGN.md §8),
  which turns the stabiliser into a max-plus associative scan and the
  cell/normaliser into linear associative scans.
* RG-LRU — input-gated diagonal linear recurrence (associative scan), with a
  causal depthwise temporal conv in front, per Griffin.

Every block exposes a ``*_decode`` single-step form carrying constant-size
state — this is what makes xlstm-1.3b / recurrentgemma-9b eligible for the
``long_500k`` cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _normal, apply_norm


# ---------------------------------------------------------------------------
# small helpers
# ---------------------------------------------------------------------------

def _linear_scan(a, b):
    """h_t = a_t * h_{t-1} + b_t along axis 1 (time). a,b: [B, S, ...]."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def _maxplus_scan(f, i):
    """m_t = max(m_{t-1} + f_t, i_t) along axis 1 (time)."""

    def combine(x, y):
        f1, m1 = x
        f2, m2 = y
        return f1 + f2, jnp.maximum(m1 + f2, m2)

    _, m = jax.lax.associative_scan(combine, (f, i), axis=1)
    return m


def causal_conv1d(p, x):
    """Depthwise causal conv along time. x: [B, S, W]; p['w']: [K, W]."""
    k = p["w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pad[:, i: i + x.shape[1], :] * p["w"][i].astype(x.dtype)
    return out + p["b"].astype(x.dtype)


def conv1d_decode(p, x_new, conv_state):
    """Single-step causal conv. conv_state: [B, K-1, W] of past inputs."""
    k = p["w"].shape[0]
    window = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)  # [B,K,W]
    out = jnp.einsum("bkw,kw->bw", window, p["w"].astype(x_new.dtype))
    out = out + p["b"].astype(x_new.dtype)
    new_state = window[:, 1:, :]
    return out, new_state


# ---------------------------------------------------------------------------
# RG-LRU (Griffin)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def init_rglru_block(key, d_model: int, width: int, conv_k: int, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    s = d_model ** -0.5
    sw = width ** -0.5
    # Lambda init so that a = exp(-c*softplus(L)) lies in (0.9, 0.999)
    u = jax.random.uniform(ks[5], (width,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _RGLRU_C)).astype(dtype)
    return {
        "wx": _normal(ks[0], (d_model, width), s, dtype),
        "wgate": _normal(ks[1], (d_model, width), s, dtype),
        "conv": {"w": _normal(ks[2], (conv_k, width), 0.5, dtype),
                 "b": jnp.zeros((width,), dtype)},
        "wa": _normal(ks[3], (width, width), sw, dtype),
        "wi": _normal(ks[4], (width, width), sw, dtype),
        "lambda": lam,
        "wo": _normal(jax.random.fold_in(key, 7), (width, d_model), sw, dtype),
    }


def _rglru_coeffs(p, u, dt):
    r = jax.nn.sigmoid(u @ p["wa"].astype(dt))
    i = jax.nn.sigmoid(u @ p["wi"].astype(dt))
    log_a = (-_RGLRU_C * jax.nn.softplus(p["lambda"].astype(jnp.float32))
             * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    b = beta * (i.astype(jnp.float32) * u.astype(jnp.float32))
    return a, b


def apply_rglru_block(p, x, return_state: bool = False):
    """x: [B, S, D] -> [B, S, D] (full-sequence, associative scan)."""
    dt = x.dtype
    u_pre = x @ p["wx"].astype(dt)
    gate = jax.nn.gelu(x @ p["wgate"].astype(dt))
    u = causal_conv1d(p["conv"], u_pre)
    a, b = _rglru_coeffs(p, u, dt)
    h = _linear_scan(a, b).astype(dt)
    out = (h * gate) @ p["wo"].astype(dt)
    if return_state:
        k = p["conv"]["w"].shape[0]
        state = {"h": h[:, -1].astype(jnp.float32),
                 "conv": u_pre[:, -(k - 1):, :]}
        return out, state
    return out


def rglru_init_state(batch: int, width: int, conv_k: int, dtype):
    return {
        "h": jnp.zeros((batch, width), jnp.float32),
        "conv": jnp.zeros((batch, conv_k - 1, width), dtype),
    }


def apply_rglru_decode(p, x, state):
    """x: [B, 1, D]; state: {'h': [B, W] fp32, 'conv': [B, K-1, W]}."""
    dt = x.dtype
    u = x[:, 0] @ p["wx"].astype(dt)
    gate = jax.nn.gelu(x[:, 0] @ p["wgate"].astype(dt))
    u, conv_state = conv1d_decode(p["conv"], u, state["conv"])
    a, b = _rglru_coeffs(p, u[:, None], dt)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = (h.astype(dt) * gate) @ p["wo"].astype(dt)
    return y[:, None], {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory)
# ---------------------------------------------------------------------------

def init_mlstm_block(key, d_model: int, width: int, n_heads: int, conv_k: int,
                     dtype=jnp.float32):
    ks = jax.random.split(key, 9)
    s = d_model ** -0.5
    sw = width ** -0.5
    return {
        "w_up": _normal(ks[0], (d_model, width), s, dtype),
        "w_gate": _normal(ks[1], (d_model, width), s, dtype),
        "conv": {"w": _normal(ks[2], (conv_k, width), 0.5, dtype),
                 "b": jnp.zeros((width,), dtype)},
        "wq": _normal(ks[3], (width, width), sw, dtype),
        "wk": _normal(ks[4], (width, width), sw, dtype),
        "wv": _normal(ks[5], (width, width), sw, dtype),
        "w_if": _normal(ks[6], (width, 2 * n_heads), sw, dtype),
        "b_if": jnp.concatenate([jnp.zeros((n_heads,), dtype),
                                 3.0 * jnp.ones((n_heads,), dtype)]),
        "o_norm": {"scale": jnp.ones((width,), dtype)},
        "w_down": _normal(ks[7], (width, d_model), sw, dtype),
    }


def _mlstm_gates(p, u, n_heads: int):
    gif = (u @ p["w_if"].astype(u.dtype)).astype(jnp.float32) + p["b_if"].astype(jnp.float32)
    i_t = gif[..., :n_heads]          # log input gate (pre-exp)
    f_t = jax.nn.log_sigmoid(gif[..., n_heads:])  # log forget gate
    return i_t, f_t


def mlstm_sequence(q, k, v, i_t, f_t, chunk: int = 256,
                   return_state: bool = False):
    """Chunkwise-parallel mLSTM.

    q,k,v: [B, H, S, d]; i_t, f_t: [B, H, S] (log-space gates).
    Returns h: [B, H, S, d] (and the final (C, n, m) carry if asked).
    """
    b, h, s, d = q.shape
    q = q.astype(jnp.float32) / (d ** 0.5)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        i_t = jnp.pad(i_t, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        f_t = jnp.pad(f_t, ((0, 0), (0, 0), (0, pad)))
    qc = q.reshape(b, h, n_chunks, chunk, d)
    kc = k.reshape(b, h, n_chunks, chunk, d)
    vc = v.reshape(b, h, n_chunks, chunk, d)
    ic = i_t.reshape(b, h, n_chunks, chunk)
    fc = f_t.reshape(b, h, n_chunks, chunk)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, idx):
        C, n, m = carry  # [B,H,d,d], [B,H,d], [B,H]
        qb, kb, vb = qc[:, :, idx], kc[:, :, idx], vc[:, :, idx]
        ib, fb = ic[:, :, idx], fc[:, :, idx]
        bcum = jnp.cumsum(fb, axis=-1)  # inclusive log-forget prefix
        # intra-chunk log weights D[t, s] = bcum[t] - bcum[s] + i[s]
        D = bcum[..., :, None] - bcum[..., None, :] + ib[..., None, :]
        D = jnp.where(causal, D, -jnp.inf)
        m_intra = jnp.max(D, axis=-1)
        m_inter = m[..., None] + bcum
        m_t = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)
        w_intra = jnp.exp(D - m_t[..., None])
        scores = jnp.einsum("bhtd,bhsd->bhts", qb, kb) * w_intra
        num = jnp.einsum("bhts,bhsd->bhtd", scores, vb)
        den = jnp.sum(scores, axis=-1)
        c_inter = jnp.exp(m_inter - m_t)
        num = num + c_inter[..., None] * jnp.einsum("bhtd,bhdv->bhtv", qb, C)
        den = den + c_inter * jnp.einsum("bhtd,bhd->bht", qb, n)
        out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update to end of chunk
        btot = bcum[..., -1]
        decay = jnp.exp(btot[..., None] - bcum + ib)  # [B,H,T] (unstabilised log)
        m_new = jnp.maximum(m + btot, jnp.max(btot[..., None] - bcum + ib, axis=-1))
        scale_old = jnp.exp(m + btot - m_new)
        w_new = jnp.exp(btot[..., None] - bcum + ib - m_new[..., None])
        C_new = scale_old[..., None, None] * C + jnp.einsum(
            "bht,bhtd,bhtv->bhdv", w_new, kb, vb)
        n_new = scale_old[..., None] * n + jnp.einsum("bht,bhtd->bhd", w_new, kb)
        del decay
        return (C_new, n_new, m_new), out

    C0 = jnp.zeros((b, h, d, d), jnp.float32)
    n0 = jnp.zeros((b, h, d), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    carry, outs = jax.lax.scan(step, (C0, n0, m0), jnp.arange(n_chunks))
    out = jnp.moveaxis(outs, 0, 2).reshape(b, h, n_chunks * chunk, d)
    if return_state:
        C_f, n_f, m_f = carry
        return out[:, :, :s], {"C": C_f, "n": n_f, "m": m_f}
    return out[:, :, :s]


def mlstm_decode(q, k, v, i_t, f_t, state):
    """One step. q,k,v: [B,H,d]; i_t,f_t: [B,H]; state {C, n, m}."""
    C, n, m = state["C"], state["n"], state["m"]
    d = q.shape[-1]
    qf = q.astype(jnp.float32) / (d ** 0.5)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    m_new = jnp.maximum(f_t + m, i_t)
    sc_old = jnp.exp(f_t + m - m_new)
    sc_new = jnp.exp(i_t - m_new)
    C = sc_old[..., None, None] * C + sc_new[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n = sc_old[..., None] * n + sc_new[..., None] * kf
    num = jnp.einsum("bhd,bhdv->bhv", qf, C)
    den = jnp.einsum("bhd,bhd->bh", qf, n)
    out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return out, {"C": C, "n": n, "m": m_new}


def apply_mlstm_block(p, x, n_heads: int, chunk: int = 256,
                      return_state: bool = False):
    """Full mLSTM residual-block body. x: [B, S, D] -> [B, S, D]."""
    dt = x.dtype
    b, s, _ = x.shape
    u = x @ p["w_up"].astype(dt)
    gate = jax.nn.silu(x @ p["w_gate"].astype(dt))
    u_conv = causal_conv1d(p["conv"], u)
    uc = jax.nn.silu(u_conv)
    width = u.shape[-1]
    hd = width // n_heads
    q = (uc @ p["wq"].astype(dt)).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    k = (uc @ p["wk"].astype(dt)).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    v = (u @ p["wv"].astype(dt)).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    i_t, f_t = _mlstm_gates(p, uc, n_heads)
    i_t = i_t.transpose(0, 2, 1)
    f_t = f_t.transpose(0, 2, 1)
    res = mlstm_sequence(q, k, v, i_t, f_t, chunk=chunk,
                         return_state=return_state)
    h, state = res if return_state else (res, None)
    h = h.transpose(0, 2, 1, 3).reshape(b, s, width).astype(dt)
    h = apply_norm(p["o_norm"], h, "rmsnorm")
    out = (h * gate) @ p["w_down"].astype(dt)
    if return_state:
        kk = p["conv"]["w"].shape[0]
        state["conv"] = u[:, -(kk - 1):, :].astype(jnp.float32)
        return out, state
    return out


def mlstm_init_state(batch: int, width: int, n_heads: int, conv_k: int):
    hd = width // n_heads
    return {
        "C": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, conv_k - 1, width), jnp.float32),
    }


def apply_mlstm_decode(p, x, state, n_heads: int):
    dt = x.dtype
    b = x.shape[0]
    u = x[:, 0] @ p["w_up"].astype(dt)
    gate = jax.nn.silu(x[:, 0] @ p["w_gate"].astype(dt))
    uconv, conv_state = conv1d_decode(p["conv"], u.astype(jnp.float32),
                                      state["conv"])
    uc = jax.nn.silu(uconv).astype(dt)
    width = u.shape[-1]
    hd = width // n_heads
    q = (uc @ p["wq"].astype(dt)).reshape(b, n_heads, hd)
    k = (uc @ p["wk"].astype(dt)).reshape(b, n_heads, hd)
    v = (u @ p["wv"].astype(dt)).reshape(b, n_heads, hd)
    i_t, f_t = _mlstm_gates(p, uc, n_heads)
    h, new_inner = mlstm_decode(q, k, v, i_t, f_t, state)
    h = h.reshape(b, width).astype(dt)
    h = apply_norm(p["o_norm"], h, "rmsnorm")
    y = (h * gate) @ p["w_down"].astype(dt)
    new_inner["conv"] = conv_state
    return y[:, None], new_inner


# ---------------------------------------------------------------------------
# sLSTM (parallelizable approximation; gates input-driven)
# ---------------------------------------------------------------------------

def init_slstm_block(key, d_model: int, n_heads: int, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    s = d_model ** -0.5
    return {
        "wz": _normal(ks[0], (d_model, d_model), s, dtype),
        "wo_gate": _normal(ks[1], (d_model, d_model), s, dtype),
        "w_if": _normal(ks[2], (d_model, 2 * n_heads), s, dtype),
        "b_if": jnp.concatenate([jnp.zeros((n_heads,), dtype),
                                 3.0 * jnp.ones((n_heads,), dtype)]),
        "o_norm": {"scale": jnp.ones((d_model,), dtype)},
        "w_down": _normal(ks[3], (d_model, d_model), s, dtype),
    }


def _slstm_parts(p, x, n_heads: int):
    dt = x.dtype
    z = jnp.tanh(x @ p["wz"].astype(dt)).astype(jnp.float32)
    o = jax.nn.sigmoid(x @ p["wo_gate"].astype(dt)).astype(jnp.float32)
    gif = (x @ p["w_if"].astype(dt)).astype(jnp.float32) + p["b_if"].astype(jnp.float32)
    i_t = gif[..., :n_heads]
    f_t = jax.nn.log_sigmoid(gif[..., n_heads:])
    return z, o, i_t, f_t


def apply_slstm_block(p, x, n_heads: int, return_state: bool = False):
    """x: [B, S, D] -> [B, S, D]; three associative scans (m, c, n)."""
    b, s, d = x.shape
    hd = d // n_heads
    z, o, i_t, f_t = _slstm_parts(p, x, n_heads)
    m = _maxplus_scan(f_t, i_t)  # [B,S,H]
    m_prev = jnp.concatenate(
        [jnp.full((b, 1, n_heads), -1e30, jnp.float32), m[:, :-1]], axis=1)
    a = jnp.exp(jnp.clip(f_t + m_prev - m, -60.0, 0.0))
    w_in = jnp.exp(i_t - m)
    zz = z.reshape(b, s, n_heads, hd)
    c = _linear_scan(a[..., None], w_in[..., None] * zz)
    n = _linear_scan(a, w_in)
    h = c / jnp.maximum(n[..., None], 1e-6)
    hflat = (o.reshape(b, s, n_heads, hd) * h).reshape(b, s, d).astype(x.dtype)
    hflat = apply_norm(p["o_norm"], hflat, "rmsnorm")
    out = hflat @ p["w_down"].astype(x.dtype)
    if return_state:
        return out, {"c": c[:, -1], "n": n[:, -1], "m": m[:, -1]}
    return out


def slstm_init_state(batch: int, d_model: int, n_heads: int):
    hd = d_model // n_heads
    return {
        "c": jnp.zeros((batch, n_heads, hd), jnp.float32),
        "n": jnp.zeros((batch, n_heads), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
    }


def apply_slstm_decode(p, x, state, n_heads: int):
    b, _, d = x.shape
    hd = d // n_heads
    z, o, i_t, f_t = _slstm_parts(p, x[:, 0:1], n_heads)
    z, o, i_t, f_t = z[:, 0], o[:, 0], i_t[:, 0], f_t[:, 0]
    m_new = jnp.maximum(f_t + state["m"], i_t)
    a = jnp.exp(f_t + state["m"] - m_new)
    w_in = jnp.exp(i_t - m_new)
    c = a[..., None] * state["c"] + w_in[..., None] * z.reshape(b, n_heads, hd)
    n = a * state["n"] + w_in
    h = c / jnp.maximum(n[..., None], 1e-6)
    h = (o.reshape(b, n_heads, hd) * h).reshape(b, d).astype(x.dtype)
    h = apply_norm(p["o_norm"], h, "rmsnorm")
    y = h @ p["w_down"].astype(x.dtype)
    return y[:, None], {"c": c, "n": n, "m": m_new}
