"""Boosting loop with adaptive early stopping (paper §3.4).

``fit_boosted`` fits one ensemble (single- or multi-output) with a
``lax.while_loop`` so training actually stops when the fresh-noise validation
loss stalls for ``early_stop_rounds`` rounds — the compute saving the paper
reports (up to 3x). Per-ensemble best-round masking makes the packed model
identical to one trained with exact per-ensemble stopping.

Warm starting (the incremental freshness loop): boosting is additive, so a
model trained to round R extends to round R + K without recomputing the
first R rounds. ``warm=`` seeds the round buffers from a previous
:class:`BoostResult` and *replays* the saved trees on the raw (pre-binning)
inputs to reconstruct the running train/val predictions — exact, because
``repro.forest.binning.transform`` guarantees ``code > b  <=>
x > edges[:, b]``, so raw-value traversal routes every row to the same leaf
the in-loop code-space routing did. Rounds past ``best_round`` were masked
to zero leaves by the early-stopping packer; the warm loop simply restarts
at ``best_round + 1`` and re-grows them (deterministic, hence bit-identical
to the original), which is at most ``early_stop_rounds - 1`` rounds of
extra compute. The net contract, asserted in tests: a warm-started run to
R + K equals a cold run to R + K bit for bit.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.config import ForestConfig
from repro.forest.tree import (Tree, grow_tree, predict_tree_codes,
                               predict_tree_values)


class BoostResult(NamedTuple):
    feat: jnp.ndarray       # [T, H] int32
    thr_val: jnp.ndarray    # [T, H] fp32
    leaf: jnp.ndarray       # [T, L, out] fp32 (rounds past best are zeroed)
    best_round: jnp.ndarray  # [] int32 (index of best validation round)
    rounds_run: jnp.ndarray  # [] int32
    val_curve: jnp.ndarray   # [T] fp32 (inf for rounds not run)


def _wmse(pred, tgt, w, axis_names: Sequence[str]):
    num = jnp.sum(w[:, None] * jnp.square(pred - tgt))
    den = jnp.sum(w) * tgt.shape[1]
    for ax in axis_names:
        num = jax.lax.psum(num, ax)
        den = jax.lax.psum(den, ax)
    return num / jnp.maximum(den, 1e-12)


def fit_boosted(codes, tgt, w, edges_sentinel, val_codes, val_tgt, val_w,
                fcfg: ForestConfig, axis_names: Sequence[str] = (),
                scatter_shards: int = 0, *, warm=None, x_raw=None,
                val_raw=None) -> BoostResult:
    """codes/val_codes: [n, p] int; tgt/val_tgt: [n, out]; w: [n] weights.

    ``warm = (feat [R, H], thr_val [R, H], leaf [R, L, out], val_curve [R],
    best_round [])`` continues a previous run (same data, edges, and config
    up to ``n_trees``): the saved rounds seed the buffers, the running
    predictions are rebuilt by replaying the trees on ``x_raw`` /
    ``val_raw`` (the *raw* pre-binning inputs the codes were quantised
    from), and the loop restarts at ``best_round + 1`` — re-growing any
    early-stop-masked tail rounds identically before appending new ones.
    """
    n, p = codes.shape
    out = tgt.shape[1]
    T, depth = fcfg.n_trees, fcfg.max_depth
    H, L = 2 ** depth - 1, 2 ** depth
    es = fcfg.early_stop_rounds

    feat_buf = jnp.zeros((T, H), jnp.int32)
    thr_buf = jnp.full((T, H), jnp.inf, jnp.float32)
    leaf_buf = jnp.zeros((T, L, out), jnp.float32)
    vcurve = jnp.full((T,), jnp.inf, jnp.float32)

    def cond(state):
        r = state[0]
        ok = r < T
        if es > 0:
            ok = ok & (state[6] < es)
        return ok

    def body(state):
        (r, pred, vpred, best_loss, best_r, bufs, patience, vc) = state
        feat_b, thr_b, leaf_b = bufs
        g = pred - tgt
        tree, node_id = grow_tree(
            codes, g, w, edges_sentinel, depth=depth, n_bins=fcfg.n_bins,
            reg_lambda=fcfg.reg_lambda, min_child_weight=fcfg.min_child_weight,
            learning_rate=fcfg.learning_rate, axis_names=axis_names,
            scatter_shards=scatter_shards, hist_bf16=fcfg.hist_bf16)
        pred = pred + tree.leaf[node_id]
        vpred = vpred + predict_tree_codes(val_codes, tree, depth)
        vloss = _wmse(vpred, val_tgt, val_w, axis_names)
        improved = vloss < best_loss
        best_loss = jnp.minimum(vloss, best_loss)
        best_r = jnp.where(improved, r, best_r)
        patience = jnp.where(improved, 0, patience + 1)
        feat_b = jax.lax.dynamic_update_slice(feat_b, tree.feat[None], (r, 0))
        thr_b = jax.lax.dynamic_update_slice(thr_b, tree.thr_val[None], (r, 0))
        leaf_b = jax.lax.dynamic_update_slice(leaf_b, tree.leaf[None], (r, 0, 0))
        vc = vc.at[r].set(vloss)
        return (r + 1, pred, vpred, best_loss, best_r,
                (feat_b, thr_b, leaf_b), patience, vc)

    if warm is None:
        state = (jnp.int32(0),
                 jnp.zeros((n, out), jnp.float32),
                 jnp.zeros((val_codes.shape[0], out), jnp.float32),
                 jnp.float32(jnp.inf), jnp.int32(0),
                 (feat_buf, thr_buf, leaf_buf), jnp.int32(0), vcurve)
    else:
        if x_raw is None or val_raw is None:
            raise ValueError("warm start needs x_raw/val_raw (the raw rows "
                             "the codes were quantised from) to replay the "
                             "saved trees")
        wf, wt, wl, wvc, wbr = warm
        R0 = wf.shape[0]
        if R0 > T:
            raise ValueError(f"warm state has {R0} rounds but "
                             f"n_trees={T}; extension needs n_trees > the "
                             "base model's round count")
        feat_buf = feat_buf.at[:R0].set(wf.astype(jnp.int32))
        thr_buf = thr_buf.at[:R0].set(wt.astype(jnp.float32))
        leaf_buf = leaf_buf.at[:R0].set(wl.astype(jnp.float32))
        vcurve = vcurve.at[:R0].set(wvc.astype(jnp.float32))
        wbr = wbr.astype(jnp.int32)

        def _replay(r, carry):
            # same leaf array, same routing (transform's strict-less-count
            # contract makes raw-value traversal == code-space routing),
            # same sequential f32 accumulation order as the original loop
            p_acc, vp_acc = carry
            p_acc = p_acc + predict_tree_values(
                x_raw, feat_buf[r], thr_buf[r], leaf_buf[r], depth)
            vp_acc = vp_acc + predict_tree_values(
                val_raw, feat_buf[r], thr_buf[r], leaf_buf[r], depth)
            return p_acc, vp_acc

        pred0, vpred0 = jax.lax.fori_loop(
            0, wbr + 1, _replay,
            (jnp.zeros((n, out), jnp.float32),
             jnp.zeros((val_raw.shape[0], out), jnp.float32)))
        # exact loop state at r = best_round + 1: the improving round set
        # best_loss to its own val loss and zeroed patience; masked rounds
        # past best_round re-grow deterministically from here
        state = (wbr + 1, pred0, vpred0, wvc[wbr], wbr,
                 (feat_buf, thr_buf, leaf_buf), jnp.int32(0), vcurve)
    state = jax.lax.while_loop(cond, body, state)
    rounds_run, _, _, _, best_r, bufs, _, vc = state
    feat_b, thr_b, leaf_b = bufs
    if es > 0:
        keep = (jnp.arange(T) <= best_r)[:, None, None]
        leaf_b = jnp.where(keep, leaf_b, 0.0)
    else:
        best_r = rounds_run - 1
    return BoostResult(feat_b, thr_b, leaf_b, best_r, rounds_run, vc)


def fit_ensemble(codes, tgt, w, edges_sentinel, val_codes, val_tgt, val_w,
                 fcfg: ForestConfig, axis_names: Sequence[str] = (),
                 scatter_shards: int = 0, *, warm=None, x_raw=None,
                 val_raw=None):
    """SO: vmap scalar-output boosting over the p outputs (shared codes);
    MO: one vector-leaf boosting run.

    Returns BoostResult with leading sub-ensemble dim:
      MO: feat [1, T, H],  leaf [1, T, L, out]
      SO: feat [out, T, H], leaf [out, T, L, 1]

    ``warm`` carries the previous :class:`BoostResult` arrays *with* the
    sub-ensemble leading dim (``feat [n_sub, R, H]``, ..., ``best_round
    [n_sub]``); ``x_raw``/``val_raw`` are the shared raw inputs every
    sub-ensemble replays its saved trees on (see :func:`fit_boosted`).
    """
    if fcfg.multi_output:
        w1 = None if warm is None else tuple(a[0] for a in warm)
        res = fit_boosted(codes, tgt, w, edges_sentinel, val_codes, val_tgt,
                          val_w, fcfg, axis_names, scatter_shards,
                          warm=w1, x_raw=x_raw, val_raw=val_raw)
        return jax.tree_util.tree_map(lambda a: a[None], res)

    if warm is None:
        def one(t_col, v_col):
            return fit_boosted(codes, t_col[:, None], w, edges_sentinel,
                               val_codes, v_col[:, None], val_w, fcfg,
                               axis_names, scatter_shards)

        return jax.vmap(one, in_axes=(1, 1))(tgt, val_tgt)

    def one_warm(t_col, v_col, wsub):
        return fit_boosted(codes, t_col[:, None], w, edges_sentinel,
                           val_codes, v_col[:, None], val_w, fcfg,
                           axis_names, scatter_shards, warm=wsub,
                           x_raw=x_raw, val_raw=val_raw)

    return jax.vmap(one_warm, in_axes=(1, 1, 0))(tgt, val_tgt, warm)
