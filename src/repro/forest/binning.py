"""Quantile binning — the QuantileDMatrix analogue.

``fit_bins`` computes per-feature quantile edges once; ``transform`` turns raw
features into small integer bin codes (int8 when n_bins <= 128). Downstream
training touches only the codes: 4-8x smaller than fp32 features, computed
on-the-fly per ensemble from (X0, X1) so the [n_t, nK, p] array of noised
inputs is never materialised (paper Issue 1 / App. B.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fit_bins(x, n_bins: int):
    """Per-feature quantile edges.

    x: [n, p]. Returns edges [p, n_bins - 1] (ascending; code = #edges < x).
    Matches XGBoost sketch semantics closely enough for distribution metrics.
    """
    qs = jnp.linspace(0.0, 1.0, n_bins + 1)[1:-1]
    edges = jnp.quantile(x, qs, axis=0).T  # [p, n_bins-1]
    return edges.astype(jnp.float32)


def fit_bins_streaming(X, n_bins: int, *, max_entries: int = 2048,
                       row_chunk: int = 65536):
    """Out-of-core twin of :func:`fit_bins`: per-feature quantile edges
    without ever sorting (or even materialising) a full column.

    ``X`` is fed in row chunks through a mergeable
    :class:`repro.data.sketch.QuantileSketch`; a
    :class:`repro.data.store.DatasetStore` short-circuits to the sketch its
    ingest already built, so the edges cost one manifest read. Exact
    (bit-equal to ``fit_bins``) while the data has at most ``max_entries``
    rows; bounded-rank-error approximate beyond that.
    """
    sketch = getattr(X, "sketch", None)   # DatasetStore: precomputed
    if sketch is None:
        from repro.data.sketch import sketch_dataset
        sketch = sketch_dataset(X, max_entries=max_entries,
                                row_chunk=row_chunk)
    return jnp.asarray(sketch.edges(n_bins, mode="linear"))


def transform(x, edges):
    """Bin codes: code[i, j] = number of edges strictly below x[i, j].

    Returns int32 in [0, n_bins - 1]. ``code > b``  <=>  ``x > edges[:, b]``.
    Uses per-feature searchsorted so no [n, p, n_bins] temporary is built
    (the binning-time version of the paper's memory discipline).
    """
    def per_feature(col, e):
        return jnp.searchsorted(e, col, side="left")

    return jax.vmap(per_feature, in_axes=(1, 0), out_axes=1)(x, edges).astype(
        jnp.int32)


def pack_codes(codes, n_bins: int):
    """Store codes at the narrowest dtype (int8 when it fits)."""
    if n_bins <= 127:
        return codes.astype(jnp.int8)
    if n_bins <= 32767:
        return codes.astype(jnp.int16)
    return codes


def edges_with_sentinel(edges):
    """Append +inf so thr_bin == n_bins - 1 means 'never go right'."""
    p = edges.shape[0]
    inf = jnp.full((p, 1), jnp.inf, edges.dtype)
    return jnp.concatenate([edges, inf], axis=1)  # [p, n_bins]
