"""Level-wise tree growth with static shapes (TPU-native XGBoost `hist`).

Trees are grown breadth-first to a fixed depth; per-sample state is a single
int32 node id, histogram accumulation is a segment-sum (Pallas one-hot matmul
on TPU), and split selection is a tiny replicated reduction. Heap layout:
internal node h has children 2h+1 / 2h+2; leaves are node_id in [0, 2^depth).

In the paper's operating regime (depth 7, no regularisation) XGBoost trees are
max-size anyway (§3.3 Benefit 3), so fixed-depth growth is faithful; gain-gated
sentinel splits reproduce don't-split behaviour where it matters.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.forest.hist import build_histogram
from repro.forest.split import best_splits


class Tree(NamedTuple):
    feat: jnp.ndarray      # [2^depth - 1] int32 (heap order)
    thr_bin: jnp.ndarray   # [2^depth - 1] int32
    thr_val: jnp.ndarray   # [2^depth - 1] fp32 (raw-value thresholds, +inf sentinel)
    leaf: jnp.ndarray      # [2^depth, out] fp32 (already learning-rate scaled)


def _reduced_best_splits(sum_g, count, reg_lambda, min_child_weight,
                         axis_names: Sequence[str], scatter_shards: int,
                         hist_bf16: bool):
    """Cross-device histogram reduction + split search.

    scatter_shards == 0: classic all-reduce of the full histogram, replicated
    split search (distributed XGBoost / Rabit semantics).

    scatter_shards > 0: reduce-scatter over the FEATURE dim on the innermost
    data axis — each shard owns p/shards features, finds its local best
    split, and only tiny (gain, feat, thr) triples are combined. Halves the
    collective payload (RS vs AR is 1x vs 2x size) and shards the split-search
    compute (LightGBM's data+feature "voting parallel" idea). §Perf cell C.
    """
    if hist_bf16:
        sum_g = sum_g.astype(jnp.bfloat16)
        count = count.astype(jnp.bfloat16)
    if not scatter_shards or not axis_names:
        for ax in axis_names:
            sum_g = jax.lax.psum(sum_g, ax)
            count = jax.lax.psum(count, ax)
        return best_splits(sum_g.astype(jnp.float32),
                           count.astype(jnp.float32),
                           reg_lambda, min_child_weight)
    ax = axis_names[-1]
    for a in axis_names[:-1]:
        sum_g = jax.lax.psum(sum_g, a)
        count = jax.lax.psum(count, a)
    nodes, p, bins = count.shape
    p_pad = -(-p // scatter_shards) * scatter_shards
    if p_pad != p:
        sum_g = jnp.pad(sum_g, ((0, 0), (0, p_pad - p), (0, 0), (0, 0)))
        count = jnp.pad(count, ((0, 0), (0, p_pad - p), (0, 0)))
    sum_g = jax.lax.psum_scatter(sum_g, ax, scatter_dimension=1, tiled=True)
    count = jax.lax.psum_scatter(count, ax, scatter_dimension=1, tiled=True)
    feat_l, thr_l, gain_l = best_splits(sum_g.astype(jnp.float32),
                                        count.astype(jnp.float32),
                                        reg_lambda, min_child_weight)
    p_loc = p_pad // scatter_shards
    feat_g = feat_l + jax.lax.axis_index(ax) * p_loc
    packed = jnp.stack([gain_l, feat_g.astype(jnp.float32),
                        thr_l.astype(jnp.float32)], axis=-1)  # [nodes, 3]
    allp = jax.lax.all_gather(packed, ax)                     # [shards,nodes,3]
    best = jnp.argmax(allp[..., 0], axis=0)                   # [nodes]
    sel = jnp.take_along_axis(allp, best[None, :, None], axis=0)[0]
    feat = jnp.clip(sel[:, 1].astype(jnp.int32), 0, p - 1)
    thr = sel[:, 2].astype(jnp.int32)
    gain = sel[:, 0]
    dead = ~(gain > 0.0)
    feat = jnp.where(dead, 0, feat)
    thr = jnp.where(dead, bins - 1, thr)
    return feat, thr, jnp.where(dead, 0.0, gain)


def grow_tree(codes, g, w, edges_sentinel, *, depth: int, n_bins: int,
              reg_lambda: float, min_child_weight: float, learning_rate: float,
              axis_names: Sequence[str] = (), scatter_shards: int = 0,
              hist_bf16: bool = False):
    """Fit one regression tree on gradients g (vector-valued for MO).

    codes: [n, p] int; g: [n, out] fp32; w: [n] fp32 sample weights;
    edges_sentinel: [p, n_bins] fp32 raw-value bin edges (+inf last).
    Returns (Tree, node_id [n] int32 leaf assignment).
    """
    n, p = codes.shape
    n_heap = 2 ** depth - 1
    feat_heap = jnp.zeros((n_heap,), jnp.int32)
    thr_heap = jnp.full((n_heap,), n_bins - 1, jnp.int32)
    node_id = jnp.zeros((n,), jnp.int32)

    for level in range(depth):
        n_nodes = 2 ** level
        sum_g, count = build_histogram(codes, node_id, g, w, n_nodes, n_bins,
                                       axis_names=())
        feat_l, thr_l, _ = _reduced_best_splits(
            sum_g, count, reg_lambda, min_child_weight, axis_names,
            scatter_shards, hist_bf16)
        lo = 2 ** level - 1
        feat_heap = feat_heap.at[lo:lo + n_nodes].set(feat_l)
        thr_heap = thr_heap.at[lo:lo + n_nodes].set(thr_l)
        f_i = feat_l[node_id]                                  # [n]
        c_i = jnp.take_along_axis(codes.astype(jnp.int32), f_i[:, None],
                                  axis=1)[:, 0]
        go_right = c_i > thr_l[node_id]
        node_id = node_id * 2 + go_right.astype(jnp.int32)

    # leaf values: Newton step -G/(H + lambda), lr-scaled
    n_leaves = 2 ** depth
    leaf_g = jax.ops.segment_sum(g * w[:, None], node_id,
                                 num_segments=n_leaves)
    leaf_h = jax.ops.segment_sum(w, node_id, num_segments=n_leaves)
    for ax in axis_names:
        leaf_g = jax.lax.psum(leaf_g, ax)
        leaf_h = jax.lax.psum(leaf_h, ax)
    leaf = -learning_rate * leaf_g / (leaf_h[:, None] + reg_lambda + 1e-12)
    thr_val = edges_sentinel[feat_heap, thr_heap]
    return Tree(feat_heap, thr_heap, thr_val, leaf), node_id


def predict_tree_codes(codes, tree: Tree, depth: int):
    """Traverse by bin codes (training-time). Returns [n, out]."""
    n = codes.shape[0]
    node = jnp.zeros((n,), jnp.int32)
    for level in range(depth):
        heap = node + (2 ** level - 1)
        f = tree.feat[heap]
        t = tree.thr_bin[heap]
        c = jnp.take_along_axis(codes.astype(jnp.int32), f[:, None], axis=1)[:, 0]
        node = node * 2 + (c > t).astype(jnp.int32)
    return tree.leaf[node]


def predict_tree_values(x, feat, thr_val, leaf, depth: int):
    """Traverse by raw values (generation-time). x: [n, p]. Returns [n, out]."""
    n = x.shape[0]
    node = jnp.zeros((n,), jnp.int32)
    for level in range(depth):
        heap = node + (2 ** level - 1)
        f = feat[heap]
        t = thr_val[heap]
        c = jnp.take_along_axis(x, f[:, None], axis=1)[:, 0]
        node = node * 2 + (c > t).astype(jnp.int32)
    return leaf[node]
