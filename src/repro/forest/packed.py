"""Packed forests: stacked tree arrays + vectorised inference.

The packed layout (feat/thr/leaf arrays with leading [n_sub, T] dims) is what
the Pallas ``tree_predict`` kernel consumes; ``predict_forest`` here is the
XLA/ref path. One packed forest represents one (timestep, class) ensemble;
the generator stacks them further to [n_t, ...] for the ODE/SDE solve.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.forest.tree import predict_tree_values


class PackedForest(NamedTuple):
    feat: jnp.ndarray      # [n_sub, T, H] int32
    thr_val: jnp.ndarray   # [n_sub, T, H] fp32
    leaf: jnp.ndarray      # [n_sub, T, L, out_sub] fp32
    multi_output: bool     # static


def from_boost_result(res, multi_output: bool) -> PackedForest:
    return PackedForest(res.feat, res.thr_val, res.leaf, multi_output)


def predict_forest(x, forest: PackedForest, depth: int):
    """x: [n, p] raw feature values. Returns [n, p_out]."""

    def sub_predict(feat, thr, leaf):
        def tree_step(acc, tr):
            f, t, l = tr
            return acc + predict_tree_values(x, f, t, l, depth), None

        acc0 = jnp.zeros((x.shape[0], leaf.shape[-1]), jnp.float32)
        acc, _ = jax.lax.scan(tree_step, acc0, (feat, thr, leaf))
        return acc

    out = jax.vmap(sub_predict)(forest.feat, forest.thr_val, forest.leaf)
    if forest.multi_output:
        return out[0]                      # [n, p_out]
    return jnp.transpose(out[:, :, 0])     # SO: [p_out, n, 1] -> [n, p_out]
