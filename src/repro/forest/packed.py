"""Packed forests: stacked tree arrays + vectorised inference.

The packed layout (feat/thr/leaf arrays with leading [n_sub, T] dims) is what
the Pallas ``tree_predict`` kernel consumes; ``predict_forest`` here routes
every traversal through :func:`repro.kernels.tree_predict.ops.forest_predict`
— one dispatch point, switchable between the XLA reference scan and the
Pallas kernel per call (``impl=`` | ``ForestConfig.predict_impl`` |
``REPRO_TREE_PREDICT_IMPL``) — so samplers, imputation, and serving all
inherit the kernel without their own plumbing. One packed forest represents
one (timestep, class) ensemble; the generator stacks them further to
[n_t, ...] for the ODE/SDE solve.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.tree_predict.ops import forest_predict


class PackedForest(NamedTuple):
    feat: jnp.ndarray      # [n_sub, T, H] int32
    thr_val: jnp.ndarray   # [n_sub, T, H] fp32
    leaf: jnp.ndarray      # [n_sub, T, L, out_sub] fp32
    multi_output: bool     # static


def from_boost_result(res, multi_output: bool) -> PackedForest:
    return PackedForest(res.feat, res.thr_val, res.leaf, multi_output)


def predict_forest(x, forest: PackedForest, depth: int,
                   impl: Optional[str] = None):
    """x: [n, p] raw feature values. Returns [n, p_out].

    ``impl`` selects the traversal backend (resolved per call; the Pallas
    kernel is vmapped over the ``n_sub`` sub-ensembles exactly like the
    reference scan, so both paths see identical shapes).
    """

    def sub_predict(feat, thr, leaf):
        return forest_predict(x, feat, thr, leaf, depth, impl=impl)

    out = jax.vmap(sub_predict)(forest.feat, forest.thr_val, forest.leaf)
    if forest.multi_output:
        return out[0]                      # [n, p_out]
    return jnp.transpose(out[:, :, 0])     # SO: [p_out, n, 1] -> [n, p_out]
