"""Best-split search from histograms (second-order boosting gain).

For squared-error boosting the hessian is 1, so H == the accumulated sample
weight. Multi-output trees (Zhang & Jung, GBDT-MO) sum the gain over outputs
and share one split structure — this is what makes MO trees p-times cheaper
at generation and better at joint structure (paper §3.4).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def best_splits(sum_g, count, reg_lambda: float, min_child_weight: float
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pick the best (feature, bin) per node.

    sum_g: [nodes, p, bins, out]; count: [nodes, p, bins].
    Returns (feat [nodes] int32, thr_bin [nodes] int32, gain [nodes] fp32).
    Nodes whose best gain <= 0 get thr_bin = n_bins - 1 (the +inf sentinel:
    every sample routes left — the static-shape analogue of not splitting).
    """
    nodes, p, bins, out = sum_g.shape
    gl = jnp.cumsum(sum_g, axis=2)          # left sums for split at bin b
    hl = jnp.cumsum(count, axis=2)
    gt = gl[:, :, -1:, :]
    ht = hl[:, :, -1:]
    gr = gt - gl
    hr = ht - hl

    def score(g2, h):
        return jnp.sum(jnp.square(g2), axis=-1) / (h + reg_lambda + 1e-12)

    gain = score(gl, hl) + score(gr, hr) - score(gt, ht)  # [nodes, p, bins]
    valid = (hl >= min_child_weight) & (hr >= min_child_weight)
    gain = jnp.where(valid, gain, -jnp.inf)
    flat = gain.reshape(nodes, p * bins)
    best = jnp.argmax(flat, axis=1)
    best_gain = jnp.take_along_axis(flat, best[:, None], axis=1)[:, 0]
    feat = (best // bins).astype(jnp.int32)
    thr = (best % bins).astype(jnp.int32)
    dead = ~(best_gain > 0.0)
    feat = jnp.where(dead, 0, feat)
    thr = jnp.where(dead, bins - 1, thr)
    return feat, thr, jnp.where(dead, 0.0, best_gain)
