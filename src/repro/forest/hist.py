"""Gradient/count histogram accumulation — the XGBoost ``hist`` hot spot.

``build_histogram`` is the pure-jnp implementation (segment-sum per feature).
On TPU the Pallas kernel in ``repro/kernels/hist`` implements the same
contract as a one-hot MXU matmul; ``repro.kernels.hist.ops.histogram``
dispatches between them.

``axis_names`` turns this into the *distributed* histogram: rows are sharded
across the named mesh axes and partial histograms are psum'd — exactly
XGBoost's Rabit allreduce-of-histograms, expressed as a JAX collective.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_impl


def build_histogram(codes, node_id, g, w, n_nodes: int, n_bins: int,
                    axis_names: Sequence[str] = (),
                    impl: Optional[str] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Accumulate per-(node, feature, bin) gradient sums and weights.

    codes: [n, p] int; node_id: [n] int32; g: [n, out] fp32; w: [n] fp32.
    Returns (sum_g [n_nodes, p, n_bins, out], count [n_nodes, p, n_bins]).

    ``impl`` ('xla' | 'pallas' | 'pallas_interpret'; TPU runs set
    REPRO_HIST_IMPL=pallas) is resolved per call — setting the env var after
    import works, unlike the old module-level snapshot. Inside an
    already-compiled trainer the choice is baked in at trace time.
    """
    impl = resolve_impl(impl, env_var="REPRO_HIST_IMPL")
    if impl != "xla":
        from repro.kernels.hist.hist_kernel import histogram_pallas
        sums, cnt = histogram_pallas(codes, node_id, g, w, n_nodes, n_bins,
                                     interpret=(impl == "pallas_interpret"))
        for ax in axis_names:
            sums = jax.lax.psum(sums, ax)
            cnt = jax.lax.psum(cnt, ax)
        return sums, cnt
    n, p = codes.shape
    seg_base = node_id.astype(jnp.int32) * n_bins

    def per_feature(codes_j):
        seg = seg_base + codes_j.astype(jnp.int32)
        sums = jax.ops.segment_sum(g * w[:, None], seg,
                                   num_segments=n_nodes * n_bins)
        cnt = jax.ops.segment_sum(w, seg, num_segments=n_nodes * n_bins)
        return sums.reshape(n_nodes, n_bins, -1), cnt.reshape(n_nodes, n_bins)

    sums, cnt = jax.vmap(per_feature, in_axes=1, out_axes=1)(codes)
    # sums: [n_nodes, p, n_bins, out]; cnt: [n_nodes, p, n_bins]
    for ax in axis_names:
        sums = jax.lax.psum(sums, ax)
        cnt = jax.lax.psum(cnt, ax)
    return sums, cnt
