"""Distributed ForestFlow training under shard_map.

Layout (the TPU-native version of the paper's joblib pool, DESIGN.md §2):

* rows of (X0, w) are sharded across the ``data`` mesh axes (and ``pod``);
* the (timestep, class) ensemble grid is sharded across the ``model`` axis —
  each model-axis slice trains its own ensembles on the *same* row shards;
* histogram accumulation psums partial [nodes, p, bins] histograms over the
  data axes — exactly distributed XGBoost's allreduce, as a JAX collective;
* bin edges come from a gathered per-device subsample (the distributed
  quantile-sketch approximation).

Class conditioning is weight-masking: ensemble e has per-row weight
``w * (class_id == y_e)`` so row shards never need class-sorted layouts.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ForestConfig
from repro.core import interpolants as itp
from repro.forest.binning import (edges_with_sentinel, pack_codes,
                                  transform)
from repro.forest.boosting import fit_ensemble


def _sketch_edges(xt, w, n_bins: int, data_axes: Sequence[str],
                  sketch_rows: int = 2048):
    """Approximate global quantile edges from a gathered subsample."""
    take = min(sketch_rows, xt.shape[0])
    sample = xt[:take]
    sw = w[:take]
    for ax in data_axes:
        sample = jax.lax.all_gather(sample, ax, axis=0, tiled=True)
        sw = jax.lax.all_gather(sw, ax, axis=0, tiled=True)
    big = jnp.where(sw[:, None] > 0, sample, jnp.inf)
    s = jnp.sort(big, axis=0)
    n_real = jnp.sum(sw > 0).astype(jnp.float32)
    qs = jnp.arange(1, n_bins, dtype=jnp.float32) / n_bins
    idx = jnp.clip((qs * (n_real - 1.0)).astype(jnp.int32), 0, s.shape[0] - 1)
    return jnp.transpose(s[idx])


def _fit_one_sharded(x0, w, class_id, t, y_e, key2, fcfg: ForestConfig,
                     data_axes: Tuple[str, ...], scatter_shards: int = 0):
    """Train one (t, y) ensemble on this device's row shard (+collectives)."""
    K = fcfg.duplicate_k
    x0d = jnp.repeat(x0, K, axis=0)
    wd = jnp.repeat(w * (class_id == y_e).astype(jnp.float32), K, axis=0)
    # decorrelate noise across row shards: fold the data-axis coordinates in
    shard_id = jnp.int32(0)
    for ax in data_axes:
        shard_id = shard_id * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    k_tr = jax.random.fold_in(key2[0], shard_id)
    k_va = jax.random.fold_in(key2[1], shard_id)
    # sample_bridge splits each key so the CFM jitter is decorrelated from
    # x1 (one key for both draws made the jitter exactly sigma * x1)
    _, xt, tgt = itp.sample_bridge(k_tr, x0d, fcfg.method, t, fcfg.sigma)
    edges = _sketch_edges(xt, wd, fcfg.n_bins, data_axes)
    codes = transform(xt, edges)
    _, xtv, tgtv = itp.sample_bridge(k_va, x0d, fcfg.method, t, fcfg.sigma)
    codes_v = transform(xtv, edges)
    if fcfg.int8_codes:   # QuantileDMatrix-style narrow storage
        codes = pack_codes(codes, fcfg.n_bins)
        codes_v = pack_codes(codes_v, fcfg.n_bins)
    return fit_ensemble(codes, tgt, wd, edges_with_sentinel(edges),
                        codes_v, tgtv, wd, fcfg, axis_names=data_axes,
                        scatter_shards=scatter_shards)


def make_distributed_fit(mesh: Mesh, fcfg: ForestConfig,
                         data_axes: Tuple[str, ...] = ("data",),
                         model_axis: str = "model"):
    """Build the jitted shard_map trainer.

    Returned fn signature:
      fn(x0 [n, p], w [n], class_id [n], ts [n_ens], ys [n_ens],
         keys [n_ens, 2] PRNG keys) -> BoostResult stacked over n_ens.
    n must divide by prod(data axes); n_ens by the model axis.
    """

    shards = (dict(zip(mesh.axis_names, mesh.devices.shape))[data_axes[-1]]
              if fcfg.split_reduce == "reduce_scatter" else 0)

    def per_device(x0, w, cid, ts, ys, keys):
        fit = functools.partial(_fit_one_sharded, x0, w, cid,
                                fcfg=fcfg, data_axes=data_axes,
                                scatter_shards=shards)
        # sequential map over local ensembles: one set of codes live at a
        # time (the Issue-1 memory discipline under sharding)
        return jax.lax.map(lambda tyk: fit(tyk[0], tyk[1], tyk[2]),
                           (ts, ys, keys))

    row_spec = P(data_axes)
    ens_spec = P(model_axis)
    try:
        from jax import shard_map  # jax >= 0.6
        replication_kw = {"check_vma": False}
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map
        replication_kw = {"check_rep": False}  # pre-0.6 spelling
    mapped = shard_map(
        per_device, mesh=mesh,
        in_specs=(row_spec, row_spec, row_spec, ens_spec, ens_spec,
                  P(model_axis, None, None)),
        out_specs=jax.tree_util.tree_map(lambda _: P(model_axis), _result_spec()),
        **replication_kw)
    return jax.jit(mapped)


def _result_spec():
    """Tree prototype matching BoostResult for out_specs construction."""
    from repro.forest.boosting import BoostResult
    return BoostResult(0, 0, 0, 0, 0, 0)


def input_specs_forest(fcfg: ForestConfig, n_rows: int, p: int, n_ens: int):
    """ShapeDtypeStructs for the distributed-forest dry-run."""
    sds = jax.ShapeDtypeStruct
    return (
        sds((n_rows, p), jnp.float32),       # x0
        sds((n_rows,), jnp.float32),         # w
        sds((n_rows,), jnp.int32),           # class_id
        sds((n_ens,), jnp.float32),          # ts
        sds((n_ens,), jnp.int32),            # ys
        sds((n_ens, 2, 2), jnp.uint32),      # keys (legacy uint32[2] per split)
    )
