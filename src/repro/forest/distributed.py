"""Distributed ForestFlow training under shard_map.

Layout (the TPU-native version of the paper's joblib pool, DESIGN.md §2):

* rows of (X0, w) are sharded across the ``data`` mesh axes (and ``pod``);
* the (timestep, class) ensemble grid is sharded across the ``model`` axis —
  each model-axis slice trains its own ensembles on the *same* row shards;
* histogram accumulation psums partial [nodes, p, bins] histograms over the
  data axes — exactly distributed XGBoost's allreduce, as a JAX collective;
* bin edges come from a gathered per-device subsample (the distributed
  quantile-sketch approximation).

Class conditioning is weight-masking: ensemble e has per-row weight
``w * (class_id == y_e)`` so row shards never need class-sorted layouts.

The module is split along the pipeline boundary (PR 3): the *input-build*
half (:func:`build_row_shards` — per-shard row materialisation with weight
masks and per-class scalers via ``make_array_from_callback`` — and
:func:`build_batch_inputs` — per-batch timesteps/classes/PRNG keys) is pure
host work that the pipelined trainer runs on a prefetch thread, while the
*dispatch* half (:func:`make_distributed_fit`) is the compiled shard_map
program. ``int8_codes`` packing stays inside the device program (codes only
exist after the per-ensemble quantile transform), gated by the same
:class:`ForestConfig` flag either way.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ForestConfig
from repro.core import interpolants as itp
from repro.forest.binning import (edges_with_sentinel, pack_codes,
                                  transform)
from repro.forest.boosting import fit_ensemble


def _sketch_edges(xt, w, n_bins: int, data_axes: Sequence[str],
                  sketch_rows: int = 2048):
    """Approximate global quantile edges from a gathered subsample."""
    take = min(sketch_rows, xt.shape[0])
    sample = xt[:take]
    sw = w[:take]
    for ax in data_axes:
        sample = jax.lax.all_gather(sample, ax, axis=0, tiled=True)
        sw = jax.lax.all_gather(sw, ax, axis=0, tiled=True)
    big = jnp.where(sw[:, None] > 0, sample, jnp.inf)
    s = jnp.sort(big, axis=0)
    n_real = jnp.sum(sw > 0).astype(jnp.float32)
    qs = jnp.arange(1, n_bins, dtype=jnp.float32) / n_bins
    idx = jnp.clip((qs * (n_real - 1.0)).astype(jnp.int32), 0, s.shape[0] - 1)
    return jnp.transpose(s[idx])


def _fit_one_sharded(x0, w, class_id, t, y_e, key2, fcfg: ForestConfig,
                     data_axes: Tuple[str, ...], scatter_shards: int = 0,
                     warm=None):
    """Train one (t, y) ensemble on this device's row shard (+collectives).

    ``warm`` is this ensemble's base-model slice ``(feat [n_sub, R, H], ...,
    best_round [n_sub])`` for a warm-start continuation: the saved trees are
    replayed on this shard's raw noised rows (the running predictions are
    row-sharded exactly like the training loop's, so the psum'd validation
    loss continues bit-identically — see :mod:`repro.forest.boosting`).
    """
    K = fcfg.duplicate_k
    x0d = jnp.repeat(x0, K, axis=0)
    wd = jnp.repeat(w * (class_id == y_e).astype(jnp.float32), K, axis=0)
    # decorrelate noise across row shards: fold the data-axis coordinates in
    shard_id = jnp.int32(0)
    for ax in data_axes:
        shard_id = shard_id * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    k_tr = jax.random.fold_in(key2[0], shard_id)
    k_va = jax.random.fold_in(key2[1], shard_id)
    # sample_bridge splits each key so the CFM jitter is decorrelated from
    # x1 (one key for both draws made the jitter exactly sigma * x1)
    _, xt, tgt = itp.sample_bridge(k_tr, x0d, fcfg.method, t, fcfg.sigma)
    edges = _sketch_edges(xt, wd, fcfg.n_bins, data_axes)
    codes = transform(xt, edges)
    _, xtv, tgtv = itp.sample_bridge(k_va, x0d, fcfg.method, t, fcfg.sigma)
    codes_v = transform(xtv, edges)
    if fcfg.int8_codes:   # QuantileDMatrix-style narrow storage
        codes = pack_codes(codes, fcfg.n_bins)
        codes_v = pack_codes(codes_v, fcfg.n_bins)
    return fit_ensemble(codes, tgt, wd, edges_with_sentinel(edges),
                        codes_v, tgtv, wd, fcfg, axis_names=data_axes,
                        scatter_shards=scatter_shards, warm=warm,
                        x_raw=xt, val_raw=xtv)


def make_distributed_fit(mesh: Mesh, fcfg: ForestConfig,
                         data_axes: Sequence[str] = ("data",),
                         model_axis: str = "model", warm_rounds: int = 0):
    """Build the jitted shard_map trainer.

    Returned fn signature:
      fn(x0 [n, p], w [n], class_id [n], ts [n_ens], ys [n_ens],
         keys [n_ens, 2] PRNG keys) -> BoostResult stacked over n_ens.
    n must divide by prod(data axes); n_ens by the model axis.

    With ``warm_rounds = R > 0`` (a warm-start extension from an R-round
    base model) the fn takes five extra model-axis-sharded arrays — this
    batch's base slices ``feat [n_ens, n_sub, R, H]``, ``thr_val``,
    ``leaf``, ``val_curve [n_ens, n_sub, R]``, ``best_round [n_ens,
    n_sub]`` — and every ensemble continues boosting from its slice.

    Cached on (mesh, config, axes, warm rounds): every ``fit_artifacts``
    call with the same trainer reuses one jitted callable, so repeated fits
    (resume, benchmarks, serving-side retrains) pay XLA compilation once
    per process instead of once per call.
    """
    return _make_distributed_fit(mesh, fcfg, tuple(data_axes), model_axis,
                                 int(warm_rounds))


@functools.lru_cache(maxsize=16)
def _make_distributed_fit(mesh: Mesh, fcfg: ForestConfig,
                          data_axes: Tuple[str, ...], model_axis: str,
                          warm_rounds: int = 0):

    shards = (dict(zip(mesh.axis_names, mesh.devices.shape))[data_axes[-1]]
              if fcfg.split_reduce == "reduce_scatter" else 0)

    def per_device(x0, w, cid, ts, ys, keys, *warm):
        fit = functools.partial(_fit_one_sharded, x0, w, cid,
                                fcfg=fcfg, data_axes=data_axes,
                                scatter_shards=shards)
        # sequential map over local ensembles: one set of codes live at a
        # time (the Issue-1 memory discipline under sharding)
        if warm:
            return jax.lax.map(
                lambda a: fit(a[0], a[1], a[2], warm=tuple(a[3:])),
                (ts, ys, keys) + warm)
        return jax.lax.map(lambda tyk: fit(tyk[0], tyk[1], tyk[2]),
                           (ts, ys, keys))

    row_spec = P(data_axes)
    ens_spec = P(model_axis)
    in_specs = (row_spec, row_spec, row_spec, ens_spec, ens_spec,
                P(model_axis, None, None))
    if warm_rounds:
        # base-model slices: batch dim over the model axis, trailing dims
        # replicated (P pads with None)
        in_specs = in_specs + (P(model_axis),) * 5
    try:
        from jax import shard_map  # jax >= 0.6
        replication_kw = {"check_vma": False}
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map
        replication_kw = {"check_rep": False}  # pre-0.6 spelling
    mapped = shard_map(
        per_device, mesh=mesh, in_specs=in_specs,
        out_specs=jax.tree_util.tree_map(lambda _: P(model_axis), _result_spec()),
        **replication_kw)
    return jax.jit(mapped)


def _result_spec():
    """Tree prototype matching BoostResult for out_specs construction."""
    from repro.forest.boosting import BoostResult
    return BoostResult(0, 0, 0, 0, 0, 0)


# ---------------------------------------------------------------------------
# input-build stage (host side; runs on the pipeline's prefetch thread)
# ---------------------------------------------------------------------------

def build_row_shards(mesh: Mesh, X_np, cid_full, mins, maxs, perm,
                     data_axes: Tuple[str, ...] = ("data",)):
    """Materialise the sharded row arrays for the distributed trainer.

    Pure input-build: each device's callback touches only its own row slice
    of ``X_np`` (one advanced-index copy of ``n_pad / d_size`` rows under
    the ``perm`` shuffle), rescaled with that row's per-class scaler; the
    weight mask is 1 for real rows and 0 for the padded tail, and
    ``class_id`` carries the weight-mask class conditioning. Returns
    ``(x0, w, class_id)`` as data-axis-sharded ``jax.Array``s — the only
    host→device row traffic in a fit, which the pipelined trainer performs
    on its prefetch thread so the upload overlaps dispatch-side work.

    ``X_np`` may be any array-like supporting fancy row indexing and
    ``.shape`` — in particular a :class:`repro.data.store.DatasetStore`,
    whose ``__getitem__`` gathers each device's rows directly from the
    on-disk shards they live in (grouped per shard, memmap reads). The
    dataset is then never resident on the host as a whole: peak host
    memory per callback is one device's row slice.
    """
    from repro.tabgen.artifacts import rescale

    n, p = X_np.shape
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    d_size = int(np.prod([axis_sizes[a] for a in data_axes], dtype=np.int64))
    n_pad = -(-n // d_size) * d_size       # rows padded to w=0 tail

    def _rows(idx, fill, build):
        """Materialise one device's row slice of a [n_pad, ...] array."""
        sl = idx[0]
        lo = sl.start or 0
        hi = n_pad if sl.stop is None else sl.stop
        take = perm[lo:min(hi, n)]
        out = build(take)
        if hi > n:                          # tail padding rows
            pad_shape = (hi - max(lo, n),) + out.shape[1:]
            out = np.concatenate([out, np.full(pad_shape, fill, out.dtype)])
        return out

    def x_cb(idx):
        return _rows(idx, 0.0, lambda take: rescale(
            np.asarray(X_np[take], np.float32), mins[cid_full[take]],
            maxs[cid_full[take]]).astype(np.float32))

    def w_cb(idx):
        return _rows(idx, 0.0,
                     lambda take: np.ones((len(take),), np.float32))

    def c_cb(idx):
        return _rows(idx, 0, lambda take: cid_full[take])

    row_sh = NamedSharding(mesh, P(data_axes))
    x0 = jax.make_array_from_callback((n_pad, p), row_sh, x_cb)
    w = jax.make_array_from_callback((n_pad,), row_sh, w_cb)
    cid = jax.make_array_from_callback((n_pad,), row_sh, c_cb)
    return x0, w, cid


@jax.jit
def _grid_key_pairs(root, ids):
    return jax.vmap(lambda e: jnp.stack([
        jax.random.fold_in(root, e * 2),
        jax.random.fold_in(root, e * 2 + 1)]))(ids)


def build_grid_key_table(root, n_ens: int):
    """Every ensemble's (train, val) PRNG keys in one vectorized dispatch:
    ``[n_ens, 2, 2]`` uint32. Bit-identical to the per-batch sequential
    ``fold_in`` pairs of :func:`build_batch_inputs` (vmapped threefry is
    value-equal to the scalar calls), but costs one device round-trip per
    fit instead of ``2 * bs`` per batch — both trainer loops build it up
    front and slice plain numpy thereafter, which also keeps the
    pipeline's prefetch thread off the device queues. (Module-level jit:
    the threefry program compiles once per process, not once per fit.)
    """
    ids = jnp.arange(n_ens, dtype=jnp.uint32)
    return np.asarray(_grid_key_pairs(root, ids), np.uint32)


def build_batch_inputs(chunk, ts, n_y: int, root, key_table=None):
    """Host-side inputs for one ensemble batch (already padded to the batch
    size): timestep values, class indices, and the two per-ensemble PRNG
    keys. Keys fold in the grid-linearised ensemble id, so whichever thread
    builds them — the serial loop or the pipeline's prefetcher — the batch
    is bit-identical. ``key_table`` (from :func:`build_grid_key_table`)
    replaces the sequential per-ensemble ``fold_in`` dispatches with a
    numpy slice of the same values.
    """
    t_arr = np.asarray([ts[ti] for ti, _ in chunk], np.float32)
    y_arr = np.asarray([yi for _, yi in chunk], np.int32)
    if key_table is not None:
        keys = key_table[[ti * n_y + yi for ti, yi in chunk]]
    else:
        keys = np.stack([np.stack([
            np.asarray(jax.random.fold_in(root, (ti * n_y + yi) * 2),
                       np.uint32),
            np.asarray(jax.random.fold_in(root, (ti * n_y + yi) * 2 + 1),
                       np.uint32)]) for ti, yi in chunk])
    return t_arr, y_arr, keys


def input_specs_forest(fcfg: ForestConfig, n_rows: int, p: int, n_ens: int):
    """ShapeDtypeStructs for the distributed-forest dry-run."""
    sds = jax.ShapeDtypeStruct
    return (
        sds((n_rows, p), jnp.float32),       # x0
        sds((n_rows,), jnp.float32),         # w
        sds((n_rows,), jnp.int32),           # class_id
        sds((n_ens,), jnp.float32),          # ts
        sds((n_ens,), jnp.int32),            # ys
        sds((n_ens, 2, 2), jnp.uint32),      # keys (legacy uint32[2] per split)
    )
