"""NN-backed baselines sharing the same interpolants as the forest models.

* ``NNGenerativeModel`` — an MLP vector field trained on the identical CFM /
  score-matching losses (STaSy / TabDDPM-style, minibatched like NNs are);
  the apples-to-apples NN-vs-forest comparison the paper draws.
* ``TVAEBaseline`` — a small tabular VAE (ELBO with Gaussian decoder).

Both consume/emit numpy like ForestGenerativeModel.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ForestConfig, TrainConfig
from repro.core import interpolants as itp
from repro.train.optim import adamw_update, init_opt_state


def _mlp_init(key, sizes, dtype=jnp.float32):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k = jax.random.fold_in(key, i)
        params.append({
            "w": (a ** -0.5) * jax.random.normal(k, (a, b), dtype),
            "b": jnp.zeros((b,), dtype),
        })
    return params


def _mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i + 1 < len(params):
            x = jax.nn.silu(x)
    return x


def _time_embed(t, dim=32):
    freqs = jnp.exp(jnp.linspace(0.0, 5.0, dim // 2))
    ang = t[:, None] * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class NNGenerativeModel:
    """MLP vector field trained on the same CFM / score losses."""

    def __init__(self, fcfg: ForestConfig, hidden: int = 256, depth: int = 3,
                 steps: int = 2000, batch: int = 256, lr: float = 1e-3):
        self.fcfg = fcfg
        self.hidden, self.depth = hidden, depth
        self.steps, self.batch, self.lr = steps, batch, lr

    def fit(self, X, y=None, *, seed: int = 0):
        X = np.asarray(X, np.float32)
        n, p = X.shape
        self._mins, self._maxs = X.min(0), X.max(0)
        scale = np.where(self._maxs > self._mins, self._maxs - self._mins, 1.0)
        Xs = (X - self._mins) / scale * 2 - 1
        if y is None:
            y = np.zeros((n,), np.int64)
        self._classes, y_idx = np.unique(y, return_inverse=True)
        n_y = len(self._classes)
        self.p, self.n_y = p, n_y
        self._counts = np.bincount(y_idx, minlength=n_y)

        key = jax.random.PRNGKey(seed)
        in_dim = p + 32 + n_y
        params = _mlp_init(key, [in_dim] + [self.hidden] * self.depth + [p])
        opt = init_opt_state(params)
        tcfg = TrainConfig(learning_rate=self.lr, warmup_steps=50,
                           total_steps=self.steps, weight_decay=0.0,
                           grad_clip=1.0)
        Xd = jnp.asarray(Xs)
        yd = jax.nn.one_hot(jnp.asarray(y_idx), n_y)
        fcfg = self.fcfg

        def loss_fn(pp, k):
            k1, k2, k3 = jax.random.split(k, 3)
            idx = jax.random.randint(k1, (self.batch,), 0, n)
            x0 = Xd[idx]
            yo = yd[idx]
            t = jax.random.uniform(k2, (self.batch,),
                                   minval=fcfg.eps_diff
                                   if fcfg.method == "diffusion" else 0.0)
            x1 = jax.random.normal(k3, x0.shape)
            xt, tgt = jax.vmap(
                lambda a, b, tt: itp.make_xt_target(fcfg.method, a, b, tt)
            )(x0, x1, t)
            # scale score targets so the regression is O(1) (precondition)
            if fcfg.method == "diffusion":
                _, sig = itp.vp_alpha_sigma(t)
                tgt = tgt * sig[:, None]
            inp = jnp.concatenate([xt, _time_embed(t), yo], axis=-1)
            out = _mlp_apply(pp, inp)
            return jnp.mean(jnp.square(out - tgt))

        @jax.jit
        def step(pp, oo, k):
            l, g = jax.value_and_grad(loss_fn)(pp, k)
            pp, oo, _ = adamw_update(g, oo, pp, tcfg)
            return pp, oo, l

        for i in range(self.steps):
            params, opt, l = step(params, opt, jax.random.fold_in(key, i + 1))
        self.params = params
        return self

    def _field(self, x, t, y_onehot):
        tt = jnp.full((x.shape[0],), t)
        inp = jnp.concatenate([x, _time_embed(tt), y_onehot], axis=-1)
        out = _mlp_apply(self.params, inp)
        if self.fcfg.method == "diffusion":
            _, sig = itp.vp_alpha_sigma(t)
            out = out / sig
        return out

    def generate(self, n: int, *, seed: int = 0, n_steps: int = 50):
        rng = np.random.default_rng(seed)
        probs = self._counts / self._counts.sum()
        y_idx = np.sort(rng.choice(self.n_y, size=n, p=probs))
        yo = jax.nn.one_hot(jnp.asarray(y_idx), self.n_y)
        key = jax.random.PRNGKey(seed + 11)
        x = jax.random.normal(key, (n, self.p))
        fcfg = self.fcfg
        if fcfg.method == "flow":
            h = 1.0 / (n_steps - 1)
            for t in np.linspace(1.0, h, n_steps - 1):
                x = x - h * self._field(x, jnp.float32(t), yo)
        else:
            ts = np.asarray(itp.timesteps("diffusion", n_steps,
                                          fcfg.eps_diff))[::-1]
            for t_now, t_next in zip(ts[:-1], ts[1:]):
                a_now, s_now = itp.vp_alpha_sigma(jnp.float32(t_now))
                a_next, s_next = itp.vp_alpha_sigma(jnp.float32(t_next))
                score = self._field(x, jnp.float32(t_now), yo)
                eps_hat = -s_now * score
                x0_hat = jnp.clip((x - s_now * eps_hat) / a_now, -1.5, 1.5)
                eps_hat = (x - a_now * x0_hat) / s_now
                x = a_next * x0_hat + s_next * eps_hat
        x = np.asarray(x)
        scale = np.where(self._maxs > self._mins, self._maxs - self._mins, 1.0)
        X = (x + 1) / 2 * scale + self._mins
        return X, self._classes[y_idx]


class TVAEBaseline:
    """Small tabular VAE (Gaussian encoder/decoder), TVAE-style."""

    def __init__(self, latent: int = 8, hidden: int = 128, steps: int = 1500,
                 batch: int = 256, lr: float = 1e-3):
        self.latent, self.hidden = latent, hidden
        self.steps, self.batch, self.lr = steps, batch, lr

    def fit(self, X, y=None, *, seed: int = 0):
        X = np.asarray(X, np.float32)
        n, p = X.shape
        self.p = p
        self._mins, self._maxs = X.min(0), X.max(0)
        scale = np.where(self._maxs > self._mins, self._maxs - self._mins, 1.0)
        Xs = (X - self._mins) / scale * 2 - 1
        key = jax.random.PRNGKey(seed)
        enc = _mlp_init(jax.random.fold_in(key, 0),
                        [p, self.hidden, 2 * self.latent])
        dec = _mlp_init(jax.random.fold_in(key, 1),
                        [self.latent, self.hidden, p])
        params = {"enc": enc, "dec": dec}
        opt = init_opt_state(params)
        tcfg = TrainConfig(learning_rate=self.lr, warmup_steps=50,
                           total_steps=self.steps, weight_decay=0.0)
        Xd = jnp.asarray(Xs)

        def loss_fn(pp, k):
            k1, k2 = jax.random.split(k)
            idx = jax.random.randint(k1, (self.batch,), 0, n)
            x = Xd[idx]
            h = _mlp_apply(pp["enc"], x)
            mu, logvar = h[:, :self.latent], h[:, self.latent:]
            z = mu + jnp.exp(0.5 * logvar) * jax.random.normal(k2, mu.shape)
            xr = _mlp_apply(pp["dec"], z)
            rec = jnp.mean(jnp.sum(jnp.square(xr - x), -1))
            kl = -0.5 * jnp.mean(jnp.sum(1 + logvar - mu ** 2
                                         - jnp.exp(logvar), -1))
            return rec + 0.1 * kl

        @jax.jit
        def step(pp, oo, k):
            l, g = jax.value_and_grad(loss_fn)(pp, k)
            pp, oo, _ = adamw_update(g, oo, pp, tcfg)
            return pp, oo, l

        for i in range(self.steps):
            params, opt, _ = step(params, opt, jax.random.fold_in(key, i + 1))
        self.params = params
        return self

    def generate(self, n: int, *, seed: int = 0):
        z = jax.random.normal(jax.random.PRNGKey(seed), (n, self.latent))
        x = np.asarray(_mlp_apply(self.params["dec"], z))
        scale = np.where(self._maxs > self._mins, self._maxs - self._mins, 1.0)
        return ((x + 1) / 2 * scale + self._mins).astype(np.float32)
