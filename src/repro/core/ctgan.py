"""CTGAN-style conditional tabular GAN baseline (paper Table 2, [95]).

A compact JAX implementation: MLP generator/discriminator, conditional
class one-hot, non-saturating GAN loss with R1 gradient penalty. Sized for
the benchmark-suite comparison role, not for SOTA GAN training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig
from repro.core.nn_baselines import _mlp_apply, _mlp_init
from repro.train.optim import adamw_update, init_opt_state


class CTGANBaseline:
    def __init__(self, latent: int = 32, hidden: int = 128,
                 steps: int = 2000, batch: int = 128, lr: float = 2e-4):
        self.latent, self.hidden = latent, hidden
        self.steps, self.batch, self.lr = steps, batch, lr

    def fit(self, X, y=None, *, seed: int = 0):
        X = np.asarray(X, np.float32)
        n, p = X.shape
        self.p = p
        self._mins, self._maxs = X.min(0), X.max(0)
        scale = np.where(self._maxs > self._mins, self._maxs - self._mins, 1.)
        Xs = (X - self._mins) / scale * 2 - 1
        if y is None:
            y = np.zeros((n,), np.int64)
        self._classes, y_idx = np.unique(y, return_inverse=True)
        n_y = len(self._classes)
        self.n_y = n_y
        self._counts = np.bincount(y_idx, minlength=n_y)

        key = jax.random.PRNGKey(seed)
        gen = _mlp_init(jax.random.fold_in(key, 0),
                        [self.latent + n_y, self.hidden, self.hidden, p])
        dis = _mlp_init(jax.random.fold_in(key, 1),
                        [p + n_y, self.hidden, self.hidden, 1])
        g_opt, d_opt = init_opt_state(gen), init_opt_state(dis)
        tcfg = TrainConfig(learning_rate=self.lr, warmup_steps=20,
                           total_steps=self.steps, weight_decay=0.0,
                           beta1=0.5, beta2=0.9)
        Xd = jnp.asarray(Xs)
        yd = jax.nn.one_hot(jnp.asarray(y_idx), n_y)

        def sample_fake(gp, k, cond):
            z = jax.random.normal(k, (cond.shape[0], self.latent))
            return jnp.tanh(_mlp_apply(gp, jnp.concatenate([z, cond], -1)))

        def d_loss(dp, gp, k):
            k1, k2 = jax.random.split(k)
            idx = jax.random.randint(k1, (self.batch,), 0, n)
            real, cond = Xd[idx], yd[idx]
            fake = sample_fake(gp, k2, cond)
            d_real = _mlp_apply(dp, jnp.concatenate([real, cond], -1))
            d_fake = _mlp_apply(dp, jnp.concatenate([fake, cond], -1))
            loss = (jnp.mean(jax.nn.softplus(-d_real))
                    + jnp.mean(jax.nn.softplus(d_fake)))
            # R1 penalty on real data
            grad = jax.grad(lambda r: jnp.sum(_mlp_apply(
                dp, jnp.concatenate([r, cond], -1))))(real)
            return loss + 1.0 * jnp.mean(jnp.sum(grad ** 2, -1))

        def g_loss(gp, dp, k):
            k1, k2 = jax.random.split(k)
            idx = jax.random.randint(k1, (self.batch,), 0, n)
            cond = yd[idx]
            fake = sample_fake(gp, k2, cond)
            return jnp.mean(jax.nn.softplus(
                -_mlp_apply(dp, jnp.concatenate([fake, cond], -1))))

        @jax.jit
        def step(gp, dp, go, do, k):
            kd, kg = jax.random.split(k)
            dl, dg = jax.value_and_grad(d_loss)(dp, gp, kd)
            dp, do, _ = adamw_update(dg, do, dp, tcfg)
            gl, gg = jax.value_and_grad(g_loss)(gp, dp, kg)
            gp, go, _ = adamw_update(gg, go, gp, tcfg)
            return gp, dp, go, do, dl, gl

        for i in range(self.steps):
            gen, dis, g_opt, d_opt, dl, gl = step(
                gen, dis, g_opt, d_opt, jax.random.fold_in(key, 2 + i))
        self.gen = gen
        return self

    def generate(self, n: int, *, seed: int = 0):
        rng = np.random.default_rng(seed)
        probs = self._counts / self._counts.sum()
        y_idx = np.sort(rng.choice(self.n_y, size=n, p=probs))
        cond = jax.nn.one_hot(jnp.asarray(y_idx), self.n_y)
        z = jax.random.normal(jax.random.PRNGKey(seed + 5), (n, self.latent))
        from repro.core.nn_baselines import _mlp_apply as apply
        x = np.asarray(jnp.tanh(apply(self.gen,
                                      jnp.concatenate([z, cond], -1))))
        scale = np.where(self._maxs > self._mins, self._maxs - self._mins, 1.)
        return ((x + 1) / 2 * scale + self._mins).astype(np.float32), \
            self._classes[y_idx]
