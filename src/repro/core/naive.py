"""Original-style (pre-rework) implementation — the paper's comparison
baseline, with its pathologies faithfully recreated (paper §3.2):

* Issue 1: materialises the full ``X_train`` of shape [n_t, nK, p] up front.
* Issue 2 analogue: stores the noise array X1 (and a duplicate per-ensemble
  *copy* of its training slice, like joblib advanced-indexing copies did).
* Issue 3: keeps every trained ensemble in memory until the end.
* Issue 6: refits bin edges / code matrices separately per output column.
* Issue 7: runs the data path in float64.

Used by benchmarks/bench_resource_scaling.py to reproduce Figure 1/2/4.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ForestConfig
from repro.core import interpolants as itp
from repro.forest.binning import edges_with_sentinel, fit_bins, transform
from repro.forest.boosting import fit_boosted


class NaiveForestGenerativeModel:
    def __init__(self, fcfg: ForestConfig):
        self.fcfg = fcfg

    def fit(self, X, y=None, *, seed: int = 0):
        fcfg = self.fcfg
        X = np.asarray(X, np.float64)                      # Issue 7
        n, p = X.shape
        if y is None:
            y = np.zeros((n,), np.int64)
        classes = np.unique(y)
        mn, mx = X.min(0), X.max(0)
        scale = np.where(mx > mn, mx - mn, 1.0)
        Xs = (X - mn) / scale * 2 - 1
        self._mins, self._maxs = mn, mx
        K = fcfg.duplicate_k
        rng = np.random.default_rng(seed)
        X0 = np.tile(Xs, (K, 1))                           # [nK, p]
        X1 = rng.normal(size=X0.shape)                     # stored noise
        yd = np.tile(np.asarray(y), K)
        ts = np.asarray(itp.timesteps(fcfg.method, fcfg.n_t, fcfg.eps_diff))
        # Issue 1: all timesteps at once -> [n_t, nK, p]
        if fcfg.method == "flow":
            X_train = ts[:, None, None] * X1 + (1 - ts[:, None, None]) * X0
            Z = X1 - X0
        else:
            a, s = np.asarray(itp.vp_alpha_sigma(jnp.asarray(ts)))
            X_train = a[:, None, None] * X0 + s[:, None, None] * X1
            Z = None
        self.models = []                                   # Issue 3
        for ti in range(fcfg.n_t):
            for c in classes:
                mask = yd == c                             # boolean-mask copies
                xt_c = X_train[ti][mask]                   # (Issue 5)
                if fcfg.method == "flow":
                    z_c = Z[mask]
                else:
                    _, sig = itp.vp_alpha_sigma(jnp.asarray(ts[ti]))
                    z_c = -X1[mask] / float(sig)
                w = jnp.ones((xt_c.shape[0],), jnp.float32)
                for j in range(p):                         # Issue 6: per-output
                    edges = fit_bins(jnp.asarray(xt_c, jnp.float32),
                                     fcfg.n_bins)
                    codes = transform(jnp.asarray(xt_c, jnp.float32), edges)
                    res = fit_boosted(
                        codes, jnp.asarray(z_c[:, j:j + 1], jnp.float32), w,
                        edges_with_sentinel(edges), codes,
                        jnp.asarray(z_c[:, j:j + 1], jnp.float32), w, fcfg)
                    self.models.append(((ti, int(c), j),
                                        jax.tree_util.tree_map(np.asarray,
                                                               res)))
        self._X_train = X_train     # held live, like the original
        self._X1 = X1
        return self
