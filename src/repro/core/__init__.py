# The paper's primary contribution: memory-efficient diffusion / flow-matching
# generative models whose vector field is a boosted-tree forest.
from repro.core.forest_flow import ForestGenerativeModel  # noqa: F401
