# The paper's primary contribution: memory-efficient diffusion / flow-matching
# generative models whose vector field is a boosted-tree forest. The
# composable API lives in repro.tabgen; ForestGenerativeModel is the
# deprecated monolithic facade.
from repro.core.forest_flow import ForestGenerativeModel  # noqa: F401
