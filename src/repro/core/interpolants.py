"""Diffusion (VP-SDE) and conditional-flow-matching bridges (paper §2.1-2.2).

flow (CFM, Eq. 5):       x_t = t x1 + (1-t) x0 (+ sigma eps),  target = x1 - x0
diffusion (VP, Eq. 2):   x_t = alpha(t) x0 + sigma(t) x1,      target = -x1 / sigma(t)
                         (the conditional score  grad log p_t(x_t | x0))
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BETA_MIN = 0.1
BETA_MAX = 20.0


def vp_alpha_sigma(t):
    """VP-SDE marginal coefficients (Song et al. 2021)."""
    log_alpha = -0.25 * t ** 2 * (BETA_MAX - BETA_MIN) - 0.5 * t * BETA_MIN
    alpha = jnp.exp(log_alpha)
    sigma = jnp.sqrt(jnp.maximum(1.0 - alpha ** 2, 1e-12))
    return alpha, sigma


def vp_beta(t):
    return BETA_MIN + t * (BETA_MAX - BETA_MIN)


def timesteps(method: str, n_t: int, eps: float, schedule: str = "uniform"):
    """Timestep grid. ``cosine`` concentrates models near t=0 (data), where
    the paper observes underfitting is worst (Fig. 3 / App. C.2's suggested
    non-uniform partitioning)."""
    lo = 0.0 if method == "flow" else eps
    if schedule == "cosine":
        u = jnp.linspace(0.0, 1.0, n_t)
        t = 1.0 - jnp.cos(0.5 * jnp.pi * u)     # dt -> 0 at t=0: dense there
        return lo + (1.0 - lo) * t
    return jnp.linspace(lo, 1.0, n_t)


def sample_bridge(key, x0, method: str, t, sigma_cfm: float = 0.0):
    """Draw noise ``x1`` and the ``(x_t, target)`` training pair from one key.

    The key is split so the CFM jitter inside :func:`make_xt_target` is
    decorrelated from ``x1`` — passing the same key to both draws makes the
    "independent" jitter exactly equal to ``x1`` (same key, same shape ⇒
    identical normal sample), i.e. x_t = (t + sigma) x1 + (1-t) x0.
    Returns ``(x1, xt, target)``.
    """
    k_noise, k_jitter = jax.random.split(key)
    x1 = jax.random.normal(k_noise, x0.shape, jnp.float32)
    xt, target = make_xt_target(method, x0, x1, t, sigma_cfm, k_jitter)
    return x1, xt, target


def make_xt_target(method: str, x0, x1, t, sigma_cfm: float = 0.0, key=None):
    """x0: data rows; x1: standard normal noise of the same shape; t scalar."""
    if method == "flow":
        xt = t * x1 + (1.0 - t) * x0
        if sigma_cfm > 0.0 and key is not None:
            xt = xt + sigma_cfm * jax.random.normal(key, x0.shape, x0.dtype)
        target = x1 - x0
        return xt, target
    if method == "diffusion":
        alpha, sigma = vp_alpha_sigma(t)
        xt = alpha * x0 + sigma * x1
        target = -x1 / sigma
        return xt, target
    raise ValueError(method)
