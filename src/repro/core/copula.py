"""GaussianCopula baseline (paper Table 2, [45]).

Rank-transform each marginal to standard normal, fit the Gaussian copula
correlation, sample, and map back through the empirical quantiles.
"""
from __future__ import annotations

import numpy as np
from scipy import stats


class GaussianCopula:
    def fit(self, X: np.ndarray):
        X = np.asarray(X, np.float64)
        n, p = X.shape
        self._sorted = np.sort(X, axis=0)
        ranks = np.empty_like(X)
        for j in range(p):
            ranks[:, j] = stats.rankdata(X[:, j], method="average")
        u = ranks / (n + 1.0)
        z = stats.norm.ppf(u)
        self._corr = np.corrcoef(z, rowvar=False)
        self._corr = np.atleast_2d(self._corr)
        # regularise to PSD
        w, v = np.linalg.eigh(self._corr)
        w = np.clip(w, 1e-6, None)
        self._chol = v @ np.diag(np.sqrt(w))
        return self

    def generate(self, n: int, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        p = self._sorted.shape[1]
        z = rng.normal(size=(n, p)) @ self._chol.T
        u = stats.norm.cdf(z)
        out = np.empty((n, p))
        m = self._sorted.shape[0]
        idx = np.clip((u * (m - 1)).astype(int), 0, m - 1)
        for j in range(p):
            out[:, j] = self._sorted[idx[:, j], j]
        return out.astype(np.float32)
