"""Mixed-type tabular handling (paper App. D.1: "Categorical variables are
one-hot encoded", integer targets rounded).

``TabularSchema`` dummy-encodes categorical columns before fitting and
post-processes generated rows: one-hot groups re-argmaxed, integer columns
rounded and clipped to the observed range — the original ForestDiffusion's
``cat_indexes``/``int_indexes`` behaviour.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


class TabularSchema:
    def __init__(self, cat_cols: Sequence[int] = (),
                 int_cols: Sequence[int] = ()):
        self.cat_cols = sorted(cat_cols)
        self.int_cols = sorted(set(int_cols) - set(cat_cols))

    def fit(self, X: np.ndarray):
        X = np.asarray(X)
        self.n_raw = X.shape[1]
        self._cats: Dict[int, np.ndarray] = {}
        for c in self.cat_cols:
            self._cats[c] = np.unique(X[:, c])
        self._int_lo = {c: np.floor(X[:, c].min()) for c in self.int_cols}
        self._int_hi = {c: np.ceil(X[:, c].max()) for c in self.int_cols}
        # encoded layout: numeric/int columns first (original order), then
        # one-hot blocks per categorical column
        self._num_cols = [j for j in range(self.n_raw)
                          if j not in self.cat_cols]
        return self

    @property
    def encoded_width(self) -> int:
        return len(self._num_cols) + sum(len(v) for v in self._cats.values())

    def encode(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X)
        parts = [X[:, self._num_cols].astype(np.float32)]
        for c in self.cat_cols:
            cats = self._cats[c]
            onehot = (X[:, c][:, None] == cats[None, :]).astype(np.float32)
            parts.append(onehot)
        return np.concatenate(parts, axis=1)

    def decode(self, Z: np.ndarray) -> np.ndarray:
        Z = np.asarray(Z)
        out = np.empty((Z.shape[0], self.n_raw), np.float64)
        k = len(self._num_cols)
        for i, j in enumerate(self._num_cols):
            col = Z[:, i].astype(np.float64)
            if j in self.int_cols:
                col = np.clip(np.round(col), self._int_lo[j], self._int_hi[j])
            out[:, j] = col
        for c in self.cat_cols:
            cats = self._cats[c]
            block = Z[:, k:k + len(cats)]
            out[:, c] = cats[np.argmax(block, axis=1)]
            k += len(cats)
        return out
