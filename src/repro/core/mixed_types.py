"""Mixed-type tabular handling (paper App. D.1: "Categorical variables are
one-hot encoded", integer targets rounded).

``TabularSchema`` dummy-encodes categorical columns before fitting and
post-processes generated rows: one-hot groups re-argmaxed, integer columns
rounded and clipped to the observed range — the original ForestDiffusion's
``cat_indexes``/``int_indexes`` behaviour.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


class TabularSchema:
    """Column-type schema: which raw columns are categorical / integer.

    ``encode``/``decode`` map between the raw column layout and the
    continuous representation the forest models are trained on.
    ``to_dict``/``from_dict`` make the fitted schema JSON-portable so a
    saved generator can decode on a serving host that never saw the
    training data.
    """
    def __init__(self, cat_cols: Sequence[int] = (),
                 int_cols: Sequence[int] = ()):
        self.cat_cols = sorted(cat_cols)
        self.int_cols = sorted(set(int_cols) - set(cat_cols))

    def fit(self, X: np.ndarray):
        X = np.asarray(X)
        self.n_raw = X.shape[1]
        self._cats: Dict[int, np.ndarray] = {}
        for c in self.cat_cols:
            self._cats[c] = np.unique(X[:, c])
        self._int_lo = {c: np.floor(X[:, c].min()) for c in self.int_cols}
        self._int_hi = {c: np.ceil(X[:, c].max()) for c in self.int_cols}
        # encoded layout: numeric/int columns first (original order), then
        # one-hot blocks per categorical column
        self._num_cols = [j for j in range(self.n_raw)
                          if j not in self.cat_cols]
        return self

    @property
    def encoded_width(self) -> int:
        return len(self._num_cols) + sum(len(v) for v in self._cats.values())

    def encode(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X)
        parts = [X[:, self._num_cols].astype(np.float32)]
        for c in self.cat_cols:
            cats = self._cats[c]
            onehot = (X[:, c][:, None] == cats[None, :]).astype(np.float32)
            parts.append(onehot)
        return np.concatenate(parts, axis=1)

    def decode(self, Z: np.ndarray) -> np.ndarray:
        Z = np.asarray(Z)
        numeric = all(np.issubdtype(np.asarray(v).dtype, np.number)
                      for v in self._cats.values())
        out = np.empty((Z.shape[0], self.n_raw),
                       np.float64 if numeric else object)
        k = len(self._num_cols)
        for i, j in enumerate(self._num_cols):
            col = Z[:, i].astype(np.float64)
            if j in self.int_cols:
                col = np.clip(np.round(col), self._int_lo[j], self._int_hi[j])
            out[:, j] = col
        for c in self.cat_cols:
            cats = self._cats[c]
            block = Z[:, k:k + len(cats)]
            out[:, c] = cats[np.argmax(block, axis=1)]
            k += len(cats)
        return out

    def encode_with_missing(self, X: np.ndarray) -> np.ndarray:
        """Like ``encode`` but NaNs survive the trip: a missing numeric cell
        stays NaN, and a missing categorical cell NaNs its whole one-hot
        block — exactly the mask shape imputation needs."""
        X = np.asarray(X)
        Z = self.encode(np.where(_isnan(X), 0, X) if X.dtype == object
                        else np.nan_to_num(X.astype(np.float64)))
        nan = _isnan(X)
        for i, j in enumerate(self._num_cols):
            Z[nan[:, j], i] = np.nan
        k = len(self._num_cols)
        for c in self.cat_cols:
            w = len(self._cats[c])
            Z[nan[:, c], k:k + w] = np.nan
            k += w
        return Z

    # -- JSON portability ---------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "cat_cols": list(self.cat_cols),
            "int_cols": list(self.int_cols),
            "n_raw": int(self.n_raw),
            "cats": {str(c): np.asarray(v).tolist()
                     for c, v in self._cats.items()},
            "int_lo": {str(c): float(v) for c, v in self._int_lo.items()},
            "int_hi": {str(c): float(v) for c, v in self._int_hi.items()},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "TabularSchema":
        schema = cls(cat_cols=d["cat_cols"], int_cols=d["int_cols"])
        schema.n_raw = int(d["n_raw"])
        schema._cats = {int(c): np.asarray(v) for c, v in d["cats"].items()}
        schema._int_lo = {int(c): v for c, v in d["int_lo"].items()}
        schema._int_hi = {int(c): v for c, v in d["int_hi"].items()}
        schema._num_cols = [j for j in range(schema.n_raw)
                            if j not in schema.cat_cols]
        return schema


def _isnan(X: np.ndarray) -> np.ndarray:
    """Elementwise NaN test that also works on object arrays (mixed string /
    float columns)."""
    if X.dtype != object:
        return np.isnan(X.astype(np.float64, copy=False)) \
            if np.issubdtype(X.dtype, np.floating) else np.zeros(X.shape, bool)
    # x != x catches every NaN flavour (float, np.float32/64) elementwise;
    # strings and other types compare equal to themselves
    return np.asarray(X != X, dtype=bool)
