"""Samplers: Euler ODE (ForestFlow) and reverse-SDE Euler-Maruyama
(ForestDiffusion) over stacked per-timestep forests (paper App. B.2).

The per-class solve is a single ``lax.scan`` over timesteps whose xs are the
stacked forest arrays — one jitted program for the whole trajectory, the
batched-inference analogue of the paper's Issues 8/9 fix (no per-feature,
per-timestep Python dispatch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import interpolants as itp
from repro.forest.packed import PackedForest, predict_forest


def flow_euler(x1, forests_stacked: PackedForest, depth: int, n_t: int,
               ts=None, impl=None):
    """Integrate dx = v dt from t=1 to t=0 with the learned vector field.

    x1: [n, p] noise. forests_stacked arrays have leading dim n_t (timestep
    order matching itp.timesteps). ``ts`` is the (possibly non-uniform)
    timestep grid; per-interval Euler steps h_i = t_i - t_{i-1}.
    """
    if ts is None:
        ts = jnp.linspace(0.0, 1.0, n_t)
    hs = (ts[1:] - ts[:-1])[::-1]            # descending intervals

    def step(x, inp):
        h, feat, thr, leaf = inp
        f = PackedForest(feat, thr, leaf, forests_stacked.multi_output)
        v = predict_forest(x, f, depth, impl=impl)
        return x - h * v, None

    # iterate timesteps n_t-1 ... 1 (descending t)
    xs = (hs,
          forests_stacked.feat[::-1][: n_t - 1],
          forests_stacked.thr_val[::-1][: n_t - 1],
          forests_stacked.leaf[::-1][: n_t - 1])
    x0, _ = jax.lax.scan(step, x1, xs)
    return x0


def flow_heun(x1, forests_stacked: PackedForest, depth: int, n_t: int,
              ts=None, impl=None):
    """Heun (explicit trapezoid) ODE integration of the learned flow.

    Second-order accurate in h: each interval evaluates the vector field at
    both endpoints — the forest trained at t_i for the predictor and the one
    at t_{i-1} for the corrector — so coarse grids (small ``n_t``, where the
    paper shows quality degrades fastest) lose much less than Euler does, at
    2x the forest evaluations per step.
    """
    if ts is None:
        ts = jnp.linspace(0.0, 1.0, n_t)
    hs = (ts[1:] - ts[:-1])[::-1]            # descending intervals

    def forest_at(i):
        return PackedForest(forests_stacked.feat[i],
                            forests_stacked.thr_val[i],
                            forests_stacked.leaf[i],
                            forests_stacked.multi_output)

    def step(x, inp):
        # forest at the current (larger) t predicts; forest at the target
        # (smaller) t corrects. Scanning over *indices* into the closed-over
        # stack (instead of two shifted copies as scan xs) keeps device
        # memory at one forest stack, not three.
        h, i = inp
        v1 = predict_forest(x, forest_at(i), depth, impl=impl)
        v2 = predict_forest(x - h * v1, forest_at(i - 1), depth, impl=impl)
        return x - 0.5 * h * (v1 + v2), None

    idx = jnp.arange(n_t - 1, 0, -1)         # timesteps n_t-1 ... 1
    x0, _ = jax.lax.scan(step, x1, (hs, idx))
    return x0


def diffusion_ddim(x1, forests_stacked: PackedForest, depth: int, n_t: int,
                   eps: float, clip: float = 1.5, ts=None, impl=None):
    """Deterministic DDIM / exponential-integrator sampling of the VP process.

    Unconditionally stable at coarse grids (the paper's Euler-Maruyama needs
    beta*h < 1; at n_t <= 20 the VP drift violates that). At each grid point
    the score model gives eps_hat = -sigma_t * s(x, t); we reconstruct x0,
    clamp it to the scaled-data range (trees cannot extrapolate outside their
    binned support, so unclamped reconstructions can run away), and re-noise
    to the next grid time exactly.
    """
    if ts is None:
        ts = itp.timesteps("diffusion", n_t, eps)
    ts = ts[::-1]  # descending

    def step(x, inp):
        t_now, t_next, feat, thr, leaf = inp
        f = PackedForest(feat, thr, leaf, forests_stacked.multi_output)
        score = predict_forest(x, f, depth, impl=impl)
        a_now, s_now = itp.vp_alpha_sigma(t_now)
        a_next, s_next = itp.vp_alpha_sigma(t_next)
        eps_hat = -s_now * score
        x0_hat = jnp.clip((x - s_now * eps_hat) / a_now, -clip, clip)
        eps_hat = (x - a_now * x0_hat) / s_now
        return a_next * x0_hat + s_next * eps_hat, None

    xs = (ts[: n_t - 1], ts[1:],
          forests_stacked.feat[::-1][: n_t - 1],
          forests_stacked.thr_val[::-1][: n_t - 1],
          forests_stacked.leaf[::-1][: n_t - 1])
    x, _ = jax.lax.scan(step, x1, xs)
    # final denoise at t = eps with the last model
    f = PackedForest(forests_stacked.feat[0], forests_stacked.thr_val[0],
                     forests_stacked.leaf[0], forests_stacked.multi_output)
    a, s = itp.vp_alpha_sigma(ts[-1])
    score = predict_forest(x, f, depth, impl=impl)
    return (x + s ** 2 * score) / a


def diffusion_em(x1, forests_stacked: PackedForest, depth: int, n_t: int,
                 eps: float, key, ts=None, impl=None):
    """Reverse VP-SDE Euler-Maruyama from t=1 to t=eps using the score model."""
    if ts is None:
        ts = itp.timesteps("diffusion", n_t, eps)
    hs = (ts[1:] - ts[:-1])[::-1]
    ts = ts[::-1]  # descending

    def step(carry, inp):
        x, k = carry
        t, h, feat, thr, leaf = inp
        f = PackedForest(feat, thr, leaf, forests_stacked.multi_output)
        score = predict_forest(x, f, depth, impl=impl)
        beta = itp.vp_beta(t)
        drift = -0.5 * beta * x - beta * score
        k, sub = jax.random.split(k)
        noise = jax.random.normal(sub, x.shape, x.dtype)
        x = x - drift * h + jnp.sqrt(beta * h) * noise
        return (x, k), None

    xs = (ts[: n_t - 1], hs, forests_stacked.feat[::-1][: n_t - 1],
          forests_stacked.thr_val[::-1][: n_t - 1],
          forests_stacked.leaf[::-1][: n_t - 1])
    (x0, _), _ = jax.lax.scan(step, (x1, key), xs)
    return x0
