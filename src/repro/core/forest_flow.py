"""ForestFlow / ForestDiffusion: the paper's system, re-engineered for JAX.

Memory discipline (paper §3.3, re-expressed for accelerators):

* Issue 1 — the [n_t, nK, p] array of noised inputs is never built. Each
  ensemble batch constructs its own x_t inside the jitted fit.
* Issue 2 — exactly one copy of X0 lives in memory; noise X1 is *never stored
  at all*: it is regenerated on device from a counter-based PRNG key (a
  strictly stronger version of the shared-memmap fix).
* Issue 3 — trained ensembles are streamed to disk per batch
  (``checkpoint_dir``) and training resumes from the manifest after failure.
* Issues 5-7 — classes are sorted/padded into dense [n_y, n_max, p] blocks
  (static-shape slices, no boolean-mask copies), one quantised code matrix is
  shared by all p outputs of an ensemble (DMatrix reuse), and everything is
  fp32.

Algorithmic additions from §3.4: multi-output trees, early stopping on a
fresh-noise validation set, per-class min-max scalers, empirical label
sampling.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ForestConfig
from repro.core import interpolants as itp
from repro.core.generate import diffusion_ddim, diffusion_em, flow_euler
from repro.forest.binning import edges_with_sentinel, transform
from repro.forest.boosting import fit_ensemble
from repro.forest.packed import PackedForest


def weighted_edges(x, w, n_bins: int):
    """Quantile edges over the rows with positive weight (padded rows excluded).

    x: [n, p]; w: [n]. Returns [p, n_bins - 1] fp32.
    """
    big = jnp.where(w[:, None] > 0, x, jnp.inf)
    s = jnp.sort(big, axis=0)
    n_real = jnp.sum(w > 0).astype(jnp.float32)
    qs = jnp.arange(1, n_bins, dtype=jnp.float32) / n_bins
    idx = jnp.clip((qs * (n_real - 1.0)).astype(jnp.int32), 0,
                   x.shape[0] - 1)
    return jnp.transpose(s[idx])


class ForestGenerativeModel:
    """User-facing trainer/sampler for tabular data.

    >>> model = ForestGenerativeModel(ForestConfig(n_t=8, duplicate_k=10))
    >>> model.fit(X, y, seed=0)
    >>> Xgen, ygen = model.generate(512, seed=1)
    """

    def __init__(self, fcfg: ForestConfig):
        self.fcfg = fcfg
        self.forests: Optional[Dict[str, np.ndarray]] = None
        self.val_curves: Optional[np.ndarray] = None
        self.best_rounds: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    # fitting
    # ------------------------------------------------------------------

    def _prepare(self, X: np.ndarray, y: Optional[np.ndarray]):
        X = np.asarray(X, np.float32)          # Issue 7: fp32 end-to-end
        n, p = X.shape
        if y is None:
            y = np.zeros((n,), np.int64)
        order = np.argsort(y, kind="stable")   # Issue 5: sort + slice
        X, y = X[order], np.asarray(y)[order]
        classes, counts = np.unique(y, return_counts=True)
        n_y = len(classes)
        n_max = int(counts.max())
        Xc = np.zeros((n_y, n_max, p), np.float32)
        Wc = np.zeros((n_y, n_max), np.float32)
        mins = np.zeros((n_y, p), np.float32)
        maxs = np.ones((n_y, p), np.float32)
        start = 0
        for i, c in enumerate(counts):
            rows = X[start:start + c]
            mins[i] = rows.min(axis=0)
            maxs[i] = rows.max(axis=0)
            scale = np.where(maxs[i] > mins[i], maxs[i] - mins[i], 1.0)
            rows = (rows - mins[i]) / scale * 2.0 - 1.0  # per-class scaler
            Xc[i, :c] = rows
            Xc[i, c:] = rows[0] if c else 0.0
            Wc[i, :c] = 1.0
            start += c
        self._classes = classes
        self._counts = counts
        self._mins, self._maxs = mins, maxs
        self._labels_sorted = y
        return Xc, Wc

    def fit(self, X, y=None, *, seed: int = 0,
            checkpoint_dir: Optional[str] = None, resume: bool = False,
            ensembles_per_batch: int = 0):
        fcfg = self.fcfg
        Xc, Wc = self._prepare(X, y)
        n_y, n_max, p = Xc.shape
        Xc_d = jnp.asarray(Xc)
        Wc_d = jnp.asarray(Wc)
        ts = np.asarray(itp.timesteps(fcfg.method, fcfg.n_t, fcfg.eps_diff,
                              fcfg.t_schedule))
        root = jax.random.PRNGKey(seed)

        K = fcfg.duplicate_k

        def fit_one(t, y_idx, eid):
            """Train the (t, y) ensemble; everything transient lives here."""
            x0 = Xc_d[y_idx]
            w = Wc_d[y_idx]
            x0d = jnp.repeat(x0, K, axis=0)                  # [mK, p]
            wd = jnp.repeat(w, K, axis=0)
            k_tr = jax.random.fold_in(root, eid * 2)
            k_va = jax.random.fold_in(root, eid * 2 + 1)
            x1 = jax.random.normal(k_tr, x0d.shape, jnp.float32)
            xt, tgt = itp.make_xt_target(fcfg.method, x0d, x1, t,
                                         fcfg.sigma, k_tr)
            edges = weighted_edges(xt, wd, fcfg.n_bins)
            codes = transform(xt, edges)
            x1v = jax.random.normal(k_va, x0d.shape, jnp.float32)
            xtv, tgtv = itp.make_xt_target(fcfg.method, x0d, x1v, t,
                                           fcfg.sigma, k_va)
            codes_v = transform(xtv, edges)
            res = fit_ensemble(codes, tgt, wd, edges_with_sentinel(edges),
                               codes_v, tgtv, wd, fcfg)
            return res

        fit_batch = jax.jit(jax.vmap(fit_one, in_axes=(0, 0, 0)))

        grid = [(ti, yi) for ti in range(fcfg.n_t) for yi in range(n_y)]
        bs = ensembles_per_batch or max(1, min(len(grid), 8))
        manifest_path = (os.path.join(checkpoint_dir, "manifest.json")
                         if checkpoint_dir else None)
        done = set()
        if resume and manifest_path and os.path.exists(manifest_path):
            with open(manifest_path) as f:
                done = set(tuple(e) for e in json.load(f)["batches"])

        results = {}
        for b0 in range(0, len(grid), bs):
            chunk = grid[b0:b0 + bs]
            key_id = (b0, len(chunk))
            if key_id in done:
                data = np.load(os.path.join(checkpoint_dir, f"batch_{b0}.npz"))
                res_np = {k: data[k] for k in data.files}
            else:
                t_arr = jnp.asarray([ts[ti] for ti, _ in chunk], jnp.float32)
                y_arr = jnp.asarray([yi for _, yi in chunk], jnp.int32)
                e_arr = jnp.asarray([ti * n_y + yi for ti, yi in chunk],
                                    jnp.int32)
                res = fit_batch(t_arr, y_arr, e_arr)
                res_np = {
                    "feat": np.asarray(res.feat),
                    "thr_val": np.asarray(res.thr_val),
                    "leaf": np.asarray(res.leaf),
                    "best_round": np.asarray(res.best_round),
                    "rounds_run": np.asarray(res.rounds_run),
                    "val_curve": np.asarray(res.val_curve),
                }
                if checkpoint_dir:   # Issue 3: stream to disk, checkpointed
                    os.makedirs(checkpoint_dir, exist_ok=True)
                    np.savez(os.path.join(checkpoint_dir, f"batch_{b0}.npz"),
                             **res_np)
                    done.add(key_id)
                    with open(manifest_path, "w") as f:
                        json.dump({"batches": sorted(done)}, f)
            for j, (ti, yi) in enumerate(chunk):
                results[(ti, yi)] = {k: v[j] for k, v in res_np.items()}

        # stack into [n_t, n_y, ...]
        def stack(field):
            return np.stack([
                np.stack([results[(ti, yi)][field] for yi in range(n_y)])
                for ti in range(fcfg.n_t)])

        self.forests = {k: stack(k) for k in
                        ("feat", "thr_val", "leaf", "best_round", "rounds_run",
                         "val_curve")}
        self.n_y = n_y
        self.p = p
        return self

    # ------------------------------------------------------------------
    # generation
    # ------------------------------------------------------------------

    def _sample_labels(self, n: int, rng: np.random.Generator):
        counts = self._counts
        if self.fcfg.label_sampler == "multinomial":
            probs = counts / counts.sum()
            idx = rng.choice(len(counts), size=n, p=probs)
        else:  # empirical label distribution (paper C.4)
            reps = np.floor(n * counts / counts.sum()).astype(int)
            rem = n - reps.sum()
            frac = n * counts / counts.sum() - reps
            extra = np.argsort(-frac)[:rem]
            reps[extra] += 1
            idx = np.repeat(np.arange(len(counts)), reps)
        idx.sort()
        return idx

    def generate(self, n: int, *, seed: int = 0):
        assert self.forests is not None, "fit() first"
        fcfg = self.fcfg
        rng = np.random.default_rng(seed)
        label_idx = self._sample_labels(n, rng)
        key = jax.random.PRNGKey(seed + 7)
        ts = np.asarray(itp.timesteps(fcfg.method, fcfg.n_t, fcfg.eps_diff,
                                      fcfg.t_schedule))
        outs, labels = [], []
        for yi in range(self.n_y):
            n_c = int((label_idx == yi).sum())
            if n_c == 0:
                continue
            key, k1, k2 = jax.random.split(key, 3)
            x1 = jax.random.normal(k1, (n_c, self.p), jnp.float32)
            stacked = PackedForest(
                jnp.asarray(self.forests["feat"][:, yi]),
                jnp.asarray(self.forests["thr_val"][:, yi]),
                jnp.asarray(self.forests["leaf"][:, yi]),
                fcfg.multi_output)
            ts_d = jnp.asarray(ts)
            if fcfg.method == "flow":
                x0 = flow_euler(x1, stacked, fcfg.max_depth, fcfg.n_t,
                                ts=ts_d)
            elif fcfg.diff_sampler == "em":
                x0 = diffusion_em(x1, stacked, fcfg.max_depth, fcfg.n_t,
                                  fcfg.eps_diff, k2, ts=ts_d)
            else:
                x0 = diffusion_ddim(x1, stacked, fcfg.max_depth, fcfg.n_t,
                                    fcfg.eps_diff, ts=ts_d)
            x0 = np.asarray(x0)
            scale = np.where(self._maxs[yi] > self._mins[yi],
                             self._maxs[yi] - self._mins[yi], 1.0)
            x0 = (x0 + 1.0) / 2.0 * scale + self._mins[yi]
            outs.append(x0)
            labels.append(np.full((n_c,), self._classes[yi]))
        X = np.concatenate(outs, axis=0)
        yv = np.concatenate(labels, axis=0)
        perm = rng.permutation(len(X))
        return X[perm], yv[perm]

    # ------------------------------------------------------------------
    # imputation (the companion capability of Jolicoeur-Martineau et al.:
    # REPAINT-style clamping of observed features along the reverse solve)
    # ------------------------------------------------------------------

    def impute(self, X_missing, y=None, *, seed: int = 0, refine_rounds: int = 3):
        """Fill NaNs. Observed features are clamped to a fixed-noise bridge at
        every solver step; the whole solve is then repeated ``refine_rounds``
        times from annealed restart times (re-noising the previous imputation)
        so the conditioning — which only becomes informative at small t —
        propagates back through the trajectory (RePaint-style refinement for
        a deterministic solver)."""
        assert self.forests is not None, "fit() first"
        fcfg = self.fcfg
        X_missing = np.asarray(X_missing, np.float32)
        n, p = X_missing.shape
        if y is None:
            assert self.n_y == 1, "labels required for conditional models"
            y_idx = np.zeros((n,), int)
        else:
            lut = {c: i for i, c in enumerate(self._classes)}
            y_idx = np.asarray([lut[v] for v in np.asarray(y)])
        out = X_missing.copy()
        key = jax.random.PRNGKey(seed + 31)
        ts = np.asarray(itp.timesteps(fcfg.method, fcfg.n_t, fcfg.eps_diff,
                              fcfg.t_schedule))
        h = 1.0 / (fcfg.n_t - 1)
        for yi in range(self.n_y):
            sel = np.where(y_idx == yi)[0]
            if len(sel) == 0:
                continue
            rows = X_missing[sel]
            mask = ~np.isnan(rows)                      # observed
            scale = np.where(self._maxs[yi] > self._mins[yi],
                             self._maxs[yi] - self._mins[yi], 1.0)
            obs = (np.nan_to_num(rows) - self._mins[yi]) / scale * 2 - 1
            key, k1, k_fix = jax.random.split(key, 3)
            m = jnp.asarray(mask)
            obs_d = jnp.asarray(obs)
            # one fixed noise draw -> observed coords follow a single
            # consistent bridge path across all solver steps
            eps_fix = jax.random.normal(k_fix, (len(sel), p), jnp.float32)
            stacked = PackedForest(
                jnp.asarray(self.forests["feat"][:, yi]),
                jnp.asarray(self.forests["thr_val"][:, yi]),
                jnp.asarray(self.forests["leaf"][:, yi]),
                fcfg.multi_output)
            from repro.forest.packed import predict_forest

            x0_est = jnp.zeros((len(sel), p), jnp.float32)
            for r in range(max(1, refine_rounds)):
                # annealed restart: round 0 from pure noise at t=1; later
                # rounds re-noise the previous estimate from smaller t
                frac = 1.0 if r == 0 else float(ts[-1]) * (0.6 ** r)
                i_start = int(np.argmin(np.abs(ts - frac)))
                i_start = max(i_start, 1)
                key, kr = jax.random.split(key)
                eps_r = jax.random.normal(kr, (len(sel), p), jnp.float32)
                t0 = float(ts[i_start])
                if fcfg.method == "flow":
                    x = t0 * eps_r + (1 - t0) * x0_est
                else:
                    a0, s0 = itp.vp_alpha_sigma(jnp.float32(t0))
                    x = a0 * x0_est + s0 * eps_r
                for i in range(i_start, 0, -1):
                    t = float(ts[i])
                    h_i = float(ts[i] - ts[i - 1])
                    f = PackedForest(stacked.feat[i], stacked.thr_val[i],
                                     stacked.leaf[i], fcfg.multi_output)
                    if fcfg.method == "flow":
                        bridge = t * eps_fix + (1 - t) * obs_d
                        x = jnp.where(m, bridge, x)
                        x = x - h_i * predict_forest(x, f, fcfg.max_depth)
                    else:
                        a, s_ = itp.vp_alpha_sigma(jnp.float32(t))
                        x = jnp.where(m, a * obs_d + s_ * eps_fix, x)
                        score = predict_forest(x, f, fcfg.max_depth)
                        t_next = float(ts[i - 1])
                        a2, s2 = itp.vp_alpha_sigma(jnp.float32(t_next))
                        eps_hat = -s_ * score
                        x0_hat = jnp.clip((x - s_ * eps_hat) / a, -1.5, 1.5)
                        eps_hat = (x - a * x0_hat) / s_
                        x = a2 * x0_hat + s2 * eps_hat
                x0_est = jnp.where(m, obs_d, x)
            x = x0_est
            vals = (np.asarray(x) + 1) / 2 * scale + self._mins[yi]
            filled = np.where(mask, rows, vals)
            out[sel] = filled
        return out

    # ------------------------------------------------------------------
    # diagnostics
    # ------------------------------------------------------------------

    def trees_at_best_iteration(self):
        """Paper Fig. 3: number of trees kept per timestep (mean over y, subs)."""
        br = self.forests["best_round"]  # [n_t, n_y, n_sub]
        return np.mean(br + 1, axis=(1, 2))
