"""Deprecation shim: ``ForestGenerativeModel`` over :mod:`repro.tabgen`.

The monolithic trainer/sampler that used to live here was carved into the
composable ``repro.tabgen`` subsystem:

* training            -> :func:`repro.tabgen.fit_artifacts`
* trained state       -> :class:`repro.tabgen.ForestArtifacts` (a pytree
                         with ``save``/``load``)
* sampling            -> :func:`repro.tabgen.sample` (registry-dispatched,
                         one jitted class-vmapped program per call)
* imputation          -> :func:`repro.tabgen.impute`
* mixed-type frontend -> :class:`repro.tabgen.TabularGenerator`

This class remains so existing code keeps working; new code should use the
``tabgen`` API directly.
"""
from __future__ import annotations

import warnings
from typing import Optional

import numpy as np

from repro.config import ForestConfig
from repro.tabgen.artifacts import ForestArtifacts
from repro.tabgen.fitting import fit_artifacts, weighted_edges  # noqa: F401
from repro.tabgen.imputation import impute as _impute
from repro.tabgen.sampling import sample as _sample


class ForestGenerativeModel:
    """Deprecated facade kept for backward compatibility.

    >>> model = ForestGenerativeModel(ForestConfig(n_t=8, duplicate_k=10))
    >>> model.fit(X, y, seed=0)
    >>> Xgen, ygen = model.generate(512, seed=1)
    """

    def __init__(self, fcfg: ForestConfig):
        warnings.warn(
            "ForestGenerativeModel is deprecated; use repro.tabgen "
            "(TabularGenerator / fit_artifacts + sample)",
            DeprecationWarning, stacklevel=2)
        self.fcfg = fcfg
        self.artifacts: Optional[ForestArtifacts] = None
        self._forests_host = None

    def fit(self, X, y=None, *, seed: int = 0,
            checkpoint_dir: Optional[str] = None, resume: bool = False,
            ensembles_per_batch: int = 0):
        self.artifacts = fit_artifacts(
            X, y, self.fcfg, seed=seed, checkpoint_dir=checkpoint_dir,
            resume=resume, ensembles_per_batch=ensembles_per_batch)
        self._forests_host = None
        return self

    def generate(self, n: int, *, seed: int = 0):
        assert self.artifacts is not None, "fit() first"
        return _sample(self.artifacts, n, seed=seed)

    def impute(self, X_missing, y=None, *, seed: int = 0,
               refine_rounds: int = 3):
        assert self.artifacts is not None, "fit() first"
        return _impute(self.artifacts, X_missing, y, seed=seed,
                       refine_rounds=refine_rounds)

    def trees_at_best_iteration(self):
        return self.artifacts.trees_at_best_iteration()

    # -- legacy attribute surface ------------------------------------------

    @property
    def forests(self):
        if self.artifacts is None:
            return None
        if self._forests_host is None:  # device->host copy once, not per access
            self._forests_host = {
                k: np.asarray(getattr(self.artifacts, k)) for k in
                ("feat", "thr_val", "leaf", "best_round", "rounds_run",
                 "val_curve")}
        return self._forests_host

    @property
    def n_y(self):
        return self.artifacts.n_y

    @property
    def p(self):
        return self.artifacts.p

    @property
    def _classes(self):
        return np.asarray(self.artifacts.classes)

    @property
    def _counts(self):
        return np.asarray(self.artifacts.counts)

    @property
    def _mins(self):
        return np.asarray(self.artifacts.mins)

    @property
    def _maxs(self):
        return np.asarray(self.artifacts.maxs)
