"""Prometheus text exposition (format version 0.0.4) for MetricsRegistry.

One function, :func:`render_prometheus`, turns one or more registries into
the plain-text family format every scraper understands:

* counters gain the ``_total`` suffix on exposition (instruments store the
  base name, e.g. ``serving_rows`` -> ``serving_rows_total``), matching
  the official client-library convention;
* histograms expand to cumulative ``<name>_bucket{le="..."}`` series
  (``+Inf`` included) plus ``<name>_sum`` / ``<name>_count``;
* label values escape backslash, double-quote, and newline; ``# HELP``
  text escapes backslash and newline.

``GET /metrics`` in :mod:`repro.launch.serve_http` and the offline
:mod:`repro.launch.metrics` dump CLI both call this; serve it with
:data:`CONTENT_TYPE` so Prometheus autodetects the format.
"""
from __future__ import annotations

from repro.obs.metrics import Counter, Histogram, MetricsRegistry

__all__ = ["render_prometheus", "CONTENT_TYPE"]

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def _escape_label(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _escape_help(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n")


def _fmt(v: float) -> str:
    """Value formatting: integers bare (``7`` not ``7.0``), floats repr."""
    f = float(v)
    if f != f:
        return "NaN"
    if f in (float("inf"), float("-inf")):
        return "+Inf" if f > 0 else "-Inf"
    if f.is_integer() and abs(f) < 2**53:
        return str(int(f))
    return repr(f)


def _labels_str(labelnames, key, extra=()) -> str:
    pairs = [f'{ln}="{_escape_label(val)}"'
             for ln, val in zip(labelnames, key)]
    pairs.extend(f'{ln}="{_escape_label(val)}"' for ln, val in extra)
    return "{" + ",".join(pairs) + "}" if pairs else ""


def render_prometheus(*registries: MetricsRegistry) -> str:
    """Render registries to Prometheus text; duplicates collapse by id.

    Accepts several registries because serving components each own a
    private one unless the caller wires a shared registry through — the
    exporter unions them (instrument names are namespaced per subsystem,
    so families never collide; a genuine name collision raises).
    """
    seen_regs, regs = set(), []
    for r in registries:
        if id(r) not in seen_regs:
            seen_regs.add(id(r))
            regs.append(r)

    lines = []
    seen_names = set()
    for reg in regs:
        for inst in reg.collect():
            name = inst.name
            if isinstance(inst, Counter) and not name.endswith("_total"):
                name = name + "_total"
            if name in seen_names:
                raise ValueError(
                    f"metric family {name!r} exported by two registries")
            seen_names.add(name)

            if inst.help:
                lines.append(f"# HELP {name} {_escape_help(inst.help)}")
            lines.append(f"# TYPE {name} {inst.kind}")

            series = inst.series()
            if isinstance(inst, Histogram):
                for key in sorted(series):
                    s = series[key]
                    acc = 0
                    for bound, n in zip(inst.buckets, s["buckets"]):
                        acc += n
                        ls = _labels_str(inst.labelnames, key,
                                         extra=(("le", _fmt(bound)),))
                        lines.append(f"{name}_bucket{ls} {_fmt(acc)}")
                    ls = _labels_str(inst.labelnames, key,
                                     extra=(("le", "+Inf"),))
                    lines.append(f"{name}_bucket{ls} {_fmt(s['count'])}")
                    ls = _labels_str(inst.labelnames, key)
                    lines.append(f"{name}_sum{ls} {_fmt(s['sum'])}")
                    lines.append(f"{name}_count{ls} {_fmt(s['count'])}")
            else:
                for key in sorted(series):
                    ls = _labels_str(inst.labelnames, key)
                    lines.append(f"{name}{ls} {_fmt(series[key])}")
    return "\n".join(lines) + "\n"
