"""ResourceMonitor: where do the bytes actually go, as gauges.

The paper's core finding is that the method's memory blow-ups were
implementation artifacts — which makes live resource telemetry a product
feature of this repro, not a nicety.  :class:`ResourceMonitor` samples

* host RSS (current + peak, from ``/proc/self/status``, with a
  ``resource.getrusage`` fallback),
* jax device memory: backend allocator stats when the platform exposes
  them (``device.memory_stats()`` — present on TPU/GPU, ``None`` on CPU)
  plus a backend-independent proxy, live ``jax.Array`` bytes per device,
* the jit executable-cache entry count (compile-cache pressure — the
  recompile-leak signal JX003 guards statically),
* live queue depths per priority from an
  :class:`~repro.serving.admission.AdmissionController`,
* hot-model bytes / counts from a
  :class:`~repro.serving.registry.ModelRegistry`,

into ``resource_*`` gauges on a :class:`~repro.obs.MetricsRegistry`
(default: the process-wide :func:`repro.obs.default_registry`), so a
serving process that shares its registry with the monitor carries them on
``GET /metrics`` with zero extra wiring.

``sample()`` is one synchronous pass (used by ``repro.launch.metrics
--resource`` for offline dumps); ``start()``/``stop()`` run the same pass
on a daemon thread every ``interval_s`` seconds and are idempotent —
``start()`` on a running monitor is a no-op, as is ``stop()`` on a
stopped one.  Sampling never raises out of the background thread: a jax
backend that refuses introspection degrades to the host-side gauges.

Stdlib-only at import time — jax is imported lazily inside the sampling
pass, keeping :mod:`repro.obs` importable from the linter's bare CI lane.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

from repro.obs.metrics import MetricsRegistry

__all__ = ["ResourceMonitor"]


def _host_rss() -> Tuple[int, int]:
    """(current_rss_bytes, peak_rss_bytes), best effort.

    ``/proc/self/status`` gives both on Linux; the ``getrusage`` fallback
    only knows the peak, which is then reported for both.
    """
    try:
        cur = peak = 0
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    cur = int(line.split()[1]) * 1024
                elif line.startswith("VmHWM:"):
                    peak = int(line.split()[1]) * 1024
        if cur:
            return cur, peak or cur
    except (OSError, ValueError, IndexError):
        pass
    try:
        import resource
        peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
        return peak, peak
    except Exception:
        return 0, 0


def _jit_cache_entries() -> Optional[int]:
    """Entries across jax's C++ pjit executable caches, or ``None`` when
    the (private, version-dependent) introspection surface is absent."""
    try:
        from jax._src import pjit as _pjit
    except Exception:
        return None
    total, found = 0, False
    for attr in ("_cpp_pjit_cache_fun_only",
                 "_cpp_pjit_cache_explicit_attributes"):
        cache = getattr(_pjit, attr, None)
        size = getattr(cache, "size", None)
        if callable(size):
            try:
                total += int(size())
                found = True
            except Exception:
                pass
    return total if found else None


class ResourceMonitor:
    """Background sampler publishing ``resource_*`` gauges.

    ``admission`` and ``registry`` are optional serving-plane hooks: when
    given, queue depths and hot-model placement ride the same sample.
    Pass the serving process's shared ``metrics`` registry (as
    ``serve_http`` does) so ``/metrics`` carries the gauges; the default
    is the process-wide registry, which ``repro.launch.metrics`` dumps.
    """

    def __init__(self, metrics: Optional[MetricsRegistry] = None, *,
                 interval_s: float = 5.0,
                 admission=None, registry=None):
        if interval_s <= 0:
            raise ValueError(f"interval_s={interval_s} must be > 0")
        if metrics is None:
            from repro.obs import default_registry
            metrics = default_registry()
        self.metrics = metrics
        self.interval_s = float(interval_s)
        self.admission = admission
        self.registry = registry
        m = metrics
        self._g_rss = m.gauge(
            "resource_rss_bytes", "Host resident set size (current)")
        self._g_rss_peak = m.gauge(
            "resource_rss_peak_bytes", "Host resident set size (peak)")
        self._g_dev_buffers = m.gauge(
            "resource_device_buffer_bytes",
            "Live jax.Array bytes per device (backend-independent)",
            ("device",))
        self._g_dev_mem = m.gauge(
            "resource_device_memory_bytes",
            "Backend allocator stats per device (bytes_in_use, "
            "peak_bytes_in_use, ...); absent on backends without "
            "memory_stats (CPU)", ("device", "kind"))
        self._g_live_arrays = m.gauge(
            "resource_live_arrays", "Live jax.Array count in the process")
        self._g_jit_cache = m.gauge(
            "resource_jit_cache_entries",
            "Entries in jax's compiled-executable caches")
        self._g_queue_depth = m.gauge(
            "resource_queue_depth",
            "Admission queue depth per priority class (sampled)",
            ("priority",))
        self._g_hot_bytes = m.gauge(
            "resource_hot_model_bytes",
            "Device-placed model bytes (sampled from the model registry)")
        self._g_hot_models = m.gauge(
            "resource_hot_models", "Device-placed model count (sampled)")
        self._m_samples = m.counter(
            "resource_samples", "Resource sampling passes completed")
        self._lifecycle = threading.Lock()
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- one sampling pass ---------------------------------------------------

    def _sample_jax(self, out: dict) -> None:
        """Device + compile-cache gauges; every probe is allowed to fail
        independently (CPU has no memory_stats, old jax no live_arrays)."""
        import jax
        try:
            arrays = jax.live_arrays()
        except Exception:
            arrays = None
        if arrays is not None:
            per_dev: Dict[str, int] = {}
            for a in arrays:
                try:
                    devs = list(a.devices())
                    nbytes = int(a.nbytes)
                except Exception:
                    continue
                for d in devs:
                    key = f"{d.platform}:{d.id}"
                    # replicated arrays charge every device holding a copy
                    per_dev[key] = per_dev.get(key, 0) + nbytes
            with self.metrics.lock:
                self._g_dev_buffers.reset()
                for dev, nbytes in per_dev.items():
                    self._g_dev_buffers.set(nbytes, device=dev)
            self._g_live_arrays.set(len(arrays))
            out["live_arrays"] = len(arrays)
            out["device_buffer_bytes"] = per_dev
        mem: Dict[str, Dict[str, int]] = {}
        for d in jax.devices():
            try:
                stats = d.memory_stats()
            except Exception:
                stats = None
            if not stats:
                continue
            key = f"{d.platform}:{d.id}"
            mem[key] = {}
            for kind in ("bytes_in_use", "peak_bytes_in_use",
                         "bytes_limit", "largest_alloc_size"):
                if kind in stats:
                    mem[key][kind] = int(stats[kind])
                    self._g_dev_mem.set(stats[kind], device=key, kind=kind)
        if mem:
            out["device_memory"] = mem
        entries = _jit_cache_entries()
        if entries is not None:
            self._g_jit_cache.set(entries)
            out["jit_cache_entries"] = entries

    def sample(self) -> dict:
        """One synchronous pass: update every gauge, return the readings.

        The returned dict is JSON-serializable (what ``repro.launch.metrics
        --resource`` prints next to the Prometheus dump).
        """
        out: dict = {}
        cur, peak = _host_rss()
        self._g_rss.set(cur)
        self._g_rss_peak.set(peak)
        out["rss_bytes"], out["rss_peak_bytes"] = cur, peak
        try:
            self._sample_jax(out)
        except Exception:
            pass  # no jax (bare checkout) or a backend refusing introspection
        if self.admission is not None:
            depths = self.admission.queued()
            for prio, depth in depths.items():
                self._g_queue_depth.set(depth, priority=prio)
            out["queue_depth"] = dict(depths)
        if self.registry is not None:
            hot_bytes = self.registry.hot_bytes()
            hot_models = len(self.registry.hot_names())
            self._g_hot_bytes.set(hot_bytes)
            self._g_hot_models.set(hot_models)
            out["hot_model_bytes"] = int(hot_bytes)
            out["hot_models"] = hot_models
        self._m_samples.inc()
        return out

    # -- background lifecycle ------------------------------------------------

    def _loop(self) -> None:
        while True:
            try:
                self.sample()
            except Exception:
                pass  # a failed pass must never kill the sampler thread
            if self._stop_evt.wait(self.interval_s):
                return

    def start(self) -> bool:
        """Start the sampler thread (samples immediately, then every
        ``interval_s``).  Idempotent: returns False when already running."""
        with self._lifecycle:
            if self._thread is not None and self._thread.is_alive():
                return False
            self._stop_evt.clear()
            self._thread = threading.Thread(
                target=self._loop, name="resource-monitor", daemon=True)
            self._thread.start()
            return True

    def stop(self, timeout: float = 5.0) -> bool:
        """Stop the sampler thread.  Idempotent: returns False when not
        running.  A stopped monitor can be ``start()``ed again."""
        with self._lifecycle:
            t, self._thread = self._thread, None
            if t is None or not t.is_alive():
                return False
            self._stop_evt.set()
        t.join(timeout)
        return True

    @property
    def running(self) -> bool:
        with self._lifecycle:
            return self._thread is not None and self._thread.is_alive()
