"""Typed metric instruments behind one lock: the process metrics model.

A :class:`MetricsRegistry` holds named instruments — :class:`Counter`,
:class:`Gauge`, and fixed-bucket :class:`Histogram` — each carrying an
optional label set.  Every instrument in a registry shares the registry's
single re-entrant lock, so ``snapshot()`` is a *consistent* cut: no reader
can observe a counter from before an update and a histogram from after it.
That is the property ``/statz`` and ``/metrics`` lean on to never disagree
(both are views over the same snapshot).

Design points, deliberately boring:

* stdlib-only — ``threading`` + ``bisect``; importable from the linter's
  bare-checkout CI lane and from worker threads without touching jax.
* get-or-create registration — ``registry.counter("serving_rows", ...)``
  returns the existing instrument when called twice with the same schema
  and raises on a type/label mismatch, so modules can declare their
  instruments at construction time without coordinating import order.
* label values key a dict per instrument; series appear on first touch
  (Prometheus semantics: an unobserved series does not exist).
* counters are monotonic (negative increments raise); the one sanctioned
  exception is :meth:`Counter.reset`, used by ``ModelRegistry.register``
  to mimic the legacy "re-register wipes that model's stats" behavior.

Instruments here are *storage*; the text exposition format lives in
:mod:`repro.obs.export` and span timing in :mod:`repro.obs.tracing`.
"""
from __future__ import annotations

import bisect
import re
import threading
from typing import Dict, List, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_BUCKETS",
]

# latency-ish default edges (seconds): sub-ms through tens of seconds.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
)

_NAME_OK = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_OK = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _check_name(name: str) -> None:
    if not _NAME_OK.match(name):
        raise ValueError(f"invalid metric name {name!r}")


class _Instrument:
    """Shared plumbing: name/help/labelnames + the registry lock."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str],
                 lock: threading.RLock):
        _check_name(name)
        for ln in labelnames:
            if not _LABEL_OK.match(ln):
                raise ValueError(f"invalid label name {ln!r} on {name!r}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = lock
        self._series: Dict[Tuple[str, ...], object] = {}

    def _key(self, labels: Dict[str, object]) -> Tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: got labels {sorted(labels)}, "
                f"declared {list(self.labelnames)}")
        return tuple(str(labels[ln]) for ln in self.labelnames)

    def series(self) -> Dict[Tuple[str, ...], object]:
        """Label-tuple -> value map (a copy; values are plain data)."""
        with self._lock:
            return dict(self._series)

    def reset(self, **labels) -> None:
        """Drop series whose labels match the given subset (all if empty).

        Two users: the one legacy surface that wipes stats in place
        (model re-registration), and sampled gauges whose label sets
        shrink between passes — ``ResourceMonitor`` resets its per-device
        gauge before republishing so a freed device's series disappears
        instead of reporting its last value forever.  Scrapers see a
        dropped counter series restart at zero, which Prometheus treats
        as a counter reset.
        """
        with self._lock:
            if not labels:
                self._series.clear()
                return
            idx = [(self.labelnames.index(k), str(v))
                   for k, v in labels.items()]
            for key in [k for k in self._series
                        if all(k[i] == v for i, v in idx)]:
                del self._series[key]


class Counter(_Instrument):
    """Monotonically increasing float, one value per label set."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> float:
        if value < 0:
            raise ValueError(f"{self.name}: counter increment {value} < 0")
        key = self._key(labels)
        with self._lock:
            v = self._series.get(key, 0.0) + value
            self._series[key] = v
            return v

    def get(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(self._key(labels), 0.0))

    def sum(self, **labels) -> float:
        """Total over every series matching the given label subset."""
        idx = [(self.labelnames.index(k), str(v)) for k, v in labels.items()]
        with self._lock:
            return float(sum(
                v for k, v in self._series.items()
                if all(k[i] == want for i, want in idx)))


class Gauge(_Instrument):
    """Settable value that can go up and down."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._series[key] = float(value)

    def inc(self, value: float = 1.0, **labels) -> float:
        key = self._key(labels)
        with self._lock:
            v = self._series.get(key, 0.0) + value
            self._series[key] = v
            return v

    def dec(self, value: float = 1.0, **labels) -> float:
        return self.inc(-value, **labels)

    def set_max(self, value: float, **labels) -> None:
        """Ratchet: keep the running maximum of observed values."""
        key = self._key(labels)
        with self._lock:
            if value > self._series.get(key, float("-inf")):
                self._series[key] = float(value)

    def get(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(self._key(labels), 0.0))


class Histogram(_Instrument):
    """Fixed-bucket histogram: per-series bucket counts + sum + count.

    ``buckets`` are finite upper bounds (inclusive, Prometheus ``le``
    semantics); the ``+Inf`` bucket is implicit.  ``observe`` costs one
    bisect and three writes under the registry lock.
    """

    kind = "histogram"

    def __init__(self, name, help, labelnames, lock,
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames, lock)
        bs = tuple(float(b) for b in buckets)
        if not bs or list(bs) != sorted(set(bs)):
            raise ValueError(f"{name}: buckets must be sorted and unique")
        if any(b != b or b in (float("inf"), float("-inf")) for b in bs):
            raise ValueError(f"{name}: buckets must be finite")
        self.buckets = bs

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        i = bisect.bisect_left(self.buckets, float(value))
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = {"buckets": [0] * len(self.buckets), "sum": 0.0,
                     "count": 0}
                self._series[key] = s
            if i < len(self.buckets):
                s["buckets"][i] += 1
            s["sum"] += float(value)
            s["count"] += 1

    def get(self, **labels) -> Dict[str, object]:
        """``{"buckets": [per-bucket counts], "sum": float, "count": int}``
        (zeros for an untouched series)."""
        key = self._key(labels)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                return {"buckets": [0] * len(self.buckets), "sum": 0.0,
                        "count": 0}
            return {"buckets": list(s["buckets"]), "sum": s["sum"],
                    "count": s["count"]}

    def sum(self, **labels) -> float:
        """Total of ``sum`` over series matching the label subset."""
        idx = [(self.labelnames.index(k), str(v)) for k, v in labels.items()]
        with self._lock:
            return float(sum(
                s["sum"] for k, s in self._series.items()
                if all(k[i] == want for i, want in idx)))

    def count(self, **labels) -> int:
        """Total of ``count`` over series matching the label subset."""
        idx = [(self.labelnames.index(k), str(v)) for k, v in labels.items()]
        with self._lock:
            return int(sum(
                s["count"] for k, s in self._series.items()
                if all(k[i] == want for i, want in idx)))

    def series(self):
        with self._lock:
            return {k: {"buckets": list(s["buckets"]), "sum": s["sum"],
                        "count": s["count"]}
                    for k, s in self._series.items()}


class MetricsRegistry:
    """Process- or component-scoped set of instruments, one shared lock.

    Serving components default to a *private* registry apiece so tests and
    benchmark arms never bleed counters into each other; ``serve_http``
    hands one shared registry to every component so ``/metrics`` is a
    single family set.  Offline paths (fit pipeline, ingest) use the
    module-level default registry from :func:`repro.obs.default_registry`.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._instruments: Dict[str, _Instrument] = {}

    @property
    def lock(self) -> threading.RLock:
        """The shared instrument lock (re-entrant).  Hold it to make a
        multi-instrument read one consistent cut — e.g. the serving
        ``stats_snapshot()`` folds several instruments into one dict."""
        return self._lock

    # -- registration (get-or-create, schema-checked) -----------------------

    def _get_or_create(self, cls, name, help, labelnames, **kwargs):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is not None:
                if not isinstance(inst, cls) or \
                        inst.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"{name!r} re-registered as {cls.kind}"
                        f"{tuple(labelnames)}, was {inst.kind}"
                        f"{inst.labelnames}")
                return inst
            inst = cls(name, help, labelnames, self._lock, **kwargs)
            self._instruments[name] = inst
            return inst

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    # -- read side -----------------------------------------------------------

    def collect(self) -> List[_Instrument]:
        """Instruments sorted by name (stable exposition order)."""
        with self._lock:
            return [self._instruments[n] for n in sorted(self._instruments)]

    def snapshot(self) -> Dict[str, dict]:
        """One consistent cut of every instrument in the registry.

        ``{name: {"kind", "help", "labelnames", "values": {labels: v}}}``
        where ``v`` is a float for counters/gauges and a
        ``{"buckets", "sum", "count"}`` dict (plus ``"bucket_bounds"`` at
        the instrument level) for histograms.  Taken under the shared lock,
        so cross-instrument invariants (requests vs rows, sum vs count)
        hold within one snapshot.
        """
        with self._lock:
            out = {}
            for name in sorted(self._instruments):
                inst = self._instruments[name]
                entry = {
                    "kind": inst.kind,
                    "help": inst.help,
                    "labelnames": list(inst.labelnames),
                    "values": inst.series(),
                }
                if isinstance(inst, Histogram):
                    entry["bucket_bounds"] = list(inst.buckets)
                out[name] = entry
            return out
