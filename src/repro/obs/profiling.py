"""On-demand, bounded jax profiler captures for a live server.

``POST /debug/profile`` on the serving front end lands here: a
:class:`Profiler` owns a capture directory and runs one
``jax.profiler.start_trace`` / ``stop_trace`` window at a time.  Two
guard rails make it safe to expose on a production port (behind the
admin token):

* **bounded** — ``duration_s`` is clamped to ``max_seconds``; a typo'd
  ``duration_s=3600`` cannot pin the profiler (and its host-side event
  buffering) for an hour.
* **exclusive** — jax supports one active trace per process; a second
  ``capture()`` while one runs raises :class:`ProfileInProgress`
  immediately (HTTP 409) instead of corrupting the first capture.

Captures land in numbered subdirectories (``capture-0001``, ...) of the
base dir, viewable with ``tensorboard --logdir`` or xprof.  Stdlib-only
at import time; jax loads inside ``capture()``.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Optional

__all__ = ["ProfileInProgress", "Profiler"]


class ProfileInProgress(RuntimeError):
    """A capture is already running; jax allows one trace per process."""


class Profiler:
    """Serialized, duration-clamped ``jax.profiler`` captures.

    ``base_dir`` is created on first use.  ``capture()`` blocks the
    *calling* thread for the capture window (the HTTP front end calls it
    from the request handler thread, so the POST returns when the trace
    is on disk) while other threads keep serving.
    """

    def __init__(self, base_dir: str, *, max_seconds: float = 10.0):
        if max_seconds <= 0:
            raise ValueError(f"max_seconds={max_seconds} must be > 0")
        self.base_dir = base_dir
        self.max_seconds = float(max_seconds)
        self._lock = threading.Lock()  # non-reentrant: one capture at a time
        self._captures = 0

    @property
    def active(self) -> bool:
        """True while a capture window is open (used by tests/statz)."""
        if self._lock.acquire(blocking=False):
            self._lock.release()
            return False
        return True

    def capture(self, duration_s: float,
                *, out_dir: Optional[str] = None) -> dict:
        """Run one bounded trace window; returns capture metadata.

        Raises :class:`ProfileInProgress` when a capture is already
        running, ``ValueError`` on a non-positive duration.  Durations
        beyond ``max_seconds`` are clamped, not rejected — the caller
        learns the effective window from the returned ``duration_s``.
        """
        duration_s = float(duration_s)
        if duration_s <= 0:
            raise ValueError(f"duration_s={duration_s} must be > 0")
        duration_s = min(duration_s, self.max_seconds)
        if not self._lock.acquire(blocking=False):
            raise ProfileInProgress(
                "a profiler capture is already running; retry when it ends")
        try:
            import jax
            self._captures += 1
            n = self._captures
            d = out_dir or os.path.join(self.base_dir, f"capture-{n:04d}")
            os.makedirs(d, exist_ok=True)
            jax.profiler.start_trace(d)
            try:
                time.sleep(duration_s)
            finally:
                jax.profiler.stop_trace()
            return {"dir": d, "duration_s": duration_s, "capture": n}
        finally:
            self._lock.release()
