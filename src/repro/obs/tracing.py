"""Lightweight span tracing: ring-buffered, queryable, cross-thread safe.

A :class:`Span` is a named interval with attributes; a :class:`Tracer`
collects completed spans into a bounded ring (old spans evict, memory is
O(capacity) forever).  Two usage shapes:

* ``with tracer.span("fit.dispatch", batch=3) as sp:`` — scoped work on
  one thread.  Nesting is tracked per-thread, so ``sp.parent_id`` links
  child to parent and a flamegraph falls out of the JSONL export.
* ``sp = tracer.start("serve.queue", ...); ... sp.end()`` — intervals
  that *cross* threads (a request enqueued on the HTTP thread and claimed
  by the scheduler thread).  This is how the serving hot path measures
  queue-wait and device-time: span durations, not hand-stamped deltas.

Spans can carry *trace context* (PR 10): ``start(..., trace_id=rid)``
stamps a request identity on a span, ``start(..., links=(rid1, rid2))``
marks a span (e.g. one coalesced ``serve.device`` batch) as serving many
request traces at once, and ``tracer.trace(rid)`` returns every completed
span indexed under that id — the per-request timeline behind
``GET /v1/trace/<id>``.  ``start(..., t_start=now)`` lets the caller
supply the clock reading, so a deadline computed from the same reading
can never skew from the span (the scheduler's one-reading contract).

``tracer.spans(name=...)`` queries completed spans (oldest first);
``tracer.export_jsonl(path)`` dumps them for offline tooling (truncating
by default; ``append=True`` accumulates across dumps — the
:class:`SlowLog` below is always append).  Setting
``REPRO_OBS_JAX_TRACE=1`` (or ``Tracer(jax_annotations=True)``) wraps
scoped spans in ``jax.profiler.TraceAnnotation`` so they show up on the
device timeline in a jax profiler capture — resolved lazily per span, so
this module stays importable without jax and never snapshots the env at
import time.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import os
import threading
import time
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Span", "SlowLog", "Tracer"]


class Span:
    """One timed interval.  Create via ``Tracer.start`` / ``Tracer.span``.

    ``trace_id`` names the request trace this span *belongs to* (one
    ``serve.queue`` span per request); ``links`` are the trace ids a span
    *served* without belonging to any single one (one coalesced
    ``serve.device`` batch links every request it carried).  Both index
    the span under ``Tracer.trace``.
    """

    __slots__ = ("name", "attrs", "span_id", "parent_id", "thread",
                 "t_start", "t_end", "trace_id", "links", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, object],
                 span_id: int, parent_id: Optional[int], *,
                 trace_id: Optional[str] = None,
                 links: Sequence[str] = (),
                 t_start: Optional[float] = None):
        self.name = name
        self.attrs = attrs
        self.span_id = span_id
        self.parent_id = parent_id
        self.thread = threading.current_thread().name
        self.t_start = time.monotonic() if t_start is None else float(t_start)
        self.t_end: Optional[float] = None
        self.trace_id = trace_id
        self.links: Tuple[str, ...] = tuple(links)
        self._tracer = tracer

    @property
    def duration_s(self) -> float:
        """Seconds from start to end (to *now* while still open)."""
        end = self.t_end if self.t_end is not None else time.monotonic()
        return end - self.t_start

    def end(self, **attrs) -> float:
        """Close the span (idempotent), record it, return the duration.

        Extra keyword attributes merge in at close — e.g.
        ``sp.end(outcome="deadline")`` on the drop path.
        """
        if self.t_end is None:
            self.t_end = time.monotonic()
            if attrs:
                self.attrs.update(attrs)
            self._tracer._record(self)
        return self.t_end - self.t_start

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "trace_id": self.trace_id,
            "links": list(self.links),
            "thread": self.thread,
            "t_start": self.t_start,
            "t_end": self.t_end,
            "duration_s": None if self.t_end is None else self.duration_s,
            "attrs": self.attrs,
        }

    def __repr__(self):
        state = f"{self.duration_s * 1e3:.2f}ms" if self.t_end else "open"
        return f"Span({self.name!r}, {state}, attrs={self.attrs!r})"


class Tracer:
    """Bounded ring of completed spans + per-thread nesting stacks.

    ``capacity`` bounds memory: the ring holds the newest N completed
    spans and silently evicts the oldest.  All mutation happens under one
    lock; ``start``/``end`` are a few dict ops, cheap enough for the
    serving hot path (one queue span per request, one device span per
    batch).
    """

    def __init__(self, capacity: int = 2048,
                 jax_annotations: Optional[bool] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._jax_annotations = jax_annotations
        self._lock = threading.Lock()
        # eviction is manual (not deque(maxlen=...)): the trace index below
        # must drop exactly the spans the ring drops, or an evicted span
        # would pin memory and serve stale lookups forever
        self._ring: deque = deque()
        self._by_trace: Dict[str, List[Span]] = {}
        self._ids = itertools.count(1)
        self._tls = threading.local()

    # -- internals -----------------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    @staticmethod
    def _trace_ids(span: Span) -> Iterable[str]:
        """Every trace id a span is indexed under: its own + its links."""
        if span.trace_id is not None:
            yield span.trace_id
        for tid in span.links:
            if tid != span.trace_id:
                yield tid

    def _record(self, span: Span) -> None:
        with self._lock:
            self._ring.append(span)
            for tid in self._trace_ids(span):
                self._by_trace.setdefault(tid, []).append(span)
            while len(self._ring) > self.capacity:
                old = self._ring.popleft()
                for tid in self._trace_ids(old):
                    bucket = self._by_trace.get(tid)
                    if bucket is not None:
                        try:
                            bucket.remove(old)
                        except ValueError:
                            pass
                        if not bucket:
                            del self._by_trace[tid]

    def _jax_annotation(self, name: str):
        """A ``jax.profiler.TraceAnnotation`` for scoped spans, or a
        null context.  The env knob is read per call, not at import."""
        on = self._jax_annotations
        if on is None:
            on = os.environ.get("REPRO_OBS_JAX_TRACE", "") not in ("", "0")
        if not on:
            return contextlib.nullcontext()
        try:
            from jax.profiler import TraceAnnotation
        except Exception:
            return contextlib.nullcontext()
        return TraceAnnotation(name)

    # -- span creation -------------------------------------------------------

    def start(self, name: str, *, trace_id: Optional[str] = None,
              links: Sequence[str] = (),
              t_start: Optional[float] = None, **attrs) -> Span:
        """Begin a span that may end on a *different* thread.

        The parent link comes from the starting thread's active scoped
        span (if any).  Call ``span.end()`` to close and record it.

        ``trace_id`` / ``links`` index the span for :meth:`trace` lookups;
        ``t_start`` overrides the start timestamp with a clock reading the
        caller already took (``time.monotonic()`` domain), so one reading
        can drive both the span and caller-side arithmetic (deadlines).
        The three names are reserved — they cannot be used as span attrs.
        """
        st = self._stack()
        parent = st[-1].span_id if st else None
        return Span(self, name, attrs, next(self._ids), parent,
                    trace_id=trace_id, links=links, t_start=t_start)

    @contextlib.contextmanager
    def span(self, name: str, *, trace_id: Optional[str] = None,
             links: Sequence[str] = (), **attrs):
        """Scoped span: times the ``with`` body, tracks nesting."""
        sp = self.start(name, trace_id=trace_id, links=links, **attrs)
        st = self._stack()
        st.append(sp)
        try:
            with self._jax_annotation(name):
                yield sp
        finally:
            st.pop()
            sp.end()

    # -- read side -----------------------------------------------------------

    def spans(self, name: Optional[str] = None,
              prefix: Optional[str] = None) -> List[Span]:
        """Completed spans, oldest first, optionally filtered."""
        with self._lock:
            out = list(self._ring)
        if name is not None:
            out = [s for s in out if s.name == name]
        if prefix is not None:
            out = [s for s in out if s.name.startswith(prefix)]
        return out

    def trace(self, trace_id: str) -> List[Span]:
        """Completed spans indexed under ``trace_id`` (the span's own id
        or one of its ``links``), ordered by start time.  Empty when the
        id is unknown *or its spans were evicted from the ring* — callers
        (``GET /v1/trace/<id>``) must treat the two the same."""
        with self._lock:
            out = list(self._by_trace.get(trace_id, ()))
        out.sort(key=lambda s: s.t_start)
        return out

    def durations(self, name: str) -> List[float]:
        return [s.duration_s for s in self.spans(name=name)]

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self._by_trace.clear()

    def export_jsonl(self, path: str, *, append: bool = False) -> int:
        """Write completed spans as JSON lines; returns the span count.

        **Truncates** ``path`` by default: each export is a self-contained
        snapshot of the ring (dumping twice yields one ring's worth of
        spans, not two).  Pass ``append=True`` to accumulate exports in
        one file — e.g. periodic dumps from a long-running server.  The
        slow-request log is different on purpose: :class:`SlowLog` always
        appends, because each record is written exactly once, as it
        happens, and must survive later dumps.
        """
        spans = self.spans()
        with open(path, "a" if append else "w") as f:
            for s in spans:
                f.write(json.dumps(s.to_dict(), default=str) + "\n")
        return len(spans)


class SlowLog:
    """Append-only JSONL sink for slow-request timelines.

    The scheduler writes one record per resolved request whose latency
    (submit -> delivery) exceeds ``threshold_s``: the request identity,
    its latency, and the linked span timeline (queue + device spans).
    Unlike :meth:`Tracer.export_jsonl`, records are *appended* as they
    happen — a restarted server extends the same file, and an operator
    can tail it live.  The file is created eagerly so "no slow requests"
    reads as an empty file, not a missing one.
    """

    def __init__(self, path: str, threshold_s: float):
        if threshold_s < 0:
            raise ValueError(f"threshold_s={threshold_s} must be >= 0")
        self.path = path
        self.threshold_s = float(threshold_s)
        self._lock = threading.Lock()
        self.written = 0
        with open(path, "a"):
            pass

    def record(self, payload: dict) -> None:
        """Append one JSON record (thread-safe, flushed per line)."""
        line = json.dumps(payload, default=str)
        with self._lock:
            with open(self.path, "a") as f:
                f.write(line + "\n")
            self.written += 1
