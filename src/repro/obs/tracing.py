"""Lightweight span tracing: ring-buffered, queryable, cross-thread safe.

A :class:`Span` is a named interval with attributes; a :class:`Tracer`
collects completed spans into a bounded ring (old spans evict, memory is
O(capacity) forever).  Two usage shapes:

* ``with tracer.span("fit.dispatch", batch=3) as sp:`` — scoped work on
  one thread.  Nesting is tracked per-thread, so ``sp.parent_id`` links
  child to parent and a flamegraph falls out of the JSONL export.
* ``sp = tracer.start("serve.queue", ...); ... sp.end()`` — intervals
  that *cross* threads (a request enqueued on the HTTP thread and claimed
  by the scheduler thread).  This is how the serving hot path measures
  queue-wait and device-time: span durations, not hand-stamped deltas.

``tracer.spans(name=...)`` queries completed spans (oldest first);
``tracer.export_jsonl(path)`` dumps them for offline tooling.  Setting
``REPRO_OBS_JAX_TRACE=1`` (or ``Tracer(jax_annotations=True)``) wraps
scoped spans in ``jax.profiler.TraceAnnotation`` so they show up on the
device timeline in a jax profiler capture — resolved lazily per span, so
this module stays importable without jax and never snapshots the env at
import time.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = ["Span", "Tracer"]


class Span:
    """One timed interval.  Create via ``Tracer.start`` / ``Tracer.span``."""

    __slots__ = ("name", "attrs", "span_id", "parent_id", "thread",
                 "t_start", "t_end", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, object],
                 span_id: int, parent_id: Optional[int]):
        self.name = name
        self.attrs = attrs
        self.span_id = span_id
        self.parent_id = parent_id
        self.thread = threading.current_thread().name
        self.t_start = time.monotonic()
        self.t_end: Optional[float] = None
        self._tracer = tracer

    @property
    def duration_s(self) -> float:
        """Seconds from start to end (to *now* while still open)."""
        end = self.t_end if self.t_end is not None else time.monotonic()
        return end - self.t_start

    def end(self, **attrs) -> float:
        """Close the span (idempotent), record it, return the duration.

        Extra keyword attributes merge in at close — e.g.
        ``sp.end(outcome="deadline")`` on the drop path.
        """
        if self.t_end is None:
            self.t_end = time.monotonic()
            if attrs:
                self.attrs.update(attrs)
            self._tracer._record(self)
        return self.t_end - self.t_start

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "thread": self.thread,
            "t_start": self.t_start,
            "t_end": self.t_end,
            "duration_s": None if self.t_end is None else self.duration_s,
            "attrs": self.attrs,
        }

    def __repr__(self):
        state = f"{self.duration_s * 1e3:.2f}ms" if self.t_end else "open"
        return f"Span({self.name!r}, {state}, attrs={self.attrs!r})"


class Tracer:
    """Bounded ring of completed spans + per-thread nesting stacks.

    ``capacity`` bounds memory: the ring holds the newest N completed
    spans and silently evicts the oldest.  All mutation happens under one
    lock; ``start``/``end`` are a few dict ops, cheap enough for the
    serving hot path (one queue span per request, one device span per
    batch).
    """

    def __init__(self, capacity: int = 2048,
                 jax_annotations: Optional[bool] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._jax_annotations = jax_annotations
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=capacity)
        self._ids = itertools.count(1)
        self._tls = threading.local()

    # -- internals -----------------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _record(self, span: Span) -> None:
        with self._lock:
            self._ring.append(span)

    def _jax_annotation(self, name: str):
        """A ``jax.profiler.TraceAnnotation`` for scoped spans, or a
        null context.  The env knob is read per call, not at import."""
        on = self._jax_annotations
        if on is None:
            on = os.environ.get("REPRO_OBS_JAX_TRACE", "") not in ("", "0")
        if not on:
            return contextlib.nullcontext()
        try:
            from jax.profiler import TraceAnnotation
        except Exception:
            return contextlib.nullcontext()
        return TraceAnnotation(name)

    # -- span creation -------------------------------------------------------

    def start(self, name: str, **attrs) -> Span:
        """Begin a span that may end on a *different* thread.

        The parent link comes from the starting thread's active scoped
        span (if any).  Call ``span.end()`` to close and record it.
        """
        st = self._stack()
        parent = st[-1].span_id if st else None
        return Span(self, name, attrs, next(self._ids), parent)

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Scoped span: times the ``with`` body, tracks nesting."""
        sp = self.start(name, **attrs)
        st = self._stack()
        st.append(sp)
        try:
            with self._jax_annotation(name):
                yield sp
        finally:
            st.pop()
            sp.end()

    # -- read side -----------------------------------------------------------

    def spans(self, name: Optional[str] = None,
              prefix: Optional[str] = None) -> List[Span]:
        """Completed spans, oldest first, optionally filtered."""
        with self._lock:
            out = list(self._ring)
        if name is not None:
            out = [s for s in out if s.name == name]
        if prefix is not None:
            out = [s for s in out if s.name.startswith(prefix)]
        return out

    def durations(self, name: str) -> List[float]:
        return [s.duration_s for s in self.spans(name=name)]

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def export_jsonl(self, path: str) -> int:
        """Write completed spans as JSON lines; returns the span count."""
        spans = self.spans()
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s.to_dict(), default=str) + "\n")
        return len(spans)
