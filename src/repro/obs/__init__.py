"""repro.obs — unified observability: metrics, exposition, span tracing.

The third leg after benchmarks (``benchmarks/``, the BENCH_*.json
trajectory) and static analysis (``repro.analysis.lint``): *runtime*
visibility.  Three stdlib-only pieces:

* :mod:`repro.obs.metrics` — :class:`MetricsRegistry` of typed
  instruments (Counter / Gauge / fixed-bucket Histogram with labels),
  all behind one lock so snapshots are consistent cuts.
* :mod:`repro.obs.export` — Prometheus text exposition
  (:func:`render_prometheus`), mounted as ``GET /metrics`` by
  ``repro.launch.serve_http`` and dumped offline by
  ``repro.launch.metrics``.
* :mod:`repro.obs.tracing` — ring-buffered :class:`Tracer` spans
  threaded through the serving hot path, the fit pipeline, and
  ``DatasetStore.ingest``; queue-wait vs device-time comes from span
  durations, with optional JSONL export and ``jax.profiler``
  trace-annotation passthrough (``REPRO_OBS_JAX_TRACE=1``).  Spans carry
  trace context (``trace_id`` / ``links``) so ``Tracer.trace(rid)``
  reconstructs a per-request timeline; :class:`SlowLog` is the
  append-only sink for over-threshold request timelines.
* :mod:`repro.obs.resources` — :class:`ResourceMonitor`, a background
  sampler publishing ``resource_*`` gauges (RSS, device memory, live
  array bytes, jit-cache entries, queue depths, hot-model bytes).
* :mod:`repro.obs.profiling` — :class:`Profiler`, serialized bounded
  ``jax.profiler`` captures behind ``POST /debug/profile``.

Scoping convention: serving components (scheduler / admission / model
registry) each default to a *private* registry+tracer for test and
benchmark isolation, and ``serve_http`` wires one shared pair through
all of them.  Offline single-pipeline processes (``train_forest``,
``ingest``) use the process-wide defaults below, which
``repro.launch.metrics`` dumps.  See ``docs/observability.md`` for the
operator guide and the full instrument reference.
"""
from __future__ import annotations

from repro.obs.export import CONTENT_TYPE, render_prometheus
from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.profiling import ProfileInProgress, Profiler
from repro.obs.resources import ResourceMonitor
from repro.obs.tracing import SlowLog, Span, Tracer

__all__ = [
    "CONTENT_TYPE",
    "Counter",
    "DEFAULT_BUCKETS",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ProfileInProgress",
    "Profiler",
    "ResourceMonitor",
    "SlowLog",
    "Span",
    "Tracer",
    "default_registry",
    "default_tracer",
    "render_prometheus",
]

_default_registry = None
_default_tracer = None


def default_registry() -> MetricsRegistry:
    """The process-wide registry used by offline paths (fit, ingest)."""
    global _default_registry
    if _default_registry is None:
        _default_registry = MetricsRegistry()
    return _default_registry


def default_tracer() -> Tracer:
    """The process-wide tracer used by offline paths (fit, ingest)."""
    global _default_tracer
    if _default_tracer is None:
        _default_tracer = Tracer(capacity=4096)
    return _default_tracer
