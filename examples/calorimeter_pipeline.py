"""End-to-end CaloForest pipeline (the paper's §4.3 application):

  data -> per-class scaling -> ForestFlow(MO) with checkpoint streaming ->
  generation -> CaloChallenge metrics (chi^2 separation powers + AUC).

    PYTHONPATH=src python examples/calorimeter_pipeline.py [--full]

--full uses the real schema sizes (p=368, 15 classes; hours on CPU).
"""
import argparse
import tempfile

import numpy as np

from repro.config import ForestConfig
from repro.tabgen import TabularGenerator
from repro.data import calorimeter as calo
from repro.eval import metrics as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    dataset = "photons" if args.full else "photons_mini"
    n = 120000 if args.full else 1200

    X, y = calo.generate(dataset, n, seed=0)
    Xte, _ = calo.generate(dataset, n, seed=1)
    if not args.full:
        y = y % 5
    print(f"dataset={dataset} n={n} p={X.shape[1]} classes={len(set(y))}")

    fcfg = ForestConfig(
        method="flow",
        n_t=100 if args.full else 5,
        duplicate_k=20 if args.full else 4,
        n_trees=20 if args.full else 10,
        max_depth=7 if args.full else 4,
        learning_rate=1.5 if args.full else 0.5,
        n_bins=64 if args.full else 32,
        reg_lambda=1.0, multi_output=True)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        print("training CaloForest (checkpoints stream to disk;"
              " rerun with resume=True restarts after failure)...")
        model = TabularGenerator(fcfg).fit(
            X, y, seed=0, checkpoint_dir=ckpt_dir)
        G, _ = model.generate(n, seed=2)

    f_real = calo.high_level_features(Xte, dataset)
    f_gen = calo.high_level_features(G, dataset)
    print("chi^2 separation powers (lower is better):")
    for k in sorted(f_real):
        print(f"  {k:16s} {calo.chi2_separation(f_real[k], f_gen[k]):.4f}")
    print(f"classifier AUC: {M.classifier_auc(Xte, G):.4f}"
          " (0.5 = indistinguishable)")


if __name__ == "__main__":
    main()
