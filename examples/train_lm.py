"""End-to-end LM training driver: a few hundred real optimisation steps of a
(reduced) assigned architecture with checkpoint/restart, demonstrating the
trainer substrate the dry-run lowers at 132B scale.

    PYTHONPATH=src python examples/train_lm.py --arch smollm-135m --steps 200

On a TPU pod the same entrypoint is `python -m repro.launch.train` with the
full config and the production mesh.
"""
import argparse
import tempfile

from repro.config import TrainConfig
from repro.configs import get_arch
from repro.data.tokens import FastTokenStream
from repro.train.loop import run_with_retries, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=True)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=args.steps // 10,
                       total_steps=args.steps, remat_policy="none")
    stream = FastTokenStream(cfg.vocab, args.seq, args.batch, seed=0)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        def job():
            return train(cfg, tcfg, stream.batch_at, steps=args.steps,
                         ckpt_dir=ckpt_dir, ckpt_every=50, log_every=20)

        params, _, history = run_with_retries(job)
    print(f"\nfinal: loss {history[0]['loss']:.3f} -> "
          f"{history[-1]['loss']:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
