"""Distributed ForestFlow on an 8-device mesh (host-device simulation).

Rows are sharded on the `data` axis, (timestep) ensembles on the `model`
axis, and histogram accumulation psums across the data axis — the same
program the multi-pod dry-run lowers for 512 chips.

    PYTHONPATH=src python examples/distributed_forest.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ForestConfig
from repro.forest.distributed import make_distributed_fit
from repro.forest.packed import PackedForest, predict_forest


def main():
    print(f"devices: {len(jax.devices())}")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    n, p = 1024, 6
    mu = rng.normal(size=p).astype(np.float32)
    X = (mu + 0.4 * rng.normal(size=(n, p))).astype(np.float32)
    mn, mx = X.min(0), X.max(0)
    Xs = (X - mn) / (mx - mn) * 2 - 1

    fcfg = ForestConfig(n_t=8, duplicate_k=8, n_trees=12, max_depth=4,
                        n_bins=32, reg_lambda=1.0)
    fit = make_distributed_fit(mesh, fcfg, data_axes=("data",))

    n_ens = fcfg.n_t
    ts = jnp.linspace(0.0, 1.0, n_ens)
    ys = jnp.zeros((n_ens,), jnp.int32)
    keys = jax.random.split(jax.random.PRNGKey(0), n_ens * 2)
    keys = jnp.asarray(np.asarray(keys, np.uint32).reshape(n_ens, 2, 2))

    print("training 8 ensembles across the model axis, rows sharded 4-way...")
    res = fit(jnp.asarray(Xs), jnp.ones((n,), jnp.float32),
              jnp.zeros((n,), jnp.int32), ts, ys, keys)

    # generate from the distributed ensembles (flow Euler, host-side loop)
    h = 1.0 / (n_ens - 1)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(7), (512, p)))
    for i in range(n_ens - 1, 0, -1):
        f = PackedForest(jnp.asarray(res.feat[i]),
                         jnp.asarray(res.thr_val[i]),
                         jnp.asarray(res.leaf[i]), False)
        x = x - h * np.asarray(predict_forest(jnp.asarray(x), f, 4))
    gen = (x + 1) / 2 * (mx - mn) + mn
    print("true mean:", np.round(mu, 2))
    print("gen  mean:", np.round(gen.mean(0), 2))
    print("gen  std :", np.round(gen.std(0), 2), "(true 0.4)")


if __name__ == "__main__":
    main()
