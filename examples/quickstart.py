"""Quickstart: train ForestFlow on two-moons, generate, evaluate.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.config import ForestConfig
from repro.core.forest_flow import ForestGenerativeModel
from repro.data.tabular import two_moons
from repro.eval import metrics as M


def main():
    X, y = two_moons(600, seed=0)
    tr, te = X[:480], X[480:]
    ytr = y[:480]

    fcfg = ForestConfig(method="flow", n_t=10, duplicate_k=20, n_trees=40,
                        max_depth=4, n_bins=32, reg_lambda=1.0,
                        early_stop_rounds=5)
    print("fitting ForestFlow (SO + early stopping)...")
    model = ForestGenerativeModel(fcfg).fit(tr, ytr, seed=0)
    print("trees kept per timestep:",
          np.round(model.trees_at_best_iteration(), 1))

    G, yg = model.generate(480, seed=1)
    print(f"generated {G.shape[0]} samples")
    print(f"  sliced-W1 to train: {M.sliced_w1(G, tr):.4f}")
    print(f"  sliced-W1 to test:  {M.sliced_w1(G, te):.4f}")
    print(f"  coverage of test:   {M.coverage(G, te, k=3):.3f}")
    print(f"  two-sample AUC:     {M.classifier_auc(te, G):.3f} "
          "(0.5 = indistinguishable)")


if __name__ == "__main__":
    main()
