"""Quickstart for the composable tabular-generation API:

    fit -> save -> load -> generate (registry sampler) -> impute -> evaluate

    PYTHONPATH=src python examples/quickstart.py [--smoke]

``--smoke`` shrinks the config for the CI budget (scripts/ci_smoke.sh).
"""
import argparse
import os
import tempfile

import numpy as np

from repro.config import ForestConfig
from repro.data.tabular import two_moons
from repro.eval import metrics as M
from repro.tabgen import TabularGenerator, list_samplers


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config for CI smoke runs")
    args = ap.parse_args()

    n = 200 if args.smoke else 600
    X, y = two_moons(n, seed=0)
    cut = int(0.8 * n)
    tr, te = X[:cut], X[cut:]
    ytr = y[:cut]

    fcfg = ForestConfig(method="flow",
                        n_t=6 if args.smoke else 10,
                        duplicate_k=5 if args.smoke else 20,
                        n_trees=10 if args.smoke else 40,
                        max_depth=4, n_bins=32, reg_lambda=1.0,
                        early_stop_rounds=5)
    print("fitting ForestFlow (SO + early stopping)...")
    gen = TabularGenerator(fcfg).fit(tr, ytr, seed=0)
    print("trees kept per timestep:",
          np.round(gen.artifacts.trees_at_best_iteration(), 1))

    # save / load round-trip: artifacts are a single .npz + .json pair
    with tempfile.TemporaryDirectory() as d:
        base = gen.save(os.path.join(d, "two_moons"))
        print(f"saved artifacts to {base}.npz / {base}.json")
        gen = TabularGenerator.load(base)

    G, yg = gen.generate(cut, seed=1)
    print(f"generated {G.shape[0]} samples "
          f"(samplers available: {', '.join(list_samplers('flow'))})")
    print(f"  sliced-W1 to train: {M.sliced_w1(G, tr):.4f}")
    print(f"  sliced-W1 to test:  {M.sliced_w1(G, te):.4f}")
    print(f"  coverage of test:   {M.coverage(G, te, k=3):.3f}")
    print(f"  two-sample AUC:     {M.classifier_auc(te, G):.3f} "
          "(0.5 = indistinguishable)")

    # heun: 2nd-order ODE solver from the registry, better at coarse n_t
    Gh, _ = gen.generate(cut, sampler="heun", seed=1)
    print(f"  heun sliced-W1:     {M.sliced_w1(Gh, te):.4f}")

    # imputation: clamp observed features, solve for the missing ones
    Xm = tr[:40].copy()
    Xm[:, 1] = np.nan
    filled = gen.impute(Xm, ytr[:40], seed=2,
                        refine_rounds=2 if args.smoke else 3)
    err = np.mean(np.abs(filled[:, 1] - tr[:40, 1]))
    print(f"imputed 40 rows; mean abs error on masked feature: {err:.3f}")


if __name__ == "__main__":
    main()
