#!/usr/bin/env python
"""Repo-local jaxlint entry point: ``python scripts/jaxlint.py [paths...]``.

Thin wrapper so the linter runs without an editable install — it prepends
``src`` to ``sys.path`` relative to the repo root, then delegates to
``repro.analysis.lint`` (same CLI as ``python -m repro.analysis.lint``).
"""
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

from repro.analysis.lint.cli import main  # noqa: E402

if __name__ == "__main__":
    os.chdir(_REPO)  # default paths + baseline resolve against the repo root
    sys.exit(main())
