#!/usr/bin/env bash
# CI smoke: tier-1 tests + reduced-config example + benchmarks + distributed
# fit. Everything here must pass on a stock CPU container (no optional deps).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 test suite =="
python -m pytest -x -q

echo "== quickstart example (reduced config) =="
python examples/quickstart.py --smoke

echo "== distributed fit smoke (8 virtual devices, shard_map trainer) =="
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  python -m repro.launch.train_forest --demo --demo-rows 256 --demo-cols 4 \
    --mesh 4x2 --n-t 4 --n-trees 6 --max-depth 3 --n-bins 16 --duplicate-k 6

echo "== out-of-core smoke: ingest -> store-backed fit (DatasetStore) =="
store_dir="$(mktemp -d)"
python -m repro.launch.ingest --out "$store_dir/store" \
  --synthetic 4096x8x2 --shard-rows 1024 --batch-rows 512
python -m repro.launch.train_forest --data-dir "$store_dir/store" \
  --mesh none --n-t 2 --n-trees 4 --max-depth 3 --n-bins 16 --duplicate-k 2
# crash-resume path: a second ingest over the same spec must be a no-op
python -m repro.launch.ingest --out "$store_dir/store" \
  --synthetic 4096x8x2 --shard-rows 1024 --batch-rows 512 --resume

echo "== generation benchmark (emits BENCH_generation.json) =="
# write to a scratch dir: the committed trajectory artifacts stay untouched
# and a stale copy can't mask a benchmark failure
bench_out="$(mktemp -d)"
python benchmarks/run.py --only generation --json-dir "$bench_out"
test -s "$bench_out/BENCH_generation.json" && echo "BENCH_generation.json written"

echo "== training benchmark (emits BENCH_training.json) =="
python benchmarks/run.py --only training --json-dir "$bench_out"
test -s "$bench_out/BENCH_training.json" && echo "BENCH_training.json written"

echo "== store-scaling benchmark (emits BENCH_resource_scaling.json) =="
# in-memory vs DatasetStore-backed fit: peak-RSS record + ABBA min-of-reps
# throughput, incl. a dataset >= 10x the largest in-memory bench config
python benchmarks/run.py --only store_scaling --json-dir "$bench_out"
test -s "$bench_out/BENCH_resource_scaling.json" \
  && echo "BENCH_resource_scaling.json written"

echo "== benchmark regression gate (vs committed trajectory) =="
# >30% rows/sec drop vs the committed BENCH_*.json fails the build; tune
# with BENCH_TOLERANCE (fraction, e.g. 0.5) on noisy hardware
python scripts/check_bench.py --fresh "$bench_out" --baseline .
