#!/usr/bin/env bash
# CI smoke: tier-1 tests + reduced-config example + benchmarks + distributed
# fit. Everything here must pass on a stock CPU container (no optional deps).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 test suite =="
python -m pytest -x -q

echo "== jaxlint (repo bug-class static analysis) =="
# fails on any unsuppressed, non-baselined finding; see README "Static
# analysis" and src/repro/analysis/lint/
python -m repro.analysis.lint src tests benchmarks scripts

echo "== docs check (links + fenced python blocks) =="
# broken relative links and non-compiling python blocks in README/docs
# fail the build; --exec is a dev-side deep check (README blocks are
# illustrative fragments)
python scripts/check_docs.py

echo "== quickstart example (reduced config) =="
python examples/quickstart.py --smoke

echo "== distributed fit smoke (8 virtual devices, shard_map trainer) =="
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  python -m repro.launch.train_forest --demo --demo-rows 256 --demo-cols 4 \
    --mesh 4x2 --n-t 4 --n-trees 6 --max-depth 3 --n-bins 16 --duplicate-k 6

echo "== out-of-core smoke: ingest -> store-backed fit (DatasetStore) =="
store_dir="$(mktemp -d)"
python -m repro.launch.ingest --out "$store_dir/store" \
  --synthetic 4096x8x2 --shard-rows 1024 --batch-rows 512
python -m repro.launch.train_forest --data-dir "$store_dir/store" \
  --mesh none --n-t 2 --n-trees 4 --max-depth 3 --n-bins 16 --duplicate-k 2
# crash-resume path: a second ingest over the same spec must be a no-op
python -m repro.launch.ingest --out "$store_dir/store" \
  --synthetic 4096x8x2 --shard-rows 1024 --batch-rows 512 --resume

echo "== serving HTTP smoke (control plane end to end) =="
# boot the multi-tenant front end on an ephemeral port, hit /healthz and
# /v1/generate over real HTTP, then SIGINT it and require a clean exit
python - <<'EOF'
import json, os, signal, subprocess, sys, urllib.error, urllib.request
env = dict(os.environ, PYTHONUNBUFFERED="1")
proc = subprocess.Popen(
    [sys.executable, "-m", "repro.launch.serve_http", "--demo", "--port", "0",
     "--buckets", "64,256"],
    stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env)
base = None
for line in proc.stdout:
    sys.stdout.write(line)
    if line.startswith("serving on "):
        base = line.split()[-1].strip()
        break
assert base, "serve_http never came up"
with urllib.request.urlopen(base + "/healthz", timeout=60) as r:
    health = json.load(r)
assert health["ok"] and health["models"] == ["demo"], health
req = urllib.request.Request(
    base + "/v1/generate", method="POST",
    data=json.dumps({"model": "demo", "n": 48, "tenant": "ci",
                     "priority": "interactive"}).encode(),
    headers={"Content-Type": "application/json"})
with urllib.request.urlopen(req, timeout=120) as r:
    body = json.load(r)
    rid = r.headers["X-Repro-Request-Id"]
assert len(body["rows"]) == 48 and len(body["labels"]) == 48, body.keys()
# the response header is the trace handle: it must match the body and
# resolve through /v1/trace/<id> to a queue+device timeline
assert rid and rid == body["request_id"], (rid, body.get("request_id"))
with urllib.request.urlopen(base + "/v1/trace/" + rid, timeout=60) as r:
    trace = json.load(r)
assert trace["summary"]["rows"] == 48, trace["summary"]
assert any(s["name"] == "serve.device" for s in trace["spans"]), trace
try:
    urllib.request.urlopen(base + "/v1/trace/deadbeef", timeout=60)
    raise AssertionError("bogus trace id did not 404")
except urllib.error.HTTPError as e:
    assert e.code == 404, e.code
# /metrics is Prometheus text and must reconcile exactly with /statz
with urllib.request.urlopen(base + "/metrics", timeout=60) as r:
    ctype, prom = r.headers["Content-Type"], r.read().decode()
assert ctype.startswith("text/plain; version=0.0.4"), ctype
assert "resource_rss_bytes" in prom, "ResourceMonitor gauges missing"
rows_total = sum(
    float(line.rsplit(" ", 1)[1]) for line in prom.splitlines()
    if line.startswith("serving_rows_total"))
with urllib.request.urlopen(base + "/statz", timeout=60) as r:
    statz = json.load(r)
assert rows_total == statz["scheduler"]["rows"] == 48, (
    rows_total, statz["scheduler"]["rows"])
# the traced timeline reconciles with the aggregate counters: one request,
# so its queue wait and device time ARE the scheduler totals
q = next(s for s in trace["spans"] if s["name"] == "serve.queue")
d_sp = next(s for s in trace["spans"] if s["name"] == "serve.device")
assert abs(q["duration_s"] - statz["scheduler"]["queue_wait_s"]) < 1e-9
assert abs(d_sp["duration_s"] - statz["scheduler"]["device_s"]) < 1e-9
proc.send_signal(signal.SIGINT)
proc.wait(timeout=60)
rest = proc.stdout.read()
sys.stdout.write(rest)
assert proc.returncode == 0 and "bye" in rest, proc.returncode
print("serving HTTP smoke ok")
EOF

echo "== freshness-loop smoke (append -> warm extend -> live hot-swap) =="
# the full refresh path over real HTTP: ingest a store, train + serve a
# base model, then run repro.launch.refresh (append fresh rows, warm-start
# extend, admin reload) while generates are in flight — zero dropped
refresh_dir="$(mktemp -d)"
python -m repro.launch.ingest --out "$refresh_dir/store" \
  --synthetic 1024x4x2 --shard-rows 512 --batch-rows 512
python -m repro.launch.train_forest --data-dir "$refresh_dir/store" \
  --mesh none --n-t 2 --n-trees 4 --max-depth 3 --n-bins 16 \
  --duplicate-k 2 --out "$refresh_dir/base"
REFRESH_DIR="$refresh_dir" python - <<'EOF'
import json, os, signal, subprocess, sys, threading, time, urllib.request
d = os.environ["REFRESH_DIR"]
env = dict(os.environ, PYTHONUNBUFFERED="1")
proc = subprocess.Popen(
    [sys.executable, "-m", "repro.launch.serve_http",
     "--model", "fresh=" + os.path.join(d, "base"),
     "--port", "0", "--buckets", "64", "--no-warm"],
    stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env)
base = None
for line in proc.stdout:
    sys.stdout.write(line)
    if line.startswith("serving on "):
        base = line.split()[-1].strip()
        break
assert base, "serve_http never came up"

def post(path, body):
    req = urllib.request.Request(
        base + path, method="POST", data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=300) as r:
        return json.load(r)

results, stop = [], threading.Event()
def hammer():  # keep generates in flight across the swap
    while not stop.is_set():
        results.append(len(post("/v1/generate",
                                {"model": "fresh", "n": 8})["rows"]))
        time.sleep(0.05)
threads = [threading.Thread(target=hammer) for _ in range(2)]
for t in threads:
    t.start()
ref = subprocess.run(
    [sys.executable, "-m", "repro.launch.refresh",
     "--store", os.path.join(d, "store"),
     "--synthetic", "512x4x2", "--seed", "1", "--batch-rows", "256",
     "--artifacts", os.path.join(d, "base"),
     "--out", os.path.join(d, "ext"), "--extra-trees", "2",
     "--server", base, "--model", "fresh"], env=env)
stop.set()
for t in threads:
    t.join(timeout=120)
assert ref.returncode == 0, "refresh CLI failed"
assert results and all(n == 8 for n in results), (len(results), results[:5])
with urllib.request.urlopen(base + "/v1/models", timeout=60) as r:
    m = json.load(r)["models"]["fresh"]
assert m["version"] == 2, m
lin = m["lineage"]
assert lin["base"]["round_range"] == [4, 6], lin
assert lin["store"]["version"] == 2 and lin["rows"] == 1536, lin
proc.send_signal(signal.SIGINT)
proc.wait(timeout=60)
sys.stdout.write(proc.stdout.read())
print(f"freshness loop smoke ok: {len(results)} in-flight generates, "
      "0 dropped, model v2 with lineage")
EOF

echo "== same-shape hot-swap recompile budget (in-process) =="
# a reload that keeps every array shape must reuse every compiled program:
# recompile_budget(0) fails the build on any compile during swap + generate
REFRESH_DIR="$refresh_dir" python - <<'EOF'
import dataclasses, os
import numpy as np
from repro.analysis.runtime import recompile_budget
from repro.launch.serve_http import ServingApp
from repro.serving import AdmissionController, ModelRegistry
from repro.tabgen import TabularGenerator
d = os.environ["REFRESH_DIR"]
gen = TabularGenerator.load(os.path.join(d, "base"))
shifted = dataclasses.replace(
    gen.artifacts, mins=np.asarray(gen.artifacts.mins) + 1.0,
    maxs=np.asarray(gen.artifacts.maxs) + 1.0)
p2 = os.path.join(d, "base_shifted")
shifted.save(p2)
registry = ModelRegistry(buckets=(64,))
registry.register("m", gen.artifacts)
registry.warmup()
app = ServingApp(registry, AdmissionController(), model_paths={"m": p2})
app.scheduler.submit(8, model="m").result(timeout=300)
with recompile_budget(0):
    status, body = app.reload_model("m", {})
    assert status == 200 and body["version"] == 2, (status, body)
    X, _ = app.scheduler.submit(8, model="m").result(timeout=300)
app.stop()
assert X.shape == (8, 4), X.shape
print("same-shape hot-swap: zero recompiles ok")
EOF

echo "== generation benchmark (emits BENCH_generation.json) =="
# write to a scratch dir: the committed trajectory artifacts stay untouched
# and a stale copy can't mask a benchmark failure
bench_out="$(mktemp -d)"
python benchmarks/run.py --only generation --json-dir "$bench_out"
test -s "$bench_out/BENCH_generation.json" && echo "BENCH_generation.json written"

echo "== training benchmark (emits BENCH_training.json) =="
python benchmarks/run.py --only training --json-dir "$bench_out"
test -s "$bench_out/BENCH_training.json" && echo "BENCH_training.json written"

echo "== store-scaling benchmark (emits BENCH_resource_scaling.json) =="
# in-memory vs DatasetStore-backed fit: peak-RSS record + ABBA min-of-reps
# throughput, incl. a dataset >= 10x the largest in-memory bench config
python benchmarks/run.py --only store_scaling --json-dir "$bench_out"
test -s "$bench_out/BENCH_resource_scaling.json" \
  && echo "BENCH_resource_scaling.json written"

echo "== serving benchmark (emits BENCH_serving.json) =="
# open-loop mixed-tenant load: in-flight scheduler vs drain-then-serve
python benchmarks/run.py --only serving --json-dir "$bench_out"
test -s "$bench_out/BENCH_serving.json" && echo "BENCH_serving.json written"

echo "== refresh benchmark (emits BENCH_refresh.json) =="
# warm-start extension vs full refit (bit-identity asserted in the bench)
python benchmarks/run.py --only refresh --json-dir "$bench_out"
test -s "$bench_out/BENCH_refresh.json" && echo "BENCH_refresh.json written"

echo "== benchmark regression gate (vs committed trajectory) =="
# >25% rows/sec drop vs the committed BENCH_*.json fails the build; tune
# with BENCH_TOLERANCE (fraction, e.g. 0.4) on noisy hardware
python scripts/check_bench.py --fresh "$bench_out" --baseline .
