#!/usr/bin/env bash
# CI smoke: tier-1 tests + reduced-config example + benchmarks + distributed
# fit. Everything here must pass on a stock CPU container (no optional deps).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 test suite =="
python -m pytest -x -q

echo "== quickstart example (reduced config) =="
python examples/quickstart.py --smoke

echo "== distributed fit smoke (8 virtual devices, shard_map trainer) =="
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  python -m repro.launch.train_forest --demo --demo-rows 256 --demo-cols 4 \
    --mesh 4x2 --n-t 4 --n-trees 6 --max-depth 3 --n-bins 16 --duplicate-k 6

echo "== generation benchmark (emits BENCH_generation.json) =="
# write to a scratch dir: the committed trajectory artifacts stay untouched
# and a stale copy can't mask a benchmark failure
bench_out="$(mktemp -d)"
python benchmarks/run.py --only generation --json-dir "$bench_out"
test -s "$bench_out/BENCH_generation.json" && echo "BENCH_generation.json written"

echo "== training benchmark (emits BENCH_training.json) =="
python benchmarks/run.py --only training --json-dir "$bench_out"
test -s "$bench_out/BENCH_training.json" && echo "BENCH_training.json written"

echo "== benchmark regression gate (vs committed trajectory) =="
# >30% rows/sec drop vs the committed BENCH_*.json fails the build; tune
# with BENCH_TOLERANCE (fraction, e.g. 0.5) on noisy hardware
python scripts/check_bench.py --fresh "$bench_out" --baseline .
