#!/usr/bin/env bash
# CI smoke: tier-1 tests + reduced-config example + generation benchmark.
# Everything here must pass on a stock CPU container (no optional deps).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 test suite =="
# the two deselects are pre-existing seed failures (LM-side, documented in
# ROADMAP.md "Open items"); drop them once fixed
python -m pytest -x -q \
  --deselect tests/test_flops_model.py::test_fwd_flops_match_hlo_dense \
  --deselect tests/test_sharding_and_dryrun.py::test_dryrun_code_path_small_mesh

echo "== quickstart example (reduced config) =="
python examples/quickstart.py --smoke

echo "== generation benchmark (emits BENCH_generation.json) =="
# write to a scratch dir: the committed trajectory artifact stays untouched
# and a stale copy can't mask a benchmark failure
bench_out="$(mktemp -d)"
python benchmarks/run.py --only generation --json-dir "$bench_out"
test -s "$bench_out/BENCH_generation.json" && echo "BENCH_generation.json written"
