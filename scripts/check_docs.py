#!/usr/bin/env python
"""Docs checker: relative links resolve, fenced python blocks are valid.

Stdlib-only (runs in the bare-checkout CI docs lane):

  python scripts/check_docs.py                # README.md + docs/*.md
  python scripts/check_docs.py --exec         # also exec each python block
  python scripts/check_docs.py docs/observability.md

Checks per markdown file:

* every relative link / image target ``[text](path)`` exists on disk
  (anchors and ``http(s)://`` / ``mailto:`` targets are skipped; an
  in-page ``#fragment`` on an existing file is fine — fragments are not
  resolved);
* every fenced ```` ```python ```` block at least ``compile()``s —
  stale identifiers still slip through compile, so ``--exec`` runs each
  block in a fresh namespace (with ``src`` on ``sys.path``) and fails on
  any exception.  Blocks that are deliberately illustrative fragments
  can opt out of execution (they are still compiled) with
  ```` ```python notest ```` on the fence line.

Exit 1 on any finding, 0 when clean.
"""
from __future__ import annotations

import argparse
import os
import re
import sys

_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_FENCE_RE = re.compile(r"^```(\S*)\s*(.*)$")
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def _strip_code(text: str) -> str:
    """Markdown with fenced blocks blanked, so code is not link-checked."""
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE_RE.match(line):
            in_fence = not in_fence
            out.append("")
        else:
            out.append("" if in_fence else line)
    return "\n".join(out)


def check_links(path: str, text: str) -> list:
    problems = []
    base = os.path.dirname(os.path.abspath(path))
    for m in _LINK_RE.finditer(_strip_code(text)):
        target = m.group(1)
        if target.startswith(_SKIP_SCHEMES) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(base, rel))
        if not os.path.exists(resolved):
            line = text[:m.start()].count("\n") + 1
            problems.append(f"{path}:{line}: broken link -> {target}")
    return problems


def python_blocks(text: str):
    """(start_line, source, notest) for each fenced python block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE_RE.match(lines[i])
        if m and m.group(1) in ("python", "py"):
            notest = "notest" in m.group(2)
            start = i + 1
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            yield start + 1, "\n".join(body), notest
        i += 1


def check_python(path: str, text: str, do_exec: bool) -> list:
    problems = []
    for line, src, notest in python_blocks(text):
        label = f"{path}:{line}"
        try:
            code = compile(src, label, "exec")
        except SyntaxError as err:
            problems.append(f"{label}: python block does not compile: {err}")
            continue
        if do_exec and not notest:
            try:
                exec(code, {"__name__": f"docs_block_{line}"})
            except Exception as err:  # noqa: BLE001 — report, don't crash
                problems.append(f"{label}: python block raised "
                                f"{type(err).__name__}: {err}")
    return problems


def default_files(root: str) -> list:
    files = [os.path.join(root, "README.md")]
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        files += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                        if f.endswith(".md"))
    return [f for f in files if os.path.exists(f)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*",
                    help="markdown files (default: README.md + docs/*.md)")
    ap.add_argument("--exec", dest="do_exec", action="store_true",
                    help="execute python blocks instead of just compiling")
    ap.add_argument("--syntax-only", action="store_true",
                    help="alias for the default compile-only mode")
    args = ap.parse_args(argv)
    do_exec = args.do_exec and not args.syntax_only

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = args.files or default_files(root)
    if do_exec:
        sys.path.insert(0, os.path.join(root, "src"))

    problems, n_blocks = [], 0
    for path in files:
        with open(path, encoding="utf-8") as f:
            text = f.read()
        problems += check_links(path, text)
        blocks = list(python_blocks(text))
        n_blocks += len(blocks)
        problems += check_python(path, text, do_exec)

    for p in problems:
        print(p)
    mode = "exec" if do_exec else "compile"
    print(f"check_docs: {len(files)} file(s), {n_blocks} python block(s) "
          f"({mode}), {len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
