#!/usr/bin/env python
"""Benchmark regression gate: compare freshly emitted BENCH_*.json files
against the committed trajectory and fail CI on big throughput regressions.

Usage (as wired into scripts/ci_smoke.sh):

  python scripts/check_bench.py --fresh "$bench_out" --baseline . \
      [--tolerance 0.30] [--files BENCH_generation.json BENCH_training.json]

Matching is schema-agnostic so the gate survives benchmark evolution:
records inside each file are keyed by their identity fields (``config``,
``devices``, ``mesh``), and every numeric metric whose name ends in
``rows_per_sec`` (at any nesting depth, e.g.
``pipeline_comparison.pipelined_rows_per_sec``) is compared. A fresh value
below ``baseline * (1 - tolerance)`` is a regression; metrics or records
present on only one side are reported but don't fail (a retuned benchmark
should land together with its refreshed baseline). Error records on the
baseline side are skipped; on the fresh side they fail the gate.

The default tolerance started at a loose 30% when the gate compared
mean-of-3 walls; every gated bench has since moved to ABBA-interleaved
min-of-reps (the stable statistic on these noisy boxes), so the default is
now 25%. Tighten further with ``--tolerance`` or the ``BENCH_TOLERANCE``
environment variable once the fleet is homogeneous.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_FILES = ("BENCH_generation.json", "BENCH_training.json",
                 "BENCH_resource_scaling.json", "BENCH_serving.json",
                 "BENCH_refresh.json")
METRIC_SUFFIX = "rows_per_sec"
IDENTITY_KEYS = ("config", "devices", "mesh")
# Reference arms exist to be compared against, not to be our perf
# trajectory: the generation bench's per-class dispatch loop is hundreds of
# tiny sequential dispatches — pure Python/dispatch overhead, the most
# load-sensitive number on a shared box (observed ±45% between adjacent CI
# runs). Gating it makes the gate flap without guarding anything we ship.
# ``pallas_interpret`` is the CPU op-by-op emulation of the TPU kernel — a
# correctness arm recorded for the trajectory, not shipped perf (the real
# kernel number comes from a TPU run of the same bench).
# ``padded_coldstart`` is the store-scaling bench's single-device padded
# reference arm: its per-call jit makes the timing compile-dominated, so
# it is recorded for the RSS comparison, not gated as throughput.
# ``drain_reference`` is the serving bench's PR-4 drain-then-serve arm —
# it exists to be beaten by the in-flight scheduler (the gated
# ``inflight_rows_per_sec``), and a *faster* drain arm would read as a
# regression of a code path we deliberately keep only as a baseline.
# ``full_refit`` is the refresh bench's from-scratch arm, the baseline the
# gated ``warm_extend_rows_per_sec`` is measured against.
IGNORED_METRIC_SUBSTRINGS = ("per_class_loop", "pallas_interpret",
                             "padded_coldstart", "drain_reference",
                             "full_refit")


def record_key(rec: dict) -> str:
    """Stable identity of a benchmark record (which workload/device count)."""
    ident = {k: rec.get(k) for k in IDENTITY_KEYS if k in rec}
    return json.dumps(ident, sort_keys=True)


def metrics(rec, prefix: str = "") -> dict:
    """All ``*rows_per_sec`` numbers in a record, flattened by dotted path."""
    out = {}
    if isinstance(rec, dict):
        for k, v in rec.items():
            path = f"{prefix}{k}"
            if isinstance(v, dict):
                out.update(metrics(v, path + "."))
            elif (isinstance(v, (int, float)) and not isinstance(v, bool)
                  and k.endswith(METRIC_SUFFIX)
                  and not any(s in path for s in IGNORED_METRIC_SUBSTRINGS)):
                out[path] = float(v)
    return out


def load_records(path: str):
    with open(path) as f:
        return json.load(f).get("records", [])


def check_file(fresh_path: str, base_path: str, tolerance: float,
               allow_no_overlap: bool = False):
    """Returns (regressions, notes) for one benchmark file pair.

    Fails closed: if record identities drifted so far that not a single
    metric could be compared, that is itself a gate failure — an "ok" must
    mean real numbers were actually checked, never that the comparison
    quietly matched nothing. ``allow_no_overlap`` downgrades that case to a
    note: the nightly ``--full`` lane measures paper-sized workloads whose
    identities deliberately differ from the committed quick-size trajectory,
    so until a full-size baseline is committed it compares what it can and
    still trips on error records.
    """
    regressions, notes = [], []
    compared = 0
    base = {record_key(r): r for r in load_records(base_path)
            if not r.get("error")}
    seen_keys = set()
    for rec in load_records(fresh_path):
        key = record_key(rec)
        seen_keys.add(key)
        if rec.get("error"):
            regressions.append((key, "error", 0.0, 0.0,
                                rec["error"][-200:]))
            continue
        base_rec = base.get(key)
        if base_rec is None:
            notes.append(f"  new record (no baseline): {key}")
            continue
        fresh_m, base_m = metrics(rec), metrics(base_rec)
        for name, b in sorted(base_m.items()):
            f = fresh_m.get(name)
            if f is None:
                notes.append(f"  metric dropped: {name} @ {key}")
                continue
            compared += 1
            floor = b * (1.0 - tolerance)
            if f < floor:
                regressions.append((key, name, b, f, None))
            elif f > b * (1.0 + tolerance):
                notes.append(
                    f"  improvement: {name} {b:.0f} -> {f:.0f} @ {key} "
                    "(consider refreshing the committed baseline)")
    for key in sorted(set(base) - seen_keys):
        notes.append(f"  baseline record not measured this run: {key}")
    if compared == 0 and base:
        if allow_no_overlap:
            notes.append(
                "  no metric overlapped the committed baseline (different "
                "workload sizes); tolerated by --allow-no-overlap")
        else:
            regressions.append((
                "<file>", "no-overlap", 0.0, 0.0,
                "no metric could be compared against the committed baseline "
                "(record identities drifted?) — refresh the baseline together "
                "with the benchmark change"))
    return regressions, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", required=True,
                    help="directory with freshly emitted BENCH_*.json")
    ap.add_argument("--baseline", default=".",
                    help="directory with the committed trajectory files")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_TOLERANCE", "0.25")),
                    help="allowed fractional rows/sec drop (default 0.25)")
    ap.add_argument("--files", nargs="*", default=list(DEFAULT_FILES))
    ap.add_argument("--allow-no-overlap", action="store_true",
                    help="tolerate zero comparable metrics (nightly --full "
                         "lane vs quick-size committed baselines); error "
                         "records still fail")
    args = ap.parse_args(argv)

    failed = False
    for name in args.files:
        fresh_path = os.path.join(args.fresh, name)
        base_path = os.path.join(args.baseline, name)
        if not os.path.exists(fresh_path):
            print(f"[check_bench] {name}: FAIL — fresh file missing "
                  f"({fresh_path})")
            failed = True
            continue
        if not os.path.exists(base_path):
            print(f"[check_bench] {name}: no committed baseline, skipping")
            continue
        regressions, notes = check_file(fresh_path, base_path,
                                        args.tolerance,
                                        args.allow_no_overlap)
        status = "FAIL" if regressions else "ok"
        print(f"[check_bench] {name}: {status} "
              f"(tolerance {args.tolerance:.0%})")
        for key, metric, b, f, err in regressions:
            if err is not None:
                print(f"  ERROR record @ {key}: {err}")
            else:
                print(f"  REGRESSION {metric}: {b:.0f} -> {f:.0f} "
                      f"({f / b - 1.0:+.0%}) @ {key}")
        for line in notes:
            print(line)
        failed = failed or bool(regressions)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
