"""Generate EXPERIMENTS.md tables from the dry-run artifacts."""
import json
import sys
from pathlib import Path

ARCH_ORDER = ["dbrx-132b", "deepseek-v2-236b", "llava-next-34b",
              "smollm-135m", "phi4-mini-3.8b", "granite-3-8b", "stablelm-12b",
              "whisper-tiny", "xlstm-1.3b", "recurrentgemma-9b", "caloforest"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "photons", "pions"]


def load(d):
    recs = {}
    for f in Path(d).glob("*.json"):
        r = json.loads(f.read_text())
        key = (r["arch"], r["shape"], r["mesh"], r.get("tag", ""))
        recs[key] = r
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}GiB"


def roofline_table(recs):
    print("| arch | shape | mesh | status | peak B/dev | t_comp (s) | "
          "t_mem (s) | t_coll (s) | dominant | MODEL/HLO | mfu_bound |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("16x16", "2x16x16"):
                r = recs.get((arch, shape, mesh, ""))
                if r is None:
                    continue
                if r["status"] == "skipped":
                    print(f"| {arch} | {shape} | {mesh} | skipped "
                          f"(full-attn @500k) | - | - | - | - | - | - | - |")
                    continue
                if r["status"] != "ok":
                    print(f"| {arch} | {shape} | {mesh} | FAILED | - | - |"
                          " - | - | - | - | - |")
                    continue
                ro = r.get("roofline", {})
                mem = r.get("memory_analysis", {})
                peak = mem.get("peak_bytes_per_device")
                print(f"| {arch} | {shape} | {mesh} | ok | {fmt_bytes(peak)} "
                      f"| {ro.get('t_compute_s', 0):.2e} "
                      f"| {ro.get('t_memory_s', 0):.2e} "
                      f"| {ro.get('t_collective_s', 0):.2e} "
                      f"| {ro.get('dominant', '-')} "
                      f"| {ro.get('useful_flops_ratio', 0):.3f} "
                      f"| {ro.get('mfu_bound', 0):.3f} |")


def perf_table(recs):
    cells = [
        ("deepseek-v2-236b", "decode_32k", ["", "absorb", "absorb_w8"]),
        ("smollm-135m", "train_4k",
         ["", "packed", "packed_dots", "packed_dots_dp"]),
        ("caloforest", "pions", ["", "rs", "rs_bf16", "rs_bf16_int8"]),
    ]
    print("| cell | variant | t_comp | t_mem | t_coll | dominant |"
          " mfu_bound |")
    print("|---|---|---|---|---|---|---|")
    for arch, shape, tags in cells:
        for tag in tags:
            r = recs.get((arch, shape, "16x16", tag))
            if r is None or r.get("status") != "ok":
                print(f"| {arch}/{shape} | {tag or 'baseline'} | ? | ? | ? |"
                      " ? | ? |")
                continue
            ro = r["roofline"]
            print(f"| {arch}/{shape} | {tag or 'baseline'} "
                  f"| {ro['t_compute_s']:.2e} | {ro['t_memory_s']:.2e} "
                  f"| {ro['t_collective_s']:.2e} | {ro['dominant']} "
                  f"| {ro['mfu_bound']:.3f} |")


if __name__ == "__main__":
    recs = load(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    which = sys.argv[2] if len(sys.argv) > 2 else "all"
    if which in ("all", "roofline"):
        roofline_table(recs)
        print()
    if which in ("all", "perf"):
        perf_table(recs)
