"""Add analytic per-chip memory estimates to existing dry-run artifacts
(no recompiles needed; derived from configs only)."""
import json
import sys
from pathlib import Path

sys.path.insert(0, "src")

from repro.analysis.flops import chip_memory_estimate  # noqa: E402
from repro.config import SHAPES_BY_NAME  # noqa: E402
from repro.configs import get_arch  # noqa: E402


def main(d="experiments/dryrun"):
    for f in Path(d).glob("*.json"):
        r = json.loads(f.read_text())
        if r.get("status") != "ok" or r["arch"] == "caloforest":
            continue
        cfg = get_arch(r["arch"])
        shape = SHAPES_BY_NAME[r["shape"]]
        est = chip_memory_estimate(
            cfg, shape, chips=r.get("chips", 256),
            remat_policy=r.get("remat", "full"),
            moe_w8=("w8" in r.get("tag", "")))
        r["chip_memory_estimate"] = est
        f.write_text(json.dumps(r, indent=1, default=str))
    print("patched")


if __name__ == "__main__":
    main(*sys.argv[1:])
