"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Sections:
  resource_scaling  - Fig. 1 / 2 / 4 (top+middle): time+memory vs n
  quality           - Table 2 / 7: W1 + coverage + mean rank, 7 methods
  calo              - Table 3/4/5: chi^2 separation + classifier AUC
  generation        - Fig. 4 (bottom): SO vs MO generation time
  training          - §3.3 scaling: fit throughput + memory vs device count
  store_scaling     - §3.3 out-of-core: in-memory vs DatasetStore-backed fit
                      (peak RSS + ABBA min-of-reps throughput vs dataset size)
  serving           - open-loop mixed-tenant load: in-flight scheduler vs
                      drain-then-serve reference + latency percentiles
  refresh           - freshness loop: warm-start extension vs full refit
  ablation          - Fig. 3 / 10 / 11: early stopping + K/n_tree sweeps
  roofline          - dry-run roofline table (scale deliverable)

Full-size variants are driven by the flags below; defaults are sized for the
CPU CI budget.
"""
from __future__ import annotations

import argparse
import os
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of sections")
    ap.add_argument("--full", action="store_true",
                    help="paper-sized settings (hours on CPU)")
    ap.add_argument("--json-dir", default=".",
                    help="where BENCH_*.json artifacts are written (CI can "
                         "point this at a scratch dir to keep the committed "
                         "trajectory files untouched)")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (bench_ablations, bench_calo, bench_generation,
                            bench_quality, bench_refresh,
                            bench_resource_scaling, bench_roofline,
                            bench_serving, bench_training)
    sections = {
        "resource_scaling": lambda: bench_resource_scaling.main(
            sizes=(200, 500, 1000) if quick else (1000, 3000, 10000)),
        "quality": lambda: bench_quality.main(quick=quick),
        "calo": lambda: bench_calo.main(quick=quick,
                                        n=1500 if quick else 120000),
        "generation": lambda: bench_generation.main(
            quick=quick, json_path=os.path.join(args.json_dir,
                                                "BENCH_generation.json")),
        "training": lambda: bench_training.main(
            quick=quick, json_path=os.path.join(args.json_dir,
                                                "BENCH_training.json")),
        "store_scaling": lambda: bench_resource_scaling.main_store(
            quick=quick, json_path=os.path.join(
                args.json_dir, "BENCH_resource_scaling.json")),
        "serving": lambda: bench_serving.main(
            quick=quick, json_path=os.path.join(args.json_dir,
                                                "BENCH_serving.json")),
        "refresh": lambda: bench_refresh.main(
            quick=quick, json_path=os.path.join(args.json_dir,
                                                "BENCH_refresh.json")),
        "ablation": lambda: bench_ablations.main(quick=quick),
        "roofline": lambda: bench_roofline.main(),
    }
    chosen = (args.only.split(",") if args.only else list(sections))
    print("name,us_per_call,derived")
    failed = []
    for name in chosen:
        try:
            sections[name]()
        except Exception:  # keep the harness going; report the failure
            failed.append(name)
            print(f"{name},fail,{traceback.format_exc().splitlines()[-1]}",
                  flush=True)
    if failed:  # after all sections ran, make CI see the failure
        raise SystemExit(f"benchmark sections failed: {','.join(failed)}")


if __name__ == "__main__":
    main()
