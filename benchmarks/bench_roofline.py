"""Roofline table: reads the dry-run artifacts (experiments/dryrun/*.json)
and prints the per-(arch x shape x mesh) three-term roofline.

CSV: name,us_per_call,derived where us_per_call = bound step time in us and
derived = "dom=..|mfu=..|tc=..|tm=..|tx=..".
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit


def main(dryrun_dir: str = "experiments/dryrun") -> None:
    d = Path(dryrun_dir)
    files = sorted(d.glob("*.json")) if d.exists() else []
    if not files:
        emit("roofline/NO_ARTIFACTS", "-",
             "run: python -m repro.launch.dryrun --all --mesh both")
        return
    for f in files:
        rec = json.loads(f.read_text())
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("tag"):
            name += f"/{rec['tag']}"
        if rec["status"] == "skipped":
            emit(name, "-", "skipped(long-context-full-attention)")
            continue
        if rec["status"] != "ok":
            emit(name, "fail", rec.get("error", "")[:80])
            continue
        r = rec.get("roofline")
        if not r:
            emit(name, "-", rec.get("note", "ok")[:80])
            continue
        bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        emit(name, f"{bound * 1e6:.0f}",
             f"dom={r['dominant']}|mfu={r['mfu_bound']:.3f}"
             f"|tc={r['t_compute_s']:.2e}|tm={r['t_memory_s']:.2e}"
             f"|tx={r['t_collective_s']:.2e}")


if __name__ == "__main__":
    main()
