"""Paper Figure 4 (bottom) + App. B.2: generation time, SO vs MO, and the
Pallas tree-inference kernel vs the XLA reference (interpret mode = CPU
correctness; the timing signal of interest is SO-vs-MO ensemble count).

CSV: name,us_per_call,derived (derived = ms per generated datapoint).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.config import ForestConfig
from repro.core.forest_flow import ForestGenerativeModel
from repro.data.tabular import synthetic_resource_dataset


def main(quick: bool = True) -> None:
    n, n_y = (500, 2) if quick else (2000, 5)
    for p in (4, 16) if quick else (10, 30, 100):
        X, y = synthetic_resource_dataset(n, p, n_y, seed=0)
        for mo in (False, True):
            fcfg = ForestConfig(n_t=6, duplicate_k=5, n_trees=10, max_depth=4,
                                n_bins=32, reg_lambda=1.0, multi_output=mo)
            model = ForestGenerativeModel(fcfg).fit(X, y, seed=0)
            # warm-up compile, then measure steady-state generation
            model.generate(n, seed=1)
            t0 = time.time()
            reps = 3
            for r in range(reps):
                model.generate(n, seed=2 + r)
            dt = (time.time() - t0) / reps
            name = "MO" if mo else "SO"
            emit(f"generation/{name}/p={p}", f"{dt * 1e6:.0f}",
                 f"ms_per_point={1000 * dt / n:.4f}")


if __name__ == "__main__":
    main()
