"""Paper Figure 4 (bottom) + App. B.2: generation time, SO vs MO — and the
perf trajectory of the serving path: the old per-class Python dispatch loop
vs the class-vmapped single-program sampler (PR 1), and the PR-4
kernel/mesh serving arms (tree-predict impl and mesh-sharded ``sample``).

CSV: name,us_per_call,derived (derived = ms per generated datapoint or
rows/sec). With ``json_path`` set, also writes a ``BENCH_generation.json``:
rows/sec for loop vs vmapped per configuration, plus one ``impl_comparison``
record per device count (1 and 8 virtual devices) recording single-device
XLA vs mesh-sharded XLA vs Pallas-interpret rows/sec — ABBA-interleaved
min-of-reps walls (this container's wall-clock drifts 2x between runs), warm
programs, and a sharded-vs-single allclose parity bit.

The ``pallas_interpret`` arm is a *reference* arm (interpret mode emulates
the TPU kernel op-by-op on CPU — correctness, not shipped perf) and is
exempt from the ``check_bench`` gate; the 8-virtual-device sharded numbers
are a floor on a 2-core container for the same reason the training
pipeline's are (both cores saturated by device compute).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit, run_measured
from repro.config import ForestConfig
from repro.data.tabular import synthetic_resource_dataset
from repro.tabgen import fit_artifacts, sample, sample_loop_reference


def _time(fn, reps: int = 5) -> float:
    """Min-of-reps wall time. This box's per-rep walls have 3x heavy tails
    (observed: 112k..302k rows/sec for the same warmed program), so the old
    mean-of-3 made the committed trajectory a lottery; the min is the stable
    statistic here (same methodology as the training bench's
    pipeline_comparison and this file's impl_comparison arms)."""
    fn()  # warm-up compile
    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)
    return min(walls)


# One subprocess per device count (XLA_FLAGS must precede jax init): warm
# every arm, then ABBA-interleave single-device vs sharded so host-load
# drift hits both arms equally; min-of-reps is the stable statistic here.
_IMPL_SNIPPET = r"""
import time
import jax
import numpy as np
from repro.config import ForestConfig
from repro.data.tabular import synthetic_resource_dataset
from repro.launch.mesh import auto_forest_mesh
from repro.tabgen import fit_artifacts, sample

n, p, n_y, n_gen = {n}, {p}, 2, {n_gen}
X, y = synthetic_resource_dataset(n, p, n_y, seed=0)
fcfg = ForestConfig(n_t={n_t}, duplicate_k=5, n_trees={n_trees}, max_depth=4,
                    n_bins=32, reg_lambda=1.0, multi_output=True)
art = fit_artifacts(X, y, fcfg, seed=0)
mesh = auto_forest_mesh()
art_sh = art.shard(mesh) if mesh is not None else None

def wall(fn):
    t0 = time.perf_counter(); fn(); return time.perf_counter() - t0

single = lambda: sample(art, n_gen, seed=2)
sharded = ((lambda: sample(art_sh, n_gen, seed=2, mesh=mesh))
           if art_sh is not None else None)
pallas = lambda: sample(art, n_gen, seed=2, impl="pallas_interpret")

single(); pallas()                       # warm the programs
parity = None
if art_sh is not None:
    G1, _ = single(); G2, _ = sharded()  # also warms the sharded program
    parity = bool(np.allclose(G1, G2, rtol=1e-5, atol=1e-5))

s_walls, sh_walls = [], []
for _ in range({reps}):                  # ABBA: single,sharded,sharded,single
    s_walls.append(wall(single))
    if art_sh is not None:
        sh_walls.append(wall(sharded))
        sh_walls.append(wall(sharded))
    s_walls.append(wall(single))
p_wall = min(wall(pallas) for _ in range(2))
s_wall = min(s_walls)
sh_wall = min(sh_walls) if sh_walls else None

result = {{
    "config": {{"n_gen": n_gen, "p": p, "n_y": n_y, "multi_output": True,
                "n_t": fcfg.n_t, "sampler": "euler",
                "section": "impl_comparison"}},
    "devices": len(jax.devices()),
    "mesh": (dict(zip(mesh.axis_names, mesh.devices.shape))
             if mesh is not None else None),
    "impl_comparison": {{
        "includes_compile": False,
        "reps_per_arm": len(s_walls),
        "xla_rows_per_sec": n_gen / s_wall,
        "sharded_rows_per_sec": (n_gen / sh_wall) if sh_wall else None,
        "sharded_speedup": (s_wall / sh_wall) if sh_wall else None,
        "sharded_matches_single": parity,
        # reference arm (kernel correctness emulation, gate-exempt)
        "pallas_interpret_rows_per_sec": n_gen / p_wall,
    }},
}}
"""


def _impl_comparison_records(quick: bool):
    n, p, n_t, n_trees = (512, 4, 4, 6) if quick else (2000, 10, 8, 20)
    n_gen = 4096 if quick else 16384
    reps = 2 if quick else 3
    records = []
    for d in (1, 8):
        snippet = _IMPL_SNIPPET.format(n=n, p=p, n_t=n_t, n_trees=n_trees,
                                       n_gen=n_gen, reps=reps)
        r = run_measured(snippet, timeout=1800, env_extra={
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={d}"})
        if r.get("error"):
            emit(f"generation/impl/devices={d}", "fail", r["error"][-160:])
            records.append({"devices": d, "error": r["error"][-800:]})
            continue
        ic = r["impl_comparison"]
        emit(f"generation/impl/devices={d}",
             f"{n_gen / ic['xla_rows_per_sec'] * 1e6:.0f}",
             f"xla_rows_per_sec={ic['xla_rows_per_sec']:.0f}|"
             f"sharded_rows_per_sec={ic['sharded_rows_per_sec'] or 0:.0f}|"
             f"pallas_interpret_rows_per_sec="
             f"{ic['pallas_interpret_rows_per_sec']:.0f}|"
             f"sharded_matches_single={ic['sharded_matches_single']}")
        records.append(r)
    return records


def main(quick: bool = True, json_path: str = None) -> None:
    n, n_y = (500, 2) if quick else (2000, 5)
    records = []
    for p in (4, 16) if quick else (10, 30, 100):
        X, y = synthetic_resource_dataset(n, p, n_y, seed=0)
        for mo in (False, True):
            fcfg = ForestConfig(n_t=6, duplicate_k=5, n_trees=10, max_depth=4,
                                n_bins=32, reg_lambda=1.0, multi_output=mo)
            art = fit_artifacts(X, y, fcfg, seed=0)
            name = "MO" if mo else "SO"

            dt_loop = _time(lambda: sample_loop_reference(art, n, seed=2))
            dt_vmap = _time(lambda: sample(art, n, seed=2))
            emit(f"generation/{name}/p={p}/per_class_loop",
                 f"{dt_loop * 1e6:.0f}",
                 f"rows_per_sec={n / dt_loop:.0f}")
            emit(f"generation/{name}/p={p}/vmapped",
                 f"{dt_vmap * 1e6:.0f}",
                 f"rows_per_sec={n / dt_vmap:.0f}|"
                 f"speedup={dt_loop / dt_vmap:.2f}x")
            records.append({
                "config": {"n": n, "p": p, "n_y": n_y, "multi_output": mo,
                           "n_t": fcfg.n_t, "sampler": "euler"},
                "per_class_loop_rows_per_sec": n / dt_loop,
                "vmapped_rows_per_sec": n / dt_vmap,
                "speedup": dt_loop / dt_vmap,
            })
    records.extend(_impl_comparison_records(quick))
    if json_path:
        d = os.path.dirname(json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(json_path, "w") as f:
            json.dump({"bench": "generation", "records": records}, f, indent=1)
        emit("generation/json", "-", json_path)


if __name__ == "__main__":
    main(json_path="BENCH_generation.json")
