"""Paper Figure 4 (bottom) + App. B.2: generation time, SO vs MO — and the
PR 1 perf trajectory: the old per-class Python dispatch loop vs the new
class-vmapped single-program sampler (``repro.tabgen.sample``).

CSV: name,us_per_call,derived (derived = ms per generated datapoint or
rows/sec). With ``json_path`` set, also writes a ``BENCH_generation.json``
with rows/sec for loop vs vmapped per configuration.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.config import ForestConfig
from repro.data.tabular import synthetic_resource_dataset
from repro.tabgen import fit_artifacts, sample, sample_loop_reference


def _time(fn, reps: int = 3) -> float:
    fn()  # warm-up compile
    t0 = time.time()
    for _ in range(reps):
        fn()
    return (time.time() - t0) / reps


def main(quick: bool = True, json_path: str = None) -> None:
    n, n_y = (500, 2) if quick else (2000, 5)
    records = []
    for p in (4, 16) if quick else (10, 30, 100):
        X, y = synthetic_resource_dataset(n, p, n_y, seed=0)
        for mo in (False, True):
            fcfg = ForestConfig(n_t=6, duplicate_k=5, n_trees=10, max_depth=4,
                                n_bins=32, reg_lambda=1.0, multi_output=mo)
            art = fit_artifacts(X, y, fcfg, seed=0)
            name = "MO" if mo else "SO"

            dt_loop = _time(lambda: sample_loop_reference(art, n, seed=2))
            dt_vmap = _time(lambda: sample(art, n, seed=2))
            emit(f"generation/{name}/p={p}/per_class_loop",
                 f"{dt_loop * 1e6:.0f}",
                 f"rows_per_sec={n / dt_loop:.0f}")
            emit(f"generation/{name}/p={p}/vmapped",
                 f"{dt_vmap * 1e6:.0f}",
                 f"rows_per_sec={n / dt_vmap:.0f}|"
                 f"speedup={dt_loop / dt_vmap:.2f}x")
            records.append({
                "config": {"n": n, "p": p, "n_y": n_y, "multi_output": mo,
                           "n_t": fcfg.n_t, "sampler": "euler"},
                "per_class_loop_rows_per_sec": n / dt_loop,
                "vmapped_rows_per_sec": n / dt_vmap,
                "speedup": dt_loop / dt_vmap,
            })
    if json_path:
        d = os.path.dirname(json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(json_path, "w") as f:
            json.dump({"bench": "generation", "records": records}, f, indent=1)
        emit("generation/json", "-", json_path)


if __name__ == "__main__":
    main(json_path="BENCH_generation.json")
