"""Paper Figure 1 / Figure 2 / Figure 4 (top+middle): training time and peak
memory, Original-style implementation vs ours (SO, MO, +ES), scaling in n.

Each configuration runs in a fresh subprocess so peak RSS is per-config.
CSV: name,us_per_call,derived  (derived = peak RSS in MiB).

:func:`main_store` (the ``store_scaling`` section) is the paper §3.3
out-of-core record: peak host RSS + fit throughput vs dataset size for the
in-memory trainer vs a :class:`repro.data.store.DatasetStore`-backed fit,
emitted as ``BENCH_resource_scaling.json`` and gated by
``scripts/check_bench.py``. The throughput side is ABBA-ordered min-of-reps
(both arms run the same 1x1-mesh shard_map program warm, so the ratio
isolates the data path); the ``in_memory_padded`` reference arm is the
single-device padded-block route (per-call jit, cold) and is exempt from
the gate like the other reference arms. All arms use multi-output trees
(the paper's recommended mode; SO would train p per-feature sub-forests
per ensemble and blow the CI budget at these row counts).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit, run_measured

_FIT_SNIPPET = """
import numpy as np
from repro.config import ForestConfig
from repro.data.tabular import synthetic_resource_dataset
{import_line}
X, y = synthetic_resource_dataset({n}, {p}, {n_y}, seed=0)
fcfg = ForestConfig(n_t={n_t}, duplicate_k={K}, n_trees={T}, max_depth=4,
                    n_bins=32, reg_lambda=1.0, multi_output={mo},
                    early_stop_rounds={es})
m = {ctor}(fcfg).fit(X, y, seed=0)
result = {{}}
"""


def variants():
    ours = ("from repro.tabgen import TabularGenerator",
            "TabularGenerator")
    naive = ("from repro.core.naive import NaiveForestGenerativeModel",
             "NaiveForestGenerativeModel")
    return [
        ("original", naive, False, 0),
        ("ours-SO", ours, False, 0),
        ("ours-MO", ours, True, 0),
        ("ours-SO-ES", ours, False, 5),
        ("ours-MO-ES", ours, True, 5),
    ]


def main(sizes=(200, 500, 1000), p=8, n_y=2, n_t=3, K=10, T=10) -> None:
    for n in sizes:
        for name, (imp, ctor), mo, es in variants():
            if name == "original" and n > 500:
                # the pathological baseline becomes impractical quickly —
                # the paper's red-cross regime; don't burn the CI budget
                emit(f"resource_scaling/{name}/n={n}", "skipped(x)",
                     "skipped(x)")
                continue
            snippet = _FIT_SNIPPET.format(import_line=imp, ctor=ctor, n=n,
                                          p=p, n_y=n_y, n_t=n_t, K=K, T=T,
                                          mo=mo, es=es)
            res = run_measured(snippet, timeout=1200)
            if res.get("error"):
                emit(f"resource_scaling/{name}/n={n}", "fail", "fail")
                continue
            us = res["wall_s"] * 1e6
            mib = res["peak_rss_bytes"] / 2 ** 20
            emit(f"resource_scaling/{name}/n={n}", f"{us:.0f}", f"{mib:.1f}")


# ---------------------------------------------------------------------------
# out-of-core store vs in-memory fit (ISSUE 5 / paper §3.3 scaling record)
# ---------------------------------------------------------------------------

_OOC_SNIPPET = """
import os, tempfile, time
import numpy as np
import jax
from repro.config import ForestConfig
from repro.data.tabular import synthetic_resource_batches
from repro.tabgen import fit_artifacts

n, p, n_y, arm = {n}, {p}, {n_y}, {arm!r}
fcfg = ForestConfig(n_t={n_t}, duplicate_k={K}, n_trees={T}, max_depth=3,
                    n_bins=32, reg_lambda=1.0, multi_output=True)
result = {{}}
if arm == "store":
    from repro.data.store import ingest
    t0 = time.perf_counter()
    data = ingest(synthetic_resource_batches(n, p, n_y,
                                             batch_rows={batch_rows},
                                             seed=0),
                  os.path.join(tempfile.mkdtemp(), "store"),
                  shard_rows={shard_rows})
    result["ingest_wall_s"] = round(time.perf_counter() - t0, 3)
    labels, mesh = None, None          # auto-routes to the 1x1 sharded fit
else:
    parts = list(synthetic_resource_batches(n, p, n_y,
                                            batch_rows={batch_rows},
                                            seed=0))
    data = np.concatenate([x for x, _ in parts])
    labels = np.concatenate([y for _, y in parts])
    del parts
    # same 1x1 shard_map program as the store arm (so min-of-reps isolates
    # the data path), except the padded reference arm: the default
    # single-device route with dense [n_y, n_max, p] class blocks
    mesh = (None if arm == "in_memory_padded"
            else jax.make_mesh((1, 1), ("data", "model")))
walls = []
for _ in range({reps}):
    t0 = time.perf_counter()
    art = fit_artifacts(data, labels, fcfg, seed=0, mesh=mesh)
    jax.block_until_ready(art.leaf)
    walls.append(time.perf_counter() - t0)
result["fit_wall_s"] = min(walls)
result["reps"] = len(walls)
result["n_ens"] = fcfg.n_t * art.n_y
"""


def _ooc_run(arm: str, n: int, p: int, n_y: int, fit_cfg: dict,
             reps: int) -> dict:
    snippet = _OOC_SNIPPET.format(arm=arm, n=n, p=p, n_y=n_y, reps=reps,
                                  **fit_cfg)
    return run_measured(snippet, timeout=2400)


def main_store(quick: bool = True, json_path: str | None = None,
               n_y: int = 2, sizes=None) -> None:
    """Store-backed vs in-memory fit: throughput (ABBA min-of-reps) + peak
    host RSS per dataset size. The largest size is >= 10x any in-memory
    bench config (training bench tops out at n=2048), demonstrating the
    out-of-core route on a fixed-RAM box."""
    p = 32
    fit_cfg = dict(n_t=2, K=2, T=3, mo=True, batch_rows=8192,
                   shard_rows=16384)
    # full sizes are bounded by hosted-runner RAM: the quick trajectory
    # measures ~12 KiB RSS/row for the gated arms (XLA temps scale with n),
    # so 524288 rows ~ 6 GiB — comfortably inside a 16 GB nightly runner,
    # while anything million-row would OOM all three arms into error
    # records and fail the gate by construction
    sizes = sizes or ((16384, 131072) if quick else (262144, 524288))
    records = []
    for n in sizes:
        runs: dict = {"in_memory": [], "store": []}
        for arm in ("in_memory", "store", "store", "in_memory"):   # ABBA
            runs[arm].append(_ooc_run(arm, n, p, n_y, fit_cfg, reps=2))
        for arm, res_list in runs.items():
            errs = [r["error"] for r in res_list if r.get("error")]
            if errs:
                emit(f"store_scaling/{arm}/n={n}", "fail", "fail")
                records.append({"config": {"workload": "store_scaling",
                                           "arm": arm, "n": n, "p": p},
                                "error": errs[0]})
                continue
            wall = min(r["fit_wall_s"] for r in res_list)
            n_ens = res_list[0]["n_ens"]
            rss = max(r["peak_rss_bytes"] for r in res_list)
            rec = {
                "config": {"workload": "store_scaling", "arm": arm,
                           "n": n, "p": p, "n_y": n_y, **{
                               k: fit_cfg[k]
                               for k in ("n_t", "K", "T", "mo")}},
                "devices": 1,
                "trainer": "sharded_1x1",
                "fit_wall_s": wall,
                "includes_compile": False,   # min over 2 reps x 2 runs
                "rows_per_sec": n * n_ens / wall,
                "peak_rss_bytes": rss,
                "dataset_bytes": n * p * 4,
                "abba_runs": len(res_list),
                "reps_per_run": 2,
            }
            if arm == "store":
                rec["ingest_wall_s"] = min(r["ingest_wall_s"]
                                           for r in res_list)
            records.append(rec)
            emit(f"store_scaling/{arm}/n={n}",
                 f"{wall * 1e6:.0f}", f"{rss / 2**20:.1f}")
    # reference arm: the default single-device padded route (per-call jit
    # -> cold timing; exempt from the gate). Its padded blocks + full sorts
    # cost ~2x the sharded arms' RSS, so in the full lane it runs at the
    # *smaller* size to stay inside the runner — the RSS contrast is the
    # point, not the absolute n
    n_ref = sizes[-1] if quick else sizes[0]
    res = _ooc_run("in_memory_padded", n_ref, p, n_y, fit_cfg, reps=1)
    if res.get("error"):
        emit(f"store_scaling/in_memory_padded/n={n_ref}", "fail", "fail")
        records.append({"config": {"workload": "store_scaling",
                                   "arm": "in_memory_padded", "n": n_ref,
                                   "p": p}, "error": res["error"]})
    else:
        records.append({
            "config": {"workload": "store_scaling", "arm": "in_memory_padded",
                       "n": n_ref, "p": p, "n_y": n_y,
                       **{k: fit_cfg[k] for k in ("n_t", "K", "T", "mo")}},
            "devices": 1,
            "trainer": "single_padded",
            "fit_wall_s": res["fit_wall_s"],
            "includes_compile": True,
            "padded_coldstart_rows_per_sec": n_ref * res["n_ens"]
            / res["fit_wall_s"],
            "peak_rss_bytes": res["peak_rss_bytes"],
            "dataset_bytes": n_ref * p * 4,
        })
        emit(f"store_scaling/in_memory_padded/n={n_ref}",
             f"{res['fit_wall_s'] * 1e6:.0f}",
             f"{res['peak_rss_bytes'] / 2**20:.1f}")
    if json_path:
        payload = {
            "bench": "resource_scaling",
            "note": ("store arm: ingest + DatasetStore-backed fit (rows "
                     "gathered from disk shards; class stats/sketch from "
                     "the manifest). Host RSS includes the device-resident "
                     "row shards on this CPU-only box; on TPU those live "
                     "in HBM and host staging is O(shard + batch)."),
            "records": records,
        }
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)


if __name__ == "__main__":
    main()
    main_store()
