"""Paper Figure 1 / Figure 2 / Figure 4 (top+middle): training time and peak
memory, Original-style implementation vs ours (SO, MO, +ES), scaling in n.

Each configuration runs in a fresh subprocess so peak RSS is per-config.
CSV: name,us_per_call,derived  (derived = peak RSS in MiB).
"""
from __future__ import annotations

from benchmarks.common import emit, run_measured

_FIT_SNIPPET = """
import numpy as np
from repro.config import ForestConfig
from repro.data.tabular import synthetic_resource_dataset
{import_line}
X, y = synthetic_resource_dataset({n}, {p}, {n_y}, seed=0)
fcfg = ForestConfig(n_t={n_t}, duplicate_k={K}, n_trees={T}, max_depth=4,
                    n_bins=32, reg_lambda=1.0, multi_output={mo},
                    early_stop_rounds={es})
m = {ctor}(fcfg).fit(X, y, seed=0)
result = {{}}
"""


def variants():
    ours = ("from repro.tabgen import TabularGenerator",
            "TabularGenerator")
    naive = ("from repro.core.naive import NaiveForestGenerativeModel",
             "NaiveForestGenerativeModel")
    return [
        ("original", naive, False, 0),
        ("ours-SO", ours, False, 0),
        ("ours-MO", ours, True, 0),
        ("ours-SO-ES", ours, False, 5),
        ("ours-MO-ES", ours, True, 5),
    ]


def main(sizes=(200, 500, 1000), p=8, n_y=2, n_t=3, K=10, T=10) -> None:
    for n in sizes:
        for name, (imp, ctor), mo, es in variants():
            if name == "original" and n > 500:
                # the pathological baseline becomes impractical quickly —
                # the paper's red-cross regime; don't burn the CI budget
                emit(f"resource_scaling/{name}/n={n}", "skipped(x)",
                     "skipped(x)")
                continue
            snippet = _FIT_SNIPPET.format(import_line=imp, ctor=ctor, n=n,
                                          p=p, n_y=n_y, n_t=n_t, K=K, T=T,
                                          mo=mo, es=es)
            res = run_measured(snippet, timeout=1200)
            if res.get("error"):
                emit(f"resource_scaling/{name}/n={n}", "fail", "fail")
                continue
            us = res["wall_s"] * 1e6
            mib = res["peak_rss_bytes"] / 2 ** 20
            emit(f"resource_scaling/{name}/n={n}", f"{us:.0f}", f"{mib:.1f}")


if __name__ == "__main__":
    main()
