"""Paper Table 3/4/5 + Figure 5: CaloForest on calorimeter data.

Synthetic showers with the CaloChallenge schema (data/calorimeter.py), full
feature width (p=368 photons / 533 pions), reduced n for the CPU container.
Metrics: chi^2 separation power of each expert feature family (Eq. 7) and
the two-sample classifier AUC — exactly the Challenge metric set.

CSV: name,us_per_call,derived.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.config import ForestConfig
from repro.tabgen import TabularGenerator
from repro.data import calorimeter as calo
from repro.eval import metrics as M


def run_dataset(dataset: str, n: int, quick: bool = True):
    X, y = calo.generate(dataset, n, seed=0)
    Xte, yte = calo.generate(dataset, n, seed=1)
    # quick mode also coarsens labels 15 -> 5 classes (fewer ensembles)
    if quick:
        y, yte = y % 5, yte % 5
    fcfg = ForestConfig(
        method="flow", n_t=4 if quick else 20, duplicate_k=4 if quick else 20,
        n_trees=10 if quick else 20, max_depth=4 if quick else 7,
        learning_rate=0.5 if quick else 1.5, n_bins=32,
        reg_lambda=1.0, multi_output=True)   # MO: CPU-tractable at p>=368
    t0 = time.time()
    model = TabularGenerator(fcfg).fit(X, y, seed=0)
    fit_s = time.time() - t0
    t0 = time.time()
    G, yg = model.generate(n, seed=2)
    gen_s = time.time() - t0
    emit(f"calo/{dataset}/train", f"{fit_s * 1e6:.0f}", f"n={n}|p={X.shape[1]}")
    emit(f"calo/{dataset}/generate", f"{gen_s * 1e6:.0f}",
         f"ms_per_shower={1000 * gen_s / n:.3f}")

    f_real = calo.high_level_features(Xte, dataset)
    f_gen = calo.high_level_features(G, dataset)
    groups = {"e_dep": [], "ce": [], "width": []}
    for k in f_real:
        chi2 = calo.chi2_separation(f_real[k], f_gen[k])
        if k.startswith("e_dep"):
            groups["e_dep"].append(chi2)
        elif k.startswith("ce"):
            groups["ce"].append(chi2)
        else:
            groups["width"].append(chi2)
    for g, vals in groups.items():
        emit(f"calo/{dataset}/chi2_{g}", "-", f"{np.mean(vals):.4f}")
    auc = M.classifier_auc(Xte, G)
    emit(f"calo/{dataset}/classifier_auc", "-", f"{auc:.4f}")


def main(quick: bool = True, n: int = 1500) -> None:
    datasets = (("photons_mini", "pions_mini") if quick
                else ("photons", "pions"))
    for dataset in datasets:
        run_dataset(dataset, min(n, 1000) if quick else n, quick)


if __name__ == "__main__":
    main()
