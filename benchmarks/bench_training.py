"""Training throughput vs device count — the paper §3.3 scaling claim,
measured instead of asserted — plus the PR-3 pipelined-vs-serial trainer
comparison.

Each device count runs in its own subprocess (``XLA_FLAGS=
--xla_force_host_platform_device_count=D`` must precede jax init) and:

* fits the same dataset through ``fit_artifacts`` cold (the single-device
  trainer at D=1, the shard_map trainer on the ``auto_forest_mesh``
  otherwise) — the trajectory record, methodology unchanged since PR 2
  (``includes_compile: true``);
* then runs the pipelined-vs-serial comparison on a grid-heavy demo
  workload (many ensemble batches streaming checkpoints — the paper's
  n_t=50 regime scaled to CI): warm program, explicit mesh, ABBA-interleaved
  reps with min-of-reps walls (the box the CI runs on drifts by 2x, so
  paired mins are the only stable statistic), reporting serial and
  pipelined rows/sec, the speedup, and the pipeline's overlap accounting
  (``writer_busy_s`` = host-side gather+checkpoint work moved off the
  dispatch thread, ``overlap_efficiency`` = the fraction of it actually
  hidden from wall-clock).

Reports rows/sec, ensemble-rows/sec (rows x duplicate_k x ensembles /
wall), the compiled per-device memory estimate of the sharded fit program
("peak HBM" on a real accelerator; host bytes on the virtual mesh), and
subprocess peak RSS.

CSV: name,us_per_call,derived. With ``json_path`` set, also writes
``BENCH_training.json`` with one record per device count.

Caveat: on the CPU host the virtual devices share the same cores, so
rows/sec is NOT expected to scale with D here — and on a 2-core container
the pipeline's overlap gain is bounded by spare-core capacity (wall-clock
tracks total CPU work), so the speedup recorded here is a floor; real
scaling and overlap numbers come from running the same section on a TPU
slice or a multi-core host.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit, run_measured

_SNIPPET = r"""
import time, json, shutil, tempfile
import jax
import numpy as np

from repro.config import ForestConfig
from repro.data.tabular import synthetic_resource_dataset
from repro.tabgen import PipelineConfig, fit_artifacts
from repro.tabgen import fitting
from repro.launch.mesh import auto_forest_mesh

n, p, n_y = {n}, {p}, {n_y}
X, y = synthetic_resource_dataset(n, p, n_y, seed=0)
fcfg = ForestConfig(n_t={n_t}, duplicate_k={dup_k}, n_trees={n_trees},
                    max_depth=4, n_bins=32, reg_lambda=1.0)
mesh = auto_forest_mesh()
t0 = time.time()
art = fit_artifacts(X, y, fcfg, seed=0, mesh=mesh)
wall = time.time() - t0
n_ens = art.n_t * art.n_y

hbm = None
if mesh is not None:
    # per-device memory of the compiled shard_map fit program: the
    # fits-in-HBM number for this (rows, grid) slice
    from repro.forest.distributed import input_specs_forest, make_distributed_fit
    d_data = dict(zip(mesh.axis_names, mesh.devices.shape))["data"]
    n_pad = -(-n // d_data) * d_data
    bs = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    compiled = make_distributed_fit(mesh, fcfg).lower(
        *input_specs_forest(fcfg, n_pad, p, max(bs, min(n_ens, 8)))).compile()
    mem = compiled.memory_analysis()
    hbm = getattr(mem, "temp_size_in_bytes", None)

# ---- pipelined vs serial (PR 3): grid-heavy demo workload, warm program,
# checkpoint streaming on, ABBA interleaving with min-of-reps walls
pn, pp, pn_y = {pipe_n}, {pipe_p}, 2
pX, py = synthetic_resource_dataset(pn, pp, pn_y, seed=0)
pcfg = ForestConfig(n_t={pipe_n_t}, duplicate_k={pipe_dup_k},
                    n_trees={pipe_n_trees}, max_depth=3, n_bins=16,
                    reg_lambda=1.0)
pipe_mesh = mesh if mesh is not None else jax.make_mesh(
    (1, 1), ("data", "model"))
bpb = dict(zip(pipe_mesh.axis_names, pipe_mesh.devices.shape))["model"]
PIPE = PipelineConfig(prefetch_depth={prefetch_depth})

def timed_fit(pipeline):
    ck = tempfile.mkdtemp()
    t0 = time.perf_counter()
    fit_artifacts(pX, py, pcfg, seed=0, mesh=pipe_mesh, checkpoint_dir=ck,
                  ensembles_per_batch=bpb, pipeline=pipeline)
    w = time.perf_counter() - t0
    shutil.rmtree(ck)
    return w

timed_fit(None)          # warm the program + 9p caches once for both arms
serial_walls, pipe_walls, pipe_stats = [], [], []
def timed_pipe():
    pipe_walls.append(timed_fit(PIPE))
    pipe_stats.append(dict(fitting.LAST_PIPELINE_STATS))
for _ in range({reps}):  # ABBA: serial,pipe,pipe,serial
    serial_walls.append(timed_fit(None))
    timed_pipe()
    timed_pipe()
    serial_walls.append(timed_fit(None))
s_wall, p_wall = min(serial_walls), min(pipe_walls)
# busy times must come from the same fit as the min pipelined wall, or the
# hidden/busy ratio mixes statistics from different reps
stats = pipe_stats[pipe_walls.index(p_wall)]
pipe_ens = pcfg.n_t * pn_y
# NB both arms share the same input build (the precomputed key table), so
# the speedup isolates stage overlap + loop structure; overlap_efficiency
# is still an approximation (min walls vs one rep's busy), clamped [0, 1]
hidden = max(0.0, s_wall - p_wall)
busy = stats.get("writer_busy_s", 0.0) + stats.get("prefetch_busy_s", 0.0)

result = {{
    "devices": len(jax.devices()),
    "mesh": (dict(zip(mesh.axis_names, mesh.devices.shape))
             if mesh is not None else None),
    "fit_wall_s": wall,
    "includes_compile": True,
    "rows_per_sec": n * n_ens / wall,
    "ensemble_rows_per_sec": n * fcfg.duplicate_k * n_ens / wall,
    "per_device_temp_bytes": hbm,
    "pipeline_comparison": {{
        "workload": {{"n": pn, "p": pp, "n_y": pn_y, "n_t": pcfg.n_t,
                      "duplicate_k": pcfg.duplicate_k,
                      "n_trees": pcfg.n_trees,
                      "ensembles_per_batch": bpb,
                      "n_batches": stats.get("n_batches"),
                      "checkpoint": True}},
        "includes_compile": False,
        "reps_per_arm": len(serial_walls),
        "serial_wall_s": s_wall,
        "pipelined_wall_s": p_wall,
        "serial_rows_per_sec": pn * pipe_ens / s_wall,
        "pipelined_rows_per_sec": pn * pipe_ens / p_wall,
        "pipelined_speedup": s_wall / p_wall,
        "writer_busy_s": stats.get("writer_busy_s"),
        "prefetch_busy_s": stats.get("prefetch_busy_s"),
        "prefetch_depth": stats.get("prefetch_depth"),
        "overlap_efficiency": min(1.0, hidden / busy) if busy > 0 else None,
    }},
}}
"""


def main(quick: bool = True, json_path: str = None) -> None:
    n, p, n_y = (2048, 8, 2) if quick else (65536, 32, 4)
    n_t, dup_k, n_trees = (4, 10, 10) if quick else (10, 20, 40)
    # pipeline comparison: a grid-heavy (paper n_t=50-style) slice kept
    # CI-sized — many small ensemble batches so the per-batch host work
    # (input build, gather, checkpoint write) is a visible fraction
    pipe = (dict(pipe_n=256, pipe_p=8, pipe_n_t=16, pipe_dup_k=3,
                 pipe_n_trees=3, prefetch_depth=2, reps=2) if quick else
            dict(pipe_n=2048, pipe_p=16, pipe_n_t=50, pipe_dup_k=10,
                 pipe_n_trees=10, prefetch_depth=2, reps=3))
    device_counts = (1, 8) if quick else (1, 2, 4, 8)
    records = []
    for d in device_counts:
        snippet = _SNIPPET.format(n=n, p=p, n_y=n_y, n_t=n_t,
                                  dup_k=dup_k, n_trees=n_trees, **pipe)
        # XLA_FLAGS must be in the env before the subprocess inits jax
        r = run_measured(snippet, timeout=1800, env_extra={
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={d}"})
        if r.get("error"):
            emit(f"training/devices={d}", "fail", r["error"][-160:])
            records.append({"devices": d, "error": r["error"][-800:]})
            continue
        r.setdefault("config", {"n": n, "p": p, "n_y": n_y, "n_t": n_t,
                                "duplicate_k": dup_k, "n_trees": n_trees})
        pc = r.get("pipeline_comparison", {})
        emit(f"training/devices={d}",
             f"{r['fit_wall_s'] * 1e6:.0f}",
             f"rows_per_sec={r['rows_per_sec']:.0f}|"
             f"ensemble_rows_per_sec={r['ensemble_rows_per_sec']:.0f}|"
             f"peak_rss_mb={r['peak_rss_bytes'] / 1e6:.0f}")
        if pc:
            emit(f"training/pipeline/devices={d}",
                 f"{pc['pipelined_wall_s'] * 1e6:.0f}",
                 f"serial_wall_s={pc['serial_wall_s']:.3f}|"
                 f"pipelined_speedup={pc['pipelined_speedup']:.3f}|"
                 f"overlap_efficiency={pc['overlap_efficiency']}")
        records.append(r)
    if json_path:
        d = os.path.dirname(json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(json_path, "w") as f:
            json.dump({"bench": "training", "records": records}, f, indent=1)
        emit("training/json", "-", json_path)


if __name__ == "__main__":
    main(json_path="BENCH_training.json")
