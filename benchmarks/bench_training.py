"""Training throughput vs device count — the paper §3.3 scaling claim,
measured instead of asserted.

Each device count runs in its own subprocess (``XLA_FLAGS=
--xla_force_host_platform_device_count=D`` must precede jax init) and fits
the same dataset through ``fit_artifacts``: the single-device trainer at
D=1, the shard_map trainer on the ``auto_forest_mesh`` otherwise. Reports
rows/sec, ensemble-rows/sec (rows x duplicate_k x ensembles / wall), the
compiled per-device memory estimate of the sharded fit program ("peak HBM"
on a real accelerator; host bytes on the virtual mesh), and subprocess peak
RSS.

CSV: name,us_per_call,derived. With ``json_path`` set, also writes
``BENCH_training.json`` with one record per device count.

Caveat: on the CPU host the virtual devices share the same cores, so
rows/sec is NOT expected to scale with D here — the artifact proves the
harness and records the sharding overhead; real scaling numbers come from
running the same section on a TPU slice.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit, run_measured

_SNIPPET = r"""
import time, json
import jax
import numpy as np

from repro.config import ForestConfig
from repro.data.tabular import synthetic_resource_dataset
from repro.tabgen import fit_artifacts
from repro.launch.mesh import auto_forest_mesh

n, p, n_y = {n}, {p}, {n_y}
X, y = synthetic_resource_dataset(n, p, n_y, seed=0)
fcfg = ForestConfig(n_t={n_t}, duplicate_k={dup_k}, n_trees={n_trees},
                    max_depth=4, n_bins=32, reg_lambda=1.0)
mesh = auto_forest_mesh()
t0 = time.time()
art = fit_artifacts(X, y, fcfg, seed=0, mesh=mesh)
wall = time.time() - t0
n_ens = art.n_t * art.n_y

hbm = None
if mesh is not None:
    # per-device memory of the compiled shard_map fit program: the
    # fits-in-HBM number for this (rows, grid) slice
    from repro.forest.distributed import input_specs_forest, make_distributed_fit
    d_data = dict(zip(mesh.axis_names, mesh.devices.shape))["data"]
    n_pad = -(-n // d_data) * d_data
    bs = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    compiled = make_distributed_fit(mesh, fcfg).lower(
        *input_specs_forest(fcfg, n_pad, p, max(bs, min(n_ens, 8)))).compile()
    mem = compiled.memory_analysis()
    hbm = getattr(mem, "temp_size_in_bytes", None)

result = {{
    "devices": len(jax.devices()),
    "mesh": (dict(zip(mesh.axis_names, mesh.devices.shape))
             if mesh is not None else None),
    "fit_wall_s": wall,
    "includes_compile": True,
    "rows_per_sec": n * n_ens / wall,
    "ensemble_rows_per_sec": n * fcfg.duplicate_k * n_ens / wall,
    "per_device_temp_bytes": hbm,
}}
"""


def main(quick: bool = True, json_path: str = None) -> None:
    n, p, n_y = (2048, 8, 2) if quick else (65536, 32, 4)
    n_t, dup_k, n_trees = (4, 10, 10) if quick else (10, 20, 40)
    device_counts = (1, 8) if quick else (1, 2, 4, 8)
    records = []
    for d in device_counts:
        snippet = _SNIPPET.format(n=n, p=p, n_y=n_y, n_t=n_t,
                                  dup_k=dup_k, n_trees=n_trees)
        # XLA_FLAGS must be in the env before the subprocess inits jax
        r = run_measured(snippet, timeout=1800, env_extra={
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={d}"})
        if r.get("error"):
            emit(f"training/devices={d}", "fail", r["error"][-160:])
            records.append({"devices": d, "error": r["error"][-800:]})
            continue
        r.setdefault("config", {"n": n, "p": p, "n_y": n_y, "n_t": n_t,
                                "duplicate_k": dup_k, "n_trees": n_trees})
        emit(f"training/devices={d}",
             f"{r['fit_wall_s'] * 1e6:.0f}",
             f"rows_per_sec={r['rows_per_sec']:.0f}|"
             f"ensemble_rows_per_sec={r['ensemble_rows_per_sec']:.0f}|"
             f"peak_rss_mb={r['peak_rss_bytes'] / 1e6:.0f}")
        records.append(r)
    if json_path:
        d = os.path.dirname(json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(json_path, "w") as f:
            json.dump({"bench": "training", "records": records}, f, indent=1)
        emit("training/json", "-", json_path)


if __name__ == "__main__":
    main(json_path="BENCH_training.json")
