"""Shared benchmark helpers: subprocess peak-RSS measurement + CSV output."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time


def run_measured(snippet: str, timeout: int = 900,
                 env_extra: dict = None) -> dict:
    """Run a python snippet in a subprocess; returns its printed JSON plus
    wall time and peak RSS (KiB->bytes). Each config gets a clean process so
    peak memory is per-config (ru_maxrss is monotonic within a process).

    ``env_extra`` adds/overrides env vars — e.g. ``XLA_FLAGS`` to set a
    virtual device count, which must be in place before jax initialises."""
    wrapper = (
        "import resource, json, time\n"
        "t0 = time.time()\n"
        + snippet + "\n"
        "out = dict(result if isinstance(result, dict) else {})\n"
        "out['wall_s'] = time.time() - t0\n"
        "out['peak_rss_bytes'] = "
        "resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024\n"
        "print('\\n@@RESULT@@' + json.dumps(out))\n"
    )
    env = {"PYTHONPATH": "src", "HOME": "/root", "PATH": "/usr/bin:/bin"}
    if "JAX_PLATFORMS" in os.environ:    # scrubbed env: keep platform pin
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    if env_extra:
        env.update(env_extra)
    proc = subprocess.run([sys.executable, "-c", wrapper],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    if proc.returncode != 0:
        return {"error": proc.stderr[-1500:], "wall_s": None,
                "peak_rss_bytes": None}
    for line in proc.stdout.splitlines():
        if line.startswith("@@RESULT@@"):
            return json.loads(line[len("@@RESULT@@"):])
    return {"error": "no result line", "wall_s": None, "peak_rss_bytes": None}


def emit(name: str, us_per_call, derived):
    print(f"{name},{us_per_call},{derived}", flush=True)
