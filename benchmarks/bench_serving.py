"""Serving control-plane benchmark: open-loop mixed-tenant load against the
in-flight scheduler vs the PR-4 drain-then-serve reference.

Two tenants share one model, open-loop (arrivals follow a precomputed
exponential-gap schedule and are submitted at their scheduled time whether
or not the server is keeping up — the load that exposes queueing collapse,
unlike closed-loop clients that self-throttle):

* ``ia`` — interactive: many small requests, ``priority="interactive"``;
* ``bk`` — bulk: few large requests, ``priority="bulk"``, sized to keep the
  device saturated for the whole run.

Both arms serve the *identical* schedule ABBA-interleaved (inflight, drain,
drain, inflight, ...), min-of-reps wall -> max rows/sec, same methodology as
the generation/training benches on this noisy box. The drain arm
(``sync_resolve=True``) resolves each batch before admitting the next —
PR-4 semantics — so its host-side unpad/shuffle/deliver time stacks onto
device time; the in-flight arm overlaps the two. Sustained throughput is
``total rows / (last future resolved - first request submitted)``.

Gated metric: ``inflight_rows_per_sec``. The ``drain_reference_*`` metrics
are the comparison arm (exempt in scripts/check_bench.py — reference arms
are compared against, not gated). Latency percentiles (p50/p99 per priority
class, ms) are recorded for the trajectory; the acceptance story is bulk
saturating the device while the interactive p99 stays bounded (interactive
pops before bulk at every dispatch).

SLO accounting (PR 10): both arms run with the per-priority objectives
below (module constants, NOT part of the gated ``config`` identity — the
committed trajectory's record keys must not change) and report violation
counts per arm. With ``json_path`` set, the in-flight arm's span ring and
slow-request log land next to the JSON as ``*_trace.jsonl`` /
``*_slowlog.jsonl`` — the nightly lane uploads them as artifacts, so a
latency regression comes with the per-request timelines that explain it.

CI-container caveat (same one the training pipeline records): on the
2-core box the XLA device computation itself occupies both cores, so the
host work the in-flight arm overlaps (unpad/shuffle/slice/deliver + batch
formation) is only ~5% of wall — the measured speedup is a *floor*, real
accelerators with free host cores overlap far more. The committed
trajectory therefore gates the in-flight arm's absolute rows/sec, not the
speedup ratio.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.config import ForestConfig
from repro.obs import SlowLog
from repro.data.tabular import synthetic_resource_dataset
from repro.tabgen import fit_artifacts

#: static workload identity — check_bench matches records on ``config``, so
#: these are constants, not tuning knobs resolved at run time
QUICK = dict(n_fit=512, p=4, n_y=2, n_t=6, n_trees=10,
             ia_requests=100, ia_rows=32, ia_rate_per_s=400.0,
             bk_requests=40, bk_rows=1024, bk_rate_per_s=200.0,
             buckets=(64, 1024), reps=3)
FULL = dict(n_fit=2000, p=10, n_y=2, n_t=8, n_trees=20,
            ia_requests=300, ia_rows=32, ia_rate_per_s=600.0,
            bk_requests=120, bk_rows=2048, bk_rate_per_s=400.0,
            buckets=(64, 2048), reps=5)

#: per-priority latency objectives both arms are measured against.  These
#: are *observability* constants (violation counts ride the record, the
#: per-request timelines ride the artifacts) — deliberately outside the
#: ``config`` dicts above so check_bench record identities are unchanged.
SLO = {"interactive": 0.25, "bulk": 10.0}


def _schedule(cfg: dict, seed: int = 0):
    """The open-loop arrival plan: [(t_offset_s, priority, n_rows)],
    time-sorted, identical for every arm and rep."""
    rng = np.random.default_rng(seed)
    arr = []
    for prio, count, rows, rate in (
            ("interactive", cfg["ia_requests"], cfg["ia_rows"],
             cfg["ia_rate_per_s"]),
            ("bulk", cfg["bk_requests"], cfg["bk_rows"],
             cfg["bk_rate_per_s"])):
        t = np.cumsum(rng.exponential(1.0 / rate, size=count))
        arr.extend((float(ti), prio, rows) for ti in t)
    arr.sort()
    return arr


def _run_arm(server, schedule):
    """Replay the schedule open-loop; returns (rows_per_sec, lat_ms_by_prio).

    Latency is measured from *scheduled* arrival (not actual submit): when
    the submitting thread itself falls behind a saturated server, that lag
    is queueing delay the client experiences and must be charged to the arm.
    """
    done = {}  # idx -> completion monotonic time

    def _mark(idx):
        def cb(_fut):
            done[idx] = time.monotonic()
        return cb

    t0 = time.monotonic()
    futs = []
    for idx, (t_off, prio, n_rows) in enumerate(schedule):
        delay = t0 + t_off - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        f = server.submit(n_rows, priority=prio)
        f.add_done_callback(_mark(idx))
        futs.append(f)
    for f in futs:
        f.result(timeout=600)
    t_end = max(done.values())
    total_rows = sum(n for _, _, n in schedule)
    lat = {"interactive": [], "bulk": []}
    for idx, (t_off, prio, _) in enumerate(schedule):
        lat[prio].append((done[idx] - (t0 + t_off)) * 1e3)
    return total_rows / (t_end - t0), lat


def _percentiles(lat_ms):
    return (float(np.percentile(lat_ms, 50)), float(np.percentile(lat_ms, 99)))


def main(quick: bool = True, json_path: str = None) -> None:
    from repro.launch.serve_forest import ForestServer
    cfg = QUICK if quick else FULL
    X, y = synthetic_resource_dataset(cfg["n_fit"], cfg["p"], cfg["n_y"],
                                      seed=0)
    fcfg = ForestConfig(method="flow", n_t=cfg["n_t"], duplicate_k=5,
                        n_trees=cfg["n_trees"], max_depth=4, n_bins=32,
                        reg_lambda=1.0, multi_output=True)
    art = fit_artifacts(X, y, fcfg, seed=0)
    schedule = _schedule(cfg)

    # observability artifacts ride next to the JSON (nightly uploads the
    # whole --json-dir): the in-flight arm's span ring + any requests that
    # blew the interactive objective, with their per-span timelines.
    trace_path = slow_path = None
    if json_path:
        d = os.path.dirname(json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        stem = os.path.splitext(json_path)[0]
        trace_path = stem + "_trace.jsonl"
        slow_path = stem + "_slowlog.jsonl"

    def build(sync_resolve, slow_log=None):
        s = ForestServer(art, buckets=cfg["buckets"],
                         sync_resolve=sync_resolve,
                         slo=SLO, slow_log=slow_log)
        s.warmup()
        return s

    slow = SlowLog(slow_path, SLO["interactive"]) if slow_path else None
    servers = {"inflight": build(False, slow), "drain": build(True)}
    results = {"inflight": [], "drain": []}
    lats = {"inflight": [], "drain": []}
    order = ["inflight", "drain", "drain", "inflight"]  # ABBA
    for rep in range(cfg["reps"]):
        for arm in order:
            rps, lat = _run_arm(servers[arm], schedule)
            results[arm].append(rps)
            lats[arm].append(lat)
    stats = {arm: servers[arm].scheduler.stats_snapshot()
             for arm in servers}
    if trace_path:
        n_spans = servers["inflight"].tracer.export_jsonl(trace_path)
        emit("serving/trace", "-", f"{trace_path}|spans={n_spans}")
        if slow is not None:
            emit("serving/slowlog", "-",
                 f"{slow_path}|written={slow.written}")
    for arm in servers:
        servers[arm].stop()

    best = {arm: max(v) for arm, v in results.items()}
    # latency from each arm's best-throughput rep (the least host-noise run)
    best_lat = {arm: lats[arm][int(np.argmax(results[arm]))]
                for arm in results}
    ia_p50, ia_p99 = _percentiles(best_lat["inflight"]["interactive"])
    bk_p50, bk_p99 = _percentiles(best_lat["inflight"]["bulk"])
    d_ia_p50, d_ia_p99 = _percentiles(best_lat["drain"]["interactive"])

    record = {
        "config": {"section": "serving_open_loop", **{
            k: (list(v) if isinstance(v, tuple) else v)
            for k, v in cfg.items()}},
        "devices": 1,
        "mesh": None,
        "serving": {
            "includes_compile": False,
            "reps_per_arm": 2 * cfg["reps"],
            "total_rows": sum(n for _, _, n in schedule),
            "inflight_rows_per_sec": best["inflight"],
            # reference arm (PR-4 semantics; check_bench-exempt)
            "drain_reference_rows_per_sec": best["drain"],
            "inflight_vs_drain_speedup": best["inflight"] / best["drain"],
            "interactive_p50_ms": ia_p50,
            "interactive_p99_ms": ia_p99,
            "bulk_p50_ms": bk_p50,
            "bulk_p99_ms": bk_p99,
            "drain_interactive_p50_ms": d_ia_p50,
            "drain_interactive_p99_ms": d_ia_p99,
            "inflight_max_inflight_observed":
                stats["inflight"]["max_inflight_observed"],
            "inflight_batches": stats["inflight"]["batches"],
            "inflight_dropped_deadline": stats["inflight"]["dropped_deadline"],
            # SLO accounting over all reps (objectives: module SLO consts;
            # not rows_per_sec-suffixed, so check_bench leaves them ungated)
            "slo_interactive_objective_s": SLO["interactive"],
            "slo_bulk_objective_s": SLO["bulk"],
            "inflight_slo_violations_interactive":
                stats["inflight"]["slo"]["interactive"]["violations"],
            "inflight_slo_violations_bulk":
                stats["inflight"]["slo"]["bulk"]["violations"],
            "drain_slo_violations_interactive":
                stats["drain"]["slo"]["interactive"]["violations"],
            "drain_slo_violations_bulk":
                stats["drain"]["slo"]["bulk"]["violations"],
        },
    }
    emit("serving/open_loop/inflight",
         f"{1e6 / best['inflight']:.2f}",
         f"rows_per_sec={best['inflight']:.0f}|"
         f"speedup_vs_drain={record['serving']['inflight_vs_drain_speedup']:.2f}x|"
         f"interactive_p99_ms={ia_p99:.1f}|bulk_p99_ms={bk_p99:.1f}|"
         f"slo_viol_ia={record['serving']['inflight_slo_violations_interactive']}|"
         f"slo_viol_bk={record['serving']['inflight_slo_violations_bulk']}")
    emit("serving/open_loop/drain_reference",
         f"{1e6 / best['drain']:.2f}",
         f"rows_per_sec={best['drain']:.0f}|"
         f"interactive_p99_ms={d_ia_p99:.1f}|"
         f"slo_viol_ia={record['serving']['drain_slo_violations_interactive']}|"
         f"slo_viol_bk={record['serving']['drain_slo_violations_bulk']}")

    if json_path:
        d = os.path.dirname(json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(json_path, "w") as f:
            json.dump({"bench": "serving", "records": [record]}, f, indent=1)
        emit("serving/json", "-", json_path)


if __name__ == "__main__":
    main(json_path="BENCH_serving.json")
