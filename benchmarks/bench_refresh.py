"""Refresh-loop benchmark: warm-start extension vs full refit.

The freshness loop's economics in one number: when new rows arrive and a
served model needs ``K`` more boosting rounds, is
``extend_artifacts(base, X, extra_trees=K)`` (replay R base rounds, train
K) actually cheaper than refitting all ``R + K`` rounds from scratch?

Both arms produce an ``R + K``-tree model on the identical dataset through
the identical sharded trainer (a 1x1 mesh, so the lru-cached shard_map
program is reused across reps — compile excluded), ABBA-interleaved with
min-of-reps walls, the house methodology on noisy boxes. On the same data
the two results are bit-identical (asserted once per run, the tentpole
acceptance riding along in the bench), so the comparison is pure wall.

Gated metric: ``warm_extend_rows_per_sec``. The refit arm exists to be
beaten — ``full_refit_*`` is exempt in scripts/check_bench.py (reference
arm), and ``warm_vs_refit_speedup`` is recorded for the trajectory. The
replay cost grows with R (one tree-predict pass per base round), so the
speedup is below the ideal ``(R + K) / K``; the gap is the replay tax.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.config import ForestConfig
from repro.data.tabular import synthetic_resource_dataset
from repro.tabgen import extend_artifacts, fit_artifacts

#: static workload identity — check_bench matches records on ``config``
QUICK = dict(n=1024, p=8, n_y=2, n_t=2, dup_k=5, base_trees=12,
             extra_trees=3, reps=2)
FULL = dict(n=16384, p=16, n_y=2, n_t=8, dup_k=10, base_trees=40,
            extra_trees=10, reps=5)

_FIELDS = ("feat", "thr_val", "leaf", "best_round", "val_curve")


def main(quick: bool = True, json_path: str = None) -> None:
    import jax
    cfg = QUICK if quick else FULL
    X, y = synthetic_resource_dataset(cfg["n"], cfg["p"], cfg["n_y"], seed=0)
    mk = lambda r: ForestConfig(n_t=cfg["n_t"], duplicate_k=cfg["dup_k"],  # noqa: E731
                                n_trees=r, max_depth=4, n_bins=32,
                                reg_lambda=1.0)
    total = cfg["base_trees"] + cfg["extra_trees"]
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    base = fit_artifacts(X, y, mk(cfg["base_trees"]), seed=0, mesh=mesh)

    def warm():
        return extend_artifacts(base, X, y,
                                extra_trees=cfg["extra_trees"], seed=0,
                                mesh=mesh)

    def refit():
        return fit_artifacts(X, y, mk(total), seed=0, mesh=mesh)

    # acceptance riding along: on the same data the arms are bit-identical
    ext, cold = warm(), refit()                 # also compiles both programs
    for f in _FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(ext, f)),
                                      np.asarray(getattr(cold, f)),
                                      err_msg=f)

    walls = {"warm": [], "refit": []}
    arms = {"warm": warm, "refit": refit}
    for _ in range(cfg["reps"]):                # ABBA
        for arm in ("warm", "refit", "refit", "warm"):
            t0 = time.perf_counter()
            arms[arm]()
            walls[arm].append(time.perf_counter() - t0)
    w_wall, r_wall = min(walls["warm"]), min(walls["refit"])
    n_ens = cfg["n_t"] * cfg["n_y"]
    record = {
        "config": {"section": "refresh", **cfg},
        "devices": 1,
        "mesh": {"data": 1, "model": 1},
        "refresh": {
            "includes_compile": False,
            "reps_per_arm": 2 * cfg["reps"],
            "bit_identical_to_refit": True,
            "warm_extend_wall_s": w_wall,
            "warm_extend_rows_per_sec": cfg["n"] * n_ens / w_wall,
            # reference arm (check_bench-exempt): exists to be beaten
            "full_refit_wall_s": r_wall,
            "full_refit_rows_per_sec": cfg["n"] * n_ens / r_wall,
            "warm_vs_refit_speedup": r_wall / w_wall,
            "ideal_speedup": total / cfg["extra_trees"],
        },
    }
    emit("refresh/warm_extend", f"{w_wall * 1e6:.0f}",
         f"rows_per_sec={record['refresh']['warm_extend_rows_per_sec']:.0f}|"
         f"speedup_vs_refit={r_wall / w_wall:.2f}x|"
         f"ideal={total / cfg['extra_trees']:.1f}x")
    emit("refresh/full_refit_reference", f"{r_wall * 1e6:.0f}",
         f"rows_per_sec={record['refresh']['full_refit_rows_per_sec']:.0f}")

    if json_path:
        d = os.path.dirname(json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(json_path, "w") as f:
            json.dump({"bench": "refresh", "records": [record]}, f, indent=1)
