"""Paper Table 2 / Table 7: generated-data quality across methods.

Datasets: two-moons (nonlinear 2D), 3-class Gaussian mixture, correlated
Gaussian (joint-structure probe). Methods: FF-SO / FF-MO / FD (ours),
GaussianCopula, TVAE-like, NN-flow (STaSy-like), NN-diffusion
(TabDDPM-like). Metrics: W1_train / W1_test (per-feature + sliced),
coverage_train / coverage_test, and the mean rank per method (the paper's
summary statistic).

CSV: name,us_per_call,derived — us = fit+generate wall, derived =
"w1test=..|cov=..|rank=..".
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.config import ForestConfig
from repro.core.copula import GaussianCopula
from repro.core.ctgan import CTGANBaseline
from repro.tabgen import TabularGenerator
from repro.core.nn_baselines import NNGenerativeModel, TVAEBaseline
from repro.data.tabular import correlated_gaussian, two_moons
from repro.eval import metrics as M


def _datasets(n=600, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    X, y = two_moons(n, seed=seed)
    out["two_moons"] = (X, y)
    mus = np.array([[-2, 0, 1], [2, 1, -1], [0, -2, 2]], np.float32)
    Xg = np.concatenate([m + 0.5 * rng.normal(size=(n // 3, 3))
                         for m in mus]).astype(np.float32)
    yg = np.repeat(np.arange(3), n // 3)
    perm = rng.permutation(len(Xg))        # unordered classes for the split
    out["gauss_mix"] = (Xg[perm], yg[perm])
    Xc, _ = correlated_gaussian(n, 6, seed=seed)
    out["corr_gauss"] = (Xc, None)
    return out


def _methods(quick: bool):
    n_t = 8 if quick else 16
    K = 10 if quick else 50
    T = 15 if quick else 60
    steps = 600 if quick else 2500
    fc = dict(n_t=n_t, duplicate_k=K, n_trees=T, max_depth=4, n_bins=32,
              reg_lambda=1.0, early_stop_rounds=5)
    return {
        "FF-SO": lambda: TabularGenerator(ForestConfig(method="flow", **fc)),
        "FF-MO": lambda: TabularGenerator(
            ForestConfig(method="flow", multi_output=True, **fc)),
        "FD-SO": lambda: TabularGenerator(
            ForestConfig(method="diffusion", **fc)),
        "copula": lambda: GaussianCopula(),
        "tvae": lambda: TVAEBaseline(steps=steps),
        "nn-flow": lambda: NNGenerativeModel(
            ForestConfig(method="flow"), steps=steps),
        "nn-diff": lambda: NNGenerativeModel(
            ForestConfig(method="diffusion"), steps=steps),
        "ctgan": lambda: CTGANBaseline(steps=steps),
    }


def main(quick: bool = True) -> None:
    rows = {}
    for ds_name, (X, y) in _datasets().items():
        n = len(X)
        tr, te = X[: int(0.8 * n)], X[int(0.8 * n):]
        ytr = y[: int(0.8 * n)] if y is not None else None
        for m_name, ctor in _methods(quick).items():
            t0 = time.time()
            model = ctor()
            try:
                if isinstance(model, (GaussianCopula, TVAEBaseline)):
                    model.fit(tr)
                    G = model.generate(len(tr), seed=1)
                else:
                    model.fit(tr, ytr, seed=0)
                    G, _ = model.generate(len(tr), seed=1)
            except Exception as e:  # pragma: no cover
                emit(f"quality/{ds_name}/{m_name}", "fail", str(e)[:60])
                continue
            wall = time.time() - t0
            w1_tr = M.sliced_w1(G, tr)
            w1_te = M.sliced_w1(G, te)
            k = M.auto_k(tr, te)
            cov_te = M.coverage(G, te, k)
            rows[(ds_name, m_name)] = (w1_te, cov_te)
            emit(f"quality/{ds_name}/{m_name}", f"{wall * 1e6:.0f}",
                 f"w1train={w1_tr:.4f}|w1test={w1_te:.4f}|covtest={cov_te:.3f}")
    # mean rank per method over datasets (paper's summary)
    ds_names = sorted({d for d, _ in rows})
    m_names = sorted({m for _, m in rows})
    ranks = {m: [] for m in m_names}
    for d in ds_names:
        vals = [(rows[(d, m)][0] if (d, m) in rows else np.inf, m)
                for m in m_names]
        for r, (_, m) in enumerate(sorted(vals), start=1):
            ranks[m].append(r)
    for m in m_names:
        emit(f"quality/mean_rank/{m}", "-", f"{np.mean(ranks[m]):.2f}")


if __name__ == "__main__":
    main()
