"""Paper Figure 3 / Figure 10 (trees at best iteration vs timestep) and
Figure 11 (K / n_tree / SO-vs-MO ablation on distributional metrics).

CSV: name,us_per_call,derived.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.config import ForestConfig
from repro.tabgen import TabularGenerator
from repro.data.tabular import two_moons
from repro.eval import metrics as M


def fig3_early_stopping_profile(quick: bool = True) -> None:
    """Trees kept at the best validation round, per timestep (Fig. 3)."""
    X, y = two_moons(400, seed=0)
    fcfg = ForestConfig(n_t=8, duplicate_k=10, n_trees=40, max_depth=4,
                        n_bins=32, reg_lambda=1.0, early_stop_rounds=5)
    model = TabularGenerator(fcfg).fit(X, y, seed=0)
    prof = model.artifacts.trees_at_best_iteration()
    emit("ablation/fig3/trees_by_timestep", "-",
         "|".join(f"{v:.1f}" for v in prof))
    # the paper's qualitative claim: late timesteps (near noise) need fewer
    early, late = prof[: len(prof) // 2].mean(), prof[len(prof) // 2:].mean()
    emit("ablation/fig3/early_vs_late_mean_trees", "-",
         f"{early:.1f}_vs_{late:.1f}")


def fig11_k_ntree_ablation(quick: bool = True) -> None:
    X, y = two_moons(500, seed=1)
    n = len(X)
    tr, te = X[: int(0.8 * n)], X[int(0.8 * n):]
    ytr = y[: int(0.8 * n)]
    Ks = (5, 20) if quick else (5, 20, 100)
    Ts = (10, 40) if quick else (10, 40, 200)
    for mo in (False, True):
        for K in Ks:
            for T in Ts:
                fcfg = ForestConfig(n_t=8, duplicate_k=K, n_trees=T,
                                    max_depth=4, n_bins=32, reg_lambda=1.0,
                                    early_stop_rounds=5, multi_output=mo)
                t0 = time.time()
                m = TabularGenerator(fcfg).fit(tr, ytr, seed=0)
                G, _ = m.generate(len(tr), seed=1)
                w1 = M.sliced_w1(G, te)
                emit(f"ablation/fig11/{'MO' if mo else 'SO'}/K={K}/T={T}",
                     f"{(time.time() - t0) * 1e6:.0f}", f"w1test={w1:.4f}")


def schedule_ablation(quick: bool = True) -> None:
    """Beyond-paper: the non-uniform timestep partitioning the paper's C.2
    leaves to future work (cosine grid, dense near t=0)."""
    X, y = two_moons(500, seed=2)
    tr, te = X[:400], X[400:]
    for sched in ("uniform", "cosine"):
        fcfg = ForestConfig(n_t=10, duplicate_k=20, n_trees=30, max_depth=4,
                            n_bins=32, reg_lambda=1.0, t_schedule=sched)
        t0 = time.time()
        m = TabularGenerator(fcfg).fit(tr, y[:400], seed=0)
        G, _ = m.generate(400, seed=1)
        emit(f"ablation/t_schedule/{sched}",
             f"{(time.time() - t0) * 1e6:.0f}",
             f"w1test={M.sliced_w1(G, te):.4f}")


def main(quick: bool = True) -> None:
    fig3_early_stopping_profile(quick)
    fig11_k_ntree_ablation(quick)
    schedule_ablation(quick)


if __name__ == "__main__":
    main()
