"""Distributed forest training: shard_map + psum histograms on 8 virtual
devices. Runs in a subprocess because XLA_FLAGS must be set before jax init."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.config import ForestConfig
from repro.forest.distributed import make_distributed_fit
from repro.forest.packed import PackedForest, predict_forest

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"))

rng = np.random.default_rng(0)
n, p = 512, 4
mu = np.array([1.0, -1.0, 0.5, 0.0], np.float32)
X = (mu + 0.4 * rng.normal(size=(n, p))).astype(np.float32)
# scale to [-1, 1] like the host trainer does
mn, mx = X.min(0), X.max(0)
Xs = (X - mn) / (mx - mn) * 2 - 1

fcfg = ForestConfig(n_t=4, duplicate_k=8, n_trees=10, max_depth=3, n_bins=16,
                    reg_lambda=1.0)
fit = make_distributed_fit(mesh, fcfg, data_axes=("data",))

n_ens = 4  # = n_t, single class, sharded over model axis (2)
ts = jnp.linspace(0.0, 1.0, n_ens)
ys = jnp.zeros((n_ens,), jnp.int32)
keys = jax.random.split(jax.random.PRNGKey(0), n_ens * 2)
keys = jnp.asarray(np.asarray(keys, np.uint32).reshape(n_ens, 2, 2))

res = fit(jnp.asarray(Xs), jnp.ones((n,), jnp.float32),
          jnp.zeros((n,), jnp.int32), ts, ys, keys)
feat = np.asarray(res.feat)      # [n_ens, n_sub, T, H]
leaf = np.asarray(res.leaf)
assert feat.shape == (n_ens, p, 10, 7), feat.shape
assert np.all(np.isfinite(leaf))

# the t=0 ensemble regresses x1 - x0 given x_t = x0: its prediction at the
# data mean should be close to E[x1 - x0 | x0 = mean] = -mean (x1 is N(0,I))
f0 = PackedForest(jnp.asarray(res.feat[0]), jnp.asarray(res.thr_val[0]),
                  jnp.asarray(res.leaf[0]), False)
x_query = jnp.asarray(Xs.mean(0, keepdims=True))
v = np.asarray(predict_forest(x_query, f0, 3))[0]
target = -np.asarray(Xs.mean(0))
err = np.abs(v - target).max()
assert err < 0.35, (v, target)
print(json.dumps({"ok": True, "err": float(err)}))
"""


@pytest.mark.slow
@pytest.mark.distributed
def test_distributed_fit_8dev():
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-3000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["ok"]


_SAMPLE_SHARDED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np

from repro.config import ForestConfig
from repro.data.tabular import two_moons
from repro.launch.serve_forest import ForestServer
from repro.tabgen import fit_artifacts, sample

X, y = two_moons(300, seed=0)
fcfg = ForestConfig(n_t=5, duplicate_k=6, n_trees=8, max_depth=3, n_bins=16,
                    reg_lambda=1.0)
art = fit_artifacts(X, y, fcfg, seed=0)
mesh = jax.make_mesh((4, 2), ("data", "model"))

# sharded == single-device, bit-for-bit under a fixed seed (noise is drawn
# per (class, row) counter, so the partitioning cannot change values);
# n=151 keeps the row shards uneven on purpose
G1, y1 = sample(art, 151, seed=1)
G2, y2 = sample(art, 151, seed=1, mesh=mesh)
assert np.array_equal(y1, y2)
np.testing.assert_allclose(G1, G2, rtol=1e-5, atol=1e-5)

# pre-sharded artifacts (the serving placement) agree too
G3, _ = sample(art.shard(mesh), 151, seed=1, mesh=mesh)
np.testing.assert_allclose(G1, G3, rtol=1e-5, atol=1e-5)

# the kernel path composes with the mesh
G4, _ = sample(art, 151, seed=1, mesh=mesh, impl="pallas_interpret")
np.testing.assert_allclose(G1, G4, rtol=1e-5, atol=1e-5)

# a class count that does not divide the model axis degrades to replicated
# classes instead of failing
y3 = np.arange(300) % 3
art3 = fit_artifacts(X, y3, fcfg, seed=0)
Ga, _ = sample(art3, 100, seed=4)
Gb, _ = sample(art3, 100, seed=4, mesh=mesh)
np.testing.assert_allclose(Ga, Gb, rtol=1e-5, atol=1e-5)

# the mesh-backed server serves micro-batched requests on the same programs
server = ForestServer(art, buckets=(64, 256), mesh=mesh)
server.warmup()
futs = [server.submit(n) for n in (17, 40, 90)]
for n, f in zip((17, 40, 90), futs):
    Xs, ys = f.result(timeout=300)
    assert Xs.shape == (n, 2)
server.stop()
assert server.stats["requests"] == 3
print(json.dumps({"ok": True}))
"""


@pytest.mark.slow
@pytest.mark.distributed
def test_sharded_sample_matches_single_8dev():
    out = subprocess.run([sys.executable, "-c", _SAMPLE_SHARDED],
                         capture_output=True, text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-3000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["ok"]
