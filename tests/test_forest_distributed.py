"""Distributed forest training: shard_map + psum histograms on 8 virtual
devices. Runs in a subprocess because XLA_FLAGS must be set before jax init."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.config import ForestConfig
from repro.forest.distributed import make_distributed_fit
from repro.forest.packed import PackedForest, predict_forest

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"))

rng = np.random.default_rng(0)
n, p = 512, 4
mu = np.array([1.0, -1.0, 0.5, 0.0], np.float32)
X = (mu + 0.4 * rng.normal(size=(n, p))).astype(np.float32)
# scale to [-1, 1] like the host trainer does
mn, mx = X.min(0), X.max(0)
Xs = (X - mn) / (mx - mn) * 2 - 1

fcfg = ForestConfig(n_t=4, duplicate_k=8, n_trees=10, max_depth=3, n_bins=16,
                    reg_lambda=1.0)
fit = make_distributed_fit(mesh, fcfg, data_axes=("data",))

n_ens = 4  # = n_t, single class, sharded over model axis (2)
ts = jnp.linspace(0.0, 1.0, n_ens)
ys = jnp.zeros((n_ens,), jnp.int32)
keys = jax.random.split(jax.random.PRNGKey(0), n_ens * 2)
keys = jnp.asarray(np.asarray(keys, np.uint32).reshape(n_ens, 2, 2))

res = fit(jnp.asarray(Xs), jnp.ones((n,), jnp.float32),
          jnp.zeros((n,), jnp.int32), ts, ys, keys)
feat = np.asarray(res.feat)      # [n_ens, n_sub, T, H]
leaf = np.asarray(res.leaf)
assert feat.shape == (n_ens, p, 10, 7), feat.shape
assert np.all(np.isfinite(leaf))

# the t=0 ensemble regresses x1 - x0 given x_t = x0: its prediction at the
# data mean should be close to E[x1 - x0 | x0 = mean] = -mean (x1 is N(0,I))
f0 = PackedForest(jnp.asarray(res.feat[0]), jnp.asarray(res.thr_val[0]),
                  jnp.asarray(res.leaf[0]), False)
x_query = jnp.asarray(Xs.mean(0, keepdims=True))
v = np.asarray(predict_forest(x_query, f0, 3))[0]
target = -np.asarray(Xs.mean(0))
err = np.abs(v - target).max()
assert err < 0.35, (v, target)
print(json.dumps({"ok": True, "err": float(err)}))
"""


@pytest.mark.slow
@pytest.mark.distributed
def test_distributed_fit_8dev():
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-3000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["ok"]
