"""End-to-end tests for PR-10 request-scoped observability: trace-context
propagation over real HTTP (X-Repro-Request-Id -> /v1/trace/<id>), batch
links, SLO accounting, slow-request capture, resource telemetry, and the
bounded profiling endpoint."""
import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.config import ForestConfig
from repro.data.tabular import two_moons
from repro.obs import (MetricsRegistry, ProfileInProgress, Profiler,
                       ResourceMonitor, SlowLog, Tracer)
from repro.serving import AdmissionController, ModelRegistry, QueueFull
from repro.tabgen import fit_artifacts


@pytest.fixture(scope="module")
def moons_artifacts():
    X, y = two_moons(300, seed=0)
    fcfg = ForestConfig(method="flow", n_t=6, duplicate_k=8, n_trees=10,
                        max_depth=3, n_bins=16, reg_lambda=1.0)
    return fit_artifacts(X, y, fcfg, seed=0)


# ---------------------------------------------------------------------------
# HTTP plane with the full observability stack wired in
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_plane(moons_artifacts, tmp_path_factory):
    from repro.launch.serve_http import ServingApp, serve_in_thread
    tmp = tmp_path_factory.mktemp("tracing")
    metrics, tracer = MetricsRegistry(), Tracer()
    registry = ModelRegistry(buckets=(64,), metrics=metrics)
    registry.register("moons", moons_artifacts, samplers=("euler",))
    admission = AdmissionController(metrics=metrics)
    # threshold 0.0: every resolved request is "slow" — deterministic capture
    slow = SlowLog(str(tmp / "slow.jsonl"), threshold_s=0.0)
    app = ServingApp(
        registry, admission, metrics=metrics, tracer=tracer,
        # 1e-9 interactive objective: every request violates (objectives
        # must be > 0, so this is the deterministic always-violate setting)
        slo={"interactive": 1e-9, "bulk": 10.0},
        slow_log=slow,
        profiler=Profiler(str(tmp / "profiles"), max_seconds=5.0),
        monitor=ResourceMonitor(metrics, interval_s=60.0,
                                admission=admission, registry=registry))
    registry.warmup()
    app.monitor.sample()
    httpd, thread = serve_in_thread(app)
    host, port = httpd.server_address[:2]
    yield app, tracer, f"http://{host}:{port}", slow
    httpd.shutdown()
    httpd.server_close()
    app.stop()
    thread.join(timeout=10)


def _req(method, url, body=None, headers=()):
    hdrs = {"Content-Type": "application/json"}
    hdrs.update(dict(headers))
    req = urllib.request.Request(
        url, method=method,
        data=None if body is None else json.dumps(body).encode(),
        headers=hdrs)
    try:
        with urllib.request.urlopen(req, timeout=120) as resp:
            return resp.status, dict(resp.headers), json.load(resp)
    except urllib.error.HTTPError as err:
        return err.code, dict(err.headers), json.load(err)


def test_request_id_header_resolves_to_timeline(traced_plane):
    """The tentpole round trip: the id minted at ingress comes back in the
    response header, resolves via /v1/trace/<id> to a queue+device
    timeline, and that timeline reconciles with /statz aggregates.

    Runs first in this module, so this request is the plane's first — its
    single timeline must BE the scheduler totals exactly (same spans feed
    both views)."""
    _, _, base, _ = traced_plane
    status, headers, body = _req("POST", f"{base}/v1/generate",
                                 {"model": "moons", "n": 48, "tenant": "t0",
                                  "priority": "interactive"})
    assert status == 200 and len(body["rows"]) == 48
    rid = headers["X-Repro-Request-Id"]
    assert rid and rid == body["request_id"]

    status, _, tl = _req("GET", f"{base}/v1/trace/{rid}")
    assert status == 200
    names = [s["name"] for s in tl["spans"]]
    assert names == ["serve.queue", "serve.device"]
    q, dev = tl["spans"]
    assert q["trace_id"] == rid and rid in dev["links"]
    assert q["attrs"]["batch_id"] == dev["attrs"]["batch_id"]
    s = tl["summary"]
    assert s["model"] == "moons" and s["tenant"] == "t0"
    assert s["rows"] == 48
    assert s["queue_wait_s"] >= 0.0 and s["admission_s"] >= 0.0
    assert s["queue_depth"] >= 1
    assert s["batch"]["rows"] == 48 and s["batch"]["requests"] == 1
    assert s["batch"]["outcome"] == "ok"

    status, _, statz = _req("GET", f"{base}/statz")
    assert status == 200
    sched = statz["scheduler"]
    assert abs(q["duration_s"] - sched["queue_wait_s"]) < 1e-9
    assert abs(dev["duration_s"] - sched["device_s"]) < 1e-9


def test_unknown_trace_id_404_and_errors_carry_request_id(traced_plane):
    _, _, base, _ = traced_plane
    status, _, body = _req("GET", f"{base}/v1/trace/deadbeef")
    assert status == 404 and "deadbeef" in body["error"]
    # error responses are addressable too: the id is minted before
    # validation, so a 400 still carries the trace handle
    status, headers, body = _req("POST", f"{base}/v1/generate",
                                 {"model": "moons", "n": 0})
    assert status == 400
    assert headers["X-Repro-Request-Id"] == body["request_id"]


def test_slo_violations_and_slow_log_capture(traced_plane):
    """With a 1e-9 interactive objective every resolved request violates;
    the violation shows in /statz (budget burn) and /metrics (counter),
    and the slow log has the request's full span timeline."""
    _, _, base, slow = traced_plane
    status, _, body = _req("POST", f"{base}/v1/generate",
                           {"model": "moons", "n": 8})
    assert status == 200
    rid = body["request_id"]
    status, _, statz = _req("GET", f"{base}/statz")
    slo = statz["scheduler"]["slo"]
    assert slo["interactive"]["objective_s"] == pytest.approx(1e-9)
    assert slo["interactive"]["violations"] >= 1
    assert slo["interactive"]["violation_rate"] == 1.0
    assert slo["interactive"]["budget_burn"] >= 1.0
    assert slo["bulk"]["requests"] == 0          # objective present, unused
    with urllib.request.urlopen(f"{base}/metrics", timeout=60) as r:
        prom = r.read().decode()
    assert 'serving_slo_violations_total{priority="interactive"}' in prom
    assert "serving_slo_objective_seconds" in prom
    # slow log: threshold 0.0 captures everything, spans ride along
    recs = [json.loads(ln) for ln in open(slow.path).read().splitlines()]
    mine = [r for r in recs if r["request_id"] == rid]
    assert len(mine) == 1 and mine[0]["latency_s"] > 0.0
    assert {s["name"] for s in mine[0]["spans"]} == {"serve.queue",
                                                     "serve.device"}
    assert slow.written == len(recs)


def test_resource_gauges_on_metrics_endpoint(traced_plane):
    _, _, base, _ = traced_plane
    with urllib.request.urlopen(f"{base}/metrics", timeout=60) as r:
        prom = r.read().decode()
    assert "resource_rss_bytes" in prom
    assert "resource_samples_total" in prom
    rss = next(float(ln.rsplit(" ", 1)[1]) for ln in prom.splitlines()
               if ln.startswith("resource_rss_bytes "))
    assert rss > 0


def test_concurrent_scrapes_during_traffic(traced_plane):
    """/metrics and /statz stay consistent 200s while generates hammer the
    plane from other threads — the one-registry lock story under load."""
    _, _, base, _ = traced_plane
    stop = threading.Event()
    errors = []

    def hammer():
        while not stop.is_set():
            status, _, _ = _req("POST", f"{base}/v1/generate",
                                {"model": "moons", "n": 8})
            if status != 200:
                errors.append(("generate", status))

    def scrape(path):
        while not stop.is_set():
            try:
                with urllib.request.urlopen(base + path, timeout=60) as r:
                    if r.status != 200:
                        errors.append((path, r.status))
                    r.read()
            except Exception as e:               # noqa: BLE001
                errors.append((path, repr(e)))

    threads = ([threading.Thread(target=hammer) for _ in range(2)]
               + [threading.Thread(target=scrape, args=("/metrics",)),
                  threading.Thread(target=scrape, args=("/statz",))])
    for t in threads:
        t.start()
    time.sleep(1.5)
    stop.set()
    for t in threads:
        t.join(timeout=60)
    assert not errors, errors[:5]


def test_profile_endpoint_overlap_disabled_and_admin(traced_plane):
    app, _, base, _ = traced_plane
    done = {}

    def long_capture():
        done.update(_req("POST", f"{base}/debug/profile",
                         {"duration_ms": 800})[2])

    t = threading.Thread(target=long_capture)
    t.start()
    deadline = time.monotonic() + 10.0
    while not app.profiler.active:               # wait for capture to start
        assert time.monotonic() < deadline, "profile capture never started"
        time.sleep(0.01)
    status, _, body = _req("POST", f"{base}/debug/profile",
                           {"duration_ms": 100})
    assert status == 409 and "already running" in body["error"]
    t.join(timeout=60)
    assert done["duration_s"] == pytest.approx(0.8) and done["dir"]
    # bad duration -> 400
    status, _, _ = _req("POST", f"{base}/debug/profile", {"duration_ms": -5})
    assert status == 400
    # admin guard: with a token configured, the header is required
    app.admin_token = "s3cret"
    try:
        status, _, body = _req("POST", f"{base}/debug/profile",
                               {"duration_ms": 50})
        assert status == 401
        status, _, _ = _req("POST", f"{base}/debug/profile",
                            {"duration_ms": 50},
                            headers={"X-Repro-Admin-Token": "s3cret"})
        assert status == 200
    finally:
        app.admin_token = None
    # disabled plane (no --profile-dir) -> 403
    saved, app.profiler = app.profiler, None
    try:
        status, _, body = _req("POST", f"{base}/debug/profile",
                               {"duration_ms": 50})
        assert status == 403 and "disabled" in body["error"]
    finally:
        app.profiler = saved


# ---------------------------------------------------------------------------
# scheduler-level: batch links, one-clock deadlines
# ---------------------------------------------------------------------------

def test_coalesced_batch_links_every_request(moons_artifacts):
    """Two requests coalesced into one dispatch: the serve.device span
    links BOTH request ids, and each id's timeline shares the batch_id."""
    from repro.launch.serve_forest import ForestServer
    server = ForestServer(moons_artifacts, buckets=(64,),
                          coalesce_window_s=2.0)
    server.warmup()
    try:
        f1 = server.submit(32)
        f2 = server.submit(32)
        for f in (f1, f2):
            X, _ = f.result(timeout=120)
            assert len(X) == 32
        r1, r2 = f1.request_id, f2.request_id
        assert r1 != r2
        dev = server.tracer.spans(name="serve.device")
        assert len(dev) == 1                     # one coalesced dispatch
        assert set(dev[0].links) == {r1, r2}
        tl1, tl2 = server.tracer.trace(r1), server.tracer.trace(r2)
        assert [s.name for s in tl1] == ["serve.queue", "serve.device"]
        assert tl1[1] is dev[0] and tl2[1] is dev[0]
        assert (tl1[0].attrs["batch_id"] == tl2[0].attrs["batch_id"]
                == dev[0].attrs["batch_id"])
    finally:
        server.stop()


class _SkewedTracer(Tracer):
    """Backdates spans it owns the timestamp for — a regression guard that
    the scheduler's deadline math never borrows tracer-owned time."""

    def start(self, name, *, t_start=None, **kw):
        if t_start is None:
            t_start = time.monotonic() - 999.0
        return super().start(name, t_start=t_start, **kw)


class _SpyAdmission(AdmissionController):
    """Records the offered request, then rejects it."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.seen = []

    def offer(self, req):
        self.seen.append(req)
        raise QueueFull("spy: rejecting everything", retry_after_s=0.1)


def test_deadline_and_span_share_one_clock_reading(moons_artifacts):
    """submit() takes ONE monotonic reading for the span start and the
    absolute deadline. A tracer that skews timestamps it owns must not be
    able to move the deadline (the PR-10 one-clock fix)."""
    from repro.serving import InflightScheduler
    metrics, tracer = MetricsRegistry(), _SkewedTracer()
    registry = ModelRegistry(buckets=(64,), metrics=metrics)
    registry.register("moons", moons_artifacts, samplers=("euler",))
    spy = _SpyAdmission(metrics=metrics)
    sched = InflightScheduler(registry, spy, metrics=metrics, tracer=tracer)
    try:
        before = time.monotonic()
        with pytest.raises(QueueFull):
            sched.submit(8, model="moons", deadline_s=1.5)
        after = time.monotonic()
        (req,) = spy.seen
        # one reading: deadline - enqueue is EXACTLY the relative SLO, and
        # the queue span starts at that same reading (not the skewed time)
        assert req.deadline_s == req.enqueued_s + 1.5
        assert req.span.t_start == req.enqueued_s
        assert before <= req.enqueued_s <= after  # sane, un-skewed clock
        assert req.span.attrs["outcome"] == "rejected"
    finally:
        sched.stop()
