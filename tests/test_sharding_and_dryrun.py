"""Sharding rules + dry-run machinery on a small virtual-device mesh.

The production 512-device sweep runs via ``repro.launch.dryrun``; these tests
prove the same code path (rules -> jit(in_shardings) -> lower -> compile ->
collective inventory) on an 8-device host mesh inside the test suite.
"""
import json
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.sharding import rules


def test_param_specs_shapes_divisible():
    """Every sharded dim must be divisible by its mesh axis size."""
    sizes = {"data": 16, "model": 16}
    for arch_id in ("dbrx-132b", "deepseek-v2-236b", "smollm-135m",
                    "xlstm-1.3b", "recurrentgemma-9b", "whisper-tiny"):
        cfg = get_arch(arch_id)
        from repro.models import lm
        import jax.numpy as jnp
        shapes = jax.eval_shape(
            lambda c=cfg: lm.init_params(jax.random.PRNGKey(0), c,
                                         jnp.float32))
        specs = rules.param_specs(shapes, cfg, ("data",), "model", 16, 16)

        def check(path, leaf, spec):
            for d, ax in enumerate(spec):
                if ax is None:
                    continue
                size = np.prod([sizes[a] for a in
                                (ax if isinstance(ax, tuple) else (ax,))])
                assert leaf.shape[d] % size == 0, (arch_id, path, leaf.shape,
                                                   spec)

        jax.tree_util.tree_map_with_path(
            lambda p, l, s: check(p, l, s), shapes, specs)


def test_param_specs_no_tp_when_tp_size_1():
    cfg = get_arch("smollm-135m")
    from repro.models import lm
    import jax.numpy as jnp
    shapes = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))
    specs = rules.param_specs(shapes, cfg, ("data", "model"), "model",
                              256, 1)
    for spec in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)):
        for entry in spec:
            assert entry != "model" or isinstance(entry, tuple)


_DRYRUN_SMALL = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp

from repro.config import SHAPES_BY_NAME, TrainConfig, ShapeConfig
from repro.configs import get_arch
from repro.models import lm
from repro.sharding import rules
from repro.train.optim import adamw_update

# reduced config, small shape, 4x2 mesh — full dry-run code path
cfg = get_arch("smollm-135m", reduced=True)
shape = ShapeConfig("mini_train", 64, 8, "train")
mesh = jax.make_mesh((4, 2), ("data", "model"))
dp, tp = ("data",), "model"
specs = lm.input_specs(cfg, shape, jnp.float32)
params_shape = jax.eval_shape(
    lambda: lm.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))
pspecs = rules.param_specs(params_shape, cfg, dp, tp, 4, 2)
p_shard = jax.tree_util.tree_map(
    lambda s: jax.sharding.NamedSharding(mesh, s), pspecs)
opt_shape = {"m": params_shape, "v": params_shape,
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
opt_shard = {"m": p_shard, "v": p_shard,
             "step": jax.sharding.NamedSharding(mesh,
                                                jax.sharding.PartitionSpec())}
bspecs = rules.batch_specs(specs, dp, tp, 4)
b_shard = jax.tree_util.tree_map(
    lambda s: jax.sharding.NamedSharding(mesh, s), bspecs)
tcfg = TrainConfig()

def train_step(params, opt_state, batch):
    (loss, _), grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, batch, cfg, dtype=jnp.float32,
                             remat_policy="none"), has_aux=True)(params)
    params, opt_state, _ = adamw_update(grads, opt_state, params, tcfg)
    return params, opt_state, loss

fn = jax.jit(train_step, in_shardings=(p_shard, opt_shard, b_shard))
lowered = fn.lower(params_shape, opt_shape, specs)
compiled = lowered.compile()
mem = compiled.memory_analysis()
from repro.analysis.flops import hlo_cost_analysis
cost = hlo_cost_analysis(compiled)  # dict/list-of-dicts across jax versions

from repro.launch.dryrun import collective_inventory
inv = collective_inventory(compiled.as_text())
print(json.dumps({
    "ok": True,
    "flops": cost.get("flops", 0),
    "has_collectives": bool(inv),
    "inventory_kinds": sorted(inv),
}))
"""


def test_dryrun_code_path_small_mesh():
    out = subprocess.run([sys.executable, "-c", _DRYRUN_SMALL],
                         capture_output=True, text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root",
                              # keep libtpu from probing TPU metadata for
                              # minutes in the scrubbed subprocess env
                              "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-3000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["ok"]
    assert payload["flops"] > 0
    assert payload["has_collectives"], payload


def test_collective_inventory_parser():
    from repro.launch.dryrun import collective_inventory
    hlo = """
ENTRY %main.1 (p0: f32[8]) -> f32[8] {
  %all-reduce.1 = f32[256,128]{1,0} all-reduce(%x), replica_groups={}
}
%while_body.2 (p: f32[8]) -> f32[8] {
  %ag = bf16[64,32]{1,0} all-gather(%y), dimensions={0}
}
"""
    inv = collective_inventory(hlo)
    assert inv["all-reduce"] == 256 * 128 * 4
    assert inv["all-gather.scanned"] == 64 * 32 * 2
