"""Property-based tests on system invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dependency; see README + the shim module
    from _hypothesis_fallback import given, settings, strategies as st

from repro.models import recurrent as rec
from repro.models.moe import apply_moe, init_moe
from repro.models.layers import apply_rope


# ---------------------------------------------------------------------------
# recurrent blocks: parallel-scan sequence == stepwise decode
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_rglru_scan_equals_stepwise(seed):
    rng = np.random.default_rng(seed)
    b, s, d, w = 2, 12, 8, 16
    p = rec.init_rglru_block(jax.random.PRNGKey(seed % 97), d, w, 4)
    x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
    full = rec.apply_rglru_block(p, x)
    state = rec.rglru_init_state(b, w, 4, jnp.float32)
    outs = []
    for t in range(s):
        y, state = rec.apply_rglru_decode(p, x[:, t:t + 1], state)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), rtol=2e-4,
                               atol=2e-4)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_mlstm_chunked_equals_stepwise(seed):
    rng = np.random.default_rng(seed)
    b, s, d, w, h = 1, 10, 8, 16, 2
    p = rec.init_mlstm_block(jax.random.PRNGKey(seed % 89), d, w, h, 4)
    x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
    full = rec.apply_mlstm_block(p, x, h, chunk=4)
    state = rec.mlstm_init_state(b, w, h, 4)
    outs = []
    for t in range(s):
        y, state = rec.apply_mlstm_decode(p, x[:, t:t + 1], state, h)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), rtol=3e-3,
                               atol=3e-3)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_slstm_scan_equals_stepwise(seed):
    rng = np.random.default_rng(seed)
    b, s, d, h = 2, 10, 8, 2
    p = rec.init_slstm_block(jax.random.PRNGKey(seed % 83), d, h)
    x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
    full = rec.apply_slstm_block(p, x, h)
    state = rec.slstm_init_state(b, d, h)
    outs = []
    for t in range(s):
        y, state = rec.apply_slstm_decode(p, x[:, t:t + 1], state, h)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# MoE: with no-drop capacity, combine weights conserve probability mass
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_moe_no_drop_mass_conservation(seed):
    rng = np.random.default_rng(seed)
    b, s, d, e, k = 1, 32, 16, 4, 2
    p = init_moe(jax.random.PRNGKey(seed % 79), d, 32, e, "swiglu")
    x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
    # no-drop capacity: every routed token is processed, so the MoE output of
    # a constant-zero expert stack would be zero and gates sum to 1; we check
    # linearity: scaling x scales the dispatched expert input sums
    y1, _ = apply_moe(p, x, n_experts=e, top_k=k, act="swiglu",
                      group_size=s, capacity_factor=float(e) / k)
    assert np.all(np.isfinite(np.asarray(y1)))
    # drop-free routing is deterministic: same input -> same output
    y2, _ = apply_moe(p, x, n_experts=e, top_k=k, act="swiglu",
                      group_size=s, capacity_factor=float(e) / k)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


# ---------------------------------------------------------------------------
# RoPE: rotation preserves norms and relative positions
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 100))
def test_rope_preserves_norm_and_relativity(seed, offset):
    rng = np.random.default_rng(seed)
    s, h, d = 8, 2, 16
    q = jnp.asarray(rng.normal(size=(1, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, s, h, d)).astype(np.float32))
    pos = jnp.arange(s)[None]
    q1, k1 = apply_rope(q, pos, 1e4), apply_rope(k, pos, 1e4)
    q2, k2 = apply_rope(q, pos + offset, 1e4), apply_rope(k, pos + offset, 1e4)
    # norm preservation
    np.testing.assert_allclose(np.linalg.norm(np.asarray(q1), axis=-1),
                               np.linalg.norm(np.asarray(q), axis=-1),
                               rtol=1e-4)
    # relative property: scores depend only on position differences
    s1 = jnp.einsum("bqhd,bkhd->bhqk", q1, k1)
    s2 = jnp.einsum("bqhd,bkhd->bhqk", q2, k2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# forest: feature-permutation equivariance of tree fitting
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_tree_fit_feature_permutation_equivariant(seed):
    from repro.forest.binning import edges_with_sentinel, fit_bins, transform
    from repro.forest.tree import grow_tree, predict_tree_values
    rng = np.random.default_rng(seed)
    n, p = 200, 4
    x = rng.normal(size=(n, p)).astype(np.float32)
    yv = (x[:, 0] * np.sin(x[:, 1])).astype(np.float32)[:, None]
    perm = rng.permutation(p)
    w = jnp.ones((n,), jnp.float32)

    def fit_and_predict(xp):
        edges = fit_bins(jnp.asarray(xp), 16)
        codes = transform(jnp.asarray(xp), edges)
        tree, _ = grow_tree(codes, -jnp.asarray(yv), w,
                            edges_with_sentinel(edges), depth=3, n_bins=16,
                            reg_lambda=1.0, min_child_weight=1.0,
                            learning_rate=1.0)
        return predict_tree_values(jnp.asarray(xp), tree.feat, tree.thr_val,
                                   tree.leaf, 3)

    base = np.asarray(fit_and_predict(x))
    permuted = np.asarray(fit_and_predict(x[:, perm]))
    np.testing.assert_allclose(base, permuted, rtol=1e-5, atol=1e-5)
