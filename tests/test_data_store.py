"""Out-of-core data layer: quantile-sketch parity against the in-memory
reference edge functions, ingest round-trips, crash-resume safety, and
store-backed fit parity with the in-memory trainers.

Fits run in-process on a 1x1 mesh (one CPU device) with one shared tiny
ForestConfig so the lru_cached shard_map program compiles once per module.
"""
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ForestConfig
from repro.data.sketch import QuantileSketch, sketch_dataset
from repro.data.store import DatasetStore, ingest
from repro.data.tabular import (correlated_gaussian_batches,
                                synthetic_resource_batches,
                                synthetic_resource_dataset,
                                two_moons_batches)
from repro.forest.binning import fit_bins, fit_bins_streaming, pack_codes, \
    transform
from repro.tabgen import fit_artifacts
from repro.tabgen.fitting import class_stats_streaming, weighted_edges

FIELDS = ("feat", "thr_val", "leaf", "best_round", "rounds_run", "val_curve",
          "mins", "maxs")

FCFG = ForestConfig(n_t=2, duplicate_k=3, n_trees=3, max_depth=2, n_bins=8,
                    reg_lambda=1.0)


def _equal(a, b):
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))) for f in FIELDS)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def small_data():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(96, 3)).astype(np.float32)
    y = (rng.random(96) > 0.5).astype(np.int64)
    return X, y


def _batches(X, y, k=20):
    for s in range(0, len(X), k):
        yield X[s:s + k], y[s:s + k]


# ---------------------------------------------------------------------------
# sketch parity (tentpole acceptance: weighted_edges / fit_bins semantics)
# ---------------------------------------------------------------------------

def test_sketch_floor_mode_matches_weighted_edges_exactly():
    """Unpruned sketch == weighted_edges bit-for-bit, including the padded
    zero-weight rows the trainer masks out."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(257, 5)).astype(np.float32)
    w = (rng.random(257) > 0.2).astype(np.float32)    # 0/1 row mask
    ref = np.asarray(weighted_edges(jnp.asarray(x), jnp.asarray(w), 16))
    got = QuantileSketch(5, max_entries=1024).update(x, w).edges(16, "floor")
    np.testing.assert_array_equal(got, ref)
    # unweighted: every row counts
    ref_all = np.asarray(weighted_edges(jnp.asarray(x),
                                        jnp.ones(257, jnp.float32), 16))
    got_all = QuantileSketch(5, max_entries=1024).update(x).edges(16, "floor")
    np.testing.assert_array_equal(got_all, ref_all)


def test_sketch_linear_mode_matches_fit_bins():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(211, 4)).astype(np.float32)
    ref = np.asarray(fit_bins(jnp.asarray(x), 16))
    got = QuantileSketch(4, max_entries=1024).update(x).edges(16, "linear")
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    # and the streaming front door (chunked feed, no full-column sort)
    via_stream = np.asarray(fit_bins_streaming(x, 16, max_entries=1024,
                                               row_chunk=37))
    np.testing.assert_allclose(via_stream, ref, rtol=1e-5, atol=1e-6)


def test_sketch_compression_bounds_rank_error():
    """Past max_entries the sketch compresses; quantile estimates must stay
    within a small empirical-rank error of the true quantiles."""
    rng = np.random.default_rng(2)
    big = rng.normal(size=(20000, 3)).astype(np.float32)
    sk = QuantileSketch(3, max_entries=256)
    sk._ABSORB_CHUNK = 4096               # force multiple compressions
    sk.update(big)
    assert sk.vals.shape[1] <= 2 * 256    # state stayed bounded
    qs = np.linspace(0.05, 0.95, 19)
    est = sk.quantiles(qs, "linear")
    srt = np.sort(big, axis=0)
    for f in range(3):
        ranks = np.searchsorted(srt[:, f], est[f]) / len(big)
        assert np.abs(ranks - qs).max() < 0.02


def test_sketch_merge_matches_single_pass():
    rng = np.random.default_rng(4)
    big = rng.normal(size=(8000, 2)).astype(np.float32)
    a = QuantileSketch(2, 256).update(big[:4000])
    b = QuantileSketch(2, 256).update(big[4000:])
    merged = a.merge(b)
    qs = np.linspace(0.1, 0.9, 9)
    est = merged.quantiles(qs, "linear")
    srt = np.sort(big, axis=0)
    for f in range(2):
        ranks = np.searchsorted(srt[:, f], est[f]) / len(big)
        assert np.abs(ranks - qs).max() < 0.02


def test_sketch_int8_code_path():
    """Sketch edges feed transform/pack_codes like exact edges do: same
    codes (unpruned sketch), narrow dtype, codes within [0, n_bins)."""
    rng = np.random.default_rng(5)
    x = rng.normal(size=(300, 4)).astype(np.float32)
    exact = np.asarray(weighted_edges(jnp.asarray(x),
                                      jnp.ones(300, jnp.float32), 16))
    sk_edges = sketch_dataset(x, max_entries=1024).edges(16, "floor")
    codes_exact = pack_codes(transform(jnp.asarray(x), jnp.asarray(exact)),
                             16)
    codes_sketch = pack_codes(transform(jnp.asarray(x),
                                        jnp.asarray(sk_edges)), 16)
    assert codes_sketch.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(codes_exact),
                                  np.asarray(codes_sketch))
    assert int(jnp.max(codes_sketch)) < 16 and int(jnp.min(codes_sketch)) >= 0


def test_sketch_state_roundtrip():
    rng = np.random.default_rng(6)
    x = rng.normal(size=(500, 3)).astype(np.float32)
    sk = QuantileSketch(3, 128).update(x)
    back = QuantileSketch.from_state(sk.state_dict())
    np.testing.assert_array_equal(back.edges(8), sk.edges(8))
    assert back.n_points == sk.n_points


# ---------------------------------------------------------------------------
# ingest / DatasetStore
# ---------------------------------------------------------------------------

def test_ingest_roundtrip_and_precomputed_stats(tmp_path):
    n, p, n_y = 1000, 4, 3
    parts = list(synthetic_resource_batches(n, p, n_y, batch_rows=96,
                                            seed=7))
    X = np.concatenate([x for x, _ in parts])
    y = np.concatenate([yy for _, yy in parts])
    store = ingest(synthetic_resource_batches(n, p, n_y, batch_rows=96,
                                              seed=7),
                   str(tmp_path / "store"), shard_rows=256)
    assert store.shape == (n, p) and store.n_shards == 4
    # row access: full range, arbitrary gather order, slices
    np.testing.assert_array_equal(store[np.arange(n)], X)
    idx = np.array([5, 999, 3, 500, 500])
    np.testing.assert_array_equal(store[idx], X[idx])
    np.testing.assert_array_equal(store[100:300], X[100:300])
    np.testing.assert_array_equal(store.labels(), y)
    # manifest stats == the streaming pass the fit would otherwise run
    for got, ref in zip(store.class_stats(), class_stats_streaming(X, y)):
        np.testing.assert_array_equal(got, ref)
    # precomputed sketch edges == full-sort reference (exact: n < entries)
    ref_edges = np.asarray(weighted_edges(jnp.asarray(X),
                                          jnp.ones(n, jnp.float32), 8))
    np.testing.assert_array_equal(store.edges(8, "floor"), ref_edges)
    # iter_batches streams the same rows back
    out = np.concatenate([xb for xb, _ in store.iter_batches(130)])
    np.testing.assert_array_equal(out, X)


def test_ingest_unlabelled_and_generator_determinism(tmp_path):
    store = ingest(correlated_gaussian_batches(300, 3, batch_rows=64,
                                               seed=1),
                   str(tmp_path / "u"), shard_rows=128)
    assert not store.has_labels
    np.testing.assert_array_equal(store.labels(), np.zeros(300, np.int64))
    classes, counts, _, _ = store.class_stats()
    assert classes.tolist() == [0] and counts.tolist() == [300]
    # chunked generators are deterministic in their seed
    a = [x for x in correlated_gaussian_batches(300, 3, batch_rows=64,
                                                seed=1)]
    b = [x for x in correlated_gaussian_batches(300, 3, batch_rows=64,
                                                seed=1)]
    for xa, xb in zip(a, b):
        np.testing.assert_array_equal(xa, xb)
    # odd batch sizes still total exactly n (two_moons returns 2*(n//2)
    # rows, so the generator over-asks and slices)
    moons = list(two_moons_batches(101, batch_rows=40, seed=2))
    assert sum(len(x) for x, _ in moons) == 101


def test_ingest_refuses_dirty_dir_and_mismatched_fingerprint(tmp_path):
    d = str(tmp_path / "s")
    ingest(_batches(*synthetic_resource_dataset(200, 3, 2, seed=0)), d,
           shard_rows=64)
    with pytest.raises(ValueError, match="resume=True"):
        ingest(_batches(*synthetic_resource_dataset(200, 3, 2, seed=0)), d,
               shard_rows=64)
    # resume with a different config refuses before consuming anything
    with pytest.raises(ValueError, match="mismatched"):
        ingest(_batches(*synthetic_resource_dataset(200, 3, 2, seed=0)), d,
               shard_rows=32, resume=True)
    # resume of a complete store with the matching config is a no-op
    again = ingest(_batches(*synthetic_resource_dataset(200, 3, 2, seed=0)),
                   d, shard_rows=64, resume=True)
    assert again.n_rows == 200


def test_crash_resume_finishes_without_touching_committed_shards(tmp_path):
    X, y = synthetic_resource_dataset(1000, 4, 3, seed=11)

    def batches(crash_after=None):
        sent = 0
        for s in range(0, 1000, 96):
            if crash_after is not None and sent >= crash_after:
                raise RuntimeError("simulated ingest crash")
            yield X[s:s + 96], y[s:s + 96]
            sent += 1

    clean = ingest(batches(), str(tmp_path / "clean"), shard_rows=256)

    crash_dir = str(tmp_path / "crash")
    with pytest.raises(RuntimeError, match="simulated"):
        ingest(batches(crash_after=5), crash_dir, shard_rows=256)
    man = json.load(open(os.path.join(crash_dir, "manifest.json")))
    assert man["complete"] is False and man["n_rows"] == 256
    with pytest.raises(ValueError, match="unfinished ingest"):
        DatasetStore(crash_dir)        # reader refuses a partial store

    def digests():
        return {f: hashlib.sha256(
                    open(os.path.join(crash_dir, f), "rb").read()).hexdigest()
                for f in os.listdir(crash_dir) if f.startswith("shard_")}

    before = digests()
    mtimes = {f: os.stat(os.path.join(crash_dir, f)).st_mtime_ns
              for f in before}
    resumed = ingest(batches(), crash_dir, shard_rows=256, resume=True)
    # committed shard files were neither re-written nor re-derived
    assert {f: d for f, d in digests().items() if f in before} == before
    assert all(os.stat(os.path.join(crash_dir, f)).st_mtime_ns == t
               for f, t in mtimes.items())
    # the finished store is byte-equal to an uninterrupted ingest
    np.testing.assert_array_equal(resumed[np.arange(1000)],
                                  clean[np.arange(1000)])
    for got, ref in zip(resumed.class_stats(), clean.class_stats()):
        np.testing.assert_array_equal(got, ref)
    np.testing.assert_array_equal(resumed.edges(8), clean.edges(8))
    assert resumed.manifest["shards"] == clean.manifest["shards"]


def test_resume_refuses_short_stream(tmp_path):
    X, y = synthetic_resource_dataset(500, 3, 2, seed=12)
    d = str(tmp_path / "s")

    def half():
        yield X[:256], y[:256]
        raise RuntimeError("crash")

    with pytest.raises(RuntimeError):
        ingest(half(), d, shard_rows=128)
    with pytest.raises(ValueError, match="not the one"):
        ingest(iter([(X[:100], y[:100])]), d, shard_rows=128, resume=True)


# ---------------------------------------------------------------------------
# store-backed training (tentpole acceptance: parity with in-memory fits)
# ---------------------------------------------------------------------------

def test_store_backed_fit_parity_with_in_memory(tmp_path, mesh, small_data):
    X, y = small_data
    in_mem = fit_artifacts(X, y, FCFG, seed=0, mesh=mesh)
    store = ingest(_batches(X, y), str(tmp_path / "store"), shard_rows=32)
    st = fit_artifacts(store, None, FCFG, seed=0, mesh=mesh)
    assert _equal(in_mem, st)
    # mesh=None on a store auto-routes to the 1x1 sharded trainer
    st2 = fit_artifacts(store, None, FCFG, seed=0)
    assert _equal(st, st2)


def test_store_fit_with_explicit_labels_overrides_manifest(tmp_path, mesh,
                                                           small_data):
    """Regression: explicit y on a store-backed fit must re-derive the
    class stats from the given labels, not trust the manifest (whose stats
    were computed under the store's own grouping). An unlabelled store +
    3-class y used to IndexError in build_row_shards."""
    X, y = small_data
    # unlabelled store (manifest knows one class), explicit 2-class labels
    store = ingest((X[s:s + 20] for s in range(0, len(X), 20)),
                   str(tmp_path / "u"), shard_rows=32)
    assert not store.has_labels
    via_store = fit_artifacts(store, y, FCFG, seed=0, mesh=mesh)
    in_mem = fit_artifacts(X, y, FCFG, seed=0, mesh=mesh)
    assert _equal(via_store, in_mem)


def test_store_and_in_memory_checkpoints_interoperate(tmp_path, mesh,
                                                      small_data):
    """Same data, same grid -> same manifest fingerprint: an in-memory fit's
    checkpoint resumes a store-backed fit (all batches cache-served)."""
    X, y = small_data
    ck = str(tmp_path / "ck")
    in_mem = fit_artifacts(X, y, FCFG, seed=0, mesh=mesh,
                           ensembles_per_batch=2, checkpoint_dir=ck)
    store = ingest(_batches(X, y), str(tmp_path / "store"), shard_rows=32)
    resumed = fit_artifacts(store, None, FCFG, seed=0, mesh=mesh,
                            ensembles_per_batch=2, checkpoint_dir=ck,
                            resume=True)
    assert _equal(in_mem, resumed)


def test_facade_schema_refuses_store(tmp_path, small_data):
    from repro.tabgen import TabularGenerator
    X, y = small_data
    store = ingest(_batches(X, y), str(tmp_path / "store"), shard_rows=48)
    with pytest.raises(ValueError, match="schema-aware"):
        TabularGenerator(FCFG, cat_cols=[0]).fit(store)


def test_ingest_and_train_clis(tmp_path, mesh):
    """repro.launch.ingest -> train_forest --data-dir, all in-process."""
    from repro.launch import ingest as ingest_cli
    from repro.launch import train_forest
    from repro.tabgen import ForestArtifacts

    d = str(tmp_path / "store")
    ingest_cli.main(["--out", d, "--synthetic", "96x3x2", "--shard-rows",
                     "32", "--batch-rows", "20", "--seed", "3"])
    out = str(tmp_path / "model")
    train_forest.main(["--data-dir", d, "--mesh", "none", "--n-t", "2",
                       "--duplicate-k", "3", "--n-trees", "3",
                       "--max-depth", "2", "--n-bins", "8", "--out", out])
    art = ForestArtifacts.load(out)
    assert art.n_t == 2 and art.n_y == 2
    # the CLI fit is the same fit the API runs (the CLI flags above spell
    # out FCFG, so the module's one compiled program is reused)
    store = DatasetStore(d)
    api = fit_artifacts(store, None, FCFG, seed=0)
    assert _equal(art, api)
