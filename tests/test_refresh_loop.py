"""Incremental freshness loop (Issue 9): DatasetStore.append, warm-start
forest extension, lineage metadata, and the live hot-swap path.

The tentpole acceptance lives here: extending a base model by K rounds is
bit-identical to fitting R + K rounds from scratch on the same data (in
memory and store-backed), appends version the store without disturbing
open readers, and the admin reload endpoint swaps a grown model into a
serving registry with zero dropped requests.
"""
import dataclasses
import json
import threading
import time

import jax
import numpy as np
import pytest

from repro.config import ForestConfig
from repro.data.store import DatasetStore, ingest
from repro.tabgen import (TabularGenerator, extend_artifacts, fit_artifacts)
from repro.tabgen.fitting import class_stats_streaming
from repro.train.checkpoint import GridManifest

FIELDS = ("feat", "thr_val", "leaf", "best_round", "rounds_run", "val_curve",
          "mins", "maxs")

FCFG = ForestConfig(n_t=2, duplicate_k=3, n_trees=6, max_depth=2, n_bins=8,
                    reg_lambda=1.0)


def _assert_same(a, b):
    for f in FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def small_data():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(96, 3)).astype(np.float32)
    y = (rng.random(96) > 0.5).astype(np.int64)
    return X, y


def _batches(X, y, k=24):
    for s in range(0, len(X), k):
        yield X[s:s + k], y[s:s + k]


# ---------------------------------------------------------------------------
# tentpole acceptance: extend-by-K == straight fit to R + K, bit for bit
# ---------------------------------------------------------------------------

def test_extend_bit_identical_in_memory(small_data):
    X, y = small_data
    cold = fit_artifacts(X, y, FCFG, seed=5)
    base = fit_artifacts(X, y, dataclasses.replace(FCFG, n_trees=4), seed=5)
    ext = extend_artifacts(base, X, y, extra_trees=2, seed=5)
    assert ext.config.n_trees == 6
    _assert_same(cold, ext)
    # lineage records the continuation point
    assert ext.lineage["base"]["round_range"] == [4, 6]
    assert ext.lineage["rows"] == len(X) and ext.lineage["store"] is None


def test_extend_bit_identical_with_early_stopping(small_data):
    """Early stopping discards rounds past the best validation round; the
    warm start replays only the kept prefix and re-grows the rest — still
    bit-identical to the longer cold fit."""
    X, y = small_data
    fcfg = dataclasses.replace(FCFG, early_stop_rounds=2)
    cold = fit_artifacts(X, y, fcfg, seed=5)
    base = fit_artifacts(X, y, dataclasses.replace(fcfg, n_trees=4), seed=5)
    ext = extend_artifacts(base, X, y, extra_trees=2, seed=5)
    _assert_same(cold, ext)


def test_extend_bit_identical_store_backed(tmp_path, mesh, small_data):
    X, y = small_data
    store = ingest(_batches(X, y), str(tmp_path / "store"), shard_rows=32)
    cold = fit_artifacts(store, None, FCFG, seed=5, mesh=mesh)
    base = fit_artifacts(store, None, dataclasses.replace(FCFG, n_trees=4),
                         seed=5, mesh=mesh)
    ext = extend_artifacts(base, store, extra_trees=2, seed=5, mesh=mesh)
    _assert_same(cold, ext)
    assert ext.lineage["store"]["version"] == 1
    assert ext.lineage["store"]["n_rows"] == len(X)


def test_extend_on_appended_store(tmp_path, mesh, small_data):
    """The production shape: base fit on the store, append fresh rows,
    extend on the grown store — base scalers are pinned so new rounds fit
    residuals in the base model space, and lineage pins the new version."""
    X, y = small_data
    store = ingest(_batches(X[:64], y[:64]), str(tmp_path / "store"),
                   shard_rows=32)
    base = fit_artifacts(store, None, dataclasses.replace(FCFG, n_trees=4),
                         seed=5, mesh=mesh)
    grown = store.append(_batches(X[64:], y[64:]))
    assert (store.n_rows, grown.n_rows) == (64, 96)
    ext = extend_artifacts(base, grown, extra_trees=2, seed=5, mesh=mesh)
    assert ext.config.n_trees == 6
    # base trees are carried over verbatim; scalers stay the base's
    np.testing.assert_array_equal(np.asarray(ext.feat)[..., :4, :],
                                  np.asarray(base.feat))
    np.testing.assert_array_equal(np.asarray(ext.mins),
                                  np.asarray(base.mins))
    assert ext.lineage["store"]["version"] == 2
    assert ext.lineage["base"]["lineage"]["store"]["version"] == 1


# ---------------------------------------------------------------------------
# DatasetStore.append: versioning, reader isolation, crash-resume
# ---------------------------------------------------------------------------

def test_append_versions_and_merges_stats(tmp_path, small_data):
    X, y = small_data
    d = str(tmp_path / "s")
    store = ingest(_batches(X[:64], y[:64]), d, shard_rows=32)
    assert store.version == 1
    grown = store.append(_batches(X[64:], y[64:]))
    assert grown.version == 2 and grown.n_rows == 96
    # merged class stats == one streaming pass over the concatenation
    ref = class_stats_streaming(X, y)
    got = grown.class_stats()
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    # the pre-append reader keeps serving its snapshot (stats included)
    assert store.n_rows == 64 and store.version == 1
    assert len(store.class_stats()[0]) == 2
    # append streams are labelled like the ingest was
    with pytest.raises(ValueError, match="labelled"):
        store.append(iter([X[64:]]))


def test_append_refuses_inflight_and_resumes(tmp_path, small_data):
    X, y = small_data
    d = str(tmp_path / "s")
    ingest(_batches(X[:48], y[:48]), d, shard_rows=16)

    def crashing():
        yield X[48:64], y[48:64]
        raise OSError("disk gone")

    with pytest.raises(OSError):
        DatasetStore(d).append(crashing(), source="nightly")
    # marker is durable: a non-resume append refuses, with row accounting
    with pytest.raises(ValueError, match="unfinished append"):
        DatasetStore(d).append(_batches(X[48:], y[48:]), source="nightly")
    # a resume under a different source refuses too — both sources named
    with pytest.raises(ValueError, match="'nightly'.*'weekly'"):
        DatasetStore(d).append(_batches(X[48:], y[48:]), source="weekly",
                               resume=True)
    grown = DatasetStore(d).append(_batches(X[48:], y[48:], k=16),
                                   source="nightly", resume=True)
    assert grown.n_rows == 96 and grown.version == 2
    ref = class_stats_streaming(X, y)
    np.testing.assert_allclose(np.asarray(grown.class_stats()[1]),
                               np.asarray(ref[1]))
    # retry-after-success: resume with no marker is a no-op reader
    again = DatasetStore(d).append(iter(()), source="nightly", resume=True)
    assert again.n_rows == 96 and again.version == 2


def test_ingest_refusal_names_differing_keys(tmp_path, small_data):
    """Satellite: fingerprint refusals print both fingerprints plus every
    differing key, store- and checkpoint-side alike."""
    X, y = small_data
    d = str(tmp_path / "s")
    ingest(_batches(X, y), d, shard_rows=32, source={"kind": "a"})
    with pytest.raises(ValueError) as ei:
        ingest(_batches(X, y), d, shard_rows=16, resume=True,
               source={"kind": "b"})
    msg = str(ei.value)
    assert "differing keys" in msg
    assert "shard_rows" in msg and "source" in msg
    assert "store fingerprint" in msg and "requested fingerprint" in msg


# ---------------------------------------------------------------------------
# GridManifest warm-base acceptance
# ---------------------------------------------------------------------------

def test_grid_manifest_accepts_warm_base_and_refuses_strangers(tmp_path):
    d = str(tmp_path / "ckpt")
    base_fp = {"config": {"n_trees": 4, "max_depth": 2}, "grid": [2, 2],
               "ensembles_per_batch": 2, "data": [96, 3]}
    m0 = GridManifest(d, base_fp)
    m0.load_done(resume=False)
    m0.mark_done((0, 2))
    ext_fp = dict(base_fp, config={"n_trees": 6, "max_depth": 2},
                  warm_start=4)
    # warm_base match -> accepted with an EMPTY done-set (base batches
    # hold fewer-round buffers; the extension rewrites them all)
    m1 = GridManifest(d, ext_fp, warm_base={"config": base_fp["config"],
                                            "grid": base_fp["grid"]})
    assert m1.load_done(resume=True) == set()
    # no warm_base -> the PR-2 refusal, now with the full diff
    with pytest.raises(ValueError) as ei:
        GridManifest(d, ext_fp).load_done(resume=True)
    msg = str(ei.value)
    assert "differing keys" in msg and "config" in msg
    assert "checkpoint fingerprint" in msg
    # a warm_base that matches nothing on disk also refuses
    other = GridManifest(d, ext_fp, warm_base={"config": {"n_trees": 9},
                                               "grid": [2, 2]})
    with pytest.raises(ValueError, match="differing keys"):
        other.load_done(resume=True)


def test_fit_artifacts_resumes_over_base_checkpoint(tmp_path, small_data):
    """End to end: an extension pointed at the *base* run's checkpoint dir
    is accepted (warm-base fingerprint) and overwrites it in place."""
    X, y = small_data
    d = str(tmp_path / "ckpt")
    base = fit_artifacts(X, y, dataclasses.replace(FCFG, n_trees=4), seed=5,
                         checkpoint_dir=d, ensembles_per_batch=2)
    ext = extend_artifacts(base, X, y, extra_trees=2, seed=5,
                           checkpoint_dir=d, resume=True,
                           ensembles_per_batch=2)
    cold = fit_artifacts(X, y, FCFG, seed=5)
    _assert_same(cold, ext)
    man = json.loads((tmp_path / "ckpt" / "manifest.json").read_text())
    assert man["fingerprint"]["warm_start"] == 4


# ---------------------------------------------------------------------------
# extension validation
# ---------------------------------------------------------------------------

def test_extend_validation_errors(small_data):
    X, y = small_data
    base = fit_artifacts(X, y, dataclasses.replace(FCFG, n_trees=4), seed=5)
    with pytest.raises(ValueError, match="extra_trees"):
        extend_artifacts(base, X, y, extra_trees=0)
    with pytest.raises(ValueError, match="n_trees > the base"):
        fit_artifacts(X, y, dataclasses.replace(FCFG, n_trees=4),
                      warm_start=base)
    with pytest.raises(ValueError, match="max_depth: base=2 != new=3"):
        fit_artifacts(X, y, dataclasses.replace(FCFG, max_depth=3),
                      warm_start=base)
    with pytest.raises(ValueError, match=r"p=3 .*p=4"):
        extend_artifacts(base, np.zeros((96, 4), np.float32), y,
                         extra_trees=2)
    y3 = y.copy()
    y3[:5] = 2
    with pytest.raises(ValueError, match=r"\[0, 1\].*\[0, 1, 2\]"):
        extend_artifacts(base, X, y3, extra_trees=2)


# ---------------------------------------------------------------------------
# lineage persistence + the admin reload endpoint
# ---------------------------------------------------------------------------

def test_lineage_survives_save_load_and_extend_method(tmp_path, small_data):
    X, y = small_data
    base = fit_artifacts(X, y, dataclasses.replace(FCFG, n_trees=4), seed=5)
    ext = base.extend(X, y, extra_trees=2, seed=5)
    path = str(tmp_path / "m")
    ext.save(path)
    back = type(ext).load(path)
    assert back.lineage == ext.lineage
    assert back.lineage["base"]["round_range"] == [4, 6]
    # replace() (the registry's demote/promote path) keeps lineage; jit
    # round-trips drop it (it is metadata, not a pytree leaf)
    assert dataclasses.replace(ext).lineage == ext.lineage


def _post(app, name, body):
    return app.reload_model(name, body)


def test_reload_endpoint_swaps_and_surfaces_lineage(tmp_path, small_data):
    from repro.launch.serve_http import ServingApp
    from repro.serving import AdmissionController, ModelRegistry
    X, y = small_data
    base = fit_artifacts(X, y, dataclasses.replace(FCFG, n_trees=4), seed=5)
    p1, p2 = str(tmp_path / "v1"), str(tmp_path / "v2")
    base.save(p1)
    base.extend(X, y, extra_trees=2, seed=5).save(p2)

    registry = ModelRegistry(buckets=(64,))
    registry.register("m", TabularGenerator.load(p1).artifacts)
    app = ServingApp(registry, AdmissionController(),
                     model_paths={"m": p1})
    try:
        assert registry.describe()["m"]["lineage"]["base"] is None
        status, body = _post(app, "m", {"path": p2})
        assert status == 200 and body["version"] == 2
        assert body["lineage"]["base"]["round_range"] == [4, 6]
        assert registry.describe()["m"]["lineage"] == body["lineage"]
        # path-less reload reuses the registered path (refresh-in-place)
        status, body = _post(app, "m", {})
        assert status == 200 and body["path"] == p2  # remembered last path
        status, body = _post(app, "nope", {"path": p2})
        assert status == 404 and body["models"] == ["m"]
        status, body = _post(app, "m", {"path": str(tmp_path / "missing")})
        assert status == 400 and "failed" in body["error"]
        assert registry.peek("m").version == 3     # failed reload: no swap
    finally:
        app.stop()


def test_reload_under_lru_pressure_drops_no_request(tmp_path, small_data,
                                                    recompile_budget):
    """A refresh hot-swap while the registry is evicting under budget
    pressure and requests are in flight: every request completes, and a
    same-shape swap costs zero recompiles."""
    from repro.launch.serve_http import ServingApp
    from repro.serving import AdmissionController, ModelRegistry
    from repro.serving.registry import artifacts_nbytes
    X, y = small_data
    art = fit_artifacts(X, y, dataclasses.replace(FCFG, n_trees=4), seed=5)
    p1, p2 = str(tmp_path / "v1"), str(tmp_path / "v2")
    art.save(p1)
    # same shapes, shifted scalers -> same-shape swap, distinct model
    dataclasses.replace(art, mins=np.asarray(art.mins) + 1.0,
                        maxs=np.asarray(art.maxs) + 1.0).save(p2)

    budget = int(artifacts_nbytes(art) * 2.5)      # fits 2 of 3 hot
    registry = ModelRegistry(buckets=(64,), device_budget_bytes=budget)
    for name in ("a", "b", "m"):
        registry.register(name, art)
    registry.warmup()
    app = ServingApp(registry, AdmissionController(),
                     model_paths={"m": p1}, coalesce_window_s=0.0)
    stop = threading.Event()
    results, lock = [], threading.Lock()

    def hammer(name):
        while not stop.is_set():
            f = app.scheduler.submit(8, model=name)
            Xg, yg = f.result(timeout=120)
            with lock:
                results.append(Xg.shape)
            # rotate LRU pressure: touching a/b evicts/promotes around m
            time.sleep(0.002)

    threads = [threading.Thread(target=hammer, args=(n,))
               for n in ("a", "b", "m")]
    try:
        for t in threads:
            t.start()
        time.sleep(0.05)
        with recompile_budget(0):                  # same-shape swap
            status, body = app.reload_model("m", {"path": p2})
        assert status == 200 and body["version"] == 2
        time.sleep(0.05)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=60)
        app.stop()
    assert all(s == (8, 3) for s in results)       # zero dropped/mis-shaped
    assert len(results) >= 3
    assert registry.peek("m").version == 2
