"""resolve_impl precedence + the attention per-call env switch (the JX002
bug class: an import-time snapshot would make everything here impossible)."""
import numpy as np
import pytest

from repro.kernels.dispatch import VALID_IMPLS, resolve_impl


# ---------------------------------------------------------------------------
# precedence: per-call arg > config field > env var > default
# ---------------------------------------------------------------------------

def test_default_wins_when_nothing_set(monkeypatch):
    monkeypatch.delenv("REPRO_TEST_IMPL", raising=False)
    assert resolve_impl(None, None, env_var="REPRO_TEST_IMPL") == "xla"
    assert resolve_impl(env_var="REPRO_TEST_IMPL",
                        default="pallas") == "pallas"


def test_env_beats_default(monkeypatch):
    monkeypatch.setenv("REPRO_TEST_IMPL", "pallas_interpret")
    assert resolve_impl(None, env_var="REPRO_TEST_IMPL") == "pallas_interpret"


def test_config_beats_env(monkeypatch):
    monkeypatch.setenv("REPRO_TEST_IMPL", "pallas_interpret")
    assert resolve_impl(None, "pallas", env_var="REPRO_TEST_IMPL") == "pallas"


def test_call_arg_beats_config_and_env(monkeypatch):
    monkeypatch.setenv("REPRO_TEST_IMPL", "pallas_interpret")
    assert resolve_impl("xla", "pallas", env_var="REPRO_TEST_IMPL") == "xla"


def test_empty_string_means_unspecified(monkeypatch):
    monkeypatch.setenv("REPRO_TEST_IMPL", "pallas")
    assert resolve_impl("", None, env_var="REPRO_TEST_IMPL") == "pallas"
    monkeypatch.setenv("REPRO_TEST_IMPL", "")
    assert resolve_impl("", None, env_var="REPRO_TEST_IMPL") == "xla"


def test_resolution_happens_at_call_time(monkeypatch):
    """The PR-4 bug: a module constant froze the env var at import time.
    resolve_impl must see mutations made long after any import."""
    monkeypatch.delenv("REPRO_TEST_IMPL", raising=False)
    assert resolve_impl(env_var="REPRO_TEST_IMPL") == "xla"
    monkeypatch.setenv("REPRO_TEST_IMPL", "pallas")
    assert resolve_impl(env_var="REPRO_TEST_IMPL") == "pallas"
    monkeypatch.setenv("REPRO_TEST_IMPL", "xla")
    assert resolve_impl(env_var="REPRO_TEST_IMPL") == "xla"


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_typo_fails_loudly(monkeypatch):
    monkeypatch.setenv("REPRO_TEST_IMPL", "palas")  # typo'd env var
    with pytest.raises(ValueError, match="palas"):
        resolve_impl(env_var="REPRO_TEST_IMPL")
    with pytest.raises(ValueError, match="REPRO_TEST_IMPL"):
        resolve_impl("nope", env_var="REPRO_TEST_IMPL")


def test_custom_vocabulary(monkeypatch):
    monkeypatch.delenv("REPRO_TEST_IMPL", raising=False)
    assert resolve_impl("packed", env_var="REPRO_TEST_IMPL",
                        default="blocked",
                        valid=("blocked", "packed")) == "packed"
    # the default vocabulary is rejected under a custom one
    with pytest.raises(ValueError, match="blocked"):
        resolve_impl("xla", env_var="REPRO_TEST_IMPL", default="blocked",
                     valid=("blocked", "packed"))
    assert "xla" in VALID_IMPLS  # custom vocab did not mutate the default


# ---------------------------------------------------------------------------
# attention: REPRO_ATTN_IMPL is consulted per call, not at import
# ---------------------------------------------------------------------------

def _qkv(sq=8, d=4):
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    mk = lambda: jnp.asarray(rng.normal(size=(1, 2, sq, d)), jnp.float32)
    return mk(), mk(), mk()


def test_attention_env_switch_is_per_call(monkeypatch):
    from repro.models import attention

    calls = []
    real_packed = attention.mea_attention_packed

    def spy(q, k, v, block):
        calls.append(block)
        return real_packed(q, k, v, block=block)

    monkeypatch.setattr(attention, "mea_attention_packed", spy)
    q, k, v = _qkv()
    # sq=8 > kv_block=4 and causal/no-window/self-attention: packed-eligible
    monkeypatch.delenv("REPRO_ATTN_IMPL", raising=False)
    out_blocked = attention.mea_attention(q, k, v, causal=True,
                                          q_block=4, kv_block=4)
    assert not calls, "default 'blocked' must not take the packed path"
    # flipping the env var AFTER import reroutes the very next call
    monkeypatch.setenv("REPRO_ATTN_IMPL", "packed")
    out_packed = attention.mea_attention(q, k, v, causal=True,
                                         q_block=4, kv_block=4)
    assert calls == [4]
    np.testing.assert_allclose(np.asarray(out_blocked),
                               np.asarray(out_packed), atol=1e-5)


def test_attention_impl_arg_beats_env(monkeypatch):
    from repro.models import attention

    calls = []
    monkeypatch.setattr(attention, "mea_attention_packed",
                        lambda q, k, v, block: calls.append(block))
    q, k, v = _qkv()
    monkeypatch.setenv("REPRO_ATTN_IMPL", "packed")
    attention.mea_attention(q, k, v, causal=True, q_block=4, kv_block=4,
                            impl="blocked")
    assert not calls, "impl='blocked' argument must override the env var"


def test_attention_rejects_unknown_impl(monkeypatch):
    from repro.models import attention
    q, k, v = _qkv()
    monkeypatch.setenv("REPRO_ATTN_IMPL", "fused")  # not a real impl
    with pytest.raises(ValueError, match="fused"):
        attention.mea_attention(q, k, v, causal=True, q_block=4, kv_block=4)
