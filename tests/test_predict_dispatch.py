"""The kernel-backed generation path: per-call impl dispatch + parity.

PR 4 collapsed the duplicated forest traversal — ``predict_forest`` routes
through ``repro.kernels.tree_predict.ops.forest_predict`` with an impl
switch resolved at call time (argument > ``ForestConfig.predict_impl`` >
``REPRO_TREE_PREDICT_IMPL`` > xla). These tests pin:

* Pallas(interpret) <-> XLA parity for the dispatch itself (SO and MO
  forests, odd row counts) and end-to-end through the euler/heun/ddim
  solvers and the imputation loop;
* per-call env resolution (the old module-level snapshot ignored changes
  made after import) for both the tree-predict and the hist switch.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ForestConfig
from repro.data.tabular import two_moons
from repro.forest.hist import build_histogram
from repro.forest.packed import PackedForest, predict_forest
from repro.tabgen import fit_artifacts, impute, sample


@pytest.fixture(scope="module")
def moons():
    return two_moons(240, seed=0)


def _fit(moons, **kw):
    X, y = moons
    base = dict(n_t=5, duplicate_k=6, n_trees=8, max_depth=3,
                n_bins=16, reg_lambda=1.0)
    base.update(kw)
    return fit_artifacts(X, y, ForestConfig(**base), seed=0)


@pytest.fixture(scope="module")
def flow_so(moons):
    return _fit(moons, method="flow")


@pytest.fixture(scope="module")
def flow_mo(moons):
    return _fit(moons, method="flow", multi_output=True)


@pytest.fixture(scope="module")
def diff_so(moons):
    return _fit(moons, method="diffusion", n_t=6)


# ---------------------------------------------------------------------------
# predict_forest dispatch parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [64, 97, 130, 1])  # odd n: wrapper row padding
@pytest.mark.parametrize("art_name", ["flow_so", "flow_mo"])
def test_predict_forest_impl_parity(request, art_name, n):
    art = request.getfixturevalue(art_name)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-1, 1, (n, art.p)).astype(np.float32))
    forest = PackedForest(art.feat[0, 0], art.thr_val[0, 0], art.leaf[0, 0],
                          art.config.multi_output)
    ref = predict_forest(x, forest, art.config.max_depth, impl="xla")
    got = predict_forest(x, forest, art.config.max_depth,
                         impl="pallas_interpret")
    assert ref.shape == (n, art.p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end through the solvers (acceptance: <= 1e-5 through a full sample)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sampler,art_name", [
    ("euler", "flow_so"), ("heun", "flow_so"), ("euler", "flow_mo"),
    ("ddim", "diff_so"),
])
def test_sample_impl_parity_end_to_end(request, sampler, art_name):
    art = request.getfixturevalue(art_name)
    G1, y1 = sample(art, 131, sampler=sampler, seed=3)  # odd n on purpose
    G2, y2 = sample(art, 131, sampler=sampler, seed=3,
                    impl="pallas_interpret")
    np.testing.assert_array_equal(y1, y2)
    np.testing.assert_allclose(G1, G2, rtol=1e-5, atol=1e-5)


def test_impute_impl_parity(flow_so, moons):
    X, y = moons
    Xm = X[:24].copy()
    Xm[:, 1] = np.nan
    lab = np.repeat(np.asarray(flow_so.classes), 12)[:24]
    f1 = impute(flow_so, Xm, lab, seed=2, refine_rounds=1)
    f2 = impute(flow_so, Xm, lab, seed=2, refine_rounds=1,
                impl="pallas_interpret")
    np.testing.assert_allclose(f1, f2, rtol=1e-5, atol=1e-5)


def test_config_predict_impl_drives_dispatch(flow_so, tmp_path):
    """`ForestConfig.predict_impl` selects the backend and round-trips
    through the artifacts sidecar."""
    art_k = dataclasses.replace(
        flow_so, config=dataclasses.replace(flow_so.config,
                                            predict_impl="pallas_interpret"))
    G1, _ = sample(flow_so, 80, seed=5)
    G2, _ = sample(art_k, 80, seed=5)
    np.testing.assert_allclose(G1, G2, rtol=1e-5, atol=1e-5)
    from repro.tabgen import ForestArtifacts
    base = art_k.save(str(tmp_path / "m"))
    assert ForestArtifacts.load(base).config.predict_impl == "pallas_interpret"


# ---------------------------------------------------------------------------
# per-call env resolution (regression: was frozen at import time)
# ---------------------------------------------------------------------------

def test_tree_predict_env_resolved_per_call(flow_so, monkeypatch):
    G_ref, _ = sample(flow_so, 60, seed=1)
    monkeypatch.setenv("REPRO_TREE_PREDICT_IMPL", "pallas_interpret")
    G_env, _ = sample(flow_so, 60, seed=1)
    np.testing.assert_allclose(G_ref, G_env, rtol=1e-5, atol=1e-5)
    # a typo'd env var fails loudly at the next call, not silently runs xla
    monkeypatch.setenv("REPRO_TREE_PREDICT_IMPL", "bogus")
    with pytest.raises(ValueError, match="bogus"):
        sample(flow_so, 60, seed=1)


def test_hist_env_resolved_per_call(monkeypatch):
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 8, (128, 3)), jnp.int32)
    nid = jnp.asarray(rng.integers(0, 2, (128,)), jnp.int32)
    g = jnp.asarray(rng.normal(size=(128, 1)).astype(np.float32))
    w = jnp.ones((128,), jnp.float32)
    monkeypatch.delenv("REPRO_HIST_IMPL", raising=False)
    s_ref, c_ref = build_histogram(codes, nid, g, w, 2, 8)
    # env set AFTER repro.forest.hist import: must take effect (was ignored)
    monkeypatch.setenv("REPRO_HIST_IMPL", "pallas_interpret")
    s_pl, c_pl = build_histogram(codes, nid, g, w, 2, 8)
    np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c_pl), np.asarray(c_ref),
                               rtol=1e-5, atol=1e-5)
    monkeypatch.setenv("REPRO_HIST_IMPL", "bogus")
    with pytest.raises(ValueError, match="bogus"):
        build_histogram(codes, nid, g, w, 2, 8)
