"""Tests for the multi-tenant serving control plane (repro.serving)."""
import dataclasses
import json
import threading
import time
import urllib.error
import urllib.request
from concurrent.futures import Future

import numpy as np
import pytest

from repro.config import ForestConfig
from repro.data.tabular import two_moons
from repro.serving import (AdmissionController, DeadlineExceeded,
                           InflightScheduler, ModelRegistry, QueueFull,
                           RateLimited, TokenBucket, UnknownModel)
from repro.tabgen import fit_artifacts


@pytest.fixture(scope="module")
def serving_artifacts():
    X, y = two_moons(400, seed=0)
    fcfg = ForestConfig(method="flow", n_t=8, duplicate_k=10, n_trees=20,
                        max_depth=4, n_bins=32, reg_lambda=1.0)
    return fit_artifacts(X, y, fcfg, seed=0), X


# ---------------------------------------------------------------------------
# fake data plane: deterministic, gateable device work
# ---------------------------------------------------------------------------

class _FakeSample:
    def __init__(self, gate, total):
        self._gate, self._total = gate, total

    def result(self):
        assert self._gate.wait(30), "test gate never opened"
        return (np.zeros((self._total, 2), np.float32),
                np.zeros(self._total, np.int64))


class _FakeHandle:
    samplers = ("euler",)
    buckets = (64,)
    version = 1

    def __init__(self, gate):
        self._gate = gate
        self.dispatched = 0

    def generate_async(self, n, sampler, *, seed):
        self.dispatched += 1
        return _FakeSample(self._gate, n)


class _FakeRegistry:
    buckets = (64,)

    def __init__(self, handle):
        self._handle = handle

    def peek(self, name):
        return self._handle

    def acquire(self, name):
        return self._handle


# ---------------------------------------------------------------------------
# scheduler: in-flight overlap, deadlines, backpressure
# ---------------------------------------------------------------------------

def test_inflight_overlap_two_batches_in_flight():
    """While the waiter blocks on batch k, the scheduler dispatches batch
    k+1 — the property the PR-4 drain loop lacked. Gated fake device work
    makes the overlap deterministic."""
    gate = threading.Event()
    sched = InflightScheduler(_FakeRegistry(_FakeHandle(gate)),
                              coalesce_window_s=0.0, inflight_depth=2)
    try:
        f1 = sched.submit(8)
        f2 = sched.submit(8)
        deadline = time.monotonic() + 20
        while (sched.stats_snapshot()["max_inflight_observed"] < 2
               and time.monotonic() < deadline):
            time.sleep(0.005)
        snap = sched.stats_snapshot()
        assert snap["max_inflight_observed"] >= 2, snap
    finally:
        gate.set()
        sched.stop()
    for f in (f1, f2):
        X, y = f.result(timeout=30)
        assert X.shape == (8, 2) and len(y) == 8
    assert sched.stats["batches"] == 2  # window 0 -> no coalescing


def test_drain_reference_never_overlaps():
    """sync_resolve=True is the PR-4 semantics kept as the benchmark
    reference arm: at most one batch in flight, ever."""
    gate = threading.Event()
    gate.set()
    sched = InflightScheduler(_FakeRegistry(_FakeHandle(gate)),
                              coalesce_window_s=0.0, sync_resolve=True)
    try:
        futs = [sched.submit(8) for _ in range(6)]
        for f in futs:
            f.result(timeout=30)
    finally:
        sched.stop()
    assert sched.stats["max_inflight_observed"] <= 1
    assert sched.stats["requests"] == 6


def test_deadline_expired_dropped_before_dispatch():
    """A request whose deadline lapses while queued fails with
    DeadlineExceeded and never reaches the device. The pipeline is plugged
    (gate shut, depth 1) so the deadlined request must sit in the queue."""
    gate = threading.Event()
    handle = _FakeHandle(gate)
    sched = InflightScheduler(_FakeRegistry(handle),
                              coalesce_window_s=0.0, inflight_depth=1)
    try:
        plug = [sched.submit(8) for _ in range(4)]  # saturate dispatch+queue
        doomed = sched.submit(8, deadline_s=0.05)
        time.sleep(0.4)  # let the deadline lapse while the plug holds
    finally:
        gate.set()
        sched.stop()
    with pytest.raises(DeadlineExceeded):
        doomed.result(timeout=30)
    for f in plug:
        X, _ = f.result(timeout=30)
        assert X.shape == (8, 2)
    assert sched.stats["dropped_deadline"] == 1
    assert handle.dispatched == len(plug)  # doomed never dispatched


def test_queue_full_rejects_with_retry_after():
    gate = threading.Event()
    admission = AdmissionController(queue_limits={"interactive": 2, "bulk": 2})
    sched = InflightScheduler(_FakeRegistry(_FakeHandle(gate)), admission,
                              coalesce_window_s=0.0, inflight_depth=1)
    futs = []
    try:
        with pytest.raises(QueueFull) as ei:
            for _ in range(50):
                futs.append(sched.submit(8))
        assert ei.value.retry_after_s > 0
        assert admission.stats_snapshot()["tenants"]["default"][
            "rejected_queue"] >= 1
    finally:
        gate.set()
        sched.stop()
    for f in futs:  # everything admitted before the rejection still serves
        X, _ = f.result(timeout=30)
        assert X.shape == (8, 2)


def test_rate_limited_rejects_with_retry_after():
    gate = threading.Event()
    gate.set()
    admission = AdmissionController(default_rate=(100.0, 100.0))
    sched = InflightScheduler(_FakeRegistry(_FakeHandle(gate)), admission,
                              coalesce_window_s=0.0)
    try:
        ok = sched.submit(80)  # inside the 100-row burst
        with pytest.raises(RateLimited) as ei:
            sched.submit(80)   # 60 rows short at 100 rows/s
        assert 0 < ei.value.retry_after_s < 2.0
        ok.result(timeout=30)
    finally:
        sched.stop()


def test_token_bucket_refills_on_injected_clock():
    b = TokenBucket(rate_rows_per_s=10.0, burst_rows=20.0)
    assert b.take(20, now=0.0) is None          # burst drained
    retry = b.take(10, now=0.0)
    assert retry == pytest.approx(1.0)          # 10 rows / 10 rows-per-s
    assert b.take(10, now=1.0) is None          # refilled exactly enough
    assert b.take(5, now=1.0) == pytest.approx(0.5)


def test_priority_interactive_pops_before_bulk():
    adm = AdmissionController()
    mk = lambda prio: type("R", (), {  # noqa: E731 — minimal request stub
        "n": 8, "sampler": "euler", "model": "default",
        "tenant": "default", "priority": prio})()
    adm.offer(mk("bulk"))
    adm.offer(mk("interactive"))
    assert adm.pop(timeout=1).priority == "interactive"
    assert adm.pop(timeout=1).priority == "bulk"
    with pytest.raises(ValueError):
        adm.offer(mk("express"))


# ---------------------------------------------------------------------------
# eager validation + stats breakdowns (real model)
# ---------------------------------------------------------------------------

def test_submit_validates_eagerly(serving_artifacts):
    from repro.launch.serve_forest import ForestServer
    art, _ = serving_artifacts
    server = ForestServer(art, buckets=(64,))
    with pytest.raises(ValueError, match="no_such"):
        server.submit(16, sampler="no_such")       # raised HERE, not in a
    with pytest.raises(ValueError, match="no_such"):
        server.generate(16, sampler="no_such")     # future after dispatch
    with pytest.raises(UnknownModel):
        server.scheduler.submit(16, model="missing")
    server.stop()
    assert server.stats["requests"] == 0  # nothing reached the dispatcher


def test_stats_split_per_sampler_and_wait_vs_device(serving_artifacts):
    from repro.launch.serve_forest import ForestServer
    art, _ = serving_artifacts
    server = ForestServer(art, samplers=("euler", "heun"), buckets=(64,),
                          coalesce_window_s=0.05)
    server.warmup()
    server.generate(20, sampler="euler", seed=0)
    futs = [server.submit(10, sampler="heun", tenant="t1"),
            server.submit(10, sampler="heun", tenant="t2")]
    for f in futs:
        f.result(timeout=120)
    server.stop()
    s = server.scheduler.stats_snapshot()
    assert s["per_sampler"]["euler"]["requests"] == 1
    assert s["per_sampler"]["heun"]["requests"] == 2
    assert s["per_sampler"]["heun"]["rows"] == 20
    assert s["per_tenant"]["t1"]["rows"] == 10
    assert s["per_tenant"]["t2"]["rows"] == 10
    # the breakdown reconciles: aggregate device time is the sum over
    # samplers, and queued requests accrued nonnegative wait
    assert s["device_s"] == pytest.approx(
        sum(v["device_s"] for v in s["per_sampler"].values()))
    assert s["queue_wait_s"] >= 0.0
    assert s["gen_s"] > 0.0


# ---------------------------------------------------------------------------
# registry: LRU placement, promotion round-trip, hot swap
# ---------------------------------------------------------------------------

def test_registry_lru_eviction_and_promotion_roundtrip(serving_artifacts):
    art, _ = serving_artifacts
    from repro.serving.registry import artifacts_nbytes
    budget = int(artifacts_nbytes(art) * 2.5)  # fits 2 models, not 3
    reg = ModelRegistry(buckets=(64,), device_budget_bytes=budget)
    for name in ("a", "b", "c"):
        reg.register(name, art)
    assert reg.names() == ["a", "b", "c"]       # all servable...
    assert reg.hot_names() == ["b", "c"]        # ...two on device ("a" LRU'd)
    ref_X, ref_y = reg.acquire("a").generate(50, seed=3)  # promotes "a"
    assert reg.hot_names() == ["a", "c"]        # "b" became the LRU victim
    reg.acquire("b")                            # promote again -> "c" demoted
    assert reg.hot_names() == ["a", "b"]
    d = reg.describe()
    assert d["a"]["promotions"] == 1 and d["a"]["demotions"] == 1
    assert d["c"]["demotions"] == 1
    # a demote/promote round-trip is invisible to callers: bit-identical
    X2, y2 = reg.acquire("a").generate(50, seed=3)
    np.testing.assert_array_equal(ref_X, X2)
    np.testing.assert_array_equal(ref_y, y2)
    # cold models still serve (host leaves; jit uploads per call)
    Xc, _ = reg.peek("c").generate(20, seed=1)
    assert Xc.shape == (20, 2)


def test_registry_max_hot_cap(serving_artifacts):
    art, _ = serving_artifacts
    reg = ModelRegistry(buckets=(64,), max_hot=1)
    reg.register("a", art)
    reg.register("b", art)
    assert reg.hot_names() == ["b"]
    reg.acquire("a")
    assert reg.hot_names() == ["a"]
    assert reg.stats_snapshot()["hot_bytes"] > 0


def test_hot_swap_zero_downtime_under_concurrent_submits(serving_artifacts):
    """swap() must drop no request: every response is served entirely by
    the old or entirely by the new version (never mixed), with at least one
    of each across the swap."""
    from repro.launch.serve_forest import ForestServer
    art, _ = serving_artifacts
    # same shapes, unmistakably different output range (+1000 data shift)
    art_new = dataclasses.replace(art, mins=np.asarray(art.mins) + 1000.0,
                                  maxs=np.asarray(art.maxs) + 1000.0)
    server = ForestServer(art, buckets=(64,), coalesce_window_s=0.01)
    server.warmup()

    before = server.submit(30)
    Xb, _ = before.result(timeout=120)           # resolved pre-swap: old
    stop = threading.Event()
    futs, futs_lock = [], threading.Lock()

    def hammer():
        while not stop.is_set():
            try:
                f = server.submit(10)
            except Exception:
                return
            with futs_lock:
                futs.append(f)
            time.sleep(0.002)

    threads = [threading.Thread(target=hammer) for _ in range(3)]
    for t in threads:
        t.start()
    time.sleep(0.05)
    handle = server.registry.swap(server.MODEL, art_new)
    assert handle.version == 2
    time.sleep(0.05)
    stop.set()
    for t in threads:
        t.join()
    after = server.submit(30)
    Xa, _ = after.result(timeout=120)            # submitted post-swap: new
    server.stop()

    assert Xb.mean() < 500 < Xa.mean()
    n_old = n_new = 0
    for f in futs:                               # zero dropped, zero mixed
        X, y = f.result(timeout=120)
        assert X.shape == (10, 2) and len(y) == 10
        rowmeans = X.mean(axis=1)
        if (rowmeans < 500).all():
            n_old += 1
        else:
            assert (rowmeans > 500).all(), "response mixed model versions"
            n_new += 1
    assert n_old + n_new == len(futs)
    assert server.registry.describe()["default"]["swaps"] == 1


def test_hot_swap_reuses_compiled_programs(serving_artifacts,
                                           recompile_budget):
    """Same-shape swap costs one device placement, zero recompiles — the
    jit cache keys on shapes, not array identity."""
    from repro.launch.serve_forest import ForestServer
    art, _ = serving_artifacts
    art_new = dataclasses.replace(art, mins=np.asarray(art.mins) + 1000.0,
                                  maxs=np.asarray(art.maxs) + 1000.0)
    server = ForestServer(art, buckets=(64,))
    server.warmup()
    with recompile_budget(0):
        server.registry.swap(server.MODEL, art_new)
        server.submit(23).result(timeout=120)
        server.stop()


# ---------------------------------------------------------------------------
# HTTP front end (in-process)
# ---------------------------------------------------------------------------

def _http(method, url, body=None):
    req = urllib.request.Request(
        url, method=method,
        data=None if body is None else json.dumps(body).encode(),
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=120) as resp:
            return resp.status, dict(resp.headers), json.load(resp)
    except urllib.error.HTTPError as err:
        return err.code, dict(err.headers), json.load(err)


@pytest.fixture(scope="module")
def http_plane(serving_artifacts):
    from repro.launch.serve_http import ServingApp, serve_in_thread
    art, _ = serving_artifacts
    registry = ModelRegistry(buckets=(64,))
    registry.register("moons", art, samplers=("euler", "heun"))
    admission = AdmissionController(
        tenant_rates={"metered": (50.0, 50.0)})
    app = ServingApp(registry, admission)
    registry.warmup()
    httpd, thread = serve_in_thread(app)
    host, port = httpd.server_address[:2]
    yield app, f"http://{host}:{port}"
    httpd.shutdown()
    httpd.server_close()
    app.stop()
    thread.join(timeout=10)


def test_http_healthz_models_statz(http_plane):
    _, base = http_plane
    status, _, body = _http("GET", f"{base}/healthz")
    assert status == 200 and body["ok"] and body["models"] == ["moons"]
    status, _, body = _http("GET", f"{base}/v1/models")
    assert status == 200
    assert body["models"]["moons"]["samplers"] == ["euler", "heun"]
    assert body["models"]["moons"]["hot"] is True
    status, _, body = _http("GET", f"{base}/statz")
    assert status == 200
    assert {"scheduler", "admission", "registry"} <= set(body)


def test_http_generate_and_impute(http_plane):
    _, base = http_plane
    status, _, body = _http("POST", f"{base}/v1/generate",
                            {"model": "moons", "n": 40, "sampler": "heun",
                             "tenant": "t9", "priority": "bulk"})
    assert status == 200
    X = np.asarray(body["rows"])
    assert X.shape == (40, 2) and np.isfinite(X).all()
    assert len(body["labels"]) == 40 and body["version"] == 1
    status, _, body = _http(
        "POST", f"{base}/v1/impute",
        {"model": "moons", "rows": [[0.5, None], [None, 0.25]]})
    assert status == 400          # conditional model: labels required
    assert "labels" in body["error"]
    status, _, body = _http(
        "POST", f"{base}/v1/impute",
        {"model": "moons", "rows": [[0.5, None], [None, 0.25]],
         "labels": [0, 1]})
    assert status == 200
    filled = np.asarray(body["rows"], float)
    assert filled.shape == (2, 2) and np.isfinite(filled).all()
    assert filled[0, 0] == pytest.approx(0.5)   # observed cells untouched
    assert filled[1, 1] == pytest.approx(0.25)


def test_http_error_mapping(http_plane):
    _, base = http_plane
    status, _, body = _http("POST", f"{base}/v1/generate",
                            {"model": "nope", "n": 8})
    assert status == 404 and body["models"] == ["moons"]
    status, _, body = _http("POST", f"{base}/v1/generate",
                            {"model": "moons", "n": 8, "sampler": "nope"})
    assert status == 400 and "sampler" in body["error"]
    status, _, body = _http("POST", f"{base}/v1/generate",
                            {"model": "moons", "n": 0})
    assert status == 400
    status, _, _ = _http("GET", f"{base}/v1/missing")
    assert status == 404


def test_http_rate_limit_sets_retry_after(http_plane):
    _, base = http_plane
    gen = {"model": "moons", "n": 40, "tenant": "metered"}
    status, _, _ = _http("POST", f"{base}/v1/generate", gen)
    assert status == 200                        # inside the 50-row burst
    status, headers, body = _http("POST", f"{base}/v1/generate", gen)
    assert status == 429
    assert body["retry_after_s"] > 0
    assert float(headers["Retry-After"]) > 0


# ---------------------------------------------------------------------------
# live-process smoke (slow lane; also exercised by scripts/ci_smoke.sh)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serve_http_live_process(tmp_path):
    import os
    import signal
    import subprocess
    import sys
    env = dict(os.environ, PYTHONPATH="src", PYTHONUNBUFFERED="1")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve_http", "--demo",
         "--port", "0", "--buckets", "64"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    base, lines = None, []
    try:
        for line in proc.stdout:
            lines.append(line)
            if line.startswith("serving on "):
                base = line.split()[-1].strip()
                break
        assert base, "server never came up:\n" + "".join(lines)
        status, _, body = _http("GET", f"{base}/healthz")
        assert status == 200 and body["models"] == ["demo"]
        status, _, body = _http("POST", f"{base}/v1/generate",
                                {"model": "demo", "n": 32})
        assert status == 200 and len(body["rows"]) == 32
    finally:
        proc.send_signal(signal.SIGINT)
        try:
            proc.wait(timeout=60)
        except subprocess.TimeoutExpired:
            proc.kill()
            raise
    assert proc.returncode == 0
    rest = proc.stdout.read()
    assert "bye" in rest, rest
