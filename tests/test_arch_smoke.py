"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting output shapes and finiteness, plus a
prefill/decode consistency probe for each family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.configs import ARCH_IDS, get_arch
from repro.models import lm
from repro.train.optim import adamw_update, init_opt_state

TCFG = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.family == "vlm":
        n_img = cfg.n_patches
        return {
            "patches": jnp.asarray(
                rng.normal(size=(b, n_img, cfg.d_model)).astype(np.float32)),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s - n_img)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s - n_img)),
                                  jnp.int32),
        }
    if cfg.family == "audio_encdec":
        return {
            "frames": jnp.asarray(
                rng.normal(size=(b, s // 2, cfg.d_model)).astype(np.float32)),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s // 2)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s // 2)),
                                  jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_train_step(arch_id):
    cfg = get_arch(arch_id, reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    loss, metrics = jax.jit(  # jaxlint: disable=JX003 — one-shot smoke compile
        lambda p, b: lm.loss_fn(p, b, cfg, dtype=jnp.float32,
                                remat_policy="none"))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch_id}: non-finite loss"

    # one full train step: grads + AdamW
    opt = init_opt_state(params)

    @jax.jit
    def step(p, o, b):
        (l, _), g = jax.value_and_grad(
            lambda pp: lm.loss_fn(pp, b, cfg, dtype=jnp.float32,
                                  remat_policy="full"), has_aux=True)(p)
        p2, o2, m = adamw_update(g, o, p, TCFG)
        return p2, o2, l, m

    params2, opt2, loss2, m = step(params, opt, batch)
    assert np.isfinite(float(loss2))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually moved
    delta = jax.tree_util.tree_map(
        lambda a, c: float(jnp.max(jnp.abs(a - c))), params, params2)
    assert max(jax.tree_util.tree_leaves(delta)) > 0.0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step_shapes(arch_id):
    cfg = get_arch(arch_id, reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    b, size = 2, 16
    cache = lm.init_cache(cfg, b, size, jnp.float32, enc_len=8)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, cache2 = jax.jit(  # jaxlint: disable=JX003 — one-shot smoke compile
        lambda p, c, t: lm.decode_step(p, c, t, jnp.int32(3), cfg,
                                       dtype=jnp.float32))(params, cache, tok)
    assert logits.shape == (b, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    # cache structure preserved
    s1 = jax.tree_util.tree_structure(cache)
    s2 = jax.tree_util.tree_structure(cache2)
    assert s1 == s2


@pytest.mark.parametrize("arch_id", ["smollm-135m", "xlstm-1.3b",
                                     "recurrentgemma-9b", "dbrx-132b"])
def test_prefill_matches_stepwise_decode(arch_id):
    """Prefill(t0..t7) then decode(t8) == decode steps 0..8 token by token."""
    cfg = get_arch(arch_id, reduced=True)
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    b, s = 1, 8
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)), jnp.int32)

    # path A: stepwise decode from empty cache
    cache = lm.init_cache(cfg, b, s + 1, jnp.float32)
    logits_a = None
    for i in range(s + 1):
        logits_a, cache = lm.decode_step(params, cache, toks[:, i:i + 1],
                                         jnp.int32(i), cfg, dtype=jnp.float32)

    # path B: prefill first s tokens, then one decode
    pre_logits, pcache = lm.prefill_step(params, {"tokens": toks[:, :s]}, cfg,
                                         dtype=jnp.float32)
    # prefill caches are sized s; re-embed into an (s+1) cache for decode
    full = lm.init_cache(cfg, b, s + 1, jnp.float32)

    def merge(dst, src):
        if dst.ndim >= 2 and src.shape != dst.shape:
            # KV-style: insert src along its time axis
            sl = [slice(None)] * dst.ndim
            for ax in range(dst.ndim):
                if src.shape[ax] != dst.shape[ax]:
                    sl[ax] = slice(0, src.shape[ax])
            return dst.at[tuple(sl)].set(src.astype(dst.dtype))
        return src.astype(dst.dtype)

    pcache_m = jax.tree_util.tree_map(merge, full, pcache)
    logits_b, _ = lm.decode_step(params, pcache_m, toks[:, s:s + 1],
                                 jnp.int32(s), cfg, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_a), np.asarray(logits_b),
                               rtol=2e-3, atol=2e-3)


def test_vlm_masks_patch_positions():
    cfg = get_arch("llava-next-34b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = lm.loss_fn(params, batch, cfg, dtype=jnp.float32,
                               remat_policy="none")
    assert np.isfinite(float(loss))
