"""Validate the analytic cost model against XLA HLO flops on probes whose
scans are fully materialised (no While undercounting): small config, naive
attention path, single-chunk loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import flops as fl
from repro.config import ArchConfig, ShapeConfig


def _mini_dense():
    return ArchConfig(name="mini", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_head=16, d_ff=192,
                      vocab=512, norm="rmsnorm", act="swiglu")


def test_fwd_flops_match_hlo_dense():
    cfg = _mini_dense()
    b, s = 2, 128
    shape = ShapeConfig("probe", s, b, "prefill")

    from repro.models import lm

    params = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))

    def fwd(p, tokens):
        # forward only, naive-path sizes (no scan over q blocks at s=128)
        x = jnp.take(p["embed"]["tokens"], tokens, axis=0)
        from repro.models import blocks
        from repro.models.layers import apply_norm
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        for (kinds, _), seg in zip(blocks.segments_for(cfg), p["segments"]):
            x, _ = blocks.apply_segment(seg, x, pos, cfg, kinds,
                                        remat_policy="none")
        x = apply_norm(p["final_norm"], x, cfg.norm)
        return (x @ p["embed"]["tokens"].T if cfg.tie_embeddings
                else x @ p["lm_head"]["w"])

    toks = jax.ShapeDtypeStruct((b, s), jnp.int32)
    compiled = jax.jit(fwd).lower(params, toks).compile()  # jaxlint: disable=JX003 — compiled once, for cost analysis
    # fl.hlo_cost_analysis handles both the dict and list-of-dicts return
    # shapes of compiled.cost_analysis() across jax versions
    hlo_flops = fl.hlo_cost_analysis(compiled)["flops"]
    # correct for the layer scan (body counted once, trip count = n_layers)
    # by computing analytic per-layer + outside terms
    cost = fl.cell_cost(cfg, shape, chips=1, dp_size=1, tp_size=1)
    # cost.fwd_flops counts all layers; HLO counts 1 of 2 layer bodies
    per_layer = (cost.fwd_flops - 2 * b * s * cfg.d_model * cfg.vocab) / 2
    expected_hlo = per_layer + 2 * b * s * cfg.d_model * cfg.vocab
    assert hlo_flops == pytest.approx(expected_hlo, rel=0.15), (
        hlo_flops, expected_hlo)


def test_param_count_smollm_is_135m():
    from repro.configs import get_arch
    n = fl.param_count(get_arch("smollm-135m"))
    assert 120e6 < n < 150e6, n


def test_param_count_dbrx_is_132b():
    from repro.configs import get_arch
    n = fl.param_count(get_arch("dbrx-132b"))
    assert 120e9 < n < 145e9, n


def test_param_count_deepseek_is_236b():
    from repro.configs import get_arch
    n = fl.param_count(get_arch("deepseek-v2-236b"))
    assert 215e9 < n < 255e9, n


def test_active_params_deepseek_about_21b():
    from repro.configs import get_arch
    n = fl.active_param_count(get_arch("deepseek-v2-236b"))
    assert 15e9 < n < 30e9, n


def test_mla_absorb_cuts_decode_flops():
    from repro.configs import get_arch
    from repro.config import SHAPES_BY_NAME
    cfg = get_arch("deepseek-v2-236b")
    shape = SHAPES_BY_NAME["decode_32k"]
    base = fl.cell_cost(cfg, shape, chips=256, dp_size=16, tp_size=16)
    opt = fl.cell_cost(cfg, shape, chips=256, dp_size=16, tp_size=16,
                       mla_absorb=True)
    assert opt.total_flops < base.total_flops / 20


def test_packed_attention_halves_attn_term():
    from repro.configs import get_arch
    from repro.config import SHAPES_BY_NAME
    cfg = get_arch("smollm-135m")
    shape = SHAPES_BY_NAME["train_4k"]
    base = fl.cell_cost(cfg, shape, chips=256, dp_size=16, tp_size=16)
    opt = fl.cell_cost(cfg, shape, chips=256, dp_size=16, tp_size=16,
                       attn_packed=True)
    assert opt.total_flops < base.total_flops
    # smollm at 4k is ~half attention; packed factor at S=4096/block=1024 is
    # 0.625 -> expect >= 15% total reduction
    assert opt.total_flops < 0.85 * base.total_flops


def test_roofline_terms_positive_and_dominant_sane():
    from repro.configs import get_arch
    from repro.config import SHAPES_BY_NAME
    cfg = get_arch("granite-3-8b")
    for shape_name, expect_dom in [("train_4k", "compute"),
                                   ("decode_32k", "memory")]:
        cost = fl.cell_cost(cfg, SHAPES_BY_NAME[shape_name], chips=256,
                            dp_size=16, tp_size=16)
        r = fl.roofline(cost, 256)
        assert r["dominant"] == expect_dom
        assert 0 < r["mfu_bound"] <= 1.0


def test_forest_rs_halves_collectives():
    from repro.config import ForestConfig
    base = fl.forest_cost(n_rows=122880, p=533,
                          fcfg=ForestConfig(n_trees=2, duplicate_k=20,
                                            max_depth=7, n_bins=64),
                          chips=256, data_shards=16)
    rs = fl.forest_cost(n_rows=122880, p=533,
                        fcfg=ForestConfig(n_trees=2, duplicate_k=20,
                                          max_depth=7, n_bins=64,
                                          split_reduce="reduce_scatter"),
                        chips=256, data_shards=16)
    assert rs.coll_bytes < 0.55 * base.coll_bytes
