"""Serving loop + elastic checkpoint re-mesh + dry-run artifact integrity."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.serve import serve_batch
from repro.models import lm


def test_serve_batch_greedy_deterministic():
    cfg = get_arch("smollm-135m", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (3, 8)), jnp.int32)
    g1, s1 = serve_batch(cfg, params, prompts, max_new=6, cache_size=16)
    g2, s2 = serve_batch(cfg, params, prompts, max_new=6, cache_size=16)
    np.testing.assert_array_equal(g1, g2)
    assert g1.shape == (3, 6)
    assert s1["tok_per_s"] > 0


_ELASTIC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import lm
from repro.sharding import rules
from repro.train import checkpoint as ckpt

cfg = get_arch("smollm-135m", reduced=True)
params = lm.init_params(jax.random.PRNGKey(0), cfg)
import tempfile
d = tempfile.mkdtemp()
ckpt.save(d, 1, params)

# restore onto a DIFFERENT mesh shape (elastic re-scale: 4x2 -> 2x4)
mesh = jax.make_mesh((2, 4), ("data", "model"))
restored, step = ckpt.restore(d, params)
specs = rules.param_specs(params, cfg, ("data",), "model", 2, 4)
sharded = ckpt.reshard(restored, mesh, specs)

# forward works on the new mesh and matches the host result
batch = {"tokens": jnp.zeros((4, 8), jnp.int32),
         "labels": jnp.zeros((4, 8), jnp.int32)}
loss_new, _ = jax.jit(
    lambda p, b: lm.loss_fn(p, b, cfg, dtype=jnp.float32,
                            remat_policy="none"))(sharded, batch)
loss_host, _ = lm.loss_fn(params, batch, cfg, dtype=jnp.float32,
                          remat_policy="none")
assert abs(float(loss_new) - float(loss_host)) < 1e-3, (loss_new, loss_host)
print(json.dumps({"ok": True}))
"""


@pytest.mark.slow
@pytest.mark.distributed
def test_elastic_remesh_restore():
    out = subprocess.run([sys.executable, "-c", _ELASTIC],
                         capture_output=True, text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-3000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]


def test_dryrun_artifacts_complete_if_present():
    """If the sweep has been run, every artifact must be well-formed and the
    grid must be complete (10 archs x 4 shapes x 2 meshes + caloforest)."""
    d = Path("experiments/dryrun")
    if not d.exists() or not list(d.glob("*.json")):
        pytest.skip("dry-run sweep not executed in this checkout")
    base = []
    for f in d.glob("*.json"):
        r = json.loads(f.read_text())
        assert r["status"] in ("ok", "skipped"), (f.name, r.get("error"))
        if r["status"] == "ok" and r["arch"] != "caloforest":
            assert "roofline" in r and "collective_inventory" in r, f.name
            ro = r["roofline"]
            assert ro["t_compute_s"] > 0 and ro["t_memory_s"] > 0
            assert 0 <= ro["mfu_bound"] <= 1
        if not r.get("tag"):
            base.append((r["arch"], r["shape"], r["mesh"]))
    lm_cells = [b for b in base if b[0] != "caloforest"]
    assert len(set(lm_cells)) == 80, len(set(lm_cells))
