"""jaxlint: each rule against a known-bad fixture reproducing the historical
bug it encodes, plus the known-good idioms the repo actually uses, the
suppression/baseline machinery, and the CLI exit-code contract."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis.lint import (Finding, lint_source, load_baseline,
                                 parse_suppressions, split_baselined,
                                 write_baseline)

REPO = Path(__file__).resolve().parents[1]


def findings(src, select=None):
    fs, _ = lint_source(textwrap.dedent(src), "fixture.py", select)
    return fs


def rules_hit(src, select=None):
    return sorted({f.rule for f in findings(src, select)})


# ---------------------------------------------------------------------------
# JX001 — PRNG key reuse (the PR-2 CFM-jitter bug)
# ---------------------------------------------------------------------------

PR2_BUG = """
    import jax

    def sample_bridge(key, x1, sigma):
        # the shipped bug: one key drew both the endpoint noise and the
        # "independent" jitter, so jitter == the same normal draw scaled
        noise = jax.random.normal(key, x1.shape)
        jitter = sigma * jax.random.normal(key, x1.shape)
        return x1 + noise + jitter
"""


def test_jx001_flags_the_pr2_bug():
    fs = findings(PR2_BUG)
    assert [f.rule for f in fs] == ["JX001"]
    assert "split" in fs[0].message


def test_jx001_split_is_clean():
    assert rules_hit("""
        import jax

        def sample_bridge(key, x1, sigma):
            k1, k2 = jax.random.split(key)
            noise = jax.random.normal(k1, x1.shape)
            jitter = sigma * jax.random.normal(k2, x1.shape)
            return x1 + noise + jitter
    """) == []


def test_jx001_flags_loop_reuse():
    fs = findings("""
        import jax

        def draws(key, n):
            out = []
            for i in range(n):
                out.append(jax.random.normal(key, (4,)))
            return out
    """)
    assert [f.rule for f in fs] == ["JX001"]
    assert "loop" in fs[0].message


def test_jx001_fold_in_per_iteration_is_clean():
    assert rules_hit("""
        import jax

        def draws(key, n):
            out = []
            for i in range(n):
                out.append(jax.random.normal(jax.random.fold_in(key, i), (4,)))
            return out
    """) == []


def test_jx001_carried_split_in_loop_is_clean():
    # the repo's training-loop idiom: the key is re-derived every iteration
    assert rules_hit("""
        import jax

        def train(key, n):
            for i in range(n):
                key, kr = jax.random.split(key)
                x = jax.random.normal(kr, (4,))
            return x
    """) == []


def test_jx001_helper_consumption_counts():
    # PR-2 consumed the key through a helper, not jax.random directly —
    # any call taking the bare key is a consumption
    assert rules_hit("""
        import jax

        def sample(key, itp, x1):
            base = jax.random.normal(key, x1.shape)
            return itp.sample_bridge(key, base)
    """) == ["JX001"]


def test_jx001_ignores_non_prng_key_params():
    # dict-style __getitem__(self, key) and attention's K tensor share the
    # *names* but never touch the PRNG — no finding
    assert rules_hit("""
        class Store:
            def __getitem__(self, key):
                if isinstance(key, int):
                    return self.take([key])
                if isinstance(key, slice):
                    return self.take(list(key.indices(self.n)))
                return self.take(key)

        def attention(q, k, v, causal):
            if causal:
                return ref(q, k, v)
            return fast(q, k, v)
    """) == []


def test_jx001_str_split_does_not_mint_keys():
    assert rules_hit("""
        def parse(args, fetch):
            name, n = args.calo.split(":")
            a = fetch(n)
            b = fetch(n)
            return name, a, b
    """) == []


def test_jx001_early_return_branches_are_exclusive():
    # one consumption in a returning arm + one on the fall-through path
    # never happen in the same execution
    assert rules_hit("""
        import jax

        def init(key, d, gated):
            k1, k2 = jax.random.split(key)
            if gated:
                return make_gated(k1, d)
            return make_plain(k1, d)
    """) == []


def test_jx001_reuse_inside_one_branch_still_flags():
    assert rules_hit("""
        import jax

        def init(key, d, gated):
            k1, k2 = jax.random.split(key)
            if gated:
                a = jax.random.normal(k1, (d,))
                b = jax.random.normal(k1, (d,))
                return a + b
            return make_plain(k2, d)
    """) == ["JX001"]


# ---------------------------------------------------------------------------
# JX002 — import-time env snapshot (the PR-4 REPRO_HIST_IMPL bug)
# ---------------------------------------------------------------------------

PR4_ENV_BUG = """
    import os

    _IMPL = os.environ.get("REPRO_HIST_IMPL", "xla")

    def hist(x):
        if _IMPL == "pallas":
            return hist_pallas(x)
        return hist_xla(x)
"""


def test_jx002_flags_the_pr4_snapshot():
    fs = findings(PR4_ENV_BUG)
    assert [f.rule for f in fs] == ["JX002"]
    assert "resolve_impl" in fs[0].message


@pytest.mark.parametrize("read", [
    'os.environ.get("X", "d")', 'os.getenv("X")', 'os.environ["X"]'])
def test_jx002_flags_every_read_spelling(read):
    assert rules_hit(f"import os\nC = {read}\n") == ["JX002"]


def test_jx002_function_scope_read_is_clean():
    assert rules_hit("""
        import os

        def impl():
            return os.environ.get("REPRO_HIST_IMPL", "xla")
    """) == []


def test_jx002_class_method_read_is_clean():
    # a per-call env read inside a method runs at call time, not import
    # time (the Tracer._jax_annotation shape) — PR-8 false-positive fix
    assert rules_hit("""
        import os

        class Tracer:
            def annotation(self):
                return os.environ.get("REPRO_OBS_JAX_TRACE", "")
    """) == []


def test_jx002_env_write_is_clean():
    # configuring the process at import (e.g. conftest forcing a platform)
    # is not a snapshot
    assert rules_hit("""
        import os
        os.environ["JAX_PLATFORMS"] = "cpu"
        os.environ.setdefault("XLA_FLAGS", "")
    """) == []


# ---------------------------------------------------------------------------
# JX003 — jit cache fragmentation / recompile leaks
# ---------------------------------------------------------------------------

def test_jx003_flags_inline_jit_call():
    fs = findings("""
        import jax

        def serve(params, x):
            return jax.jit(lambda p, x: apply(p, x))(params, x)
    """)
    assert [f.rule for f in fs] == ["JX003"]
    assert "fresh wrapper" in fs[0].message


def test_jx003_flags_jit_built_in_loop():
    assert rules_hit("""
        import jax

        def warmup(fns, x):
            outs = []
            for f in fns:
                g = jax.jit(f)
                outs.append(g(x))
            return outs
    """) == ["JX003"]


def test_jx003_flags_unhashable_default():
    assert rules_hit("""
        import jax

        @jax.jit
        def f(x, scales=[1.0, 2.0]):
            return x
    """) == ["JX003"]


def test_jx003_module_level_wrapper_is_clean():
    assert rules_hit("""
        import jax

        fit_batch = jax.jit(jax.vmap(fit_one))

        @jax.jit
        def step(params, batch, lr=1e-3):
            return params
    """) == []


def test_jx003_partial_jit_decorator_checked():
    assert rules_hit("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("n",))
        def f(x, n, init=jax.numpy.zeros(4)):
            return x
    """) == ["JX003"]


# ---------------------------------------------------------------------------
# TH001 — lock discipline (the PR-4 serving stats race)
# ---------------------------------------------------------------------------

PR4_STATS_RACE = """
    import threading

    class ForestServer:
        def __init__(self):
            self._stats_lock = threading.Lock()
            self.stats = {"rows": 0}

        def _dispatch(self, n):
            with self._stats_lock:
                self.stats["rows"] += n

        def submit(self, n):
            self.stats["requests"] = n   # unlocked write: the race
"""


def test_th001_flags_the_pr4_stats_race():
    fs = findings(PR4_STATS_RACE)
    assert [f.rule for f in fs] == ["TH001"]
    assert "submit" in fs[0].message


def test_th001_locked_suffix_convention_is_clean():
    assert rules_hit("""
        import threading

        class Scheduler:
            def __init__(self):
                self._lock = threading.Lock()
                self.queue = []

            def submit(self, r):
                with self._lock:
                    self.queue.append(r)
                    self._start_locked(r)

            def _start_locked(self, r):
                self.queue.append(r)   # caller holds the lock
    """) == []


def test_th001_container_mutator_counts_as_write():
    # the GridManifest shape: .add under the lock, bulk assignment outside
    assert rules_hit("""
        import threading

        class Manifest:
            def __init__(self):
                self._lock = threading.Lock()
                self._done = set()

            def mark(self, k):
                with self._lock:
                    self._done.add(k)

            def load(self, entries):
                self._done = set(entries)
    """) == ["TH001"]


def test_th001_locked_read_is_guard_evidence():
    # the PR-8 admission bug: per-tenant dict mutated via an unlocked
    # setdefault helper, while the only *locked* access is the snapshot
    # read — no locked write anywhere, so the pre-PR-8 rule stayed silent
    fs = findings("""
        import threading

        class Admission:
            def __init__(self):
                self._cond = threading.Condition()
                self._tenants = {}

            def _tenant_stats(self, tenant):
                return self._tenants.setdefault(tenant, {"admitted": 0})

            def stats_snapshot(self):
                with self._cond:
                    return {t: dict(v) for t, v in self._tenants.items()}
    """)
    assert [f.rule for f in fs] == ["TH001"]
    assert "_tenant_stats" in fs[0].message


def test_th001_locked_read_respects_locked_suffix():
    # same shape, but the mutating helper declares its contract: clean
    assert rules_hit("""
        import threading

        class Admission:
            def __init__(self):
                self._cond = threading.Condition()
                self._tenants = {}

            def _tenant_stats_locked(self, tenant):
                return self._tenants.setdefault(tenant, {"admitted": 0})

            def stats_snapshot(self):
                with self._cond:
                    return {t: dict(v) for t, v in self._tenants.items()}
    """) == []


def test_th001_unguarded_attrs_are_clean():
    assert rules_hit("""
        import threading

        class Worker:
            def __init__(self):
                self._lock = threading.Lock()
                self.scratch = None

            def run(self):
                self.scratch = 1   # never touched under the lock: no claim
    """) == []


# ---------------------------------------------------------------------------
# PL001 — Pallas grid divisibility (the PR-4 odd-bucket crash)
# ---------------------------------------------------------------------------

PL_BAD = """
    import jax.experimental.pallas as pl

    def predict(x, block):
        n = x.shape[0]
        return pl.pallas_call(kern, grid=(n // block,), out_shape=None)(x)
"""


def test_pl001_flags_unguarded_floordiv_grid():
    fs = findings(PL_BAD)
    assert [f.rule for f in fs] == ["PL001"]
    assert "pad" in fs[0].message


@pytest.mark.parametrize("guard", [
    "assert n % block == 0",
    "n = -(-n // block) * block",
    "x = pad_rows(x, block)",
    "if n % block:\n                raise ValueError('pad first')",
])
def test_pl001_each_guard_style_is_clean(guard):
    src = f"""
        import jax.experimental.pallas as pl

        def predict(x, block):
            n = x.shape[0]
            {guard}
            return pl.pallas_call(kern, grid=(n // block,), out_shape=None)(x)
    """
    assert rules_hit(src) == []


def test_pl001_cdiv_grid_is_clean():
    assert rules_hit("""
        import jax.experimental.pallas as pl

        def predict(x, block):
            n = x.shape[0]
            return pl.pallas_call(kern, grid=(pl.cdiv(n, block),),
                                  out_shape=None)(x)
    """) == []


# ---------------------------------------------------------------------------
# suppression + baseline machinery
# ---------------------------------------------------------------------------

def test_inline_suppression_same_line():
    src = textwrap.dedent(PR4_ENV_BUG).replace(
        '"xla")', '"xla")  # jaxlint: disable=JX002')
    fs, n_sup = lint_source(src, "fixture.py", None)
    assert fs == [] and n_sup == 1


def test_suppression_comment_line_above():
    src = ('import os\n'
           '# jaxlint: disable=JX002 — CI toggles this before any import\n'
           'C = os.environ.get("X")\n')
    fs, n_sup = lint_source(src, "fixture.py", None)
    assert fs == [] and n_sup == 1


def test_suppression_is_rule_specific():
    src = textwrap.dedent(PR4_ENV_BUG).replace(
        '"xla")', '"xla")  # jaxlint: disable=JX001')
    fs, n_sup = lint_source(src, "fixture.py", None)
    assert [f.rule for f in fs] == ["JX002"] and n_sup == 0


def test_suppress_all():
    src = textwrap.dedent(PR4_ENV_BUG).replace(
        '"xla")', '"xla")  # jaxlint: disable=all')
    fs, _ = lint_source(src, "fixture.py", None)
    assert fs == []


def test_parse_suppressions_multiple_rules():
    sup = parse_suppressions("x = 1  # jaxlint: disable=JX001, TH001\n")
    assert sup[1] == {"JX001", "TH001"}


def test_syntax_error_reports_jx000():
    fs, _ = lint_source("def f(:\n", "broken.py", None)
    assert [f.rule for f in fs] == ["JX000"]


def test_baseline_round_trip(tmp_path):
    fs = findings(PR4_ENV_BUG)
    path = tmp_path / "baseline.json"
    write_baseline(str(path), fs)
    baseline = load_baseline(str(path))
    new, grandfathered = split_baselined(fs, baseline)
    assert new == [] and grandfathered == fs
    # a finding that moved (different line) is new again
    moved = [Finding(f.rule, f.path, f.line + 5, f.col, f.message)
             for f in fs]
    new, _ = split_baselined(moved, baseline)
    assert new == moved


def test_baseline_file_shape(tmp_path):
    path = tmp_path / "baseline.json"
    write_baseline(str(path), findings(PR4_ENV_BUG))
    data = json.loads(path.read_text())
    assert set(data) == {"comment", "findings"}


# ---------------------------------------------------------------------------
# OB001 — span leaks (unended Tracer.start spans never record)
# ---------------------------------------------------------------------------

def test_ob001_early_return_leaks_span():
    # the motivating bug shape: validation bails before the span ends
    src = """
        def submit(self, n, ok):
            sp = self.tracer.start("serve.queue", rows=n)
            if not ok:
                return None
            sp.end()
    """
    fs = findings(src, select=("OB001",))
    assert [f.rule for f in fs] == ["OB001"]
    assert "every path" in fs[0].message
    assert "tracer.span(" in fs[0].message  # suggests the context manager


def test_ob001_never_ended_flags():
    assert rules_hit("""
        def f(tracer):
            sp = tracer.start("x")
            do_work()
    """, select=("OB001",)) == ["OB001"]


def test_ob001_raise_arm_without_end_flags():
    assert rules_hit("""
        def f(tracer, ok):
            sp = tracer.start("x")
            if not ok:
                raise ValueError("no")
            sp.end()
    """, select=("OB001",)) == ["OB001"]


def test_ob001_swallowing_handler_flags():
    # body ends the span but the except arm falls through without ending
    assert rules_hit("""
        def f(tracer):
            sp = tracer.start("x")
            try:
                work()
                sp.end()
            except Exception:
                pass
    """, select=("OB001",)) == ["OB001"]


def test_ob001_end_only_inside_loop_flags():
    # zero iterations is always a possible path
    assert rules_hit("""
        def f(tracer, items):
            sp = tracer.start("x")
            for it in items:
                sp.end()
    """, select=("OB001",)) == ["OB001"]


def test_ob001_clean_shapes_pass():
    good = [
        # the suggested fix: scoped context manager
        """
        def f(tracer):
            with tracer.span("x") as sp:
                work(sp)
        """,
        # try/finally always ends
        """
        def f(tracer):
            sp = tracer.start("x")
            try:
                work()
            finally:
                sp.end()
        """,
        # both branches end (with distinct outcomes)
        """
        def f(tracer, ok):
            sp = tracer.start("x")
            if ok:
                sp.end(outcome="ok")
            else:
                sp.end(outcome="bad")
        """,
        # end-then-terminate in the early arm is fine
        """
        def f(tracer, ok):
            sp = tracer.start("x")
            if not ok:
                sp.end(outcome="rejected")
                return None
            sp.end()
        """,
        # handler ends before re-raising
        """
        def f(tracer):
            sp = tracer.start("x")
            try:
                work()
                sp.end()
            except Exception:
                sp.end(outcome="error")
                raise
        """,
    ]
    for src in good:
        assert rules_hit(src, select=("OB001",)) == [], src


def test_ob001_escaped_spans_are_not_flagged():
    # ownership moved: the scheduler pattern (span rides a Request /
    # _Inflight record and is ended by another thread)
    escapes = [
        """
        def submit(self):
            sp = self.tracer.start("serve.queue")
            req = Request(span=sp)
            self.admission.offer(req)
        """,
        """
        def dispatch(self, batch):
            dspan = self.tracer.start("serve.device")
            return Inflight(batch, dspan)
        """,
    ]
    for src in escapes:
        assert rules_hit(src, select=("OB001",)) == [], src


def test_ob001_closure_end_and_foreign_receivers_skip():
    # end inside a nested def = closure owns the span: out of scope
    assert rules_hit("""
        def f(tracer):
            sp = tracer.start("x")
            def cb():
                sp.end()
            register(cb)
    """, select=("OB001",)) == []
    # receiver must *look like* a tracer: thread/pool .start() never match
    assert rules_hit("""
        def f(self):
            t = self.pool.start("worker")
            h = self.thread.start()
    """, select=("OB001",)) == []


# ---------------------------------------------------------------------------
# CLI contract
# ---------------------------------------------------------------------------

def run_cli(*args, cwd):
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "jaxlint.py"), *args],
        cwd=cwd, capture_output=True, text=True)


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(PR4_ENV_BUG))
    good = tmp_path / "good.py"
    good.write_text("def f():\n    return 1\n")

    r = run_cli(str(bad), "--no-baseline", cwd=tmp_path)
    assert r.returncode == 1
    assert "JX002" in r.stdout
    assert run_cli(str(good), "--no-baseline", cwd=tmp_path).returncode == 0
    assert run_cli(str(bad), "--select", "NOPE",
                   cwd=tmp_path).returncode == 2


def test_cli_write_baseline_grandfathers(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(PR4_ENV_BUG))
    baseline = tmp_path / "b.json"
    assert run_cli(str(bad), "--baseline", str(baseline), "--write-baseline",
                   cwd=tmp_path).returncode == 0
    # grandfathered: exit 0; --no-baseline still reports it
    assert run_cli(str(bad), "--baseline", str(baseline),
                   cwd=tmp_path).returncode == 0
    assert run_cli(str(bad), "--no-baseline", cwd=tmp_path).returncode == 1


def test_cli_lists_all_rules():
    r = run_cli("--list-rules", cwd=REPO)
    assert r.returncode == 0
    for rule_id in ("JX001", "JX002", "JX003", "TH001", "PL001", "OB001"):
        assert rule_id in r.stdout


def test_repo_tree_is_clean():
    """The merged tree lints clean — the CI gate this PR turns on."""
    r = run_cli("src", "tests", "benchmarks", "scripts", cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr
